"""AOT pipeline: lower every (variant, op, bucket) to HLO text + weights.

Emits into ``artifacts/``:
  * ``<variant>__<op>__b<B>[_c<C>].hlo.txt`` — HLO *text* (NOT a serialized
    HloModuleProto: jax >= 0.5 emits 64-bit instruction ids which the
    xla_extension 0.5.1 proto parser rejects; the text parser reassigns
    ids and round-trips cleanly — see /opt/xla-example/README.md).
  * ``<variant>.weights.bin`` — TWB1 tensors in AOT parameter order.
  * ``manifest.json`` — machine-readable index the Rust loader consumes.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model, weights as W

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _weight_specs(schema):
    return tuple(_spec(shape) for _, shape in schema)


def _entry(name, op, variant, inputs, outputs):
    """Manifest entry. inputs/outputs: list of (name, shape, dtype-str)."""
    return {
        "artifact": name,
        "op": op,
        "variant": variant,
        "file": f"{name}.hlo.txt",
        "inputs": [
            {"name": n, "shape": list(s), "dtype": d} for (n, s, d) in inputs
        ],
        "outputs": [
            {"name": n, "shape": list(s), "dtype": d} for (n, s, d) in outputs
        ],
    }


def lower_llm(cfg: configs.LlmConfig, outdir: str, manifest: dict, quick: bool):
    schema = model.llm_weight_schema(cfg)
    wspecs = _weight_specs(schema)
    v, s = cfg.vocab, cfg.max_seq
    n_weights = len(schema)

    buckets = configs.prefill_buckets()
    dbatches = configs.DECODE_BATCHES
    if quick:
        buckets = [(1, 16), (2, 32)]
        dbatches = [1, 2]

    for batch, chunk in buckets:
        name = configs.artifact_name(cfg.name, "prefill", batch, chunk)
        kv_shape = model.kv_cache_shape(cfg, batch)

        def fn(weights, tokens, kv, offsets, lengths):
            return model.llm_prefill(cfg, weights, tokens, kv, offsets, lengths)

        lowered = jax.jit(fn).lower(
            wspecs,
            _spec((batch, chunk), I32),
            _spec(kv_shape),
            _spec((batch,), I32),
            _spec((batch,), I32),
        )
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"].append(
            _entry(
                name,
                "prefill",
                cfg.name,
                [
                    ("tokens", (batch, chunk), "i32"),
                    ("kv", kv_shape, "f32"),
                    ("offsets", (batch,), "i32"),
                    ("lengths", (batch,), "i32"),
                ],
                [
                    ("kv", kv_shape, "f32"),
                    ("last_logits", (batch, v), "f32"),
                    ("next_token", (batch,), "i32"),
                ],
            )
            | {"n_weights": n_weights, "batch": batch, "chunk": chunk}
        )
        print(f"  wrote {name}", flush=True)

    for batch in dbatches:
        name = configs.artifact_name(cfg.name, "decode", batch)
        kv_shape = model.kv_cache_shape(cfg, batch)

        def fn(weights, tokens, kv, positions):
            return model.llm_decode(cfg, weights, tokens, kv, positions)

        lowered = jax.jit(fn).lower(
            wspecs,
            _spec((batch,), I32),
            _spec(kv_shape),
            _spec((batch,), I32),
        )
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"].append(
            _entry(
                name,
                "decode",
                cfg.name,
                [
                    ("tokens", (batch,), "i32"),
                    ("kv", kv_shape, "f32"),
                    ("positions", (batch,), "i32"),
                ],
                [
                    ("kv", kv_shape, "f32"),
                    ("logits", (batch, v), "f32"),
                    ("next_token", (batch,), "i32"),
                ],
            )
            | {"n_weights": n_weights, "batch": batch}
        )
        print(f"  wrote {name}", flush=True)


def lower_encoder(cfg: configs.EncoderConfig, outdir: str, manifest: dict, quick: bool):
    schema = model.encoder_weight_schema(cfg)
    wspecs = _weight_specs(schema)
    t = cfg.max_seq
    n_weights = len(schema)
    batches = configs.ENCODER_BATCHES if not quick else [1, 4]

    for batch in batches:
        name = configs.artifact_name(cfg.name, cfg.head, batch)
        if cfg.head == "embed":

            def fn(weights, tokens, mask):
                return (model.embed_forward(cfg, weights, tokens, mask),)

            out_sig = [("embeddings", (batch, cfg.d_model), "f32")]
        else:

            def fn(weights, tokens, mask):
                return (model.rerank_forward(cfg, weights, tokens, mask),)

            out_sig = [("scores", (batch,), "f32")]

        lowered = jax.jit(fn).lower(
            wspecs, _spec((batch, t), I32), _spec((batch, t))
        )
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"].append(
            _entry(
                name,
                cfg.head,
                cfg.name,
                [("tokens", (batch, t), "i32"), ("mask", (batch, t), "f32")],
                out_sig,
            )
            | {"n_weights": n_weights, "batch": batch}
        )
        print(f"  wrote {name}", flush=True)


def write_weights(outdir: str, manifest: dict):
    for i, (vname, cfg) in enumerate(configs.LLM_VARIANTS.items()):
        schema = model.llm_weight_schema(cfg)
        arrays = W.init_weights(schema, seed=1000 + i)
        W.save_weights(os.path.join(outdir, f"{vname}.weights.bin"), schema, arrays)
        manifest["models"][vname] = {
            "kind": "llm",
            "layers": cfg.layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "vocab": cfg.vocab,
            "max_seq": cfg.max_seq,
            "weights": f"{vname}.weights.bin",
            "n_weights": len(schema),
        }
        print(f"  weights {vname} ({len(schema)} tensors)", flush=True)
    for i, (vname, cfg) in enumerate(configs.ENCODER_VARIANTS.items()):
        schema = model.encoder_weight_schema(cfg)
        arrays = W.init_weights(schema, seed=2000 + i)
        W.save_weights(os.path.join(outdir, f"{vname}.weights.bin"), schema, arrays)
        manifest["models"][vname] = {
            "kind": cfg.head,
            "layers": cfg.layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "vocab": cfg.vocab,
            "max_seq": cfg.max_seq,
            "weights": f"{vname}.weights.bin",
            "n_weights": len(schema),
        }
        print(f"  weights {vname} ({len(schema)} tensors)", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--quick", action="store_true", help="small bucket subset for CI/tests"
    )
    ap.add_argument(
        "--variants",
        default="",
        help="comma-separated LLM variant subset (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "format": 1,
        "vocab": configs.VOCAB,
        "special_tokens": {
            "pad": configs.PAD_ID,
            "bos": configs.BOS_ID,
            "eos": configs.EOS_ID,
            "sep": configs.SEP_ID,
        },
        "models": {},
        "artifacts": [],
    }

    write_weights(args.out, manifest)

    llm_names = (
        [v for v in args.variants.split(",") if v]
        if args.variants
        else list(configs.LLM_VARIANTS)
    )
    for vname in llm_names:
        print(f"lowering {vname} ...", flush=True)
        lower_llm(configs.LLM_VARIANTS[vname], args.out, manifest, args.quick)
    for vname, cfg in configs.ENCODER_VARIANTS.items():
        print(f"lowering {vname} ...", flush=True)
        lower_encoder(cfg, args.out, manifest, args.quick)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
