"""L2 JAX models: decoder LLM (prefill/decode), encoder embedder, reranker.

All forward functions take a flat *tuple* of weight arrays as their first
argument so that the lowered HLO's parameter order is exactly
``weights + activations`` — the Rust runtime uploads the weights once as
device-resident PjRtBuffers and threads them into every `execute_b` call.

The decoder supports the paper's decomposed prefilling (§4.2 Pass 3):
``llm_prefill`` consumes a *chunk* of tokens whose first token sits at a
per-row ``offset`` into an existing KV cache, computing attention of the
chunk against ``cache[:offset] ∪ chunk`` with an offset causal mask (the L1
Pallas kernel).  Partial Prefilling == calling it with offset>0 on a cache
populated by an earlier call; Full Prefilling == the final such call.
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .configs import EncoderConfig, LlmConfig
from .kernels.attention import flash_attention
from .kernels.pooling import masked_mean_pool

_LN_EPS = 1e-5

# ---------------------------------------------------------------------------
# Weight schemas.  The *order* of these lists is the AOT parameter order and
# is mirrored in artifacts/manifest.json for the Rust loader.
# ---------------------------------------------------------------------------

_LAYER_TENSORS = [
    ("ln1_scale", "d"),
    ("ln1_bias", "d"),
    ("wqkv", "d,3d"),
    ("bqkv", "3d"),
    ("wo", "d,d"),
    ("bo", "d"),
    ("ln2_scale", "d"),
    ("ln2_bias", "d"),
    ("w1", "d,f"),
    ("b1", "f"),
    ("w2", "f,d"),
    ("b2", "d"),
]


def _dims(spec: str, d: int, f: int, v: int, s: int) -> Tuple[int, ...]:
    lut = {"d": d, "3d": 3 * d, "f": f, "v": v, "s": s}
    return tuple(lut[tok] for tok in spec.split(","))


def llm_weight_schema(cfg: LlmConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) list in AOT parameter order for an LLM variant."""
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq
    out = [
        ("tok_embed", (v, d)),
        ("pos_embed", (s, d)),
        ("lnf_scale", (d,)),
        ("lnf_bias", (d,)),
    ]
    for layer in range(cfg.layers):
        for name, spec in _LAYER_TENSORS:
            out.append((f"layer{layer}.{name}", _dims(spec, d, f, v, s)))
    return out


def encoder_weight_schema(cfg: EncoderConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq
    out = [
        ("tok_embed", (v, d)),
        ("pos_embed", (s, d)),
    ]
    for layer in range(cfg.layers):
        for name, spec in _LAYER_TENSORS:
            out.append((f"layer{layer}.{name}", _dims(spec, d, f, v, s)))
    if cfg.head == "score":
        out.append(("w_score", (d, 1)))
        out.append(("b_score", (1,)))
    return out


def kv_cache_shape(cfg: LlmConfig, batch: int) -> Tuple[int, ...]:
    """[L, 2, B, H, S, Dh] — the KV cache threaded through prefill/decode."""
    return (cfg.layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)


# ---------------------------------------------------------------------------
# Shared blocks
# ---------------------------------------------------------------------------


def _layer_norm(x, scale, bias):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + _LN_EPS) * scale + bias


def _mlp(x, w1, b1, w2, b2):
    return jnp.dot(jax.nn.gelu(jnp.dot(x, w1) + b1), w2) + b2


def _split_heads(x, heads, head_dim):
    # [B, T, d] -> [B, H, T, Dh]
    b, t, _ = x.shape
    return x.reshape(b, t, heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    # [B, H, T, Dh] -> [B, T, d]
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _layer_weights(weights, base: int, layer: int):
    """Slice one layer's 12 tensors out of the flat weight tuple."""
    i = base + layer * len(_LAYER_TENSORS)
    return weights[i : i + len(_LAYER_TENSORS)]


# ---------------------------------------------------------------------------
# Decoder LLM
# ---------------------------------------------------------------------------


def llm_prefill(cfg: LlmConfig, weights, tokens, kv, offsets, lengths):
    """Chunked (partial/full) prefill.

    Args:
      weights: flat tuple per ``llm_weight_schema``.
      tokens:  [B, C] int32 chunk tokens (padded rows allowed).
      kv:      [L, 2, B, H, S, Dh] f32 existing cache (zeros on first call).
      offsets: [B] int32 absolute position of each row's chunk start.
      lengths: [B] int32 valid token count per row (<= C).
    Returns:
      (kv', last_logits[B, V], next_token[B]) — logits/argmax at each row's
      final valid position.
    """
    tok_embed, pos_embed = weights[0], weights[1]
    lnf_scale, lnf_bias = weights[2], weights[3]
    batch, chunk = tokens.shape
    heads, head_dim, seq = cfg.n_heads, cfg.head_dim, cfg.max_seq

    positions = offsets[:, None] + jnp.arange(chunk, dtype=jnp.int32)[None, :]
    x = tok_embed[tokens] + pos_embed[jnp.clip(positions, 0, seq - 1)]

    # One-hot scatter of the chunk into the cache: [B, C, S], zero for padded
    # positions so stale cache contents survive short rows.
    valid = (jnp.arange(chunk)[None, :] < lengths[:, None]).astype(jnp.float32)
    onehot = (
        jax.nn.one_hot(jnp.clip(positions, 0, seq - 1), seq, dtype=jnp.float32)
        * valid[:, :, None]
    )
    keep = 1.0 - jnp.sum(onehot, axis=1)  # [B, S] zero where overwritten

    new_kv = []
    for layer in range(cfg.layers):
        (ln1_s, ln1_b, wqkv, bqkv, wo, bo, ln2_s, ln2_b, w1, b1, w2, b2) = (
            _layer_weights(weights, 4, layer)
        )
        h = _layer_norm(x, ln1_s, ln1_b)
        qkv = jnp.dot(h, wqkv) + bqkv  # [B, C, 3d]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = _split_heads(q, heads, head_dim)  # [B, H, C, Dh]
        k = _split_heads(k, heads, head_dim)
        v = _split_heads(v, heads, head_dim)

        k_cache = kv[layer, 0] * keep[:, None, :, None] + jnp.einsum(
            "bcs,bhcd->bhsd", onehot, k
        )
        v_cache = kv[layer, 1] * keep[:, None, :, None] + jnp.einsum(
            "bcs,bhcd->bhsd", onehot, v
        )
        new_kv.append(jnp.stack([k_cache, v_cache]))

        attn = flash_attention(q, k_cache, v_cache, offsets)  # L1 kernel
        x = x + jnp.dot(_merge_heads(attn), wo) + bo
        x = x + _mlp(_layer_norm(x, ln2_s, ln2_b), w1, b1, w2, b2)

    h = _layer_norm(x, lnf_scale, lnf_bias)
    logits = jnp.dot(h, tok_embed.T)  # tied head: [B, C, V]
    last_idx = jnp.clip(lengths - 1, 0, chunk - 1)
    last_logits = logits[jnp.arange(batch), last_idx]  # [B, V]
    next_token = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    return jnp.stack(new_kv), last_logits, next_token


def llm_decode(cfg: LlmConfig, weights, tokens, kv, positions):
    """Single autoregressive decode step.

    Args:
      tokens:    [B] int32 current tokens.
      kv:        [L, 2, B, H, S, Dh] cache.
      positions: [B] int32 absolute position of `tokens`.
    Returns:
      (kv', logits[B, V], next_token[B]).
    """
    tok_embed, pos_embed = weights[0], weights[1]
    lnf_scale, lnf_bias = weights[2], weights[3]
    batch = tokens.shape[0]
    heads, head_dim, seq = cfg.n_heads, cfg.head_dim, cfg.max_seq

    x = tok_embed[tokens] + pos_embed[jnp.clip(positions, 0, seq - 1)]  # [B, d]
    onehot = jax.nn.one_hot(jnp.clip(positions, 0, seq - 1), seq, dtype=jnp.float32)
    kv_pos = jnp.arange(seq, dtype=jnp.int32)
    mask = (kv_pos[None, :] <= positions[:, None])[:, None, None, :]  # [B,1,1,S]
    scale = 1.0 / (head_dim**0.5)

    new_kv = []
    for layer in range(cfg.layers):
        (ln1_s, ln1_b, wqkv, bqkv, wo, bo, ln2_s, ln2_b, w1, b1, w2, b2) = (
            _layer_weights(weights, 4, layer)
        )
        h = _layer_norm(x, ln1_s, ln1_b)
        qkv = jnp.dot(h, wqkv) + bqkv  # [B, 3d]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(batch, heads, 1, head_dim)
        k = k.reshape(batch, heads, 1, head_dim)
        v = v.reshape(batch, heads, 1, head_dim)

        k_cache = kv[layer, 0] * (1.0 - onehot)[:, None, :, None] + jnp.einsum(
            "bs,bhd->bhsd", onehot, k[:, :, 0, :]
        )
        v_cache = kv[layer, 1] * (1.0 - onehot)[:, None, :, None] + jnp.einsum(
            "bs,bhd->bhsd", onehot, v[:, :, 0, :]
        )
        new_kv.append(jnp.stack([k_cache, v_cache]))

        # Memory-bound matvec attention: plain jnp (no kernel benefit at Tq=1).
        s = jnp.einsum("bhqd,bhsd->bhqs", q, k_cache) * scale
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhqs,bhsd->bhqd", p, v_cache)  # [B, H, 1, Dh]
        x = x + jnp.dot(attn.transpose(0, 2, 1, 3).reshape(batch, -1), wo) + bo
        x = x + _mlp(_layer_norm(x, ln2_s, ln2_b), w1, b1, w2, b2)

    h = _layer_norm(x, lnf_scale, lnf_bias)
    logits = jnp.dot(h, tok_embed.T)  # [B, V]
    next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(new_kv), logits, next_token


# ---------------------------------------------------------------------------
# Encoders (embedder / reranker)
# ---------------------------------------------------------------------------


def _encoder_trunk(cfg: EncoderConfig, weights, tokens, mask):
    """Bidirectional transformer trunk -> [B, T, d] activations."""
    tok_embed, pos_embed = weights[0], weights[1]
    batch, t = tokens.shape
    heads = cfg.n_heads
    head_dim = cfg.d_model // cfg.n_heads
    scale = 1.0 / (head_dim**0.5)

    x = tok_embed[tokens] + pos_embed[jnp.arange(t)][None, :, :]
    attn_mask = (mask[:, None, None, :] > 0.5)  # [B,1,1,T]

    for layer in range(cfg.layers):
        (ln1_s, ln1_b, wqkv, bqkv, wo, bo, ln2_s, ln2_b, w1, b1, w2, b2) = (
            _layer_weights(weights, 2, layer)
        )
        h = _layer_norm(x, ln1_s, ln1_b)
        qkv = jnp.dot(h, wqkv) + bqkv
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = _split_heads(q, heads, head_dim)
        k = _split_heads(k, heads, head_dim)
        v = _split_heads(v, heads, head_dim)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
        s = jnp.where(attn_mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", p, v)
        x = x + jnp.dot(_merge_heads(attn), wo) + bo
        x = x + _mlp(_layer_norm(x, ln2_s, ln2_b), w1, b1, w2, b2)
    return x


def embed_forward(cfg: EncoderConfig, weights, tokens, mask):
    """Sentence embeddings: trunk -> fused masked-mean-pool + L2 (L1 kernel).

    tokens: [B, T] int32; mask: [B, T] f32.  Returns [B, d] unit vectors.
    """
    x = _encoder_trunk(cfg, weights, tokens, mask)
    return masked_mean_pool(x, mask)


def rerank_forward(cfg: EncoderConfig, weights, tokens, mask):
    """Cross-encoder relevance scores from the CLS (position 0) state.

    tokens: [B, T] packed ``query SEP chunk`` pairs.  Returns [B] scores.
    """
    w_score, b_score = weights[-2], weights[-1]
    x = _encoder_trunk(cfg, weights, tokens, mask)
    return (jnp.dot(x[:, 0, :], w_score) + b_score)[:, 0]
