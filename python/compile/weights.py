"""Seeded weight initialisation and the TWB1 binary weight format.

The Rust runtime (rust/src/runtime/weights.rs) reads the same format:

    magic   b"TWB1"
    u32 LE  tensor count N
    N times:
        u32 LE  name length, then name bytes (utf-8)
        u32 LE  dtype (0 = f32)
        u32 LE  ndim, then ndim x u32 LE dims
        raw little-endian payload (prod(dims) * 4 bytes)

Tensors appear in the file in exact AOT parameter order.
"""

import struct
from typing import List, Tuple

import numpy as np

MAGIC = b"TWB1"
DTYPE_F32 = 0


def init_weights(
    schema: List[Tuple[str, Tuple[int, ...]]], seed: int
) -> List[np.ndarray]:
    """Deterministic scaled-gaussian init per tensor.

    Norm scales/biases get (1, 0); everything else N(0, 1/sqrt(fan_in)).
    """
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in schema:
        base = name.rsplit(".", 1)[-1]
        if base.endswith("_scale"):
            arr = np.ones(shape, dtype=np.float32)
        elif base.endswith("_bias") or base.startswith("b"):
            arr = np.zeros(shape, dtype=np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            arr = (
                rng.standard_normal(shape) / np.sqrt(fan_in)
            ).astype(np.float32)
        out.append(arr)
    return out


def save_weights(
    path: str, schema: List[Tuple[str, Tuple[int, ...]]], arrays: List[np.ndarray]
) -> None:
    assert len(schema) == len(arrays)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(arrays)))
        for (name, shape), arr in zip(schema, arrays):
            assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
            assert arr.dtype == np.float32
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", DTYPE_F32))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<f4").tobytes())


def load_weights(path: str) -> List[Tuple[str, np.ndarray]]:
    """Inverse of save_weights (used by the pytest suite)."""
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (dtype,) = struct.unpack("<I", f.read(4))
            assert dtype == DTYPE_F32
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            arr = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(dims)
            out.append((name, arr))
    return out
