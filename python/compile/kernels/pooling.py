"""L1 Pallas kernel: fused masked mean-pool + L2 normalisation.

The embedding engine's post-transformer step (bge-style sentence
embeddings).  Fusing pool + normalise keeps the [B, T, D] activations in
VMEM for a single pass instead of two HBM round-trips.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-6


def _pool_kernel(x_ref, mask_ref, o_ref):
    """One batch-row program.

    x_ref:    [1, T, D] f32 token activations
    mask_ref: [1, T]    f32 validity mask (1.0 for real tokens)
    o_ref:    [1, D]    f32 normalised sentence embedding
    """
    x = x_ref[0, :, :]  # [T, D]
    mask = mask_ref[0, :]  # [T]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    pooled = jnp.sum(x * mask[:, None], axis=0) / denom  # [D]
    norm = jnp.sqrt(jnp.sum(pooled * pooled) + _EPS)
    o_ref[0, :] = pooled / norm


@jax.jit
def masked_mean_pool(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked mean-pool over tokens, then L2-normalise.

    Args:
      x:    [B, T, D] token activations.
      mask: [B, T] float mask (1.0 = valid token).
    Returns:
      [B, D] unit-norm embeddings.
    """
    batch, t, d = x.shape
    return pl.pallas_call(
        _pool_kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, t, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, t), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d), jnp.float32),
        interpret=True,
    )(x, mask.astype(jnp.float32))
