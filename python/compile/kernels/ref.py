"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the pytest/hypothesis suites compare against.
They are deliberately written in the most direct way possible (materialised
score matrix, no tiling) so any disagreement implicates the kernels.
"""

import jax
import jax.numpy as jnp

_NEG_INF = -1e30
_EPS = 1e-6


def attention_ref(q, k, v, offsets):
    """Reference chunked causal attention.

    q: [B, H, C, D]; k, v: [B, H, S, D]; offsets: [B] i32.
    Query i of row b (absolute position offsets[b]+i) attends to cache
    positions j <= offsets[b]+i.
    """
    batch, heads, chunk, head_dim = q.shape
    seq = k.shape[2]
    scale = 1.0 / (head_dim**0.5)

    s = jnp.einsum("bhcd,bhsd->bhcs", q, k) * scale  # [B, H, C, S]
    q_pos = offsets[:, None] + jnp.arange(chunk)[None, :]  # [B, C]
    kv_pos = jnp.arange(seq)  # [S]
    mask = kv_pos[None, None, :] <= q_pos[:, :, None]  # [B, C, S]
    s = jnp.where(mask[:, None, :, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhcs,bhsd->bhcd", p, v)


def masked_mean_pool_ref(x, mask):
    """Reference masked mean-pool + L2 normalise. x: [B,T,D]; mask: [B,T]."""
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    pooled = jnp.sum(x * mask[:, :, None], axis=1) / denom
    norm = jnp.sqrt(jnp.sum(pooled * pooled, axis=1, keepdims=True) + _EPS)
    return pooled / norm
