"""L1 Pallas kernels (build-time only; lower into the AOT HLO)."""

from .attention import flash_attention
from .pooling import masked_mean_pool

__all__ = ["flash_attention", "masked_mean_pool"]
