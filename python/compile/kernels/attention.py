"""L1 Pallas kernel: tiled attention with offset causal masking.

This is the compute hot-spot of the paper's decomposed LLM prefilling
(Table 2: Prefilling / Partial Prefilling / Full Prefilling).  A chunk of C
new tokens, whose first token sits at absolute position ``offset[b]`` in
sequence ``b``, attends against the full KV cache (which already contains
the chunk's own keys/values at ``[offset, offset+C)``).

Hardware adaptation (paper targets CUDA warps/tensor-cores via vLLM):
  * threadblock-per-(batch, head, q-tile)  ->  Pallas grid (B*H, C/block_q)
  * shared-memory K/V staging             ->  VMEM blocks via BlockSpec
  * warp-level online softmax             ->  running (m, l, acc) over KV
    tiles, the flash-attention scheme, with MXU-shaped [tile, Dh] matmuls
  * CUDA masking predicates               ->  broadcasted_iota masks with a
    per-row offset

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO and the same code path is
executed by the Rust runtime.  VMEM/MXU estimates for a real TPU are in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# KV tile width of the online-softmax loop.  S (=256) must be a multiple.
DEFAULT_BLOCK_K = 128
# Q tile height.  C must be a multiple (or equal) for every prefill bucket.
DEFAULT_BLOCK_Q = 16

_NEG_INF = -1e30


def _attn_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One (batch*head, q-tile) program: flash-style attention over KV tiles.

    off_ref: [1]        i32, absolute position of the chunk's first token
    q_ref:   [1, Bq, D] f32, query tile
    k_ref:   [1, S,  D] f32, full key cache row for this (b, h)
    v_ref:   [1, S,  D] f32, full value cache row
    o_ref:   [1, Bq, D] f32, output tile
    """
    qi = pl.program_id(1)
    block_q = q_ref.shape[1]
    head_dim = q_ref.shape[2]
    seq = k_ref.shape[1]

    offset = off_ref[0]
    q = q_ref[0, :, :] * scale  # [Bq, D]

    # Absolute positions of the queries in this tile.
    q_pos = offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    # Running accumulators of the online softmax.
    m = jnp.full((block_q, 1), _NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q, 1), dtype=jnp.float32)
    acc = jnp.zeros((block_q, head_dim), dtype=jnp.float32)

    # Static trip count -> unrolled at trace time (interpret mode friendly).
    for kv_start in range(0, seq, block_k):
        k_tile = k_ref[0, kv_start : kv_start + block_k, :]  # [Bk, D]
        v_tile = v_ref[0, kv_start : kv_start + block_k, :]  # [Bk, D]

        s = jnp.dot(q, k_tile.T, preferred_element_type=jnp.float32)  # [Bq, Bk]

        kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = kv_pos <= q_pos  # causal w.r.t. absolute positions
        s = jnp.where(mask, s, _NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)  # [Bq, Bk]
        alpha = jnp.exp(m - m_new)  # [Bq, 1]
        l = l * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, v_tile, preferred_element_type=jnp.float32)
        m = m_new

    o_ref[0, :, :] = acc / l


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    offsets: jax.Array,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Chunked causal attention against a pre-populated KV cache.

    Args:
      q:       [B, H, C, D] chunk queries.
      k, v:    [B, H, S, D] full KV cache (chunk keys already written).
      offsets: [B] int32 absolute position of each row's chunk start.
    Returns:
      [B, H, C, D] attention outputs for the chunk.
    """
    batch, heads, chunk, head_dim = q.shape
    seq = k.shape[2]
    if chunk < block_q:
        block_q = chunk
    if seq < block_k:
        block_k = seq
    assert chunk % block_q == 0, (chunk, block_q)
    assert seq % block_k == 0, (seq, block_k)

    scale = 1.0 / (head_dim**0.5)
    bh = batch * heads
    q_r = q.reshape(bh, chunk, head_dim)
    k_r = k.reshape(bh, seq, head_dim)
    v_r = v.reshape(bh, seq, head_dim)
    # One offset per (batch*head) program, derived from the per-batch offsets.
    off_r = jnp.repeat(offsets.astype(jnp.int32), heads)

    grid = (bh, chunk // block_q)
    kernel = functools.partial(_attn_kernel, block_k=block_k, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (b,)),
            pl.BlockSpec((1, block_q, head_dim), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, chunk, head_dim), jnp.float32),
        interpret=True,
    )(off_r, q_r, k_r, v_r)
    return out.reshape(batch, heads, chunk, head_dim)
