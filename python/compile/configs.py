"""Model-variant configurations and AOT bucket grids.

Single source of truth shared by model.py, aot.py and the pytest suite.
The Rust side consumes the same information through artifacts/manifest.json
written by aot.py.

The paper deploys gemma-2-2B / llama-2-7B / llama-2-13B / llama-30B plus a
bge-large embedder and bge-reranker on 3090/A800 GPUs.  We substitute tiny
decoder/encoder transformers whose *relative* costs preserve the paper's
ordering (lite < small < medium < large); absolute latency realism comes
from running the real lowered HLO on the PJRT CPU client.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

# ---------------------------------------------------------------------------
# Token conventions (shared with rust/src/workload/tokenizer.rs)
# ---------------------------------------------------------------------------
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
SEP_ID = 3  # structured-output separator used by splittable decodes
VOCAB = 2048


@dataclass(frozen=True)
class LlmConfig:
    """Decoder-only LLM variant."""

    name: str
    layers: int
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    vocab: int = VOCAB
    max_seq: int = 256  # KV-cache capacity S

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder used for the embedding model and the cross-encoder reranker."""

    name: str
    layers: int
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    vocab: int = VOCAB
    max_seq: int = 64  # input sequence length (padded)
    # "embed": mean-pool + l2-normalise -> [B, d_model]
    # "score": CLS head -> [B] relevance scores
    head: str = "embed"


# ---------------------------------------------------------------------------
# Variants (paper model -> our analog)
# ---------------------------------------------------------------------------
LLM_VARIANTS = {
    # gemma-2-2B analog: contextualization / lightweight LLM
    "llm-lite": LlmConfig("llm-lite", layers=2),
    # llama-2-7B analog: proxy/judge + small core LLM
    "llm-small": LlmConfig("llm-small", layers=4),
    # llama-2-13B analog
    "llm-medium": LlmConfig("llm-medium", layers=6),
    # llama-30B analog
    "llm-large": LlmConfig("llm-large", layers=8),
}

ENCODER_VARIANTS = {
    # bge-large-en-v1.5 analog
    "embedder": EncoderConfig("embedder", layers=2, max_seq=64, head="embed"),
    # bge-reranker-large analog (query+chunk pair packed into one sequence)
    "reranker": EncoderConfig("reranker", layers=2, max_seq=128, head="score"),
}

# ---------------------------------------------------------------------------
# AOT bucket grids: every (variant, op, batch, chunk) tuple here becomes one
# artifacts/<variant>__<op>__b<B>[_c<C>].hlo.txt executable.
# ---------------------------------------------------------------------------
PREFILL_BATCHES: List[int] = [1, 2, 4]
PREFILL_CHUNKS: List[int] = [16, 32, 64, 128]
# Single-shot full-prefill buckets for the baselines plus the exact-size
# buckets Table 3 needs so decomposed-vs-single comparisons compute the
# same number of (unpadded) tokens on both paths.
PREFILL_FULL: List[Tuple[int, int]] = [(1, 48), (1, 160), (1, 192), (1, 256)]
DECODE_BATCHES: List[int] = [1, 2, 4, 8]
ENCODER_BATCHES: List[int] = [1, 4, 8, 16]


def prefill_buckets() -> List[Tuple[int, int]]:
    out = [(b, c) for b in PREFILL_BATCHES for c in PREFILL_CHUNKS]
    out.extend(PREFILL_FULL)
    return out


def artifact_name(variant: str, op: str, batch: int, chunk: int | None = None) -> str:
    if chunk is None:
        return f"{variant}__{op}__b{batch}"
    return f"{variant}__{op}__b{batch}_c{chunk}"
