"""Configuration/grid invariants shared with the Rust loader."""

import pytest

from compile import configs
from compile.model import encoder_weight_schema, kv_cache_shape, llm_weight_schema


def test_variant_ordering_by_cost():
    """Relative cost ordering must mirror the paper's model lineup."""
    layers = [configs.LLM_VARIANTS[v].layers for v in
              ("llm-lite", "llm-small", "llm-medium", "llm-large")]
    assert layers == sorted(layers)
    assert len(set(layers)) == 4


def test_head_dim_divides():
    for cfg in configs.LLM_VARIANTS.values():
        assert cfg.d_model % cfg.n_heads == 0


def test_artifact_names_unique():
    names = set()
    for v in configs.LLM_VARIANTS:
        for b, c in configs.prefill_buckets():
            names.add(configs.artifact_name(v, "prefill", b, c))
        for b in configs.DECODE_BATCHES:
            names.add(configs.artifact_name(v, "decode", b))
    expected = len(configs.LLM_VARIANTS) * (
        len(configs.prefill_buckets()) + len(configs.DECODE_BATCHES))
    assert len(names) == expected


def test_table3_buckets_present():
    """Exact-size buckets for the Table 3 splits (16+48, 64+64, 160+32)."""
    chunks = {c for _, c in configs.prefill_buckets()}
    for needed in (16, 48, 64, 160, 192, 128):
        assert needed in chunks, needed


def test_kv_cache_shape_matches_schema_dims():
    cfg = configs.LLM_VARIANTS["llm-small"]
    shape = kv_cache_shape(cfg, batch=2)
    assert shape == (cfg.layers, 2, 2, cfg.n_heads, cfg.max_seq, cfg.head_dim)


@pytest.mark.parametrize("variant", list(configs.LLM_VARIANTS))
def test_llm_schema_param_count(variant):
    cfg = configs.LLM_VARIANTS[variant]
    schema = llm_weight_schema(cfg)
    assert len(schema) == 4 + 12 * cfg.layers
    # total params stay modest (tiny-model budget)
    n_params = sum(
        int(__import__("numpy").prod(shape)) for _, shape in schema)
    assert n_params < 5_000_000


def test_encoder_schema_heads():
    emb = encoder_weight_schema(configs.ENCODER_VARIANTS["embedder"])
    rr = encoder_weight_schema(configs.ENCODER_VARIANTS["reranker"])
    assert [n for n, _ in rr][-2:] == ["w_score", "b_score"]
    assert not any(n.startswith("w_score") for n, _ in emb)


def test_special_tokens_disjoint():
    ids = {configs.PAD_ID, configs.BOS_ID, configs.EOS_ID, configs.SEP_ID}
    assert len(ids) == 4
    assert all(0 <= i < 4 for i in ids)
