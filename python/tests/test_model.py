"""L2 model correctness.

The decisive property for the paper's Pass 3 (LLM prefilling split): running
a prompt through *multiple chunked partial prefills* must produce exactly
the same logits and KV cache as one monolithic prefill — decomposition may
cost engine-seconds (Table 3) but never accuracy.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.weights import init_weights

jax.config.update("jax_platform_name", "cpu")

CFG = configs.LlmConfig("test-llm", layers=2, d_model=64, n_heads=2, d_ff=128,
                        vocab=128, max_seq=64)
ENC = configs.EncoderConfig("test-enc", layers=2, d_model=64, n_heads=2,
                            d_ff=128, vocab=128, max_seq=32, head="embed")
RR = configs.EncoderConfig("test-rr", layers=2, d_model=64, n_heads=2,
                           d_ff=128, vocab=128, max_seq=32, head="score")


@pytest.fixture(scope="module")
def llm_weights():
    schema = model.llm_weight_schema(CFG)
    return tuple(jnp.asarray(a) for a in init_weights(schema, seed=42))


@pytest.fixture(scope="module")
def enc_weights():
    schema = model.encoder_weight_schema(ENC)
    return tuple(jnp.asarray(a) for a in init_weights(schema, seed=43))


@pytest.fixture(scope="module")
def rr_weights():
    schema = model.encoder_weight_schema(RR)
    return tuple(jnp.asarray(a) for a in init_weights(schema, seed=44))


def _zeros_kv(batch):
    return jnp.zeros(model.kv_cache_shape(CFG, batch), dtype=jnp.float32)


def _tok(key, batch, n):
    return jax.random.randint(key, (batch, n), 4, CFG.vocab, dtype=jnp.int32)


def test_single_prefill_logits_finite(llm_weights):
    toks = _tok(jax.random.PRNGKey(0), 1, 16)
    kv, logits, nxt = model.llm_prefill(
        CFG, llm_weights, toks, _zeros_kv(1),
        jnp.zeros(1, jnp.int32), jnp.full((1,), 16, jnp.int32))
    assert logits.shape == (1, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert kv.shape == model.kv_cache_shape(CFG, 1)


@pytest.mark.parametrize("splits", [[16], [8, 8], [4, 8, 4], [1, 15]])
def test_chunked_prefill_equals_monolithic(llm_weights, splits):
    """Partial+full prefill == single full prefill (Pass 3 correctness)."""
    total = sum(splits)
    toks = _tok(jax.random.PRNGKey(1), 1, total)

    kv_m, logits_m, next_m = model.llm_prefill(
        CFG, llm_weights, toks, _zeros_kv(1),
        jnp.zeros(1, jnp.int32), jnp.full((1,), total, jnp.int32))

    kv = _zeros_kv(1)
    off = 0
    for c in splits:
        chunk = toks[:, off:off + c]
        kv, logits, nxt = model.llm_prefill(
            CFG, llm_weights, chunk, kv,
            jnp.full((1,), off, jnp.int32), jnp.full((1,), c, jnp.int32))
        off += c

    np.testing.assert_allclose(np.asarray(kv), np.asarray(kv_m), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_m), atol=1e-3, rtol=1e-3)
    assert int(nxt[0]) == int(next_m[0])


def test_decode_equals_prefill_extension(llm_weights):
    """Prefill(n) + decode(token) must equal Prefill(n+1) logits."""
    n = 12
    toks = _tok(jax.random.PRNGKey(2), 1, n + 1)

    kv, _, _ = model.llm_prefill(
        CFG, llm_weights, toks[:, :n], _zeros_kv(1),
        jnp.zeros(1, jnp.int32), jnp.full((1,), n, jnp.int32))
    kv_d, logits_d, next_d = model.llm_decode(
        CFG, llm_weights, toks[:, n], kv, jnp.full((1,), n, jnp.int32))

    kv_m, logits_m, next_m = model.llm_prefill(
        CFG, llm_weights, toks, _zeros_kv(1),
        jnp.zeros(1, jnp.int32), jnp.full((1,), n + 1, jnp.int32))

    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_m), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(kv_d), np.asarray(kv_m), atol=1e-4)
    assert int(next_d[0]) == int(next_m[0])


def test_batched_prefill_rows_independent(llm_weights):
    """Row b of a batched prefill == the same row prefilled alone."""
    toks = _tok(jax.random.PRNGKey(3), 2, 16)
    lens = jnp.asarray([16, 10], jnp.int32)
    offs = jnp.asarray([0, 0], jnp.int32)
    kv_b, logits_b, _ = model.llm_prefill(
        CFG, llm_weights, toks, _zeros_kv(2), offs, lens)

    for b in range(2):
        kv_1, logits_1, _ = model.llm_prefill(
            CFG, llm_weights, toks[b:b + 1], _zeros_kv(1),
            offs[b:b + 1], lens[b:b + 1])
        np.testing.assert_allclose(
            np.asarray(logits_b[b]), np.asarray(logits_1[0]),
            atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(
            np.asarray(kv_b[:, :, b]), np.asarray(kv_1[:, :, 0]), atol=1e-4)


def test_padded_row_does_not_corrupt_cache(llm_weights):
    """Positions past `lengths` must leave the cache untouched."""
    toks = _tok(jax.random.PRNGKey(4), 1, 16)
    kv0 = jnp.full(model.kv_cache_shape(CFG, 1), 7.0, dtype=jnp.float32)
    kv, _, _ = model.llm_prefill(
        CFG, llm_weights, toks, kv0,
        jnp.zeros(1, jnp.int32), jnp.full((1,), 4, jnp.int32))
    # slots >= 4 keep the sentinel value
    np.testing.assert_allclose(np.asarray(kv[:, :, :, :, 4:, :]), 7.0)


def test_decode_greedy_loop_deterministic(llm_weights):
    toks = _tok(jax.random.PRNGKey(5), 1, 8)
    kv, _, nxt = model.llm_prefill(
        CFG, llm_weights, toks, _zeros_kv(1),
        jnp.zeros(1, jnp.int32), jnp.full((1,), 8, jnp.int32))

    def run(kv, nxt):
        out = []
        pos = 8
        for _ in range(4):
            kv, _, nxt = model.llm_decode(
                CFG, llm_weights, nxt, kv, jnp.full((1,), pos, jnp.int32))
            out.append(int(nxt[0]))
            pos += 1
        return out

    assert run(kv, nxt) == run(kv, nxt)


def test_embedder_unit_norm_and_shape(enc_weights):
    toks = jax.random.randint(jax.random.PRNGKey(6), (4, 32), 4, 128, dtype=jnp.int32)
    mask = (jnp.arange(32)[None, :] < jnp.asarray([32, 10, 5, 1])[:, None]).astype(
        jnp.float32)
    emb = model.embed_forward(ENC, enc_weights, toks, mask)
    assert emb.shape == (4, 64)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(emb, axis=1)), np.ones(4), atol=1e-4)


def test_embedder_mask_respected(enc_weights):
    """Tokens behind the mask must not influence the embedding."""
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 32), 4, 128, dtype=jnp.int32)
    mask = (jnp.arange(32)[None, :] < 8).astype(jnp.float32)
    e1 = model.embed_forward(ENC, enc_weights, toks, mask)
    toks2 = toks.at[0, 8:].set(99)
    e2 = model.embed_forward(ENC, enc_weights, toks2, mask)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-5)


def test_reranker_scores_shape_and_order_stability(rr_weights):
    toks = jax.random.randint(jax.random.PRNGKey(8), (4, 32), 4, 128, dtype=jnp.int32)
    mask = jnp.ones((4, 32))
    s = model.rerank_forward(RR, rr_weights, toks, mask)
    assert s.shape == (4,)
    # batched scores equal per-row scores
    for b in range(4):
        s1 = model.rerank_forward(RR, rr_weights, toks[b:b + 1], mask[b:b + 1])
        np.testing.assert_allclose(np.asarray(s[b]), np.asarray(s1[0]), atol=1e-4)
