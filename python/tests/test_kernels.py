"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes, offsets and tile sizes; every example asserts
allclose against ref.py.  This is the core correctness signal for the
compute hot-spot that ends up inside every AOT prefill artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention
from compile.kernels.pooling import masked_mean_pool
from compile.kernels.ref import attention_ref, masked_mean_pool_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


@st.composite
def attn_case(draw):
    batch = draw(st.sampled_from([1, 2, 4]))
    heads = draw(st.sampled_from([1, 2, 4]))
    chunk = draw(st.sampled_from([8, 16, 32, 64]))
    seq = draw(st.sampled_from([128, 256]))
    head_dim = draw(st.sampled_from([16, 32]))
    # offsets leave room for the chunk inside the cache
    offsets = draw(
        st.lists(
            st.integers(min_value=0, max_value=seq - chunk),
            min_size=batch,
            max_size=batch,
        )
    )
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return batch, heads, chunk, seq, head_dim, offsets, seed


@settings(max_examples=25, deadline=None)
@given(attn_case())
def test_attention_matches_ref(case):
    batch, heads, chunk, seq, head_dim, offsets, seed = case
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (batch, heads, chunk, head_dim))
    k = _rand(kk, (batch, heads, seq, head_dim))
    v = _rand(kv, (batch, heads, seq, head_dim))
    off = jnp.asarray(offsets, dtype=jnp.int32)

    out = flash_attention(q, k, v, off)
    ref = attention_ref(q, k, v, off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(8, 64), (16, 128), (32, 128), (64, 256)])
def test_attention_tile_sizes(block_q, block_k):
    """Kernel result must be invariant to the tiling schedule."""
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (2, 4, 64, 32))
    k = _rand(kk, (2, 4, 256, 32))
    v = _rand(kv, (2, 4, 256, 32))
    off = jnp.asarray([0, 150], dtype=jnp.int32)
    out = flash_attention(q, k, v, off, block_q=block_q, block_k=block_k)
    ref = attention_ref(q, k, v, off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_attention_offset_zero_equals_plain_causal():
    """offset=0 must reproduce a plain causal self-attention prefill."""
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    chunk = seq = 128
    q = _rand(kq, (1, 2, chunk, 32))
    k = _rand(kk, (1, 2, seq, 32))
    v = _rand(kv, (1, 2, seq, 32))
    off = jnp.zeros((1,), dtype=jnp.int32)
    out = flash_attention(q, k, v, off)
    ref = attention_ref(q, k, v, off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_attention_ignores_stale_cache_beyond_mask():
    """Garbage in cache positions > query position must not leak through."""
    key = jax.random.PRNGKey(11)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (1, 2, 16, 32))
    k = _rand(kk, (1, 2, 256, 32))
    v = _rand(kv, (1, 2, 256, 32))
    off = jnp.asarray([40], dtype=jnp.int32)
    out1 = flash_attention(q, k, v, off)
    # poison everything after the last visible position (40 + 15)
    k2 = k.at[:, :, 56:, :].set(1e4)
    v2 = v.at[:, :, 56:, :].set(-1e4)
    out2 = flash_attention(q, k2, v2, off)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.sampled_from([1, 3, 8]),
    t=st.sampled_from([16, 64]),
    d=st.sampled_from([32, 128]),
    valid=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pooling_matches_ref(batch, t, d, valid, seed):
    valid = min(valid, t)
    key = jax.random.PRNGKey(seed)
    x = _rand(key, (batch, t, d))
    mask = (jnp.arange(t)[None, :] < valid).astype(jnp.float32)
    mask = jnp.broadcast_to(mask, (batch, t))
    out = masked_mean_pool(x, mask)
    ref = masked_mean_pool_ref(x, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_pooling_unit_norm():
    key = jax.random.PRNGKey(5)
    x = _rand(key, (4, 64, 128))
    mask = jnp.ones((4, 64))
    out = masked_mean_pool(x, mask)
    norms = jnp.linalg.norm(out, axis=1)
    np.testing.assert_allclose(np.asarray(norms), np.ones(4), atol=1e-4)


def test_pooling_all_masked_row_is_finite():
    """A fully-masked row must not produce NaNs (denominator clamp)."""
    x = jnp.ones((2, 16, 32))
    mask = jnp.zeros((2, 16))
    out = masked_mean_pool(x, mask)
    assert bool(jnp.all(jnp.isfinite(out)))
