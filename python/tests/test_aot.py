"""AOT pipeline sanity: manifest structure, weight files, HLO lowering.

Runs the quick-bucket AOT into a temp dir and validates everything the Rust
loader depends on (parameter ordering, shapes, TWB1 round-trip).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import configs, model
from compile.weights import init_weights, load_weights, save_weights

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def test_weight_roundtrip(tmp_path):
    cfg = configs.LlmConfig("tiny", layers=1, d_model=32, n_heads=2, d_ff=64,
                            vocab=64, max_seq=16)
    schema = model.llm_weight_schema(cfg)
    arrays = init_weights(schema, seed=9)
    path = str(tmp_path / "w.bin")
    save_weights(path, schema, arrays)
    back = load_weights(path)
    assert [n for n, _ in back] == [n for n, _ in schema]
    for (_, a), b in zip(back, arrays):
        np.testing.assert_array_equal(a, b)


def test_weight_init_deterministic():
    cfg = configs.ENCODER_VARIANTS["embedder"]
    schema = model.encoder_weight_schema(cfg)
    a = init_weights(schema, seed=5)
    b = init_weights(schema, seed=5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = init_weights(schema, seed=6)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_llm_schema_order_stable():
    cfg = configs.LLM_VARIANTS["llm-lite"]
    schema = model.llm_weight_schema(cfg)
    names = [n for n, _ in schema]
    assert names[:4] == ["tok_embed", "pos_embed", "lnf_scale", "lnf_bias"]
    assert names[4] == "layer0.ln1_scale"
    assert len(schema) == 4 + 12 * cfg.layers


def test_prefill_bucket_grid():
    buckets = configs.prefill_buckets()
    assert (1, 16) in buckets and (4, 128) in buckets and (1, 256) in buckets
    assert len(set(buckets)) == len(buckets)


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", out, "--quick",
         "--variants", "llm-lite"],
        cwd=os.path.join(REPO, "python"),
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_structure(quick_artifacts):
    with open(os.path.join(quick_artifacts, "manifest.json")) as f:
        m = json.load(f)
    assert m["format"] == 1
    assert m["special_tokens"]["sep"] == configs.SEP_ID
    assert "llm-lite" in m["models"]
    assert m["models"]["llm-lite"]["kind"] == "llm"
    arts = {a["artifact"]: a for a in m["artifacts"]}
    pf = arts["llm-lite__prefill__b1_c16"]
    assert pf["n_weights"] == 4 + 12 * configs.LLM_VARIANTS["llm-lite"].layers
    assert pf["inputs"][0]["shape"] == [1, 16]
    assert pf["outputs"][0]["shape"] == list(
        model.kv_cache_shape(configs.LLM_VARIANTS["llm-lite"], 1))
    # every referenced file exists
    for a in m["artifacts"]:
        assert os.path.exists(os.path.join(quick_artifacts, a["file"])), a["file"]
    for mm in m["models"].values():
        assert os.path.exists(os.path.join(quick_artifacts, mm["weights"]))


def test_hlo_text_parses_as_module(quick_artifacts):
    """HLO text must contain a parseable entry computation signature."""
    path = os.path.join(quick_artifacts, "llm-lite__prefill__b1_c16.hlo.txt")
    with open(path) as f:
        head = f.read(4096)
    assert head.startswith("HloModule")
    assert "entry_computation_layout" in head
