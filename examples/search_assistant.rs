//! Search-engine-empowered assistant (Fig. 2a): a proxy model drafts a
//! heuristic answer, a judge decides whether to search the web, and the
//! core LLM synthesizes — comparing Teola against module-sequential
//! execution on the same query.

use teola::apps::{bind_answer_tokens, AppKind};
use teola::baselines::Scheme;
use teola::bench::{next_query_id, platform_for};
use teola::graph::template::QueryConfig;
use teola::scheduler::Platform;
use teola::workload::Tokenizer;

fn main() -> teola::Result<()> {
    let core = "llm-small";
    let mut cfg = platform_for(AppKind::SearchGen, core);
    cfg.warm = false;
    let platform = Platform::start(&cfg)?;
    let tok = Tokenizer::new(platform.manifest.vocab);

    let q = QueryConfig {
        question: tok.encode("what changed in the latest orchestration framework release"),
        doc_chunks: vec![],
        top_k: 4,
        expansion: 1,
        answer_tokens: 20,
        seed: 99,
    };

    let mut template = AppKind::SearchGen.template(core);
    bind_answer_tokens(&mut template, q.answer_tokens);

    for scheme in [Scheme::LlamaDistTO, Scheme::Teola] {
        platform.set_policy(scheme.policy());
        let egraph = scheme.build(&template, &q, &platform.profiles)?;
        let t0 = std::time::Instant::now();
        let (answer, m) = platform.run_query(next_query_id(), egraph)?;
        println!(
            "{:<14} {:>8.1} ms  ({} engine ops)  answer: {}",
            scheme.name(),
            t0.elapsed().as_secs_f64() * 1000.0,
            m.n_engine_ops,
            tok.decode(&answer.flat_tokens()[..8.min(answer.flat_tokens().len())])
        );
    }
    platform.shutdown();
    Ok(())
}
