//! End-to-end serving validation driver (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Loads the real AOT-compiled models, then serves batched Poisson request
//! streams for two applications under Teola and the strongest baseline,
//! reporting latency percentiles and throughput — proof that all three
//! layers (Pallas kernel -> JAX HLO -> Rust coordinator) compose on a real
//! serving workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use teola::apps::AppKind;
use teola::baselines::Scheme;
use teola::bench::{platform_for_all, run_trace, TraceRun};
use teola::scheduler::Platform;
use teola::workload::DatasetKind;

fn main() -> teola::Result<()> {
    if !teola::runtime::default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("no artifacts: run `make artifacts` first");
        return Ok(());
    }
    let core = "llm-small";
    let apps = [
        (AppKind::DocQaNaive, DatasetKind::TruthfulQa),
        (AppKind::DocQaAdvanced, DatasetKind::TruthfulQa),
    ];
    let cfg = platform_for_all(&[apps[0].0, apps[1].0], core);
    println!("starting platform (compiling AOT artifacts on PJRT-CPU)...");
    let platform = Platform::start(&cfg)?;

    let rate = 3.0;
    let n = if teola::bench::quick() { 4 } else { 12 };
    println!(
        "serving {n} queries/app at {rate} rps (open-loop Poisson), core LLM = {core}\n"
    );
    println!(
        "{:<22} {:<14} {:>9} {:>9} {:>9} {:>10}",
        "app", "scheme", "mean_ms", "p50_ms", "p90_ms", "qps"
    );
    for (app, dataset) in apps {
        for scheme in [Scheme::LlamaDistTO, Scheme::Teola] {
            let run = TraceRun {
                app,
                scheme,
                dataset,
                core_llm: core.into(),
                rate,
                n_queries: n,
                seed: 0xE2E,
            };
            let r = run_trace(&platform, &run)?;
            println!(
                "{:<22} {:<14} {:>9.1} {:>9.1} {:>9.1} {:>10.2}",
                app.name(),
                scheme.name(),
                r.summary_ms.mean,
                r.summary_ms.p50,
                r.summary_ms.p90,
                n as f64 / r.wall_s
            );
        }
    }
    println!("\ne2e serving driver OK — all three layers composed.");
    platform.shutdown();
    Ok(())
}
