//! Advanced-RAG document QA "server": accepts a small stream of queries
//! (documents + questions) and serves them concurrently with Teola's full
//! pipeline — query expansion with streamed partial decodes, per-segment
//! embedding + search, reranking and refine-mode synthesis.

use teola::apps::{bind_answer_tokens, AppKind};
use teola::baselines::Scheme;
use teola::bench::{next_query_id, platform_for};
use teola::graph::template::QueryConfig;
use teola::scheduler::Platform;
use teola::workload::Tokenizer;

const CORPUS: [&str; 8] = [
    "quarterly revenue increased due to cloud subscription growth",
    "operating margin declined after one time restructuring charges",
    "the board approved a share repurchase program for next year",
    "research spending focused on inference acceleration hardware",
    "customer churn decreased in the enterprise segment",
    "the datacenter expansion added three new regions in asia",
    "foreign exchange headwinds reduced reported revenue growth",
    "free cash flow remained strong despite capital expenditures",
];

fn main() -> teola::Result<()> {
    let core = "llm-small";
    let mut cfg = platform_for(AppKind::DocQaAdvanced, core);
    cfg.warm = false;
    let platform = Platform::start(&cfg)?;
    let tok = Tokenizer::new(platform.manifest.vocab);

    let questions = [
        "why did operating margin decline this quarter",
        "what is driving revenue growth",
        "how is the company spending on research",
    ];

    let mut template = AppKind::DocQaAdvanced.template(core);
    bind_answer_tokens(&mut template, 20);

    // Serve the three questions concurrently (each with its own uploaded
    // document set — per-query vector-DB namespaces).
    let mut handles = Vec::new();
    let t0 = std::time::Instant::now();
    for (i, question) in questions.iter().enumerate() {
        let q = QueryConfig {
            question: tok.encode(question),
            doc_chunks: CORPUS.iter().map(|d| tok.encode(d)).collect(),
            top_k: 3,
            expansion: 3,
            answer_tokens: 20,
            seed: 500 + i as u64,
        };
        let egraph = Scheme::Teola.build(&template, &q, &platform.profiles)?;
        handles.push((question, platform.spawn_query(next_query_id(), egraph)));
    }
    for (question, h) in handles {
        let (answer, m) = h.join().expect("query thread")?;
        println!(
            "Q: {question}\n   -> {} ({} ops, {:.1} ms e2e)",
            tok.decode(&answer.flat_tokens()[..10.min(answer.flat_tokens().len())]),
            m.n_engine_ops,
            m.e2e_us as f64 / 1000.0
        );
    }
    println!(
        "served {} queries concurrently in {:.1} ms",
        questions.len(),
        t0.elapsed().as_secs_f64() * 1000.0
    );
    platform.shutdown();
    Ok(())
}
