//! Quickstart: define a workflow, optimize it, serve one query.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use teola::apps::{bind_answer_tokens, AppKind};
use teola::baselines::Scheme;
use teola::bench::{next_query_id, platform_for};
use teola::graph::template::QueryConfig;
use teola::scheduler::Platform;
use teola::workload::Tokenizer;

fn main() -> teola::Result<()> {
    // 1. Provision the engines (offline stage ①: embedder + vector DB +
    //    two instances of the core LLM, all from AOT artifacts).
    let core = "llm-lite";
    let mut cfg = platform_for(AppKind::DocQaNaive, core);
    cfg.warm = false;
    let platform = Platform::start(&cfg)?;
    println!("platform up: engines ready");

    // 2. A user query: documents + question (tokenized by the demo
    //    word-hash tokenizer).
    let tok = Tokenizer::new(platform.manifest.vocab);
    let docs = [
        "teola orchestrates llm applications with primitive level dataflow graphs",
        "the graph optimizer prunes dependencies and splits prefill into partial prefills",
        "topology aware batching fuses primitives from multiple queries by depth",
        "the runtime executes aot compiled xla artifacts on the pjrt cpu client",
    ];
    let q = QueryConfig {
        question: tok.encode("how does teola optimize end to end latency"),
        doc_chunks: docs.iter().map(|d| tok.encode(d)).collect(),
        top_k: 2,
        expansion: 2,
        answer_tokens: 16,
        seed: 1,
    };

    // 3. Build the template, construct the p-graph, run the optimization
    //    passes, and execute the e-graph (online stages ② ③ ④).
    let mut template = AppKind::DocQaNaive.template(core);
    bind_answer_tokens(&mut template, q.answer_tokens);
    let egraph = Scheme::Teola.build(&template, &q, &platform.profiles)?;
    println!(
        "e-graph: {} primitives, critical path {}",
        egraph.len(),
        egraph.critical_path_len()
    );

    let t0 = std::time::Instant::now();
    let (answer, metrics) = platform.run_query(next_query_id(), egraph)?;
    println!(
        "answer tokens: {}",
        tok.decode(&answer.flat_tokens())
    );
    println!(
        "latency {:.1} ms | engine ops {} | queue {:.1} ms | exec {:.1} ms",
        t0.elapsed().as_secs_f64() * 1000.0,
        metrics.n_engine_ops,
        metrics.queue_us as f64 / 1000.0,
        metrics.exec_us as f64 / 1000.0
    );

    platform.shutdown();
    Ok(())
}
