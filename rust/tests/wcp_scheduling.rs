//! Weighted critical-path (WCP) query scheduling (paper §8): bucket
//! ordering by remaining critical-path device time at the unit level,
//! the strict p95 win on a heterogeneous Poisson trace with WCP on vs
//! off, starvation-freedom under sustained short-query load (aging), and
//! bit-identical outputs between modes.  Trace setup comes from the
//! shared harness in `tests/common/`.

mod common;

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use common::serial;
use teola::engines::prefix::prefix_fingerprint;
use teola::engines::EngineJob;
use teola::scheduler::{
    form_batch, form_continuous_admission, rediscount_resident_prefixes, wcp_priority_us,
    BatchPolicy, Platform, PlatformConfig, QueueItem, SlotUnit, WCP_AGING_WEIGHT,
};
use teola::serving::run_wcp_comparison;

/// Queue item with an explicit remaining-critical-path stamp; `age_ms`
/// backdates the arrival to simulate time already spent queued.
fn item(query: u64, node: usize, wcp_us: u64, now: Instant, age_ms: u64) -> QueueItem {
    let (tx, rx) = channel();
    std::mem::forget(rx);
    QueueItem {
        query,
        node,
        depth: 1,
        bundle: (query, node as u64),
        arrival: now - Duration::from_millis(age_ms),
        rows: 1,
        tokens: 1,
        wcp_discounted: false,
        prefix: None,
        wcp_us,
        tenant: teola::engines::UNTENANTED,
        job: EngineJob::ToolCall { name: "t".into(), cost_us: 0 },
        reply: tx,
        successors: Vec::new(),
    }
}

/// (a) A long-tail query admitted *after* a short one gets the engine
/// slot first under WCP ordering — and not under arrival ordering.
#[test]
fn long_tail_query_overtakes_earlier_short_query() {
    let now = Instant::now();
    let mk = || {
        vec![
            // Short query 1 arrived 5 ms before long query 2.
            item(1, 10, 50_000, now, 5),
            item(2, 20, 400_000, now, 0),
        ]
    };

    let mut q = mk();
    let batch = form_batch(&mut q, BatchPolicy::TopoAware, 1, true, SlotUnit::Rows);
    assert_eq!(batch.len(), 1);
    assert_eq!(batch[0].query, 2, "WCP: the longer remaining path goes first");

    let mut q = mk();
    let batch = form_batch(&mut q, BatchPolicy::TopoAware, 1, false, SlotUnit::Rows);
    assert_eq!(batch[0].query, 1, "arrival order: the earlier query goes first");

    // Continuous admission into a partially occupied instance follows the
    // same ordering.
    let mut q = mk();
    let admitted = form_continuous_admission(&mut q, 1, true, SlotUnit::Rows);
    assert_eq!(admitted[0].query, 2);
}

/// (c) Starvation-freedom: the aging term lets a waiting short-tail
/// query overtake fresh long-tail arrivals once it has queued for more
/// than `path_gap / WCP_AGING_WEIGHT`.
#[test]
fn aged_short_query_overtakes_sustained_long_query_load() {
    let long_path = 200_000u64;
    let short_path = 10_000u64;
    // The pure priority function crosses over exactly at the bound.
    let bound_us = (long_path - short_path) / WCP_AGING_WEIGHT;
    assert!(
        wcp_priority_us(short_path, Duration::from_micros(bound_us + 1_000)) > long_path,
        "a short query waiting past the bound must outrank a fresh long query"
    );
    assert!(
        wcp_priority_us(short_path, Duration::from_micros(bound_us / 2)) < long_path,
        "before the bound the long query keeps priority"
    );

    // End-to-end through form_batch: sustained fresh long-query load
    // cannot hold back a short query that has aged past the bound.
    let now = Instant::now();
    let mut q = vec![item(1, 1, short_path, now, 150)]; // 150 ms queued
    for k in 0..8u64 {
        q.push(item(100 + k, 1, long_path, now, 0));
    }
    let batch = form_batch(&mut q, BatchPolicy::TopoAware, 1, true, SlotUnit::Rows);
    assert_eq!(batch[0].query, 1, "aged short query must win the next slot");

    // A *fresh* short query still yields to the long-tail load.
    let mut q = vec![item(1, 1, short_path, now, 1)];
    for k in 0..8u64 {
        q.push(item(100 + k, 1, long_path, now, 0));
    }
    let batch = form_batch(&mut q, BatchPolicy::TopoAware, 1, true, SlotUnit::Rows);
    assert_ne!(batch[0].query, 1);
}

/// (b) + (d): on a heterogeneous seeded Poisson trace (mixed short-RAG /
/// long-multistep decodes, one LLM instance so queueing is visible), WCP
/// ordering strictly beats arrival ordering at the tail — and produces
/// bit-identical outputs (scheduling moves work in time, never changes
/// results).
#[test]
fn wcp_cuts_p95_on_heterogeneous_trace_with_identical_outputs() {
    let _g = serial();

    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.llms[0].instances = 1;
    let platform = Platform::start(&cfg).unwrap();

    let n = 40;
    let (off, on) = run_wcp_comparison(&platform, n, 150.0, 0x9C4).unwrap();
    platform.shutdown();

    assert_eq!(off.latencies_ms.len(), n);
    assert_eq!(on.latencies_ms.len(), n);
    assert!(
        on.e2e_ms.p95 < off.e2e_ms.p95,
        "WCP p95 {:.1} ms should beat arrival-order p95 {:.1} ms",
        on.e2e_ms.p95,
        off.e2e_ms.p95
    );
    assert_eq!(on.outputs.len(), n);
    assert_eq!(
        on.outputs, off.outputs,
        "WCP must not change any query's output, only its timing"
    );
}

/// Regression (PR 4 gap): the prefix-residency discount on a queued
/// prefill's critical-path stamp used to be applied at enqueue only, so
/// residency gained *while the item waited* (another query's prefill
/// computed the prefix) never reached its priority.  The dispatch-time
/// re-discount hook applies it as soon as residency appears — and at
/// most once per item.
#[test]
fn queued_prefill_is_rediscounted_when_its_prefix_becomes_resident() {
    let now = Instant::now();
    let instr: Vec<i32> = (0..16).map(|i| 100 + i).collect();
    let fp = prefix_fingerprint(&instr);
    // llm-lite prefill cost: 100 us/token -> a 16-token resident prefix
    // discounts 1600 us off the stamp.
    let prefill_us_per_token = 100.0;

    let mk = |wcp_us: u64| {
        let mut it = item(1, 10, wcp_us, now, 0);
        it.prefix = Some(fp);
        it.tokens = 24;
        it.job = EngineJob::Prefill {
            seq: (1, 0),
            tokens: instr.iter().copied().chain(std::iter::repeat(7).take(8)).collect(),
            offset: 0,
            prefix: Some(fp),
        };
        it
    };

    // Not resident yet: the queued item keeps its full stamp.
    let mut queue = vec![mk(50_000)];
    let n = rediscount_resident_prefixes(&mut queue, |_| false, prefill_us_per_token);
    assert_eq!(n, 0);
    assert_eq!(queue[0].wcp_us, 50_000);
    assert!(!queue[0].wcp_discounted);

    // The prefix becomes resident while the item is already queued: the
    // next dispatch pass discounts the stamp by the prefix's prefill
    // time.
    let n = rediscount_resident_prefixes(&mut queue, |q| q == fp, prefill_us_per_token);
    assert_eq!(n, 1);
    assert_eq!(queue[0].wcp_us, 50_000 - 1_600);
    assert!(queue[0].wcp_discounted);

    // Re-running the hook must not double-discount.
    let n = rediscount_resident_prefixes(&mut queue, |q| q == fp, prefill_us_per_token);
    assert_eq!(n, 0);
    assert_eq!(queue[0].wcp_us, 50_000 - 1_600);

    // Items without a prefix are never touched; the discount saturates
    // at zero instead of underflowing.
    let mut queue = vec![item(2, 20, 5_000, now, 0), mk(100)];
    let n = rediscount_resident_prefixes(&mut queue, |_| true, prefill_us_per_token);
    assert_eq!(n, 1);
    assert_eq!(queue[0].wcp_us, 5_000, "no prefix, no discount");
    assert_eq!(queue[1].wcp_us, 0, "discount saturates at zero");
}

/// WCP is a TopoAware refinement: the TO/PO baselines ignore the flag
/// entirely, so their dispatch order cannot depend on it.
#[test]
fn baselines_ignore_the_wcp_flag() {
    let now = Instant::now();
    for policy in [BatchPolicy::BlindTO, BatchPolicy::PerInvocation] {
        let mk = || vec![item(1, 10, 50_000, now, 5), item(2, 20, 400_000, now, 0)];
        let (mut a, mut b) = (mk(), mk());
        let on: Vec<u64> =
            form_batch(&mut a, policy, 1, true, SlotUnit::Rows).iter().map(|i| i.query).collect();
        let off: Vec<u64> =
            form_batch(&mut b, policy, 1, false, SlotUnit::Rows).iter().map(|i| i.query).collect();
        assert_eq!(on, off, "{policy:?} must not read the wcp flag");
    }
}
