//! Integration tests for the PJRT runtime bridge against real AOT artifacts.
//!
//! Requires `make artifacts` to have populated `artifacts/` (these tests
//! are skipped with a message when the directory is absent so plain
//! `cargo test` still passes in a fresh checkout).

use std::rc::Rc;

use teola::runtime::{HostTensor, Manifest, XlaContext};

fn manifest() -> Option<Rc<Manifest>> {
    let dir = teola::runtime::default_artifacts_dir();
    if !teola::runtime::xla_backend_available() {
        eprintln!("skipping: no artifacts at {dir:?} or XLA crate stubbed");
        return None;
    }
    Some(Rc::new(Manifest::load(dir).expect("manifest parses")))
}

fn kv_zeros(m: &Manifest, variant: &str, batch: usize) -> HostTensor {
    let info = &m.models[variant];
    let shape = vec![
        info.layers,
        2,
        batch,
        info.n_heads,
        info.max_seq,
        info.d_model / info.n_heads,
    ];
    let n = shape.iter().product();
    HostTensor::f32(shape, vec![0.0; n])
}

#[test]
fn manifest_loads_and_indexes() {
    let Some(m) = manifest() else { return };
    assert!(m.models.contains_key("llm-lite"));
    assert!(m.models.contains_key("embedder"));
    assert!(!m.prefill_buckets("llm-lite").is_empty());
    assert!(!m.decode_batches("llm-small").is_empty());
    assert_eq!(m.special.sep, 3);
}

#[test]
fn embedder_produces_unit_norm_vectors() {
    let Some(m) = manifest() else { return };
    let mut ctx = XlaContext::new(m.clone()).unwrap();
    let t = 64usize;
    let tokens: Vec<i32> = (0..t as i32).map(|i| 4 + (i % 100)).collect();
    let mask: Vec<f32> = (0..t).map(|i| if i < 20 { 1.0 } else { 0.0 }).collect();
    let out = ctx
        .run(
            "embedder__embed__b1",
            Some("embedder"),
            &[
                HostTensor::i32(vec![1, t], tokens),
                HostTensor::f32(vec![1, t], mask),
            ],
        )
        .unwrap();
    let emb = out[0].to_vec::<f32>().unwrap();
    assert_eq!(emb.len(), m.models["embedder"].d_model);
    let norm: f32 = emb.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-3, "norm={norm}");
}

#[test]
fn chunked_prefill_matches_monolithic_across_buckets() {
    let Some(m) = manifest() else { return };
    let mut ctx = XlaContext::new(m.clone()).unwrap();
    let variant = "llm-lite";
    let c = 16usize;
    let tokens: Vec<i32> = (0..c as i32).map(|i| 10 + i * 3 % 500).collect();

    // Monolithic: one c16 prefill with length 16.
    let out_mono = ctx
        .run(
            "llm-lite__prefill__b1_c16",
            Some(variant),
            &[
                HostTensor::i32(vec![1, c], tokens.clone()),
                kv_zeros(&m, variant, 1),
                HostTensor::i32(vec![1], vec![0]),
                HostTensor::i32(vec![1], vec![c as i32]),
            ],
        )
        .unwrap();
    let logits_mono = out_mono[1].to_vec::<f32>().unwrap();
    let next_mono = out_mono[2].to_vec::<i32>().unwrap();

    // Chunked: two c16 prefills of 8 valid tokens each (padded).
    let mut chunk1 = tokens[..8].to_vec();
    chunk1.resize(c, 0);
    let out1 = ctx
        .run(
            "llm-lite__prefill__b1_c16",
            Some(variant),
            &[
                HostTensor::i32(vec![1, c], chunk1),
                kv_zeros(&m, variant, 1),
                HostTensor::i32(vec![1], vec![0]),
                HostTensor::i32(vec![1], vec![8]),
            ],
        )
        .unwrap();
    let kv_mid = out1[0].to_vec::<f32>().unwrap();
    let kv_shape = kv_zeros(&m, variant, 1).shape().to_vec();

    let mut chunk2 = tokens[8..].to_vec();
    chunk2.resize(c, 0);
    let out2 = ctx
        .run(
            "llm-lite__prefill__b1_c16",
            Some(variant),
            &[
                HostTensor::i32(vec![1, c], chunk2),
                HostTensor::f32(kv_shape, kv_mid),
                HostTensor::i32(vec![1], vec![8]),
                HostTensor::i32(vec![1], vec![8]),
            ],
        )
        .unwrap();
    let logits_chunked = out2[1].to_vec::<f32>().unwrap();
    let next_chunked = out2[2].to_vec::<i32>().unwrap();

    assert_eq!(next_mono, next_chunked);
    let max_err = logits_mono
        .iter()
        .zip(&logits_chunked)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-2, "prefill decomposition drift: {max_err}");
}

#[test]
fn decode_step_extends_prefill() {
    let Some(m) = manifest() else { return };
    let mut ctx = XlaContext::new(m.clone()).unwrap();
    let variant = "llm-lite";
    let c = 16usize;
    let tokens: Vec<i32> = (0..c as i32).map(|i| 5 + i).collect();

    let out = ctx
        .run(
            "llm-lite__prefill__b1_c16",
            Some(variant),
            &[
                HostTensor::i32(vec![1, c], tokens),
                kv_zeros(&m, variant, 1),
                HostTensor::i32(vec![1], vec![0]),
                HostTensor::i32(vec![1], vec![c as i32]),
            ],
        )
        .unwrap();
    let kv = out[0].to_vec::<f32>().unwrap();
    let next = out[2].to_vec::<i32>().unwrap();
    let kv_shape = kv_zeros(&m, variant, 1).shape().to_vec();

    let dec = ctx
        .run(
            "llm-lite__decode__b1",
            Some(variant),
            &[
                HostTensor::i32(vec![1], next.clone()),
                HostTensor::f32(kv_shape, kv),
                HostTensor::i32(vec![1], vec![c as i32]),
            ],
        )
        .unwrap();
    let logits = dec[1].to_vec::<f32>().unwrap();
    assert_eq!(logits.len(), m.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
    let next2 = dec[2].to_vec::<i32>().unwrap();
    assert!((0..m.vocab as i32).contains(&next2[0]));

    // Determinism: the same decode twice gives the same token.
    let kv2 = dec[0].to_vec::<f32>().unwrap();
    let kv_shape2 = kv_zeros(&m, variant, 1).shape().to_vec();
    let dec_b = ctx
        .run(
            "llm-lite__decode__b1",
            Some(variant),
            &[
                HostTensor::i32(vec![1], next2.clone()),
                HostTensor::f32(kv_shape2.clone(), kv2.clone()),
                HostTensor::i32(vec![1], vec![c as i32 + 1]),
            ],
        )
        .unwrap();
    let dec_c = ctx
        .run(
            "llm-lite__decode__b1",
            Some(variant),
            &[
                HostTensor::i32(vec![1], next2),
                HostTensor::f32(kv_shape2, kv2),
                HostTensor::i32(vec![1], vec![c as i32 + 1]),
            ],
        )
        .unwrap();
    assert_eq!(
        dec_b[2].to_vec::<i32>().unwrap(),
        dec_c[2].to_vec::<i32>().unwrap()
    );
}

#[test]
fn reranker_scores_are_finite_and_batch_consistent() {
    let Some(m) = manifest() else { return };
    let mut ctx = XlaContext::new(m.clone()).unwrap();
    let t = m.models["reranker"].max_seq;
    let mk = |seed: i32| -> Vec<i32> { (0..t as i32).map(|i| 4 + (i * seed) % 700).collect() };

    let mut tokens = Vec::new();
    for s in 1..=4 {
        tokens.extend(mk(s));
    }
    let mask = vec![1f32; 4 * t];
    let out = ctx
        .run(
            "reranker__score__b4",
            Some("reranker"),
            &[
                HostTensor::i32(vec![4, t], tokens.clone()),
                HostTensor::f32(vec![4, t], mask),
            ],
        )
        .unwrap();
    let scores = out[0].to_vec::<f32>().unwrap();
    assert_eq!(scores.len(), 4);
    assert!(scores.iter().all(|s| s.is_finite()));

    let out1 = ctx
        .run(
            "reranker__score__b1",
            Some("reranker"),
            &[
                HostTensor::i32(vec![1, t], mk(3)),
                HostTensor::f32(vec![1, t], vec![1f32; t]),
            ],
        )
        .unwrap();
    let s1 = out1[0].to_vec::<f32>().unwrap()[0];
    assert!((s1 - scores[2]).abs() < 1e-3, "{s1} vs {}", scores[2]);
}
