//! PR6 persistent KV residency: the dual-ledger lifecycle (reserved →
//! resident → freed), watermark preemption, and the instance-protocol
//! bugfixes that rode along — failed run-to-completion batches must
//! surface `Failed` per job, segment completions must route to their
//! owning job (not any job of the query), and bookkeeping ops must
//! bypass budget admission.

use std::collections::HashMap;
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use teola::engines::instance::{
    spawn_stepped_instance, BatchExecutor, RunToCompletion, StepExecutor,
};
use teola::engines::llm::SeqStore;
use teola::engines::sim::{reset_residency_stats, residency_stats, SimLlmExecutor};
use teola::engines::{Batch, Completion, EngineJob, JobOutput, SegmentSpec};
use teola::error::TeolaError;
use teola::scheduler::{Platform, PlatformConfig};
use teola::serving::run_residency_comparison;

mod common;
use common::{ctx, decode_job, prefill_job, run_to_idle, sim_llm_exec_with_slots, EOS, SEP};

/// Sim executor with a KV budget and residency watermark bound.
fn residency_exec(cap: usize, watermark_pct: usize) -> SimLlmExecutor {
    let (exec, _store, _slots) = sim_llm_exec_with_slots(0);
    exec.with_kv_budget(Arc::new(AtomicUsize::new(cap)))
        .with_kv_watermark(Arc::new(AtomicUsize::new(watermark_pct)))
}

/// Tentpole lifecycle: a prefill's charge moves reserved → resident at
/// retirement (occupancy unchanged), a warm decode admits at 1 token and
/// grows per iteration, and `FreeQuery` — and only `FreeQuery` — drains
/// the residency back to zero.
#[test]
fn residency_lifecycle_reserved_to_resident_to_freed() {
    let _guard = common::serial(); // sim residency counters are process-global
    let mut exec = residency_exec(1000, 70);
    let (tx, _rx) = channel();

    let bounced = exec.admit(vec![(ctx(1, 1, tx.clone()), prefill_job(1, 0, 16))]);
    assert!(bounced.is_empty());
    assert_eq!(exec.kv_reserved(), 16, "prefill reserves its prompt at admit");
    assert_eq!(exec.kv_resident_total(), 0);

    let mut out = Vec::new();
    run_to_idle(&mut exec, &mut out, 64);
    assert_eq!(exec.kv_reserved(), 0, "retirement drains the reservation ledger");
    assert_eq!(exec.kv_resident_total(), 16, "…into the resident ledger");
    assert_eq!(exec.kv_occupied(), 16, "commit moves tokens, never mints them");

    // Warm decode: the sequence's KV is resident, so admission charges a
    // single token (growth is reserved per iteration, not max_new up
    // front — the whole point of the residency mode).
    let bounced = exec.admit(vec![(ctx(1, 5, tx.clone()), decode_job(1, 5, 0, 8))]);
    assert!(bounced.is_empty());
    assert_eq!(exec.kv_reserved(), 1, "warm decode admits at one token");
    run_to_idle(&mut exec, &mut out, 64);
    assert_eq!(exec.kv_reserved(), 0);
    assert_eq!(
        exec.kv_resident_total(),
        24,
        "8 decoded tokens joined the 16 prefilled ones in residency"
    );

    // FreeQuery is the release point of the whole query's residency.
    let bounced = exec.admit(vec![(ctx(1, usize::MAX, tx), EngineJob::FreeQuery { query: 1 })]);
    assert!(bounced.is_empty());
    run_to_idle(&mut exec, &mut out, 8);
    assert_eq!(exec.kv_occupied(), 0, "FreeQuery drains both ledgers to zero");
    assert_eq!(exec.kv_resident_total(), 0);
}

/// Satellite-3 regression: bookkeeping jobs (FreeQuery / ClonePrefix)
/// must never be bounced by budget admission — they *release* memory (or
/// are free), and bouncing them wedges cleanup behind the very pressure
/// it would relieve.  A regular job in the same ledger state is bounced.
#[test]
fn bookkeeping_jobs_bypass_budget_admission() {
    let _guard = common::serial(); // sim residency counters are process-global
    let mut exec = residency_exec(10, 100);
    let (tx, _rx) = channel();

    // Fill the ledger: an in-flight 8-token prefill against capacity 10.
    let bounced = exec.admit(vec![(ctx(7, 1, tx.clone()), prefill_job(7, 0, 8))]);
    assert!(bounced.is_empty());
    // A second prefill does not fit and the ledger is not idle → bounced.
    let bounced = exec.admit(vec![(ctx(8, 1, tx.clone()), prefill_job(8, 0, 8))]);
    assert_eq!(bounced.len(), 1, "over-budget prefill is bounced");

    // Same ledger state: bookkeeping ops are admitted unconditionally.
    let bounced = exec.admit(vec![
        (
            ctx(9, 2, tx.clone()),
            EngineJob::ClonePrefix { src: (7, 0), dst: (9, 0), len: 4 },
        ),
        (ctx(9, usize::MAX, tx.clone()), EngineJob::FreeQuery { query: 9 }),
    ]);
    assert!(bounced.is_empty(), "bookkeeping must bypass budget admission");

    let mut out = Vec::new();
    run_to_idle(&mut exec, &mut out, 64);
    // Both bookkeeping ops completed (Unit outputs) alongside the prefill.
    let units = out.iter().filter(|c| matches!(c.output, JobOutput::Unit)).count();
    assert_eq!(units, 2);

    let (tx2, _rx2) = channel();
    let bounced =
        exec.admit(vec![(ctx(7, usize::MAX, tx2), EngineJob::FreeQuery { query: 7 })]);
    assert!(bounced.is_empty());
    run_to_idle(&mut exec, &mut out, 8);
    assert_eq!(exec.kv_occupied(), 0);
}

/// Watermark preemption: crossing `capacity * watermark / 100` evicts the
/// lowest-WCP-priority idle resident sequence (swap-out: the ledger
/// charge is freed, the host-side store entry survives), a later decode
/// on the victim re-charges its swap-in, and every query still completes
/// with deterministic outputs.
#[test]
fn watermark_preemption_evicts_and_queries_still_complete() {
    let _guard = common::serial(); // residency_stats() is process-global
    let mut exec = residency_exec(100, 50); // preemption limit: 50 tokens
    reset_residency_stats();
    let (tx, _rx) = channel();

    // Four 16-token prefills from four queries, ascending WCP priority:
    // q1 is the least urgent and must be the first eviction victim.
    for q in 1..=4u64 {
        let mut c = ctx(q, 1, tx.clone());
        c.wcp_us = q * 10;
        let bounced = exec.admit(vec![(c, prefill_job(q, 0, 16))]);
        assert!(bounced.is_empty());
    }
    let mut out = Vec::new();
    run_to_idle(&mut exec, &mut out, 64);
    assert_eq!(exec.kv_resident_total(), 64, "all four prefills resident");
    assert_eq!(residency_stats().1, 0, "no step has run above the watermark yet");

    // A warm decode on q4 pushes occupancy to 65 > 50: the next step must
    // preempt idle residency (q1 first — lowest priority; q4 is active).
    let mut c = ctx(4, 5, tx.clone());
    c.wcp_us = 40;
    let bounced = exec.admit(vec![(c, decode_job(4, 5, 0, 4))]);
    assert!(bounced.is_empty());
    run_to_idle(&mut exec, &mut out, 64);
    let evictions = residency_stats().1;
    assert!(evictions >= 1, "watermark crossing must evict at least one sequence");
    assert!(
        exec.kv_resident_total() < 64,
        "eviction freed ledger charge ({} resident)",
        exec.kv_resident_total()
    );

    // Swap-in recharge: q1 was evicted (lowest priority), so a decode on
    // its sequence must re-charge the full swapped-out KV length (16
    // prefilled tokens) plus the first new token.
    let bounced = exec.admit(vec![(ctx(1, 6, tx.clone()), decode_job(1, 6, 0, 4))]);
    assert!(bounced.is_empty());
    assert_eq!(exec.kv_reserved(), 17, "cold decode re-charges swap-in + 1");
    run_to_idle(&mut exec, &mut out, 64);

    // Every query completed: 4 prefill next-token completions + 2 decode
    // finals, all with real outputs (eviction is swap-out only — the
    // store survives, so the decodes completed despite preemption).
    drop(tx);
    assert_eq!(out.len(), 6, "4 prefills + 2 decode finals");
    assert!(out.iter().all(|c| !matches!(c.output, JobOutput::Failed(_))));
    let decode_finals = out
        .iter()
        .filter(|c| matches!(c.output, JobOutput::TokenBatch(_)))
        .count();
    assert_eq!(decode_finals, 2);

    // Cleanup drains everything the evictions left behind.
    let (tx2, _rx2) = channel();
    for q in 1..=4u64 {
        let bounced =
            exec.admit(vec![(ctx(q, usize::MAX, tx2.clone()), EngineJob::FreeQuery { query: q })]);
        assert!(bounced.is_empty());
    }
    run_to_idle(&mut exec, &mut out, 8);
    assert_eq!(exec.kv_occupied(), 0, "dual ledger conserves: everything returned");
}

/// A run-to-completion executor whose every batch fails.
struct FailingExec;

impl BatchExecutor for FailingExec {
    fn execute(
        &mut self,
        _batch: Batch,
        _emit: &mut dyn FnMut(Completion),
    ) -> teola::error::Result<()> {
        Err(TeolaError::Engine("injected failure".into()))
    }
}

/// Satellite-1 regression: when a run-to-completion batch fails, every
/// job in it must receive a `Failed` completion — silently retiring the
/// rows leaves the waiting query runners blocked forever.
#[test]
fn failed_batch_surfaces_failed_output_per_job() {
    let mut exec = RunToCompletion::new(FailingExec);
    let (tx, _rx) = channel();
    let bounced = exec.admit(vec![
        (ctx(1, 3, tx.clone()), EngineJob::ToolCall { name: "a".into(), cost_us: 0 }),
        (ctx(2, 4, tx), EngineJob::ToolCall { name: "b".into(), cost_us: 0 }),
    ]);
    assert!(bounced.is_empty());

    let mut out = Vec::new();
    let outcome = exec.step(&mut |c| out.push(c)).unwrap();
    assert_eq!(outcome.retired_rows, 2, "failed rows still retire (load accounting)");
    assert_eq!(out.len(), 2, "every job of the failed batch hears about it");
    for c in &out {
        match &c.output {
            JobOutput::Failed(msg) => {
                assert!(msg.contains("injected failure"), "got {msg:?}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }
    let mut who: Vec<(u64, usize)> = out.iter().map(|c| (c.query, c.node)).collect();
    who.sort_unstable();
    assert_eq!(who, vec![(1, 3), (2, 4)], "failure routed per job, not per batch");
    assert_eq!(exec.resident(), 0);
}

/// Satellite-2 regression: two decode jobs of the *same query* resident
/// together — each job's streamed segment completions must reach its own
/// reply channel.  The old fallback routed any unmatched completion to
/// the first job of the query, so job B's segments leaked to job A.
#[test]
fn segment_completions_route_to_owning_job() {
    let _guard = common::serial(); // sim residency counters are process-global
    common::device_off();
    let store: SeqStore = Arc::new(Mutex::new(HashMap::new()));
    let slots = Arc::new(AtomicUsize::new(0));
    let (ev_tx, ev_rx) = channel();
    let (ready_tx, ready_rx) = channel();
    let store_c = store.clone();
    let inst = spawn_stepped_instance(
        0,
        "route-regression".into(),
        move || {
            Ok::<_, TeolaError>(SimLlmExecutor::new("llm-lite", store_c, SEP, EOS, 1024, slots))
        },
        ev_tx,
        ready_tx,
    );
    ready_rx.recv().expect("instance ready");

    let recv = |rx: &std::sync::mpsc::Receiver<Completion>| {
        rx.recv_timeout(Duration::from_secs(10)).expect("completion within bound")
    };

    // Seed both sequences of query 5.
    let (ptx, prx) = channel();
    inst.sender
        .send(Batch {
            jobs: vec![
                (ctx(5, 1, ptx.clone()), prefill_job(5, 0, 8)),
                (ctx(5, 2, ptx.clone()), prefill_job(5, 1, 8)),
            ],
        })
        .unwrap();
    recv(&prx);
    recv(&prx);

    // Two same-query decodes with disjoint segment marker nodes; each
    // job carries its own reply channel.
    let (tx_a, rx_a) = channel();
    let (tx_b, rx_b) = channel();
    let decode = |seq: u32, marker: usize| EngineJob::Decode {
        seq: (5, seq),
        first_token: 42,
        segments: vec![SegmentSpec { node: marker, len: 3 }],
    };
    inst.sender
        .send(Batch {
            jobs: vec![
                (ctx(5, 10, tx_a), decode(0, 11)),
                (ctx(5, 20, tx_b), decode(1, 21)),
            ],
        })
        .unwrap();

    // Job A: streamed segment at marker 11, final at node 10 — and
    // nothing of job B's. Job B symmetric.
    let a1 = recv(&rx_a);
    let a2 = recv(&rx_a);
    let mut a_nodes = vec![a1.node, a2.node];
    a_nodes.sort_unstable();
    assert_eq!(a_nodes, vec![10, 11], "job A's completions stay on job A's channel");
    let b1 = recv(&rx_b);
    let b2 = recv(&rx_b);
    let mut b_nodes = vec![b1.node, b2.node];
    b_nodes.sort_unstable();
    assert_eq!(b_nodes, vec![20, 21], "job B's segments must not leak to job A");
    assert!(rx_a.try_recv().is_err(), "no extra completions on A");
    assert!(rx_b.try_recv().is_err(), "no extra completions on B");

    drop(inst.sender);
    inst.handle.join().expect("instance thread exits");
    drop(ev_rx);
}

/// PR6 acceptance bar: on the mixed short/long-decode trace at a tight
/// KV budget, residency-on admits strictly deeper executor concurrency
/// at equal-or-better p95, with bit-identical outputs (eviction is
/// swap-out only and synthesis is position-addressed).
#[test]
fn residency_admits_deeper_at_equal_or_better_p95() {
    let _guard = common::serial();
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.llms[0].instances = 1;
    cfg.warm = false;
    let platform = Platform::start(&cfg).expect("platform");
    let res = run_residency_comparison(&platform, 40, 200.0, 0x9C6).expect("trace");
    platform.shutdown();

    assert!(
        res.peak_rows_on > res.peak_rows_off,
        "residency must admit strictly deeper concurrency: on {} vs off {}",
        res.peak_rows_on,
        res.peak_rows_off
    );
    assert!(
        res.on.e2e_ms.p95 <= res.off.e2e_ms.p95,
        "residency-on p95 {:.1} ms must not regress off p95 {:.1} ms",
        res.on.e2e_ms.p95,
        res.off.e2e_ms.p95
    );
    assert_eq!(
        res.on.outputs, res.off.outputs,
        "outputs must be bit-identical across the residency modes"
    );
}
