//! Executor-level tests of the iteration-level (continuous-batching)
//! protocol on the simulated LLM engine: mid-flight admission, per-row
//! retirement, starvation-freedom, and output determinism with admission
//! enabled vs disabled.  Executor setup comes from the shared harness in
//! `tests/common/`.

mod common;

use std::sync::mpsc::channel;

use common::{ctx, decode_job, prefill_job, run_to_idle, sim_llm_exec};
use teola::engines::instance::StepExecutor;
use teola::engines::{Completion, JobOutput};

#[test]
fn late_short_decode_joins_inflight_long_and_finishes_first() {
    let (mut exec, _store) = sim_llm_exec(0);
    let (tx, _rx) = channel();

    // Long decode: 96 planned tokens on query 1.
    exec.admit(vec![(ctx(1, 10, tx.clone()), prefill_job(1, 0, 12))]);
    exec.step(&mut |_| {}).unwrap(); // prefill completes
    exec.admit(vec![(ctx(1, 11, tx.clone()), decode_job(1, 11, 0, 96))]);

    // Let the long decode run a few iterations alone.
    for _ in 0..5 {
        exec.step(&mut |_| {}).unwrap();
    }

    // A short (8-token) decode arrives late and joins mid-flight.
    exec.admit(vec![(ctx(2, 20, tx.clone()), prefill_job(2, 0, 6))]);
    exec.step(&mut |_| {}).unwrap(); // chunked-prefill step (decode pauses one step)
    exec.admit(vec![(ctx(2, 21, tx), decode_job(2, 21, 0, 8))]);

    let mut finals: Vec<(u64, usize)> = Vec::new();
    let mut out = Vec::new();
    run_to_idle(&mut exec, &mut out, 500);
    for c in &out {
        if matches!(c.output, JobOutput::TokenBatch(_)) {
            finals.push((c.query, c.node));
        }
    }
    // Both rows retire, and the late short decode finishes strictly
    // before the long decode's batch tail.
    assert_eq!(finals.len(), 2, "finals: {finals:?}");
    assert_eq!(finals[0], (2, 21), "short decode should retire first");
    assert_eq!(finals[1], (1, 11));
}

#[test]
fn every_admitted_row_retires_under_staggered_admission() {
    let (mut exec, _store) = sim_llm_exec(0);
    let (tx, _rx) = channel();

    // Admit 12 queries with mixed decode lengths, one every other step,
    // while earlier decodes are still in flight.
    let mut expected: Vec<(u64, usize)> = Vec::new();
    for q in 0..12u64 {
        let len = 4 + (q as usize % 7) * 9; // 4..=58 tokens
        exec.admit(vec![(ctx(q, 100, tx.clone()), prefill_job(q, 0, 5))]);
        exec.step(&mut |_| {}).unwrap();
        exec.admit(vec![(ctx(q, 101, tx.clone()), decode_job(q, 101, 0, len))]);
        expected.push((q, 101));
        exec.step(&mut |_| {}).unwrap();
        exec.step(&mut |_| {}).unwrap();
    }

    let mut out = Vec::new();
    run_to_idle(&mut exec, &mut out, 2_000);
    let mut finals: Vec<(u64, usize)> = out
        .iter()
        .filter(|c| matches!(c.output, JobOutput::TokenBatch(_)))
        .map(|c| (c.query, c.node))
        .collect();
    finals.sort_unstable();
    expected.sort_unstable();
    assert_eq!(finals, expected, "every admitted decode row must retire exactly once");
    assert_eq!(exec.resident(), 0);
}

#[test]
fn outputs_identical_with_and_without_midflight_admission() {
    // The same set of (prefill, decode) jobs, executed (a) admitted all
    // upfront (run-to-completion shape) and (b) admitted one at a time
    // between iterations (continuous shape), must produce identical final
    // outputs: sim tokens are content-addressed per sequence, never
    // functions of batch composition.
    let jobs: Vec<(u64, usize, usize)> =
        (0..6u64).map(|q| (q, 50 + q as usize, 6 + q as usize * 11)).collect();

    let collect_finals = |staggered: bool| -> Vec<(u64, usize, Vec<Vec<i32>>)> {
        let (mut exec, _store) = sim_llm_exec(0);
        let (tx, _rx) = channel();
        // Identical prefills first so every sequence has the same base.
        for &(q, node, _) in &jobs {
            exec.admit(vec![(ctx(q, node, tx.clone()), prefill_job(q, 0, 10))]);
        }
        let mut out: Vec<Completion> = Vec::new();
        run_to_idle(&mut exec, &mut out, 100);

        let mut out = Vec::new();
        for &(q, node, len) in &jobs {
            exec.admit(vec![(ctx(q, node, tx.clone()), decode_job(q, node, 0, len))]);
            if staggered {
                // Interleave admissions with live iterations.
                exec.step(&mut |c| out.push(c)).unwrap();
                exec.step(&mut |c| out.push(c)).unwrap();
            }
        }
        run_to_idle(&mut exec, &mut out, 2_000);
        let mut finals: Vec<(u64, usize, Vec<Vec<i32>>)> = out
            .into_iter()
            .filter_map(|c| match c.output {
                JobOutput::TokenBatch(segs) => Some((c.query, c.node, segs)),
                _ => None,
            })
            .collect();
        finals.sort();
        finals
    };

    let upfront = collect_finals(false);
    let staggered = collect_finals(true);
    assert_eq!(upfront.len(), jobs.len());
    assert_eq!(
        upfront, staggered,
        "decode outputs must not depend on mid-flight admission"
    );
}
