//! Deterministic scheduler-trace harness shared by the scheduler-level
//! integration tests (`wcp_scheduling.rs`, `prefix_routing.rs`,
//! `continuous_batching.rs`, `sim_serving.rs`) — replaces their
//! copy-pasted Poisson/trace/executor setup.
//!
//! Everything here is seeded and sim-backed: the same (seed, template,
//! query-id) always reproduces the same trace and outputs, so on/off
//! scheduler comparisons are apples-to-apples.

#![allow(dead_code)] // each test binary uses its own slice of the harness

use std::collections::HashMap;
use std::sync::atomic::AtomicUsize;
use std::sync::{Arc, Mutex, MutexGuard, Once};
use std::time::Instant;

use teola::bench::{one_shot_template, prepared_graphs};
use teola::engines::llm::SeqStore;
use teola::engines::sim::SimLlmExecutor;
use teola::engines::{Completion, EngineJob, RequestCtx, SegmentSpec};
use teola::graph::egraph::EGraph;
use teola::graph::template::WorkflowTemplate;

pub const SEP: i32 = 3;
pub const EOS: i32 = 2;

/// Serialize the platform tests within one test binary: the serving
/// comparisons are timing-sensitive and must not compete for cores.
pub fn serial() -> MutexGuard<'static, ()> {
    static SERIAL: Mutex<()> = Mutex::new(());
    SERIAL.lock().unwrap()
}

/// Disable the device-occupancy model for executor-level tests (charging
/// is asserted via token counters, not wall time).  Set exactly once:
/// concurrent setenv calls are a data race.
pub fn device_off() {
    static DEVICE_OFF: Once = Once::new();
    DEVICE_OFF.call_once(|| std::env::set_var("TEOLA_DEVICE_OFF", "1"));
}

/// Standalone sim LLM executor (llm-lite, raw CPU pacing) with the given
/// resident-prefix budget, plus its sequence store.
pub fn sim_llm_exec(prefix_slots: usize) -> (SimLlmExecutor, SeqStore) {
    let (exec, store, _slots) = sim_llm_exec_with_slots(prefix_slots);
    (exec, store)
}

/// [`sim_llm_exec`] also returning the shared `prefix_slots` capacity
/// handle, for tests that retune the budget mid-run.
pub fn sim_llm_exec_with_slots(
    prefix_slots: usize,
) -> (SimLlmExecutor, SeqStore, Arc<AtomicUsize>) {
    device_off();
    let store: SeqStore = Arc::new(Mutex::new(HashMap::new()));
    let slots = Arc::new(AtomicUsize::new(prefix_slots));
    (
        SimLlmExecutor::new("llm-lite", store.clone(), SEP, EOS, 1024, slots.clone()),
        store,
        slots,
    )
}

/// Request context for direct executor tests.
pub fn ctx(query: u64, node: usize, reply: std::sync::mpsc::Sender<Completion>) -> RequestCtx {
    RequestCtx {
        query,
        node,
        depth: 0,
        arrival: Instant::now(),
        wcp_us: 0,
        kv_tokens: 0,
        wcp_discounted: false,
        tenant: teola::engines::UNTENANTED,
        reply,
        successors: Vec::new(),
    }
}

/// A from-scratch prefill job of `n_tokens` identical tokens.
pub fn prefill_job(q: u64, seq: u32, n_tokens: usize) -> EngineJob {
    EngineJob::Prefill { seq: (q, seq), tokens: vec![7; n_tokens], offset: 0, prefix: None }
}

/// A single-segment decode job of `len` tokens streamed to `node`.
pub fn decode_job(q: u64, node: usize, seq: u32, len: usize) -> EngineJob {
    EngineJob::Decode {
        seq: (q, seq),
        first_token: 42,
        segments: vec![SegmentSpec { node, len }],
    }
}

/// Step a sim executor until it drains, recording every completion;
/// panics if the resident set fails to drain within `max_steps`
/// (starvation guard).
pub fn run_to_idle(exec: &mut SimLlmExecutor, out: &mut Vec<Completion>, max_steps: usize) {
    use teola::engines::instance::StepExecutor;
    let mut steps = 0;
    while exec.resident() > 0 {
        exec.step(&mut |c| out.push(c)).unwrap();
        steps += 1;
        assert!(steps <= max_steps, "executor failed to drain in {max_steps} steps");
    }
}

/// Instruction-heavy one-shot workflow: a 64-token shared instruction
/// template dominates each query's prefill (the prefix-routing shape).
pub fn instr_heavy_template(instr_name: &str, llm: &str, out_tokens: usize) -> WorkflowTemplate {
    one_shot_template(llm, instr_name, 64, out_tokens)
}

/// Build `n` optimized one-shot e-graphs whose decode length is chosen
/// per query index (mixed short/long workloads).
pub fn prepared_with_tokens(
    n: usize,
    seed: u64,
    out_tokens: impl Fn(usize) -> usize,
) -> Vec<(EGraph, u64)> {
    prepared_graphs(n, seed, |i| one_shot_template("llm-lite", "load", 12, out_tokens(i)))
}

/// Build `n` optimized one-shot e-graphs with a fixed decode length.
pub fn prepared_one_shot(n: usize, out_tokens: usize, seed: u64) -> Vec<(EGraph, u64)> {
    prepared_with_tokens(n, seed, |_| out_tokens)
}

/// Build `n` optimized instruction-heavy e-graphs; queries alternate
/// between two instruction templates (two distinct shared prefixes).
pub fn prepared_instr_heavy(n: usize, seed: u64) -> Vec<(EGraph, u64)> {
    prepared_graphs(n, seed, |i| {
        let name = if i % 2 == 0 { "instr-even" } else { "instr-odd" };
        instr_heavy_template(name, "llm-lite", 4 + i % 3)
    })
}
