//! Property-based invariants over the orchestration core (mini-proptest:
//! seeded random cases, replayable on failure).
//!
//! Invariants (DESIGN.md §5):
//!  (i)   p-graph construction preserves template reachability;
//!  (ii)  passes never create cycles and preserve data-dependency closure;
//!  (iii) topology-aware batching never exceeds the slot budget and never
//!        starves (any non-empty queue yields progress);
//!  (iv)  the object store delivers exactly once;
//!  (v)   KV pack/unpack round-trips for arbitrary geometry.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use teola::engines::llm::{pack_kv, unpack_kv, LlmDims, SeqState};
use teola::engines::profile::ProfileRegistry;
use teola::engines::{EngineJob, SeqId};
use teola::graph::passes::{pass1_prune, pass3_prefill_split, pass4_decode_pipeline};
use teola::graph::pgraph::{build_pgraph, instr_tokens, PGraph};
use teola::graph::primitive::{DataRef, PayloadSpec, PrimKind};
use teola::graph::template::*;
use teola::graph::{run_passes, OptFlags};
use teola::engines::kv_budget::KvBudget;
use teola::scheduler::object_store::ObjectStore;
use teola::scheduler::{form_batch, BatchPolicy, QueueItem, SlotUnit, WcpTracker};
use teola::util::proptest::{check, prop_assert, vec_of};
use teola::util::rng::Rng;

/// Random but well-formed workflow template + query config.
fn random_workflow(rng: &mut Rng) -> (WorkflowTemplate, QueryConfig) {
    let mut t = WorkflowTemplate::new("prop");
    let with_docs = rng.chance(0.7);
    let mut chain: Vec<usize> = Vec::new();

    let mut search_comp = None;
    if with_docs {
        let idx = t.add(Component {
            name: "idx".into(),
            kind: ComponentKind::Indexing,
            engine: "embedder".into(),
            batchable: true,
            splittable: false,
        });
        let qe = t.add(Component {
            name: "qe".into(),
            kind: ComponentKind::Embedding { of: EmbedSource::Question },
            engine: "embedder".into(),
            batchable: true,
            splittable: false,
        });
        let se = t.add(Component {
            name: "se".into(),
            kind: ComponentKind::VectorSearching { top_k: rng.range_usize(1, 6) },
            engine: "vdb".into(),
            batchable: false,
            splittable: false,
        });
        chain.extend([idx, qe, se]);
        search_comp = Some(se);
    }
    let expansion = rng.chance(0.5);
    let mut expand_comp = None;
    if expansion {
        let ex = t.add(Component {
            name: "expand".into(),
            kind: ComponentKind::LlmGenerate {
                variant: "llm-lite".into(),
                mode: SynthesisMode::OneShot,
                prompt: vec![
                    PromptPart::Instruction(instr_tokens("expand", rng.range_usize(4, 30))),
                    PromptPart::Question,
                ],
                out_tokens: rng.range_usize(6, 30),
                segments: rng.range_usize(2, 5),
                fan: 1,
            },
            engine: "llm-lite".into(),
            batchable: false,
            splittable: true,
        });
        chain.push(ex);
        expand_comp = Some(ex);
    }
    let mode = *teola::util::proptest::pick(
        rng,
        &[SynthesisMode::OneShot, SynthesisMode::Tree, SynthesisMode::Refine],
    );
    let mut prompt = vec![
        PromptPart::Instruction(instr_tokens("qa", rng.range_usize(4, 40))),
        PromptPart::Question,
    ];
    if let Some(se) = search_comp {
        prompt.push(PromptPart::Upstream { component: se, slice: None });
    } else if let Some(ex) = expand_comp {
        prompt.push(PromptPart::Upstream { component: ex, slice: None });
    }
    let needs_ctx = matches!(mode, SynthesisMode::Tree | SynthesisMode::Refine);
    let mode = if needs_ctx && search_comp.is_none() && expand_comp.is_none() {
        SynthesisMode::OneShot
    } else {
        mode
    };
    let syn = t.add(Component {
        name: "syn".into(),
        kind: ComponentKind::LlmGenerate {
            variant: "llm-lite".into(),
            mode,
            prompt,
            out_tokens: rng.range_usize(4, 30),
            segments: 1,
            fan: rng.range_usize(1, 4),
        },
        engine: "llm-lite".into(),
        batchable: false,
        splittable: false,
    });
    chain.push(syn);
    t.chain(&chain);

    let mut q = QueryConfig::example(rng.next_u64());
    q.top_k = rng.range_usize(1, 5);
    let n_chunks = rng.range_usize(1, 30);
    q.doc_chunks = (0..n_chunks)
        .map(|_| (0..rng.range_usize(4, 50)).map(|_| 4 + rng.zipf(0, 1000) as i32).collect())
        .collect();
    (t, q)
}

#[test]
fn pgraph_is_acyclic_and_output_reachable() {
    check(60, |rng| {
        let (t, q) = random_workflow(rng);
        let g = build_pgraph(&t, &q).map_err(|e| e.to_string())?;
        let order = g.topo_order().map_err(|e| e.to_string())?;
        prop_assert(order.len() == g.nodes.len(), "topo covers all nodes")?;
        // Output must be reachable from some source (trivially true if it
        // exists and graph is acyclic; check id validity).
        prop_assert(g.output < g.nodes.len(), "output id valid")
    });
}

#[test]
fn passes_preserve_acyclicity_and_data_deps() {
    let profiles = ProfileRegistry::with_defaults();
    check(60, |rng| {
        let (t, q) = random_workflow(rng);
        let g0 = build_pgraph(&t, &q).map_err(|e| e.to_string())?;
        // Record data-dependency closure over original node ids.
        let flags = match rng.range(0, 4) {
            0 => OptFlags::all(),
            1 => OptFlags::parallelization_only(),
            2 => OptFlags::pipelining_only(),
            _ => OptFlags::none(),
        };
        let n0 = g0.nodes.len();
        let g1 = run_passes(g0, flags, &profiles).map_err(|e| e.to_string())?;
        g1.topo_order().map_err(|e| format!("cycle after passes: {e}"))?;
        prop_assert(g1.nodes.len() >= n0, "passes never drop nodes")?;
        prop_assert(g1.output < g1.nodes.len(), "output survives")?;
        // Depths are consistent: every parent strictly deeper than child.
        let depths = g1.depths();
        for (a, b) in g1.all_edges() {
            prop_assert(depths[a] > depths[b] || depths[a] >= depths[b] + 1,
                format!("depth monotonic on edge {a}->{b}"))?;
        }
        Ok(())
    });
}

/// Every node/slice reference in the graph points at an existing node —
/// payload data refs, hard deps, guards, decode segment targets, output.
fn check_no_dangling(g: &PGraph) -> Result<(), String> {
    let n = g.nodes.len();
    for node in &g.nodes {
        for d in node.payload.deps() {
            if d >= n {
                return Err(format!("node {} payload ref {} out of range", node.id, d));
            }
        }
        if let PayloadSpec::Decode { segments, .. } = &node.payload {
            for (target, len) in segments {
                if *target >= n {
                    return Err(format!("node {} segment target {target} dangling", node.id));
                }
                if *len == 0 {
                    return Err(format!("node {} has an empty decode segment", node.id));
                }
            }
        }
        for &h in &node.hard_deps {
            if h >= n {
                return Err(format!("node {} hard dep {h} out of range", node.id));
            }
        }
        if let Some((gd, _)) = node.guard {
            if gd >= n {
                return Err(format!("node {} guard {gd} out of range", node.id));
            }
        }
    }
    for (a, b) in &g.template_edges {
        if *a >= n || *b >= n {
            return Err(format!("template edge {a}->{b} out of range"));
        }
    }
    if g.output >= n {
        return Err(format!("output {} out of range", g.output));
    }
    Ok(())
}

fn count_kind(g: &PGraph, kind: PrimKind) -> usize {
    g.nodes.iter().filter(|n| n.kind == kind).count()
}

#[test]
fn pass3_split_arithmetic_acyclic_no_dangling() {
    check(60, |rng| {
        let (t, q) = random_workflow(rng);
        let mut g = build_pgraph(&t, &q).map_err(|e| e.to_string())?;
        // Pass 3 must be sound with or without dependency pruning first.
        if rng.chance(0.5) {
            pass1_prune(&mut g);
        }
        let n0 = g.nodes.len();
        let prefills_before = count_kind(&g, PrimKind::Prefilling);

        pass3_prefill_split(&mut g);

        let partial = count_kind(&g, PrimKind::PartialPrefilling);
        let full = count_kind(&g, PrimKind::FullPrefilling);
        let prefills_after = count_kind(&g, PrimKind::Prefilling);
        // Each split prefill with g groups adds g-1 partial nodes and
        // converts the original node into the full-prefilling tail.
        prop_assert(
            g.nodes.len() == n0 + partial,
            format!("node growth {} != partial prefills {partial}", g.nodes.len() - n0),
        )?;
        prop_assert(
            full == prefills_before - prefills_after,
            format!("{full} fulls vs {prefills_before} -> {prefills_after} prefills"),
        )?;
        prop_assert(partial >= full, "every full prefill has at least one partial")?;
        g.topo_order().map_err(|e| format!("cycle after pass3: {e}"))?;
        check_no_dangling(&g)?;
        // The full-prefilling tail chains on a partial prefill.
        for node in &g.nodes {
            if node.kind == PrimKind::FullPrefilling {
                prop_assert(
                    node.hard_deps
                        .iter()
                        .any(|&d| g.nodes[d].kind == PrimKind::PartialPrefilling),
                    format!("full prefill {} lost its chain dep", node.id),
                )?;
            }
        }
        Ok(())
    });
}

/// Advanced-RAG shaped template (splittable expansion feeding a batchable
/// embedding) with randomized segment/fan/chunk counts — the shape Pass 4
/// co-splits.
fn advanced_like_workflow(rng: &mut Rng) -> (WorkflowTemplate, QueryConfig) {
    let mut t = WorkflowTemplate::new("adv-prop");
    let idx = t.add(Component {
        name: "idx".into(),
        kind: ComponentKind::Indexing,
        engine: "embedder".into(),
        batchable: true,
        splittable: false,
    });
    let segments = rng.range_usize(2, 6);
    let expand = t.add(Component {
        name: "expand".into(),
        kind: ComponentKind::LlmGenerate {
            variant: "llm-lite".into(),
            mode: SynthesisMode::OneShot,
            prompt: vec![
                PromptPart::Instruction(instr_tokens("expand", rng.range_usize(6, 24))),
                PromptPart::Question,
            ],
            out_tokens: rng.range_usize(6, 30),
            segments,
            fan: 1,
        },
        engine: "llm-lite".into(),
        batchable: false,
        splittable: true,
    });
    let qe = t.add(Component {
        name: "qe".into(),
        kind: ComponentKind::Embedding { of: EmbedSource::Upstream(expand) },
        engine: "embedder".into(),
        batchable: true,
        splittable: false,
    });
    let se = t.add(Component {
        name: "se".into(),
        kind: ComponentKind::VectorSearching { top_k: rng.range_usize(2, 16) },
        engine: "vdb".into(),
        batchable: false,
        splittable: false,
    });
    let syn = t.add(Component {
        name: "syn".into(),
        kind: ComponentKind::LlmGenerate {
            variant: "llm-lite".into(),
            mode: SynthesisMode::OneShot,
            prompt: vec![
                PromptPart::Instruction(instr_tokens("qa", rng.range_usize(6, 24))),
                PromptPart::Question,
                PromptPart::Upstream { component: se, slice: None },
            ],
            out_tokens: rng.range_usize(4, 24),
            segments: 1,
            fan: 1,
        },
        engine: "llm-lite".into(),
        batchable: false,
        splittable: false,
    });
    t.chain(&[idx, expand, qe, se, syn]);

    let mut q = QueryConfig::example(rng.next_u64());
    let n_chunks = rng.range_usize(2, 20);
    q.doc_chunks = (0..n_chunks)
        .map(|_| (0..rng.range_usize(4, 40)).map(|_| 4 + rng.zipf(0, 1000) as i32).collect())
        .collect();
    (t, q)
}

#[test]
fn pass4_marker_arithmetic_acyclic_no_dangling() {
    check(60, |rng| {
        let (t, q) = advanced_like_workflow(rng);
        let mut g = build_pgraph(&t, &q).map_err(|e| e.to_string())?;
        if rng.chance(0.5) {
            pass1_prune(&mut g);
        }
        let n0 = g.nodes.len();

        // Expected growth per splittable multi-segment decode: k marker
        // nodes, plus k embedding stages per batchable whole-output
        // embedding consumer (the consumer itself becomes the aggregate).
        let mut expected_markers = 0usize;
        let mut expected_new = 0usize;
        for node in &g.nodes {
            if node.kind != PrimKind::Decoding || !node.splittable {
                continue;
            }
            let PayloadSpec::Decode { segments, .. } = &node.payload else { continue };
            let k = segments.len();
            if k <= 1 {
                continue;
            }
            let consumers = g
                .nodes
                .iter()
                .filter(|c| {
                    c.batchable
                        && c.kind == PrimKind::Embedding
                        && matches!(&c.payload, PayloadSpec::Embed { sources }
                            if sources.iter().any(
                                |s| matches!(s, DataRef::Node(x) if *x == node.id)))
                })
                .count();
            expected_markers += k;
            expected_new += k + k * consumers;
        }
        prop_assert(expected_markers > 0, "generator must produce a splittable decode")?;

        pass4_decode_pipeline(&mut g);

        let markers = count_kind(&g, PrimKind::PartialDecoding);
        prop_assert(
            markers == expected_markers,
            format!("markers {markers} != expected {expected_markers}"),
        )?;
        prop_assert(
            g.nodes.len() == n0 + expected_new,
            format!("node growth {} != expected {expected_new}", g.nodes.len() - n0),
        )?;
        g.topo_order().map_err(|e| format!("cycle after pass4: {e}"))?;
        check_no_dangling(&g)?;
        // Every split decode's segments now point at marker nodes.
        for node in &g.nodes {
            if node.kind == PrimKind::Decoding && node.splittable {
                if let PayloadSpec::Decode { segments, .. } = &node.payload {
                    if segments.len() > 1 {
                        for (target, _) in segments {
                            prop_assert(
                                g.nodes[*target].kind == PrimKind::PartialDecoding,
                                format!("segment target {target} is not a marker"),
                            )?;
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn all_passes_leave_no_dangling_refs() {
    let profiles = ProfileRegistry::with_defaults();
    check(40, |rng| {
        let (t, q) = random_workflow(rng);
        let g = build_pgraph(&t, &q).map_err(|e| e.to_string())?;
        let g = run_passes(g, OptFlags::all(), &profiles).map_err(|e| e.to_string())?;
        check_no_dangling(&g)
    });
}

fn mk_item(rng: &mut Rng, t0: Instant) -> QueueItem {
    let (tx, rx) = channel();
    std::mem::forget(rx);
    QueueItem {
        query: rng.range(1, 6),
        node: rng.range_usize(0, 50),
        depth: rng.range(0, 8) as u32,
        bundle: (0, rng.range(0, 4)),
        arrival: t0 + Duration::from_micros(rng.range(0, 5000)),
        rows: rng.range_usize(1, 9),
        tokens: rng.range_usize(1, 600),
        wcp_discounted: false,
        prefix: None,
        wcp_us: rng.range(0, 500_000),
        tenant: teola::engines::UNTENANTED,
        job: EngineJob::ToolCall { name: "x".into(), cost_us: 0 },
        reply: tx,
        successors: Vec::new(),
    }
}

/// Regression (bundle-collision): the invocation-bundle key used to be
/// the packed `(query << 20) | node`, so a node id crossing 2^20 bled
/// into the query bits — e.g. (query=1, node=2^20+5) collided with
/// (query=2, node=5) — and PerInvocation silently merged unrelated
/// invocations into one bundle.  With the structured `(query, node)` key
/// every PO batch must consist of exactly one invocation, even when node
/// ids straddle the old 20-bit boundary.
#[test]
fn per_invocation_never_merges_distinct_invocations() {
    check(120, |rng| {
        let t0 = Instant::now();
        let n = rng.range_usize(2, 24);
        let mut queue: Vec<QueueItem> = (0..n)
            .map(|_| {
                let query = rng.range(1, 5);
                // Node ids around and above 2^20 — the old packing's
                // collision zone.
                let node = (rng.range_usize(0, 4) << 20) | rng.range_usize(0, 8);
                let mut it = mk_item(rng, t0);
                it.query = query;
                it.node = node;
                it.bundle = (query, node as u64);
                it
            })
            .collect();
        let total = queue.len();
        let batch =
            form_batch(&mut queue, BatchPolicy::PerInvocation, 64, rng.chance(0.5), SlotUnit::Rows);
        prop_assert(!batch.is_empty(), "progress")?;
        prop_assert(batch.len() + queue.len() == total, "no items lost")?;
        let head = batch[0].bundle;
        for it in &batch {
            prop_assert(
                it.bundle == head && (it.query, it.node as u64) == head,
                format!(
                    "cross-invocation merge: ({}, {}) in bundle {head:?}",
                    it.query, it.node
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn batching_respects_slots_and_makes_progress() {
    check(120, |rng| {
        let t0 = Instant::now();
        let n = rng.range_usize(1, 24);
        let mut queue: Vec<QueueItem> = (0..n).map(|_| mk_item(rng, t0)).collect();
        let policy = *teola::util::proptest::pick(
            rng,
            &[BatchPolicy::TopoAware, BatchPolicy::BlindTO, BatchPolicy::PerInvocation],
        );
        // Either denomination must respect its budget.
        let unit =
            if rng.chance(0.5) { SlotUnit::Rows } else { SlotUnit::Tokens };
        let budget = match unit {
            SlotUnit::Rows => rng.range_usize(1, 20),
            SlotUnit::Tokens => rng.range_usize(1, 1500),
        };
        let total_before = queue.len();
        let batch = form_batch(&mut queue, policy, budget, rng.chance(0.5), unit);
        prop_assert(!batch.is_empty(), "non-empty queue must yield progress")?;
        prop_assert(
            batch.len() + queue.len() == total_before,
            "no items lost or duplicated",
        )?;
        let cost: usize = batch.iter().map(|i| unit.cost(i)).sum();
        // A single oversized item may exceed the budget (engines split
        // internally); otherwise the budget holds.
        if batch.len() > 1 && policy != BatchPolicy::PerInvocation {
            prop_assert(cost <= budget, format!("{unit:?} cost {cost} > budget {budget}"))?;
        }
        Ok(())
    });
}

#[test]
fn batching_drains_completely() {
    check(40, |rng| {
        let t0 = Instant::now();
        let n = rng.range_usize(1, 40);
        let mut queue: Vec<QueueItem> = (0..n).map(|_| mk_item(rng, t0)).collect();
        let mut drained = 0;
        let mut rounds = 0;
        let wcp = rng.chance(0.5);
        while !queue.is_empty() {
            let b = form_batch(&mut queue, BatchPolicy::TopoAware, 8, wcp, SlotUnit::Rows);
            prop_assert(!b.is_empty(), "stuck queue")?;
            drained += b.len();
            rounds += 1;
            prop_assert(rounds <= n * 2 + 2, "too many rounds")?;
        }
        prop_assert(drained == n, "all items drained")
    });
}

/// WCP invariant: the per-query remaining-critical-path estimate is
/// monotonically non-increasing as nodes complete (in any valid
/// completion order) and reaches zero once every node has completed.
#[test]
fn wcp_remaining_path_monotone_nonincreasing() {
    let profiles = ProfileRegistry::with_defaults();
    check(60, |rng| {
        let (t, q) = random_workflow(rng);
        let g = build_pgraph(&t, &q).map_err(|e| e.to_string())?;
        let flags = if rng.chance(0.5) { OptFlags::all() } else { OptFlags::none() };
        let g = run_passes(g, flags, &profiles).map_err(|e| e.to_string())?;
        let e = teola::graph::EGraph::new(g).map_err(|e| e.to_string())?;
        let mut w = WcpTracker::new(&e);
        prop_assert(w.remaining_us() > 0, "a workflow with LLM calls has device time")?;

        // Complete in a randomized valid order: repeatedly pick any node
        // whose parents are all done (the runtime's only guarantee).
        let n = e.len();
        let mut done = vec![false; n];
        let mut prev = w.remaining_us();
        for _ in 0..n {
            let eligible: Vec<usize> = (0..n)
                .filter(|&v| !done[v] && e.parents[v].iter().all(|&p| done[p]))
                .collect();
            prop_assert(!eligible.is_empty(), "acyclic graph always has a frontier")?;
            let v = *teola::util::proptest::pick(rng, &eligible);
            done[v] = true;
            w.complete(v);
            prop_assert(
                w.remaining_us() <= prev,
                format!("remaining grew at node {v}: {} -> {}", prev, w.remaining_us()),
            )?;
            prev = w.remaining_us();
        }
        prop_assert(w.remaining_us() == 0, "all nodes complete => remaining 0")
    });
}

/// PR5 token conservation: replay the engine scheduler's reserve/release
/// discipline against per-instance `KvBudget` ledgers under random
/// admission, retire, and requeue-on-instance-death orders.  Invariants:
/// every release pairs exactly with its reservation (the ledger never
/// saturates, i.e. never would have gone negative), a live instance
/// admitted under `fits` never exceeds its capacity, a dead instance's
/// ledger is empty the moment it dies, and after the drain every
/// instance's balance is exactly zero.
#[test]
fn kv_budget_balances_to_zero_under_random_orders() {
    check(80, |rng| {
        let n_inst = rng.range_usize(1, 5);
        let cap = rng.range_usize(16, 4096);
        let mut budgets: Vec<KvBudget> = (0..n_inst).map(|_| KvBudget::new(cap)).collect();
        let mut alive = vec![true; n_inst];
        // Pending jobs (token costs, possibly larger than the whole
        // capacity — dispatched alone, the executor chunks internally)
        // and the in-flight charge list per instance.
        let mut pending: Vec<usize> =
            (0..rng.range_usize(1, 48)).map(|_| rng.range_usize(1, 900)).collect();
        let mut inflight: Vec<Vec<usize>> = vec![Vec::new(); n_inst];

        let mut steps = 0usize;
        loop {
            let work_left =
                !pending.is_empty() || inflight.iter().any(|v| !v.is_empty());
            if !work_left {
                break;
            }
            steps += 1;
            prop_assert(steps < 20_000, "random schedule failed to drain")?;
            match rng.range(0, 4) {
                // Admit the head job to a random live instance, honoring
                // the scheduler's rule: it must fit, unless the instance
                // is idle (oversized admission).
                0 | 1 if !pending.is_empty() => {
                    let live: Vec<usize> = (0..n_inst).filter(|&i| alive[i]).collect();
                    let i = *teola::util::proptest::pick(rng, &live);
                    let cost = pending[0];
                    if budgets[i].fits(cost) || budgets[i].reserved() == 0 {
                        pending.remove(0);
                        budgets[i].reserve(cost);
                        inflight[i].push(cost);
                        if cost <= cap {
                            prop_assert(
                                budgets[i].reserved() <= cap
                                    || inflight[i].iter().any(|&c| c > cap),
                                "fits-gated admission stays under capacity",
                            )?;
                        }
                    }
                }
                // Retire a random in-flight job: release exactly its
                // dispatch-time charge.
                2 => {
                    let occupied: Vec<usize> =
                        (0..n_inst).filter(|&i| !inflight[i].is_empty()).collect();
                    if occupied.is_empty() {
                        continue;
                    }
                    let i = *teola::util::proptest::pick(rng, &occupied);
                    let j = rng.range_usize(0, inflight[i].len());
                    let cost = inflight[i].remove(j);
                    let freed = budgets[i].release(cost);
                    prop_assert(
                        freed == cost,
                        format!("release clamped: ledger would have gone negative ({freed} < {cost})"),
                    )?;
                }
                // Instance death: its ledger resets and its in-flight
                // jobs requeue for re-admission elsewhere (never back to
                // a dead instance).  Keep at least one instance alive so
                // the schedule always drains.
                _ => {
                    if alive.iter().filter(|a| **a).count() < 2 {
                        continue;
                    }
                    let live: Vec<usize> = (0..n_inst).filter(|&i| alive[i]).collect();
                    let i = *teola::util::proptest::pick(rng, &live);
                    alive[i] = false;
                    pending.extend(inflight[i].drain(..));
                    budgets[i].reset();
                    prop_assert(
                        budgets[i].reserved() == 0,
                        "dead instance holds no phantom reservations",
                    )?;
                }
            }
        }
        for (i, b) in budgets.iter().enumerate() {
            prop_assert(
                b.reserved() == 0,
                format!("instance {i} balance {} != 0 after drain", b.reserved()),
            )?;
        }
        Ok(())
    });
}

/// PR6 dual-ledger token conservation: random interleavings of admit
/// (reserve), release-retire, residency-commit, per-sequence free,
/// per-query free, and watermark eviction must keep both ledgers in
/// exact agreement with an independently tracked shadow model — tokens
/// move between "reserved" and "resident" but are never minted or lost,
/// and the ledger drains to zero once everything is freed.
#[test]
fn kv_dual_ledger_conserves_tokens_under_random_orders() {
    check(80, |rng| {
        let cap = rng.range_usize(32, 4096);
        let mut b = KvBudget::new(cap);
        // Shadow model: in-flight reservations and per-sequence residency.
        let mut inflight: Vec<(SeqId, usize)> = Vec::new();
        let mut resident: std::collections::HashMap<SeqId, usize> =
            std::collections::HashMap::new();
        let mut next_seq = 0u32;

        for _ in 0..rng.range_usize(20, 200) {
            match rng.range(0, 5) {
                // Admit: reserve a fresh sequence's charge.
                0 | 1 => {
                    let q = rng.range(1, 6);
                    let t = rng.range_usize(1, 400);
                    let seq = (q, next_seq);
                    next_seq += 1;
                    b.reserve(t);
                    inflight.push((seq, t));
                }
                // Retire without residency (PR5 path): release pairs
                // exactly with the reservation, never clamps.
                2 => {
                    if inflight.is_empty() {
                        continue;
                    }
                    let i = rng.range_usize(0, inflight.len());
                    let (_, t) = inflight.remove(i);
                    let freed = b.release(t);
                    prop_assert(
                        freed == t,
                        format!("release clamped ({freed} < {t}): ledger mispairing"),
                    )?;
                }
                // Retire under residency: the charge moves ledgers.
                3 => {
                    if inflight.is_empty() {
                        continue;
                    }
                    let i = rng.range_usize(0, inflight.len());
                    let (seq, t) = inflight.remove(i);
                    let before = b.occupied();
                    b.commit_resident(seq, t, rng.range(0, 1_000_000));
                    prop_assert(
                        b.occupied() == before,
                        "commit_resident moves tokens, never mints or drops them",
                    )?;
                    *resident.entry(seq).or_insert(0) += t;
                }
                // Free residency: eviction victim, one sequence, or a
                // whole query — each must return exactly the shadow
                // model's token count.
                _ => {
                    if resident.is_empty() {
                        continue;
                    }
                    if rng.chance(0.4) {
                        let Some((victim, tokens)) = b.evict_victim(&[]) else {
                            continue;
                        };
                        prop_assert(
                            resident.get(&victim) == Some(&tokens),
                            format!("victim {victim:?} holds {tokens}, shadow disagrees"),
                        )?;
                        let freed = b.free_seq(victim);
                        prop_assert(freed == tokens, "free_seq returns the full charge")?;
                        resident.remove(&victim);
                    } else if rng.chance(0.5) {
                        let keys: Vec<SeqId> = resident.keys().copied().collect();
                        let seq = *teola::util::proptest::pick(rng, &keys);
                        let expect = resident.remove(&seq).unwrap();
                        prop_assert(
                            b.free_seq(seq) == expect,
                            format!("free_seq({seq:?}) != shadow {expect}"),
                        )?;
                    } else {
                        let q = rng.range(1, 6);
                        let expect: usize = resident
                            .iter()
                            .filter(|(s, _)| s.0 == q)
                            .map(|(_, t)| *t)
                            .sum();
                        prop_assert(
                            b.free_query(q) == expect,
                            format!("free_query({q}) != shadow {expect}"),
                        )?;
                        resident.retain(|s, _| s.0 != q);
                    }
                }
            }
            // Conservation after every operation.
            let exp_rsv: usize = inflight.iter().map(|(_, t)| *t).sum();
            let exp_res: usize = resident.values().sum();
            prop_assert(
                b.reserved() == exp_rsv,
                format!("reserved {} != shadow {exp_rsv}", b.reserved()),
            )?;
            prop_assert(
                b.resident_total() == exp_res,
                format!("resident {} != shadow {exp_res}", b.resident_total()),
            )?;
            prop_assert(
                b.resident_count() == resident.len(),
                "resident sequence count matches shadow",
            )?;
            prop_assert(
                b.occupied() == exp_rsv + exp_res,
                "occupied is exactly reserved + resident",
            )?;
        }

        // Drain everything: the dual ledger must balance to zero.
        for (_, t) in inflight.drain(..) {
            let freed = b.release(t);
            prop_assert(freed == t, "drain release pairs exactly")?;
        }
        let seqs: Vec<SeqId> = resident.keys().copied().collect();
        for seq in seqs {
            let expect = resident.remove(&seq).unwrap();
            prop_assert(b.free_seq(seq) == expect, "drain free_seq pairs exactly")?;
        }
        prop_assert(b.occupied() == 0, "dual ledger drains to zero")
    });
}

#[test]
fn object_store_exactly_once_random() {
    check(60, |rng| {
        let mut store = ObjectStore::new();
        let keys = vec_of(rng, 1, 40, |r| r.range_usize(0, 30));
        let mut seen = std::collections::HashSet::new();
        for k in keys {
            let res = store.put(k, teola::graph::Value::Unit);
            if seen.insert(k) {
                prop_assert(res.is_ok(), "first put succeeds")?;
            } else {
                prop_assert(res.is_err(), "duplicate put rejected")?;
            }
        }
        Ok(())
    });
}

#[test]
fn kv_pack_unpack_roundtrip_random_geometry() {
    check(40, |rng| {
        let dims = LlmDims {
            layers: rng.range_usize(1, 5),
            heads: rng.range_usize(1, 5),
            max_seq: 1 << rng.range_usize(2, 6),
            head_dim: 1 << rng.range_usize(2, 6),
            vocab: 64,
        };
        let batch = rng.range_usize(1, 6);
        let n_filled = rng.range_usize(0, batch + 1);
        let states: Vec<Option<SeqState>> = (0..batch)
            .map(|b| {
                if b < n_filled {
                    let n = dims.seq_kv_elems();
                    Some(SeqState {
                        kv: (0..n).map(|i| (i as f32) + b as f32 * 1e5).collect(),
                        len: rng.range_usize(0, dims.max_seq),
                    })
                } else {
                    None
                }
            })
            .collect();
        let refs: Vec<Option<&SeqState>> = states.iter().map(|s| s.as_ref()).collect();
        let packed = pack_kv(&dims, &refs, batch);
        for (b, s) in states.iter().enumerate() {
            let out = unpack_kv(&dims, &packed, batch, b);
            match s {
                Some(st) => prop_assert(out == st.kv, format!("row {b} roundtrip"))?,
                None => prop_assert(out.iter().all(|&x| x == 0.0), "empty row zero")?,
            }
        }
        Ok(())
    });
}

/// PR7 invariant (speculative template prefill): cancelling a sequence
/// via `CancelSeq` releases its *entire* KV charge — whether the cancel
/// lands while the prefill is still queued, mid-chunk, or after the
/// charge has already been committed resident — and never surfaces a
/// `Failed` completion toward the speculating query.  A leak here would
/// let every invalidated speculation permanently shrink the instance's
/// KV budget.
#[test]
fn cancelled_speculative_prefill_releases_all_kv() {
    check(60, |rng| {
        use std::collections::HashMap;
        use std::sync::atomic::AtomicUsize;
        use std::sync::{Arc, Mutex};
        use teola::engines::instance::StepExecutor;
        use teola::engines::llm::SeqStore;
        use teola::engines::sim::SimLlmExecutor;
        use teola::engines::{JobOutput, RequestCtx};

        let store: SeqStore = Arc::new(Mutex::new(HashMap::new()));
        let mut exec = SimLlmExecutor::new(
            "llm-lite",
            store.clone(),
            3,
            2,
            4096,
            Arc::new(AtomicUsize::new(0)),
        )
        .with_kv_budget(Arc::new(AtomicUsize::new(4096)));
        // Cover both ledgers: reserve-at-admit (PR5) and persistent
        // residency (PR6), where a retired prefill's charge survives as
        // a resident entry that only `CancelSeq`/`FreeQuery` can drop.
        if rng.chance(0.5) {
            exec = exec.with_kv_watermark(Arc::new(AtomicUsize::new(70)));
        }

        let (tx, rx) = channel();
        let ctx = |node: usize| RequestCtx {
            query: 0xC0FFEE,
            node,
            depth: 0,
            arrival: Instant::now(),
            wcp_us: 0,
            kv_tokens: 0,
            wcp_discounted: false,
            tenant: teola::engines::UNTENANTED,
            reply: tx.clone(),
            successors: Vec::new(),
        };

        let seq: SeqId = (0xC0FFEE, 7);
        let len = rng.range_usize(8, 200);
        let bounced = exec.admit(vec![(
            ctx(1),
            EngineJob::Prefill { seq, tokens: vec![9; len], offset: 0, prefix: None },
        )]);
        prop_assert(bounced.is_empty(), "prefill admits under a roomy budget")?;

        // Let the prefill make 0..6 chunk steps of progress before the
        // cancel arrives — sometimes it has already fully retired.
        let mut emitted = Vec::new();
        for _ in 0..rng.range_usize(0, 7) {
            exec.step(&mut |c| emitted.push(c)).map_err(|e| e.to_string())?;
        }

        let bounced = exec.admit(vec![(ctx(2), EngineJob::CancelSeq { seq })]);
        prop_assert(bounced.is_empty(), "bookkeeping jobs are never bounced")?;
        while exec.resident() > 0 {
            exec.step(&mut |c| emitted.push(c)).map_err(|e| e.to_string())?;
        }

        prop_assert(
            exec.kv_occupied() == 0,
            format!("kv charge leaked after cancel: {}", exec.kv_occupied()),
        )?;
        prop_assert(
            !store.lock().unwrap().contains_key(&seq),
            "host-side sequence state must be purged",
        )?;
        drop(tx);
        emitted.extend(rx.try_iter());
        for c in &emitted {
            prop_assert(
                !matches!(c.output, JobOutput::Failed(_)),
                "a cancelled speculation must never surface Failed",
            )?;
        }
        // A post-cancel abort has nothing left to report for this seq.
        let _ = exec.abort();
        prop_assert(exec.kv_occupied() == 0, "abort keeps the ledger empty")
    });
}

/// PR10 invariant (speculative branch cancel): cancelling a refuted
/// speculative branch — in any interleaving of queued-but-undispatched
/// items and an in-flight prefill at a random point of progress — leaks
/// nothing: the `SchedQueue` retains zero items for the cancelled node
/// (and every unrelated item survives untouched), the executor's KV
/// ledger drains to zero under both accounting modes (reserve-at-admit
/// and persistent residency), no `Failed` completion ever surfaces
/// toward the speculating runner, and the tenant's fair-queueing charge
/// refunds exactly.  This replays the same primitive sequence the
/// engine scheduler's `CancelNode` interception and the runner's
/// `cancel_branch_node` perform, under random cancel timing.
#[test]
fn cancelled_speculative_branch_leaks_nothing() {
    use std::collections::HashMap;
    use std::sync::atomic::AtomicUsize;
    use std::sync::{Arc, Mutex};
    use teola::engines::instance::StepExecutor;
    use teola::engines::llm::SeqStore;
    use teola::engines::sim::SimLlmExecutor;
    use teola::engines::{JobOutput, RequestCtx};
    use teola::scheduler::{FairQueue, SchedQueue};

    check(60, |rng| {
        let t0 = Instant::now();
        let spec_query: u64 = 0x5bec;
        let spec_node: usize = rng.range_usize(3, 40);

        // --- Queued-but-undispatched half: a SchedQueue holding a mix
        // of the speculative node's items and unrelated work.
        let mut queue = SchedQueue::new();
        let n_spec = rng.range_usize(1, 5);
        let n_other = rng.range_usize(0, 8);
        for i in 0..(n_spec + n_other) {
            let mut it = mk_item(rng, t0);
            if i < n_spec {
                it.query = spec_query;
                it.node = spec_node;
                // Speculative dispatches carry the fully discounted rank.
                it.wcp_us = 0;
            } else {
                // Unrelated: same query/different node or different query.
                if rng.chance(0.5) {
                    it.query = spec_query;
                    it.node = spec_node + 1 + rng.range_usize(0, 5);
                } else {
                    it.query = rng.range(1, 5);
                }
            }
            it.bundle = (it.query, it.node as u64);
            queue.push(it);
        }
        let before = queue.len();
        // The CancelNode interception: purge by (query, node), replies
        // dropped — a cancelled speculation must never surface Failed.
        let ids: Vec<usize> = queue
            .iter_ids()
            .filter(|(_, it)| it.query == spec_query && it.node == spec_node)
            .map(|(id, _)| id)
            .collect();
        prop_assert(ids.len() == n_spec, "purge sees every queued branch item")?;
        for id in ids {
            drop(queue.remove(id));
        }
        prop_assert(
            queue.len() == before - n_spec,
            "only the cancelled node's items leave the queue",
        )?;
        prop_assert(
            queue.iter().all(|it| !(it.query == spec_query && it.node == spec_node)),
            "zero SchedQueue slots remain for the cancelled branch",
        )?;

        // --- In-flight half: the branch's prefill is mid-execution on a
        // stepped executor when the CancelSeq lands, at a random point
        // of progress, under a random ledger mode.
        let store: SeqStore = Arc::new(Mutex::new(HashMap::new()));
        let mut exec = SimLlmExecutor::new(
            "llm-lite",
            store.clone(),
            3,
            2,
            4096,
            Arc::new(AtomicUsize::new(0)),
        )
        .with_kv_budget(Arc::new(AtomicUsize::new(4096)));
        if rng.chance(0.5) {
            exec = exec.with_kv_watermark(Arc::new(AtomicUsize::new(70)));
        }
        let (tx, rx) = channel();
        let ctx = |node: usize| RequestCtx {
            query: spec_query,
            node,
            depth: 0,
            arrival: Instant::now(),
            wcp_us: 0,
            kv_tokens: 0,
            wcp_discounted: false,
            tenant: teola::engines::UNTENANTED,
            reply: tx.clone(),
            successors: Vec::new(),
        };
        let seq: SeqId = (spec_query, spec_node as u32);
        let len = rng.range_usize(8, 200);
        let bounced = exec.admit(vec![(
            ctx(spec_node),
            EngineJob::Prefill { seq, tokens: vec![9; len], offset: 0, prefix: None },
        )]);
        prop_assert(bounced.is_empty(), "speculative prefill admits under a roomy budget")?;
        let mut emitted = Vec::new();
        for _ in 0..rng.range_usize(0, 7) {
            exec.step(&mut |c| emitted.push(c)).map_err(|e| e.to_string())?;
        }
        let bounced = exec.admit(vec![(ctx(spec_node), EngineJob::CancelSeq { seq })]);
        prop_assert(bounced.is_empty(), "CancelSeq is never bounced")?;
        while exec.resident() > 0 {
            exec.step(&mut |c| emitted.push(c)).map_err(|e| e.to_string())?;
        }
        prop_assert(
            exec.kv_occupied() == 0,
            format!("cancelled branch leaked KV: {}", exec.kv_occupied()),
        )?;
        prop_assert(
            !store.lock().unwrap().contains_key(&seq),
            "host-side sequence state purged on branch cancel",
        )?;
        drop(tx);
        emitted.extend(rx.try_iter());
        for c in &emitted {
            prop_assert(
                !matches!(c.output, JobOutput::Failed(_)),
                "a cancelled speculative branch must never surface Failed",
            )?;
        }

        // --- Fair-queueing refund: the CancelNode refund is an exact
        // inverse of the dispatch-time charge, so a cancelled branch
        // costs its tenant zero SFQ share.
        let mut fq = FairQueue::new();
        let tenant = rng.range(1, 5) as teola::engines::TenantId;
        let w = rng.range(1, 7) as u32;
        let v0 = fq.vstart(tenant);
        let cost = rng.range_usize(1, 900);
        fq.charge(tenant, cost, w);
        fq.refund(tenant, cost, w);
        prop_assert(
            fq.vstart(tenant) == v0,
            format!("refund not exact: vstart {} != {v0}", fq.vstart(tenant)),
        )
    });
}

/// PR8 invariant (start-time fair queueing): under random weights,
/// random per-dispatch costs, and a random warm-up arrival order, an
/// always-backlogged tenant set served by ascending virtual-start tag
/// (the scheduler's `TenantRank` order) (a) never starves anyone — the
/// gap between two consecutive picks of any tenant stays under the
/// analytic SFQ bound — and (b) converges to served work proportional to
/// the weights.
#[test]
fn sfq_fair_share_converges_and_never_starves() {
    use teola::scheduler::FairQueue;
    const MAX_COST: usize = 5;
    const MAX_W: u32 = 6;
    check(40, |rng| {
        let n = rng.range_usize(2, 6);
        let tenants: Vec<(u32, u32)> =
            (0..n).map(|i| (i as u32 + 1, rng.range(1, u64::from(MAX_W) + 1) as u32)).collect();
        let mut fq = FairQueue::new();
        // Random warm-up: some tenants arrive mid-run with history, so
        // convergence must not depend on a synchronized start.
        for _ in 0..rng.range_usize(0, 11) {
            let (t, w) = tenants[rng.range_usize(0, n)];
            fq.charge(t, rng.range_usize(1, MAX_COST + 1), w);
        }
        let rounds = 8000usize;
        let mut served = vec![0u64; n];
        let mut last_pick = vec![0usize; n];
        let mut max_gap = 0usize;
        for round in 0..rounds {
            // Everyone is backlogged: serve the minimum (vstart, id) —
            // exactly the unboosted TenantRank order.
            let pick = (0..n)
                .min_by_key(|&i| (fq.vstart(tenants[i].0), tenants[i].0))
                .unwrap();
            let (t, w) = tenants[pick];
            let cost = rng.range_usize(1, MAX_COST + 1);
            fq.charge(t, cost, w);
            served[pick] += cost as u64;
            max_gap = max_gap.max(round - last_pick[pick]);
            last_pick[pick] = round;
        }
        // (a) Starvation bound: between two picks of tenant i, every
        // other tenant can be served at most ~max_cost*max_w times (its
        // finish tag advances >= SCALE/max_w per pick while tenant i's
        // tag sits <= max_cost*SCALE ahead of virtual time).  Factor 2
        // of slack on the analytic bound.
        let bound = 2 * ((n - 1) * MAX_COST * MAX_W as usize + n);
        prop_assert(
            max_gap <= bound,
            format!("pick gap {max_gap} exceeds SFQ starvation bound {bound}"),
        )?;
        // (b) Weighted shares: served work within 15% of the weight
        // ratio (warm-up history + one in-flight charge of slack).
        let total: u64 = served.iter().sum();
        let sum_w: u64 = tenants.iter().map(|(_, w)| u64::from(*w)).sum();
        for (i, &(t, w)) in tenants.iter().enumerate() {
            let expected = total as f64 * f64::from(w) / sum_w as f64;
            let got = served[i] as f64;
            prop_assert(
                (got - expected).abs() <= 0.15 * expected,
                format!(
                    "tenant {t} (w={w}) served {got} vs expected {expected:.0} \
                     (weights {tenants:?}, served {served:?})"
                ),
            )?;
        }
        Ok(())
    });
}

/// Queue-item factory for the PR9 equivalence test: the spec tuple is
/// `(query, node, depth, rows, tokens, arrival_ms, wcp_us)` and can be
/// materialized once per structure under test (QueueItem is not `Clone`
/// — each copy gets its own forgotten reply channel).  Arrivals are
/// whole milliseconds past `t0` (globally distinct, monotone) and WCP
/// stamps are whole seconds, so the `wcp_priority_us` aging term — read
/// at a slightly different `Instant::now()` by each of the three
/// ordering paths — can never flip an ordering decision between calls:
/// stamp differences (multiples of 1e6 us) dwarf any aging drift, and
/// equal-stamp ties resolve to the earlier arrival under both the aging
/// term and the arrival tie-break.  The tenant is a pure function of
/// the query id, preserving the scheduler's one-tenant-per-query
/// invariant across independently generated items.
type EquivSpec = (u64, usize, u32, usize, usize, u64, u64);

fn equiv_item(t0: Instant, s: &EquivSpec) -> QueueItem {
    let (query, node, depth, rows, tokens, ms, wcp_us) = *s;
    let (tx, rx) = channel();
    std::mem::forget(rx);
    QueueItem {
        query,
        node,
        depth,
        bundle: (query, node as u64),
        arrival: t0 + Duration::from_millis(ms),
        rows,
        tokens,
        wcp_discounted: false,
        prefix: None,
        wcp_us,
        tenant: (query % 3) as teola::engines::TenantId,
        job: EngineJob::ToolCall { name: "equiv".into(), cost_us: 0 },
        reply: tx,
        successors: Vec::new(),
    }
}

/// PR9 tentpole equivalence: under random interleavings of the five
/// queue mutations the engine scheduler performs — enqueue, WCP
/// restamp, prefix rediscount, requeue-on-death, tenant boost — the
/// incremental `SchedQueue` (lazy bucket invalidation), its exact
/// rebuild-all fallback (`incremental = false`), and the original
/// sort-based `Vec` path agree on every ordering decision: the same
/// priority head, the same batch membership under every policy and
/// budget denomination, and — between the two `SchedQueue` modes —
/// the exact same batch order.  (The `Vec` path's *returned* order is
/// a `swap_remove` artifact, so its batches compare as sorted sets.)
#[test]
fn sched_queue_matches_sorted_path_under_interleavings() {
    use teola::scheduler::batching::{
        form_batch_ranked, form_continuous_admission_ranked, head_index_ranked,
    };
    use teola::scheduler::{SchedQueue, TenantRanks};

    fn mk_ranks(rng: &mut Rng) -> Option<TenantRanks> {
        if rng.chance(0.3) {
            return None;
        }
        // Distinct SFQ virtual-start tags per tenant keep the rank order
        // total even when the random deadline boosts collide.
        let mut m = TenantRanks::new();
        for t in 0u32..3 {
            m.insert(t, (rng.range(0, 2), (u64::from(t) + 1) * 100, t));
        }
        Some(m)
    }

    check(60, |rng| {
        let t0 = Instant::now();
        let mut vecq: Vec<QueueItem> = Vec::new();
        let mut incr = SchedQueue::new();
        let mut exact = SchedQueue::new();
        let mut next_ms: u64 = 0;
        let mut next_node: usize = 0;
        let mut ranks = mk_ranks(rng);
        let policy = *teola::util::proptest::pick(
            rng,
            &[BatchPolicy::TopoAware, BatchPolicy::BlindTO, BatchPolicy::PerInvocation],
        );
        let wcp_on = rng.chance(0.7);
        let key = |it: &QueueItem| (it.query, it.node);

        let mut push_burst =
            |rng: &mut Rng,
             vecq: &mut Vec<QueueItem>,
             incr: &mut SchedQueue,
             exact: &mut SchedQueue,
             next_ms: &mut u64,
             next_node: &mut usize,
             n: usize| {
                for _ in 0..n {
                    *next_ms += rng.range(1, 4);
                    *next_node += 1;
                    let spec: EquivSpec = (
                        rng.range(1, 7),
                        *next_node,
                        rng.range(0, 5) as u32,
                        rng.range_usize(1, 5),
                        rng.range_usize(1, 600),
                        *next_ms,
                        rng.range(0, 40) * 1_000_000,
                    );
                    vecq.push(equiv_item(t0, &spec));
                    incr.push(equiv_item(t0, &spec));
                    exact.push(equiv_item(t0, &spec));
                }
            };

        let seed = rng.range_usize(2, 11);
        push_burst(rng, &mut vecq, &mut incr, &mut exact, &mut next_ms, &mut next_node, seed);

        for _ in 0..rng.range_usize(3, 11) {
            match rng.range(0, 4) {
                0 => {
                    let n = rng.range_usize(1, 5);
                    push_burst(
                        rng, &mut vecq, &mut incr, &mut exact, &mut next_ms, &mut next_node, n,
                    );
                }
                1 => {
                    // WCP restamp: one query's remaining-path estimate
                    // grows (fresh profile feedback).  The closure is a
                    // pure function of the item, so the three structures
                    // see identical mutations in any iteration order.
                    let q = rng.range(1, 7);
                    let delta = rng.range(1, 5) * 1_000_000;
                    let mut f = |it: &mut QueueItem| {
                        if it.query == q {
                            it.wcp_us = it.wcp_us.saturating_add(delta);
                            true
                        } else {
                            false
                        }
                    };
                    incr.restamp_wcp(&mut f);
                    exact.restamp_wcp(&mut f);
                    for it in vecq.iter_mut() {
                        f(it);
                    }
                }
                2 => {
                    // Prefix rediscount: a query's queued items get the
                    // resident-prefix discount exactly once.
                    let q = rng.range(1, 7);
                    let cut = rng.range(1, 3) * 1_000_000;
                    let mut f = |it: &mut QueueItem| {
                        if it.query == q && !it.wcp_discounted {
                            it.wcp_discounted = true;
                            it.wcp_us = it.wcp_us.saturating_sub(cut);
                            true
                        } else {
                            false
                        }
                    };
                    incr.restamp_wcp(&mut f);
                    exact.restamp_wcp(&mut f);
                    for it in vecq.iter_mut() {
                        f(it);
                    }
                }
                _ => {
                    // Tenant boost / retune: the rank map the ordering
                    // calls consult changes out from under the queue.
                    ranks = mk_ranks(rng);
                }
            }

            // Head agreement after every mutation.
            let vh = head_index_ranked(&vecq, policy, wcp_on, ranks.as_ref())
                .map(|i| key(&vecq[i]));
            let ih = incr.head(policy, wcp_on, ranks.as_ref(), true).map(key);
            let eh = exact.head(policy, wcp_on, ranks.as_ref(), false).map(key);
            prop_assert(
                vh == ih && ih == eh,
                format!("head diverged: vec {vh:?}, incremental {ih:?}, exact {eh:?}"),
            )?;

            // Batch agreement: random denomination and budget, and for
            // TopoAware sometimes the continuous-admission path.
            let unit = if rng.chance(0.5) { SlotUnit::Rows } else { SlotUnit::Tokens };
            let budget = match unit {
                SlotUnit::Rows => rng.range_usize(1, 17),
                SlotUnit::Tokens => rng.range_usize(1, 1201),
            };
            let (vb, ib, eb) = if policy == BatchPolicy::TopoAware && rng.chance(0.4) {
                (
                    form_continuous_admission_ranked(
                        &mut vecq, budget, wcp_on, unit, ranks.as_ref(),
                    ),
                    incr.form_continuous(budget, wcp_on, unit, ranks.as_ref(), true),
                    exact.form_continuous(budget, wcp_on, unit, ranks.as_ref(), false),
                )
            } else {
                (
                    form_batch_ranked(&mut vecq, policy, budget, wcp_on, unit, ranks.as_ref()),
                    incr.form_batch(policy, budget, wcp_on, unit, ranks.as_ref(), true),
                    exact.form_batch(policy, budget, wcp_on, unit, ranks.as_ref(), false),
                )
            };
            let ik: Vec<_> = ib.iter().map(key).collect();
            let ek: Vec<_> = eb.iter().map(key).collect();
            prop_assert(
                ik == ek,
                format!("incremental batch order {ik:?} != exact fallback order {ek:?}"),
            )?;
            let mut vs: Vec<_> = vb.iter().map(key).collect();
            let mut is_ = ik.clone();
            vs.sort_unstable();
            is_.sort_unstable();
            prop_assert(
                vs == is_,
                format!("batch membership diverged: vec {vs:?} vs sched-queue {is_:?}"),
            )?;

            // Requeue-on-death: every dispatched item comes straight
            // back (instance died before the batch ran).
            vecq.extend(vb);
            for it in ib {
                incr.push(it);
            }
            for it in eb {
                exact.push(it);
            }
            prop_assert(
                vecq.len() == incr.len() && incr.len() == exact.len(),
                format!(
                    "queue lengths diverged: vec {}, incremental {}, exact {}",
                    vecq.len(),
                    incr.len(),
                    exact.len()
                ),
            )?;
        }
        Ok(())
    });
}
