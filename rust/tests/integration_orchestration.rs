//! End-to-end orchestration integration: real engines, full two-tier
//! scheduling over optimized e-graphs.
//!
//! Every scenario runs unconditionally on the simulated backend
//! (`ExecBackend::Sim` — no artifacts needed, deterministic outputs,
//! profile-driven timing), and again on the XLA backend when an
//! `artifacts/` directory is present (`make artifacts`).

use teola::engines::profile::ProfileRegistry;
use teola::engines::ExecBackend;
use teola::graph::pgraph::{build_pgraph, instr_tokens};
use teola::graph::template::*;
use teola::graph::{run_passes, EGraph, OptFlags, Value};
use teola::scheduler::{BatchPolicy, Platform, PlatformConfig};

fn have_artifacts() -> bool {
    // Requires both artifacts on disk and a real (non-stub) XLA crate.
    let ok = teola::runtime::xla_backend_available();
    if !ok {
        eprintln!("skipping XLA variant: no artifacts or XLA crate stubbed");
    }
    ok
}

fn platform(backend: ExecBackend) -> Platform {
    let cfg = match backend {
        ExecBackend::Sim => PlatformConfig::sim("llm-lite"),
        ExecBackend::Xla => PlatformConfig::default_with("artifacts", "llm-lite"),
    };
    Platform::start(&cfg).unwrap()
}

fn naive_rag_template(llm: &str) -> WorkflowTemplate {
    let mut t = WorkflowTemplate::new("naive-rag");
    let idx = t.add(Component {
        name: "indexing".into(),
        kind: ComponentKind::Indexing,
        engine: "embedder".into(),
        batchable: true,
        splittable: false,
    });
    let qe = t.add(Component {
        name: "query-embed".into(),
        kind: ComponentKind::Embedding { of: EmbedSource::Question },
        engine: "embedder".into(),
        batchable: true,
        splittable: false,
    });
    let se = t.add(Component {
        name: "search".into(),
        kind: ComponentKind::VectorSearching { top_k: 3 },
        engine: "vdb".into(),
        batchable: false,
        splittable: false,
    });
    let syn = t.add(Component {
        name: "synth".into(),
        kind: ComponentKind::LlmGenerate {
            variant: llm.into(),
            mode: SynthesisMode::Tree,
            prompt: vec![
                PromptPart::Instruction(instr_tokens("qa", 16)),
                PromptPart::Question,
                PromptPart::Upstream { component: 2, slice: None },
            ],
            out_tokens: 8,
            segments: 1,
            fan: 0,
        },
        engine: llm.into(),
        batchable: false,
        splittable: false,
    });
    t.chain(&[idx, qe, se, syn]);
    t
}

fn naive_rag_end_to_end(platform: &Platform) {
    let t = naive_rag_template("llm-lite");
    let q = QueryConfig::example(42);
    let g = build_pgraph(&t, &q).unwrap();
    let profiles = ProfileRegistry::with_defaults();
    let g = run_passes(g, OptFlags::all(), &profiles).unwrap();
    let e = EGraph::new(g).unwrap();

    let (out, metrics) = platform.run_query(1, e).unwrap();
    match out {
        Value::TokenBatch(rows) => {
            assert!(!rows.is_empty());
            assert!(!rows[0].is_empty());
        }
        other => panic!("unexpected output {other:?}"),
    }
    assert!(metrics.n_engine_ops >= 8, "ops: {}", metrics.n_engine_ops);
    assert!(metrics.exec_us > 0);
}

fn coarse_and_optimized_agree(platform: &Platform) {
    let t = naive_rag_template("llm-lite");
    let q = QueryConfig::example(43);
    let profiles = ProfileRegistry::with_defaults();

    let g1 = run_passes(build_pgraph(&t, &q).unwrap(), OptFlags::none(), &profiles).unwrap();
    let e1 = EGraph::new(g1).unwrap();
    let (out1, _) = platform.run_query(11, e1).unwrap();

    let g2 = run_passes(build_pgraph(&t, &q).unwrap(), OptFlags::all(), &profiles).unwrap();
    let e2 = EGraph::new(g2).unwrap();
    let (out2, _) = platform.run_query(12, e2).unwrap();

    // Same final-answer row count regardless of optimization level.
    assert_eq!(out1.rows().len(), out2.rows().len());
}

fn concurrent_queries(platform: &Platform) {
    let t = naive_rag_template("llm-lite");
    let profiles = ProfileRegistry::with_defaults();

    let mut handles = Vec::new();
    for i in 0..4u64 {
        let q = QueryConfig::example(100 + i);
        let g = run_passes(build_pgraph(&t, &q).unwrap(), OptFlags::all(), &profiles).unwrap();
        let e = EGraph::new(g).unwrap();
        handles.push(platform.spawn_query(100 + i, e));
    }
    for h in handles {
        let (out, m) = h.join().unwrap().unwrap();
        assert!(!out.rows().is_empty());
        assert!(m.e2e_us > 0);
    }
}

// ---- simulated backend: always runs (plain `cargo test`) ----

#[test]
fn sim_naive_rag_runs_end_to_end_optimized() {
    let p = platform(ExecBackend::Sim);
    naive_rag_end_to_end(&p);
    p.shutdown();
}

#[test]
fn sim_coarse_and_optimized_agree_on_structure() {
    let cfg = PlatformConfig::sim("llm-lite").with_policy(BatchPolicy::BlindTO);
    let p = Platform::start(&cfg).unwrap();
    coarse_and_optimized_agree(&p);
    p.shutdown();
}

#[test]
fn sim_concurrent_queries_complete() {
    let p = platform(ExecBackend::Sim);
    concurrent_queries(&p);
    p.shutdown();
}

// ---- XLA backend: needs `make artifacts` ----

#[test]
fn xla_naive_rag_runs_end_to_end_optimized() {
    if !have_artifacts() {
        return;
    }
    let p = platform(ExecBackend::Xla);
    naive_rag_end_to_end(&p);
    p.shutdown();
}

#[test]
fn xla_coarse_and_optimized_agree_on_structure() {
    if !have_artifacts() {
        return;
    }
    let cfg = PlatformConfig::default_with("artifacts", "llm-lite")
        .with_policy(BatchPolicy::BlindTO);
    let p = Platform::start(&cfg).unwrap();
    coarse_and_optimized_agree(&p);
    p.shutdown();
}

#[test]
fn xla_concurrent_queries_complete() {
    if !have_artifacts() {
        return;
    }
    let p = platform(ExecBackend::Xla);
    concurrent_queries(&p);
    p.shutdown();
}
