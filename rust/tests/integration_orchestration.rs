//! End-to-end orchestration integration: real engines, real artifacts,
//! full two-tier scheduling over optimized e-graphs.

use teola::engines::profile::ProfileRegistry;
use teola::graph::pgraph::{build_pgraph, instr_tokens};
use teola::graph::template::*;
use teola::graph::{run_passes, EGraph, OptFlags, Value};
use teola::scheduler::{BatchPolicy, Platform, PlatformConfig};

fn have_artifacts() -> bool {
    let dir = teola::runtime::default_artifacts_dir();
    let ok = dir.join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
    }
    ok
}

fn naive_rag_template(llm: &str) -> WorkflowTemplate {
    let mut t = WorkflowTemplate::new("naive-rag");
    let idx = t.add(Component {
        name: "indexing".into(),
        kind: ComponentKind::Indexing,
        engine: "embedder".into(),
        batchable: true,
        splittable: false,
    });
    let qe = t.add(Component {
        name: "query-embed".into(),
        kind: ComponentKind::Embedding { of: EmbedSource::Question },
        engine: "embedder".into(),
        batchable: true,
        splittable: false,
    });
    let se = t.add(Component {
        name: "search".into(),
        kind: ComponentKind::VectorSearching { top_k: 3 },
        engine: "vdb".into(),
        batchable: false,
        splittable: false,
    });
    let syn = t.add(Component {
        name: "synth".into(),
        kind: ComponentKind::LlmGenerate {
            variant: llm.into(),
            mode: SynthesisMode::Tree,
            prompt: vec![
                PromptPart::Instruction(instr_tokens("qa", 16)),
                PromptPart::Question,
                PromptPart::Upstream { component: 2, slice: None },
            ],
            out_tokens: 8,
            segments: 1,
            fan: 0,
        },
        engine: llm.into(),
        batchable: false,
        splittable: false,
    });
    t.chain(&[idx, qe, se, syn]);
    t
}

#[test]
fn naive_rag_runs_end_to_end_optimized() {
    if !have_artifacts() {
        return;
    }
    let cfg = PlatformConfig::default_with("artifacts", "llm-lite");
    let platform = Platform::start(&cfg).unwrap();

    let t = naive_rag_template("llm-lite");
    let q = QueryConfig::example(42);
    let g = build_pgraph(&t, &q).unwrap();
    let profiles = ProfileRegistry::with_defaults();
    let g = run_passes(g, OptFlags::all(), &profiles).unwrap();
    let e = EGraph::new(g).unwrap();

    let (out, metrics) = platform.run_query(1, e).unwrap();
    match out {
        Value::TokenBatch(rows) => {
            assert!(!rows.is_empty());
            assert!(!rows[0].is_empty());
        }
        other => panic!("unexpected output {other:?}"),
    }
    assert!(metrics.n_engine_ops >= 8, "ops: {}", metrics.n_engine_ops);
    assert!(metrics.exec_us > 0);
    platform.shutdown();
}

#[test]
fn coarse_and_optimized_agree_on_structure() {
    if !have_artifacts() {
        return;
    }
    let cfg = PlatformConfig::default_with("artifacts", "llm-lite")
        .with_policy(BatchPolicy::BlindTO);
    let platform = Platform::start(&cfg).unwrap();
    let t = naive_rag_template("llm-lite");
    let q = QueryConfig::example(43);
    let profiles = ProfileRegistry::with_defaults();

    let g1 = run_passes(build_pgraph(&t, &q).unwrap(), OptFlags::none(), &profiles).unwrap();
    let e1 = EGraph::new(g1).unwrap();
    let (out1, _) = platform.run_query(11, e1).unwrap();

    let g2 = run_passes(build_pgraph(&t, &q).unwrap(), OptFlags::all(), &profiles).unwrap();
    let e2 = EGraph::new(g2).unwrap();
    let (out2, _) = platform.run_query(12, e2).unwrap();

    // Same final-answer row count regardless of optimization level.
    assert_eq!(out1.rows().len(), out2.rows().len());
    platform.shutdown();
}

#[test]
fn concurrent_queries_complete() {
    if !have_artifacts() {
        return;
    }
    let cfg = PlatformConfig::default_with("artifacts", "llm-lite");
    let platform = Platform::start(&cfg).unwrap();
    let t = naive_rag_template("llm-lite");
    let profiles = ProfileRegistry::with_defaults();

    let mut handles = Vec::new();
    for i in 0..4u64 {
        let q = QueryConfig::example(100 + i);
        let g = run_passes(build_pgraph(&t, &q).unwrap(), OptFlags::all(), &profiles).unwrap();
        let e = EGraph::new(g).unwrap();
        handles.push(platform.spawn_query(100 + i, e));
    }
    for h in handles {
        let (out, m) = h.join().unwrap().unwrap();
        assert!(!out.rows().is_empty());
        assert!(m.e2e_us > 0);
    }
    platform.shutdown();
}
