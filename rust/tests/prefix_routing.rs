//! Cross-query KV prefix routing: executor-level hit accounting and LRU
//! eviction on the sim LLM executor, pending-queue dedupe of co-admitted
//! same-prefix prefills, mid-run `prefix_slots` retune semantics, the
//! end-to-end p95 win on an instruction-heavy Poisson trace with routing
//! on vs off, and output determinism with routing enabled.  Trace setup
//! comes from the shared harness in `tests/common/`.

mod common;

use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;

use common::{ctx, instr_heavy_template, prepared_instr_heavy, run_to_idle, serial, sim_llm_exec};
use teola::engines::instance::StepExecutor;
use teola::engines::prefix::prefix_fingerprint;
use teola::engines::profile::ProfileRegistry;
use teola::engines::sim::SimLlmExecutor;
use teola::engines::EngineJob;
use teola::graph::pgraph::{build_pgraph, instr_tokens};
use teola::graph::{run_passes, EGraph, OptFlags};
use teola::scheduler::{BatchPolicy, Platform, PlatformConfig};
use teola::serving::run_load_prepared;
use teola::workload::{Dataset, DatasetKind, PoissonTrace};

/// One fingerprinted prefill job (instruction ++ suffix).
fn fp_prefill(q: u64, instr: &[i32], suffix: usize) -> EngineJob {
    let mut tokens = instr.to_vec();
    tokens.extend(std::iter::repeat(7).take(suffix));
    EngineJob::Prefill {
        seq: (q, 0),
        tokens,
        offset: 0,
        prefix: Some(prefix_fingerprint(instr)),
    }
}

/// Admit one fingerprinted prefill and run it to completion.
fn prefill_step(exec: &mut SimLlmExecutor, q: u64, instr: &[i32], suffix: usize) {
    let (tx, _rx) = channel();
    exec.admit(vec![(ctx(q, 0, tx), fp_prefill(q, instr, suffix))]);
    run_to_idle(exec, &mut Vec::new(), 100);
}

#[test]
fn prefix_hit_charges_only_the_uncached_suffix() {
    let (mut exec, _store) = sim_llm_exec(4);
    let instr = instr_tokens("shared-instr", 16);

    // First query: cold — the full 16+8 tokens are charged and the
    // instruction prefix becomes resident.
    prefill_step(&mut exec, 1, &instr, 8);
    assert_eq!(exec.charged_prefill_tokens(), 24);

    // Second query sharing the instruction: only its 10-token suffix is
    // charged.
    prefill_step(&mut exec, 2, &instr, 10);
    assert_eq!(exec.charged_prefill_tokens(), 34);

    // A different instruction is cold again.
    let other = instr_tokens("other-instr", 16);
    prefill_step(&mut exec, 3, &other, 4);
    assert_eq!(exec.charged_prefill_tokens(), 54);
}

#[test]
fn prefix_registry_evicts_lru_at_prefix_slots() {
    let (mut exec, _store) = sim_llm_exec(2);
    let a = instr_tokens("instr-a", 16);
    let b = instr_tokens("instr-b", 16);
    let c = instr_tokens("instr-c", 16);

    prefill_step(&mut exec, 1, &a, 8); // miss: 24
    prefill_step(&mut exec, 2, &b, 8); // miss: 24
    prefill_step(&mut exec, 3, &a, 8); // hit: 8 (A refreshed, B now LRU)
    prefill_step(&mut exec, 4, &c, 8); // miss: 24 (evicts B)
    prefill_step(&mut exec, 5, &b, 8); // miss again: 24 — B was evicted
    assert_eq!(exec.charged_prefill_tokens(), 24 + 24 + 8 + 24 + 24);
}

#[test]
fn zero_prefix_slots_disables_caching() {
    let (mut exec, _store) = sim_llm_exec(0);
    let instr = instr_tokens("shared-instr", 16);
    prefill_step(&mut exec, 1, &instr, 8);
    prefill_step(&mut exec, 2, &instr, 8);
    // Both queries charged in full.
    assert_eq!(exec.charged_prefill_tokens(), 48);
}

/// Regression (PR 3 gap): prefix registration happened only at step
/// time, so two same-prefix prefills admitted in one burst both
/// prefilled cold.  With pending-queue dedupe the co-admitted batch pays
/// exactly one cold prefill plus one suffix-only charge.
#[test]
fn co_admitted_same_prefix_prefills_pay_one_cold_prefill() {
    let (mut exec, store) = sim_llm_exec(4);
    let instr = instr_tokens("burst-instr", 16);
    let (tx, _rx) = channel();

    // One admission burst, no step in between: the old behavior charged
    // (16+8) + (16+10) = 50; deduped it is (16+8) + 10 = 34.
    exec.admit(vec![
        (ctx(1, 0, tx.clone()), fp_prefill(1, &instr, 8)),
        (ctx(2, 0, tx), fp_prefill(2, &instr, 10)),
    ]);
    run_to_idle(&mut exec, &mut Vec::new(), 100);
    assert_eq!(
        exec.charged_prefill_tokens(),
        34,
        "second co-admitted prefill must be charged suffix-only"
    );
    // KV lengths are unchanged by the dedupe (outputs stay identical).
    assert_eq!(store.lock().unwrap().get(&(1, 0)).unwrap().len, 24);
    assert_eq!(store.lock().unwrap().get(&(2, 0)).unwrap().len, 26);

    // A third query still hits the registered prefix as usual.
    prefill_step(&mut exec, 3, &instr, 4);
    assert_eq!(exec.charged_prefill_tokens(), 38);
}

/// Regression (PR 3 gap): a mid-run `prefix_slots` shrink only took
/// effect at the next insert, so lookups kept serving prefixes past the
/// new budget.  `resync` at admission applies the shrink immediately —
/// an evicted prefix can never serve another hit.
#[test]
fn mid_run_prefix_slots_shrink_evicts_immediately() {
    let (mut exec, _store, slots) = common::sim_llm_exec_with_slots(4);
    let a = instr_tokens("retune-a", 16);
    let b = instr_tokens("retune-b", 16);
    let c = instr_tokens("retune-c", 16);
    prefill_step(&mut exec, 1, &a, 8);
    prefill_step(&mut exec, 2, &b, 8);
    prefill_step(&mut exec, 3, &c, 8); // resident (LRU -> MRU): a, b, c
    let charged = exec.charged_prefill_tokens();

    // Shrink 4 -> 1: only the MRU prefix (c) may survive.  A and B must
    // charge cold again; C still hits.
    slots.store(1, Ordering::Relaxed);
    prefill_step(&mut exec, 4, &a, 8);
    assert_eq!(exec.charged_prefill_tokens(), charged + 24, "evicted prefix must miss");
    // A is now the single resident prefix; C was displaced.
    prefill_step(&mut exec, 5, &c, 8);
    assert_eq!(exec.charged_prefill_tokens(), charged + 48, "displaced prefix must miss");
    prefill_step(&mut exec, 6, &c, 8);
    assert_eq!(exec.charged_prefill_tokens(), charged + 56, "resident prefix still hits");
}

#[test]
fn prefix_routing_cuts_p95_on_instruction_heavy_trace() {
    let _g = serial();

    // Two instances so affinity routing matters: with routing on, each
    // instruction template sticks to the instance holding its KV and
    // every query past the first prefills only its question suffix; with
    // prefix_slots = 0 every query re-prefills the full 64-token
    // instruction on whichever least-loaded instance it lands on.
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.llms[0].instances = 2;
    cfg.prefix_slots = 8;
    let platform = Platform::start(&cfg).unwrap();
    platform.set_policy(BatchPolicy::TopoAware);

    let n = 40;
    let rate = 140.0;
    let seed = 0xF1F0;
    let trace = PoissonTrace::generate(rate, n, seed);

    platform.set_prefix_slots(0);
    let off =
        run_load_prepared(&platform, prepared_instr_heavy(n, seed), &trace.arrivals).unwrap();

    platform.set_prefix_slots(8);
    let on =
        run_load_prepared(&platform, prepared_instr_heavy(n, seed), &trace.arrivals).unwrap();

    platform.shutdown();

    assert_eq!(off.latencies_ms.len(), n);
    assert_eq!(on.latencies_ms.len(), n);
    // Prefix routing must strictly beat the routing-off baseline at the
    // tail on the same seeded trace: the shared instruction prefill is
    // ~2/3 of every query's prefill work.
    assert!(
        on.e2e_ms.p95 < off.e2e_ms.p95,
        "prefix routing p95 {:.1} ms should beat routing-off p95 {:.1} ms",
        on.e2e_ms.p95,
        off.e2e_ms.p95
    );
}

/// Mid-run retune end-to-end: shrinking `prefix_slots` between trace
/// halves must neither hang nor change outputs (the scheduler mirror
/// resyncs instead of routing at phantom residency).
#[test]
fn mid_run_prefix_slots_retune_keeps_serving_correctly() {
    let _g = serial();

    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.llms[0].instances = 2;
    cfg.prefix_slots = 8;
    let platform = Platform::start(&cfg).unwrap();

    let n = 16;
    let seed = 0x7E7E;
    let trace = PoissonTrace::generate(200.0, n, seed);
    let first =
        run_load_prepared(&platform, prepared_instr_heavy(n, seed), &trace.arrivals).unwrap();
    // Shrink the shared budget mid-run, then replay the same trace.
    platform.set_prefix_slots(1);
    let second =
        run_load_prepared(&platform, prepared_instr_heavy(n, seed), &trace.arrivals).unwrap();
    platform.shutdown();

    assert_eq!(first.outputs.len(), n);
    assert_eq!(
        first.outputs, second.outputs,
        "a prefix_slots retune moves KV work, never changes outputs"
    );
}

#[test]
fn outputs_identical_with_prefix_routing_on_and_off() {
    let _g = serial();

    let run_once = |prefix_slots: usize| {
        let mut cfg = PlatformConfig::sim("llm-lite");
        cfg.prefix_slots = prefix_slots;
        let platform = Platform::start(&cfg).unwrap();
        let profiles = ProfileRegistry::with_defaults();
        let mut ds = Dataset::new(DatasetKind::TruthfulQa, 51);
        let q = ds.sample();
        let t = instr_heavy_template("det-instr", "llm-lite", 8);
        let g = build_pgraph(&t, &q).unwrap();
        let g = run_passes(g, OptFlags::all(), &profiles).unwrap();
        let e = EGraph::new(g).unwrap();
        // Two queries back to back so the second sees a resident prefix
        // when routing is on.
        let (warm, _) = platform.run_query(7001, e.clone()).unwrap();
        let (out, _) = platform.run_query(7002, e).unwrap();
        platform.shutdown();
        (warm, out)
    };

    let (warm_on, out_on) = run_once(8);
    let (warm_off, out_off) = run_once(0);
    // A prefix hit changes where KV work happens, never the tokens.
    assert_eq!(warm_on, warm_off);
    assert_eq!(out_on, out_off, "prefix reuse must not change outputs");
    assert!(!out_on.rows().is_empty());
}
