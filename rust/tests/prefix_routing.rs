//! Cross-query KV prefix routing: executor-level hit accounting and LRU
//! eviction on the sim LLM executor, the end-to-end p95 win on an
//! instruction-heavy Poisson trace with routing on vs off, and output
//! determinism with routing enabled.

use std::collections::HashMap;
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use teola::engines::instance::StepExecutor;
use teola::engines::llm::SeqStore;
use teola::engines::prefix::prefix_fingerprint;
use teola::engines::profile::ProfileRegistry;
use teola::engines::sim::SimLlmExecutor;
use teola::engines::{Completion, EngineJob, RequestCtx};
use teola::graph::pgraph::{build_pgraph, instr_tokens};
use teola::graph::template::*;
use teola::graph::{run_passes, EGraph, OptFlags};
use teola::scheduler::{BatchPolicy, Platform, PlatformConfig};
use teola::serving::run_load_prepared;
use teola::workload::{Dataset, DatasetKind, PoissonTrace};

// The serving comparison is timing-sensitive; serialize the platform
// tests in this binary so they don't compete for cores.
static SERIAL: Mutex<()> = Mutex::new(());

const SEP: i32 = 3;
const EOS: i32 = 2;

static DEVICE_OFF: std::sync::Once = std::sync::Once::new();

fn new_exec(prefix_slots: usize) -> SimLlmExecutor {
    // Raw CPU pacing for the executor-level tests (charging is asserted
    // via the valid-token counter, not wall time).  Set exactly once:
    // concurrent setenv calls are a data race.
    DEVICE_OFF.call_once(|| std::env::set_var("TEOLA_DEVICE_OFF", "1"));
    let store: SeqStore = Arc::new(Mutex::new(HashMap::new()));
    let slots = Arc::new(AtomicUsize::new(prefix_slots));
    SimLlmExecutor::new("llm-lite", store, SEP, EOS, 1024, slots)
}

fn ctx(query: u64, node: usize, reply: std::sync::mpsc::Sender<Completion>) -> RequestCtx {
    RequestCtx { query, node, depth: 0, arrival: Instant::now(), reply }
}

/// Admit one fingerprinted prefill (instruction ++ suffix) and run it.
fn prefill_step(exec: &mut SimLlmExecutor, q: u64, instr: &[i32], suffix: usize) {
    let (tx, _rx) = channel();
    let mut tokens = instr.to_vec();
    tokens.extend(std::iter::repeat(7).take(suffix));
    exec.admit(vec![(
        ctx(q, 0, tx),
        EngineJob::Prefill {
            seq: (q, 0),
            tokens,
            offset: 0,
            prefix: Some(prefix_fingerprint(instr)),
        },
    )]);
    while exec.resident() > 0 {
        exec.step(&mut |_| {}).unwrap();
    }
}

#[test]
fn prefix_hit_charges_only_the_uncached_suffix() {
    let mut exec = new_exec(4);
    let instr = instr_tokens("shared-instr", 16);

    // First query: cold — the full 16+8 tokens are charged and the
    // instruction prefix becomes resident.
    prefill_step(&mut exec, 1, &instr, 8);
    assert_eq!(exec.charged_prefill_tokens(), 24);

    // Second query sharing the instruction: only its 10-token suffix is
    // charged.
    prefill_step(&mut exec, 2, &instr, 10);
    assert_eq!(exec.charged_prefill_tokens(), 34);

    // A different instruction is cold again.
    let other = instr_tokens("other-instr", 16);
    prefill_step(&mut exec, 3, &other, 4);
    assert_eq!(exec.charged_prefill_tokens(), 54);
}

#[test]
fn prefix_registry_evicts_lru_at_prefix_slots() {
    let mut exec = new_exec(2);
    let a = instr_tokens("instr-a", 16);
    let b = instr_tokens("instr-b", 16);
    let c = instr_tokens("instr-c", 16);

    prefill_step(&mut exec, 1, &a, 8); // miss: 24
    prefill_step(&mut exec, 2, &b, 8); // miss: 24
    prefill_step(&mut exec, 3, &a, 8); // hit: 8 (A refreshed, B now LRU)
    prefill_step(&mut exec, 4, &c, 8); // miss: 24 (evicts B)
    prefill_step(&mut exec, 5, &b, 8); // miss again: 24 — B was evicted
    assert_eq!(exec.charged_prefill_tokens(), 24 + 24 + 8 + 24 + 24);
}

#[test]
fn zero_prefix_slots_disables_caching() {
    let mut exec = new_exec(0);
    let instr = instr_tokens("shared-instr", 16);
    prefill_step(&mut exec, 1, &instr, 8);
    prefill_step(&mut exec, 2, &instr, 8);
    // Both queries charged in full.
    assert_eq!(exec.charged_prefill_tokens(), 48);
}

/// Instruction-heavy one-shot workflow: a 64-token shared instruction
/// template dominates each query's prefill.
fn instr_heavy_template(instr_name: &str, llm: &str, out_tokens: usize) -> WorkflowTemplate {
    let mut t = WorkflowTemplate::new("instr-heavy");
    t.add(Component {
        name: "gen".into(),
        kind: ComponentKind::LlmGenerate {
            variant: llm.into(),
            mode: SynthesisMode::OneShot,
            prompt: vec![
                PromptPart::Instruction(instr_tokens(instr_name, 64)),
                PromptPart::Question,
            ],
            out_tokens,
            segments: 1,
            fan: 1,
        },
        engine: llm.into(),
        batchable: false,
        splittable: false,
    });
    t
}

/// Build `n` optimized instruction-heavy e-graphs; queries alternate
/// between two instruction templates (two distinct shared prefixes).
fn prepared_instr_heavy(n: usize, seed: u64) -> Vec<(EGraph, u64)> {
    let profiles = ProfileRegistry::with_defaults();
    let mut ds = Dataset::new(DatasetKind::WebQuestions, seed);
    (0..n)
        .map(|i| {
            let name = if i % 2 == 0 { "instr-even" } else { "instr-odd" };
            let t = instr_heavy_template(name, "llm-lite", 4 + i % 3);
            let q = ds.sample();
            let g = build_pgraph(&t, &q).unwrap();
            let g = run_passes(g, OptFlags::all(), &profiles).unwrap();
            (EGraph::new(g).unwrap(), 0u64)
        })
        .collect()
}

#[test]
fn prefix_routing_cuts_p95_on_instruction_heavy_trace() {
    let _g = SERIAL.lock().unwrap();

    // Two instances so affinity routing matters: with routing on, each
    // instruction template sticks to the instance holding its KV and
    // every query past the first prefills only its question suffix; with
    // prefix_slots = 0 every query re-prefills the full 64-token
    // instruction on whichever least-loaded instance it lands on.
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.llms[0].instances = 2;
    cfg.prefix_slots = 8;
    let platform = Platform::start(&cfg).unwrap();
    platform.set_policy(BatchPolicy::TopoAware);

    let n = 40;
    let rate = 140.0;
    let seed = 0xF1F0;
    let trace = PoissonTrace::generate(rate, n, seed);

    platform.set_prefix_slots(0);
    let off =
        run_load_prepared(&platform, prepared_instr_heavy(n, seed), &trace.arrivals).unwrap();

    platform.set_prefix_slots(8);
    let on =
        run_load_prepared(&platform, prepared_instr_heavy(n, seed), &trace.arrivals).unwrap();

    platform.shutdown();

    assert_eq!(off.latencies_ms.len(), n);
    assert_eq!(on.latencies_ms.len(), n);
    // Prefix routing must strictly beat the routing-off baseline at the
    // tail on the same seeded trace: the shared instruction prefill is
    // ~2/3 of every query's prefill work.
    assert!(
        on.e2e_ms.p95 < off.e2e_ms.p95,
        "prefix routing p95 {:.1} ms should beat routing-off p95 {:.1} ms",
        on.e2e_ms.p95,
        off.e2e_ms.p95
    );
}

#[test]
fn outputs_identical_with_prefix_routing_on_and_off() {
    let _g = SERIAL.lock().unwrap();

    let run_once = |prefix_slots: usize| {
        let mut cfg = PlatformConfig::sim("llm-lite");
        cfg.prefix_slots = prefix_slots;
        let platform = Platform::start(&cfg).unwrap();
        let profiles = ProfileRegistry::with_defaults();
        let mut ds = Dataset::new(DatasetKind::TruthfulQa, 51);
        let q = ds.sample();
        let t = instr_heavy_template("det-instr", "llm-lite", 8);
        let g = build_pgraph(&t, &q).unwrap();
        let g = run_passes(g, OptFlags::all(), &profiles).unwrap();
        let e = EGraph::new(g).unwrap();
        // Two queries back to back so the second sees a resident prefix
        // when routing is on.
        let (warm, _) = platform.run_query(7001, e.clone()).unwrap();
        let (out, _) = platform.run_query(7002, e).unwrap();
        platform.shutdown();
        (warm, out)
    };

    let (warm_on, out_on) = run_once(8);
    let (warm_off, out_off) = run_once(0);
    // A prefix hit changes where KV work happens, never the tokens.
    assert_eq!(warm_on, warm_off);
    assert_eq!(out_on, out_off, "prefix reuse must not change outputs");
    assert!(!out_on.rows().is_empty());
}
