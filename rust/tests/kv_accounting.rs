//! Token-denominated KV memory accounting (PR5): executor-side admission
//! control against the per-instance token budget (reserve-at-admit,
//! release-at-retire, suffix-only reservations on prefix hits, bounce of
//! over-budget admissions), and the end-to-end acceptance bar — on the
//! mixed 8-16/128-token heterogeneous sim trace, token accounting
//! strictly beats legacy row-slot accounting at the tail with
//! bit-identical outputs.  Trace setup comes from the shared harness in
//! `tests/common/`.

mod common;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

use common::{ctx, decode_job, prefill_job, run_to_idle, serial, sim_llm_exec_with_slots};
use teola::engines::instance::StepExecutor;
use teola::engines::prefix::prefix_fingerprint;
use teola::engines::EngineJob;
use teola::scheduler::{Platform, PlatformConfig};
use teola::serving::run_kv_comparison;

/// Sim executor bound to a KV token budget of `cap` (prefix cache off).
fn kv_exec(cap: usize) -> (teola::engines::sim::SimLlmExecutor, Arc<AtomicUsize>) {
    let (exec, _store, _slots) = sim_llm_exec_with_slots(0);
    let handle = Arc::new(AtomicUsize::new(cap));
    (exec.with_kv_budget(handle.clone()), handle)
}

#[test]
fn executor_reserves_at_admit_and_releases_at_retire() {
    let (mut exec, _cap) = kv_exec(64);
    let (tx, _rx) = channel();

    // A 24-token prefill reserves 24; a 16-token decode reserves 16.
    let bounced = exec.admit(vec![(ctx(1, 0, tx.clone()), prefill_job(1, 0, 24))]);
    assert!(bounced.is_empty());
    assert_eq!(exec.kv_reserved(), 24);
    run_to_idle(&mut exec, &mut Vec::new(), 100);
    assert_eq!(exec.kv_reserved(), 0, "prefill retirement releases its reservation");

    let bounced = exec.admit(vec![(ctx(1, 1, tx), decode_job(1, 1, 0, 16))]);
    assert!(bounced.is_empty());
    assert_eq!(exec.kv_reserved(), 16);
    run_to_idle(&mut exec, &mut Vec::new(), 100);
    assert_eq!(exec.kv_reserved(), 0, "decode retirement releases its reservation");
}

#[test]
fn executor_bounces_over_budget_admissions_until_space_frees() {
    let (mut exec, _cap) = kv_exec(40);
    let (tx, _rx) = channel();

    let bounced = exec.admit(vec![(ctx(1, 0, tx.clone()), prefill_job(1, 0, 32))]);
    assert!(bounced.is_empty());
    assert_eq!(exec.kv_reserved(), 32);

    // A second 32-token prefill exceeds the 40-token budget: bounced
    // back (not dropped, not admitted), leaving the ledger untouched.
    let bounced = exec.admit(vec![(ctx(2, 0, tx.clone()), prefill_job(2, 0, 32))]);
    assert_eq!(bounced.len(), 1);
    assert_eq!(bounced[0].0.query, 2);
    assert_eq!(exec.kv_reserved(), 32);

    // After the first prefill retires, the bounced job is admittable.
    run_to_idle(&mut exec, &mut Vec::new(), 100);
    assert_eq!(exec.kv_reserved(), 0);
    let bounced = exec.admit(bounced);
    assert!(bounced.is_empty(), "freed budget must admit the retried job");
    assert_eq!(exec.kv_reserved(), 32);
    run_to_idle(&mut exec, &mut Vec::new(), 100);
}

#[test]
fn idle_executor_accepts_oversized_job_for_liveness() {
    let (mut exec, _cap) = kv_exec(16);
    let (tx, _rx) = channel();

    // 100 tokens > the whole 16-token budget, but the executor is empty:
    // it must accept (and chunk internally) rather than starve the job.
    let bounced = exec.admit(vec![(ctx(1, 0, tx), prefill_job(1, 0, 100))]);
    assert!(bounced.is_empty(), "an empty executor accepts any job");
    assert_eq!(exec.kv_reserved(), 100);
    run_to_idle(&mut exec, &mut Vec::new(), 200);
    assert_eq!(exec.kv_reserved(), 0);
}

#[test]
fn prefix_hit_reservation_is_suffix_only() {
    let (exec, _store, _slots) = sim_llm_exec_with_slots(4);
    let handle = Arc::new(AtomicUsize::new(256));
    let mut exec = exec.with_kv_budget(handle);
    let (tx, _rx) = channel();
    let instr: Vec<i32> = (0..16).map(|i| 50 + i).collect();
    let fp = prefix_fingerprint(&instr);
    let fp_prefill = |q: u64, suffix: usize| {
        let mut tokens = instr.clone();
        tokens.extend(std::iter::repeat(7).take(suffix));
        EngineJob::Prefill { seq: (q, 0), tokens, offset: 0, prefix: Some(fp) }
    };

    // Cold: the full 16+8 tokens are reserved.
    exec.admit(vec![(ctx(1, 0, tx.clone()), fp_prefill(1, 8))]);
    assert_eq!(exec.kv_reserved(), 24);
    run_to_idle(&mut exec, &mut Vec::new(), 100);
    assert_eq!(exec.kv_reserved(), 0);

    // Warm: the resident 16-token instruction is served from KV, so the
    // reservation covers only the 10-token suffix.
    exec.admit(vec![(ctx(2, 0, tx), fp_prefill(2, 10))]);
    assert_eq!(exec.kv_reserved(), 10, "prefix hit must reserve suffix only");
    run_to_idle(&mut exec, &mut Vec::new(), 100);
    assert_eq!(exec.kv_reserved(), 0);
}

#[test]
fn runtime_kv_retune_applies_at_next_admission() {
    let (mut exec, cap) = kv_exec(24);
    let (tx, _rx) = channel();

    let bounced = exec.admit(vec![(ctx(1, 0, tx.clone()), prefill_job(1, 0, 20))]);
    assert!(bounced.is_empty());
    // 20/24 used: a 16-token decode bounces...
    let bounced = exec.admit(vec![(ctx(1, 1, tx.clone()), decode_job(1, 1, 0, 16))]);
    assert_eq!(bounced.len(), 1);
    // ...until the shared handle is retuned upward mid-run.
    cap.store(64, Ordering::Relaxed);
    let bounced = exec.admit(bounced);
    assert!(bounced.is_empty(), "retuned budget admits the bounced job");
    run_to_idle(&mut exec, &mut Vec::new(), 100);
    assert_eq!(exec.kv_reserved(), 0);
}

/// Acceptance bar (PR5): on the mixed 8-16/128-token heterogeneous sim
/// trace (one LLM instance so admission pressure is visible), token
/// accounting strictly beats legacy row-slot accounting at the tail —
/// short prefills no longer burn a full row slot each, so they batch
/// densely instead of queueing behind row exhaustion — and outputs are
/// bit-identical across both modes (accounting moves work in time, never
/// changes results).
#[test]
fn token_accounting_cuts_p95_on_heterogeneous_trace_with_identical_outputs() {
    let _g = serial();

    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.llms[0].instances = 1;
    let platform = Platform::start(&cfg).unwrap();
    // The derived default budget: max_slots (8) x sim max_seq (256).
    assert_eq!(platform.kv_tokens_of("llm-lite"), Some(2048));
    assert_eq!(platform.kv_tokens_of("embedder"), None, "encoders stay row-mode");

    // Rate 200/s needs ~10 concurrent short rows to keep up — past the
    // 8-row slot cap, so row mode queues structurally while the token
    // budget (a few hundred KV tokens in flight vs 2048) absorbs it.
    let n = 40;
    let (off, on) = run_kv_comparison(&platform, n, 200.0, 0x9C5).unwrap();
    // The comparison restores the caller's prior budget (the derived
    // default here) when it finishes.
    assert_eq!(platform.kv_tokens_of("llm-lite"), Some(2048));
    platform.shutdown();

    assert_eq!(off.latencies_ms.len(), n);
    assert_eq!(on.latencies_ms.len(), n);
    assert!(
        on.e2e_ms.p95 < off.e2e_ms.p95,
        "token accounting p95 {:.1} ms should beat row-slot p95 {:.1} ms",
        on.e2e_ms.p95,
        off.e2e_ms.p95
    );
    assert_eq!(on.outputs.len(), n);
    assert_eq!(
        on.outputs, off.outputs,
        "KV accounting must not change any query's output, only its timing"
    );
}
