//! PR8 multi-tenant QoS: the tenant stamp threads through every layer
//! (workload → graph scheduler → engine scheduler → KV ledger), the
//! `TEOLA_*` knob surface round-trips through `PlatformConfig`, and —
//! the determinism bar — a *disabled* tenancy registry makes the stamp
//! completely inert: outputs are bit-identical whether queries carry
//! real tenant ids or run untenanted.
//!
//! Everything runs on the sim backend (deterministic, no artifacts).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use teola::bench::{apply_env_knobs, tenant_mix_prepared};
use teola::engines::instance::Instance;
use teola::engines::sim::ExecBackend;
use teola::engines::{
    Batch, Completion, EngineJob, EngineKind, ExecMode, ExecTiming, InstanceEvent,
    JobOutput, QueryId, TenantId, UNTENANTED,
};
use teola::scheduler::tenancy::{SharedTenancy, TenancyConfig};
use teola::scheduler::{
    BatchPolicy, EngineScheduler, Platform, PlatformConfig, QueueItem,
};
use teola::serving::{run_load_tenants, TENANT_HEAVY, TENANT_LIGHT};
use teola::workload::{MultiTenantTrace, TenantLoad};

mod common;

/// Restores the captured `TEOLA_*` variables on drop, so a panicking
/// assertion can't leak knob settings into the other tests of this
/// binary (they all run under `common::serial()`).
struct EnvGuard {
    saved: Vec<(&'static str, Option<String>)>,
}

impl EnvGuard {
    fn capture(keys: &'static [&'static str]) -> EnvGuard {
        EnvGuard { saved: keys.iter().map(|k| (*k, std::env::var(k).ok())).collect() }
    }
}

impl Drop for EnvGuard {
    fn drop(&mut self) {
        for (k, v) in &self.saved {
            match v {
                Some(v) => std::env::set_var(k, v),
                None => std::env::remove_var(k),
            }
        }
    }
}

/// Satellite: every `TEOLA_*` environment knob — including the new
/// `TEOLA_TENANCY` — parses onto `PlatformConfig` through the single
/// shared surface (`bench::apply_env_knobs`), and unset knobs leave the
/// config untouched.  The tenancy spec string additionally round-trips
/// `parse → to_spec → parse` unchanged.
#[test]
fn env_knobs_round_trip_through_config() {
    let _guard = common::serial();
    const KEYS: &[&str] = &[
        "TEOLA_BACKEND",
        "TEOLA_BATCH_WINDOW_US",
        "TEOLA_PREFIX_SLOTS",
        "TEOLA_CONTINUOUS",
        "TEOLA_KV_TOKENS",
        "TEOLA_KV_WATERMARK",
        "TEOLA_KV_WATERMARK_LLM",
        "TEOLA_WCP",
        "TEOLA_PIPELINE",
        "TEOLA_TENANCY",
        "TEOLA_SCHED_INCREMENTAL",
    ];
    let _env = EnvGuard::capture(KEYS);

    let spec = "1:w=4,class=interactive,deadline_ms=250;2:w=1,class=batch,kv_pct=60";
    std::env::set_var("TEOLA_BACKEND", "sim");
    std::env::set_var("TEOLA_BATCH_WINDOW_US", "1234");
    std::env::set_var("TEOLA_PREFIX_SLOTS", "5");
    std::env::set_var("TEOLA_CONTINUOUS", "off");
    std::env::set_var("TEOLA_KV_TOKENS", "4096");
    std::env::set_var("TEOLA_KV_WATERMARK", "70");
    std::env::set_var("TEOLA_KV_WATERMARK_LLM", "55");
    std::env::set_var("TEOLA_WCP", "off");
    std::env::set_var("TEOLA_PIPELINE", "off");
    std::env::set_var("TEOLA_TENANCY", spec);
    std::env::set_var("TEOLA_SCHED_INCREMENTAL", "off");

    let mut cfg = PlatformConfig::default_with("artifacts", "llm-lite");
    apply_env_knobs(&mut cfg);
    assert_eq!(cfg.backend, ExecBackend::Sim);
    assert_eq!(cfg.batch_window_us, 1234);
    assert_eq!(cfg.prefix_slots, 5);
    assert!(!cfg.continuous);
    assert_eq!(cfg.kv_tokens_per_instance, Some(4096));
    assert_eq!(cfg.kv_watermark, 70);
    assert!(
        cfg.kv_watermark_overrides.contains(&(EngineKind::Llm, 55)),
        "per-kind watermark override must land: {:?}",
        cfg.kv_watermark_overrides
    );
    assert!(!cfg.wcp);
    assert!(!cfg.pipeline);
    assert!(!cfg.sched_incremental);
    assert_eq!(cfg.tenancy, TenancyConfig::parse(spec).unwrap());
    // The spec grammar is its own snapshot format: to_spec -> parse is
    // the identity, and this spec renders back verbatim.
    assert_eq!(cfg.tenancy.to_spec(), spec);
    assert_eq!(TenancyConfig::parse(&cfg.tenancy.to_spec()).unwrap(), cfg.tenancy);

    // With every knob unset, apply_env_knobs must be a no-op.
    for k in KEYS {
        std::env::remove_var(k);
    }
    let dfl = PlatformConfig::default_with("artifacts", "llm-lite");
    let mut fresh = PlatformConfig::default_with("artifacts", "llm-lite");
    apply_env_knobs(&mut fresh);
    assert_eq!(fresh.backend, dfl.backend);
    assert_eq!(fresh.batch_window_us, dfl.batch_window_us);
    assert_eq!(fresh.prefix_slots, dfl.prefix_slots);
    assert_eq!(fresh.continuous, dfl.continuous);
    assert_eq!(fresh.kv_tokens_per_instance, dfl.kv_tokens_per_instance);
    assert_eq!(fresh.kv_watermark, dfl.kv_watermark);
    assert_eq!(fresh.kv_watermark_overrides, dfl.kv_watermark_overrides);
    assert_eq!(fresh.wcp, dfl.wcp);
    assert_eq!(fresh.pipeline, dfl.pipeline);
    assert_eq!(fresh.tenancy, dfl.tenancy);
    assert_eq!(fresh.sched_incremental, dfl.sched_incremental);
}

/// The runtime registry round-trips: a config set at startup is what
/// `tenancy_snapshot` reports, `set_tenancy`/`restore_tenancy` flip the
/// live state, and the snapshot re-renders to a parseable spec string.
#[test]
fn tenancy_config_round_trips_through_platform() {
    let _guard = common::serial();
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.warm = false;
    cfg.tenancy = TenancyConfig::parse("3:w=2,class=batch,kv_pct=25").unwrap();
    let platform = Platform::start(&cfg).expect("platform");

    assert!(platform.tenancy_enabled());
    let snap = platform.tenancy_snapshot();
    assert_eq!(snap, cfg.tenancy);
    assert_eq!(TenancyConfig::parse(&snap.to_spec()).unwrap(), snap);

    platform.set_tenancy(&TenancyConfig::default());
    assert!(!platform.tenancy_enabled());
    platform.restore_tenancy(&snap);
    assert!(platform.tenancy_enabled());
    assert_eq!(platform.tenancy_snapshot(), snap);
    platform.shutdown();
}

/// Satellite (PR7 handoff x PR8): with tenancy *and* pipelining on (the
/// default config pipelines), a mixed two-tenant trace completes end to
/// end and every query — including the successor jobs the serving
/// instance hands off engine-side — stays accounted to its tenant: the
/// per-tenant report recovers exactly the issued counts of the trace.
/// No deadlines are configured, so admission control never sheds and
/// completion must be total.
#[test]
fn tenancy_on_accounts_every_query_to_its_tenant() {
    let _guard = common::serial();
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.warm = false;
    let platform = Platform::start(&cfg).expect("platform");
    let ten = TenancyConfig::parse("1:w=4,class=interactive;2:w=1,class=batch").unwrap();
    platform.set_tenancy(&ten);

    let loads = [
        TenantLoad { tenant: TENANT_LIGHT, rate: 200.0, n: 5 },
        TenantLoad { tenant: TENANT_HEAVY, rate: 200.0, n: 10 },
    ];
    let trace = MultiTenantTrace::generate(&loads, 0x8E8);
    let tenant_seq: Vec<TenantId> = trace.arrivals.iter().map(|(_, t)| *t).collect();
    let report = run_load_tenants(
        &platform,
        tenant_mix_prepared(&tenant_seq, 0x8E8),
        &trace.arrivals,
        &ten,
        |i| 0x8E8_0000 + i as QueryId,
    )
    .expect("trace");
    platform.shutdown();

    assert_eq!(report.outputs.len(), 15, "no deadline -> nothing shed");
    assert_eq!(report.tenants.len(), 2, "one report per tenant");
    let light = &report.tenants[0];
    let heavy = &report.tenants[1];
    assert_eq!(
        (light.tenant, light.issued, light.completed, light.shed),
        (TENANT_LIGHT, 5, 5, 0)
    );
    assert_eq!(
        (heavy.tenant, heavy.issued, heavy.completed, heavy.shed),
        (TENANT_HEAVY, 10, 10, 0)
    );
    // No deadline means every completion meets its (vacuous) SLO.
    assert!((light.goodput - 1.0).abs() < 1e-9);
    assert!((heavy.goodput - 1.0).abs() < 1e-9);
}

/// Tentpole determinism bar: with the registry *disabled* (the default),
/// the tenant stamp is invisible — the same seeded trace produces
/// bit-identical outputs whether queries carry their real tenant ids or
/// all run [`UNTENANTED`].  This pins the off-path of every PR8 touch
/// point (queue ranks, fair charging, shedding, quota eviction) to the
/// tenant-blind behavior.
#[test]
fn disabled_tenancy_makes_the_tenant_stamp_inert() {
    let _guard = common::serial();
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.warm = false;
    let platform = Platform::start(&cfg).expect("platform");
    assert!(!platform.tenancy_enabled(), "tenancy must default off");

    let loads = [
        TenantLoad { tenant: TENANT_LIGHT, rate: 150.0, n: 6 },
        TenantLoad { tenant: TENANT_HEAVY, rate: 150.0, n: 6 },
    ];
    let trace = MultiTenantTrace::generate(&loads, 0x8E9);
    let tenant_seq: Vec<TenantId> = trace.arrivals.iter().map(|(_, t)| *t).collect();
    let ten = TenancyConfig::default();

    // Half 1: queries stamped with their real tenants, registry off.
    teola::scheduler::wcp::reset_latency_feedback();
    let stamped = run_load_tenants(
        &platform,
        tenant_mix_prepared(&tenant_seq, 0x8E9),
        &trace.arrivals,
        &ten,
        |i| 0x8E9_0000 + i as QueryId,
    )
    .expect("stamped half");

    // Half 2: identical graphs and arrival offsets, every query
    // untenanted (fresh query ids; let queued FreeQuery cleanup land).
    let blank: Vec<(Duration, TenantId)> =
        trace.arrivals.iter().map(|(d, _)| (*d, UNTENANTED)).collect();
    teola::scheduler::wcp::reset_latency_feedback();
    std::thread::sleep(Duration::from_millis(50));
    let untenanted = run_load_tenants(
        &platform,
        tenant_mix_prepared(&tenant_seq, 0x8E9),
        &blank,
        &ten,
        |i| 0x8E9_4000 + i as QueryId,
    )
    .expect("untenanted half");
    platform.shutdown();

    assert_eq!(stamped.outputs.len(), 12);
    assert_eq!(
        stamped.outputs, untenanted.outputs,
        "disabled tenancy must make the tenant stamp invisible in outputs"
    );
}

/// Loopback executor for the QoS regression tests below: every job
/// completes instantly with `Unit` and the whole batch retires in one
/// instance event, so — with a single instance and full-batch dispatch
/// — the order completions arrive on a shared reply channel *is* the
/// scheduler's dispatch priority order.
fn loopback_instance(index: usize, ev_tx: Sender<InstanceEvent>) -> Instance {
    let (tx, rx) = channel::<Batch>();
    let handle = std::thread::spawn(move || {
        while let Ok(batch) = rx.recv() {
            let mut retired = 0usize;
            let mut retired_tokens = 0usize;
            for (ctx, job) in batch.jobs {
                retired += job.slot_rows();
                retired_tokens += ctx.kv_tokens;
                let _ = ctx.reply.send(Completion {
                    query: ctx.query,
                    node: ctx.node,
                    output: JobOutput::Unit,
                    timing: ExecTiming::default(),
                });
            }
            let _ = ev_tx.send(InstanceEvent {
                instance: index,
                resident: 0,
                retired,
                retired_tokens,
                resident_added: 0,
                resident_freed: 0,
            });
        }
    });
    Instance { sender: tx, handle }
}

/// Engine scheduler wired for the QoS regression tests: one loopback
/// instance, `TopoAware` full-batch dispatch over `slots` row slots, the
/// given batching window, WCP ordering *off* (tenant rank must be the
/// only cross-bucket discriminator), and the shared tenancy handle under
/// test.  Returned unspawned so a test can pre-seed the job channel and
/// have the first dispatch pass see the whole queue at once.
fn qos_sched(
    name: &str,
    tenancy: Arc<SharedTenancy>,
    slots: usize,
    window_us: u64,
) -> (Sender<QueueItem>, EngineScheduler) {
    let (ev_tx, ev_rx) = channel::<InstanceEvent>();
    let (job_tx, job_rx) = channel::<QueueItem>();
    let sched = EngineScheduler::new(
        name.to_string(),
        vec![loopback_instance(0, ev_tx)],
        ev_rx,
        job_rx,
        Arc::new(AtomicU8::new(BatchPolicy::TopoAware.to_u8())),
        Arc::new(AtomicUsize::new(slots)),
        Arc::new(AtomicBool::new(false)),
        Arc::new(AtomicU64::new(window_us)),
        Arc::new(AtomicUsize::new(0)),
        Arc::new(AtomicBool::new(false)),
        Arc::new(AtomicUsize::new(0)),
        Arc::new(AtomicUsize::new(0)),
        ExecMode::FullBatch,
        tenancy,
        Arc::new(AtomicBool::new(true)),
        Arc::new(teola::scheduler::stats::SchedCounters::new()),
    );
    (job_tx, sched)
}

/// Single-row tool-call item stamped with a tenant and an explicit
/// arrival (the QoS tests backdate arrivals to force deadline breaches).
fn qos_item(query: u64, tenant: TenantId, arrival: Instant, reply: Sender<Completion>) -> QueueItem {
    QueueItem {
        query,
        node: 1,
        depth: 0,
        bundle: (query, 1),
        arrival,
        rows: 1,
        tokens: 1,
        wcp_discounted: false,
        prefix: None,
        wcp_us: 0,
        tenant,
        job: EngineJob::ToolCall { name: "qos".into(), cost_us: 0 },
        reply,
        successors: Vec::new(),
    }
}

/// PR9 satellite regression: a runtime tenancy retune must reset the
/// fair-queueing ledger.  Phase 1 serves four tenant-1 batches, driving
/// its SFQ virtual-start tag well past tenant 2's.  After `configure`
/// bumps the registry epoch, a contended two-tenant batch must order by
/// the *fresh* ledger — the virtual-start tags tie at zero and the rank
/// tie-break picks tenant 1 — even though tenant 2's item arrived first
/// and the stale ledger would have ranked tenant 2 strictly ahead.
#[test]
fn tenancy_retune_resets_fair_queue_ledger() {
    let _guard = common::serial();
    let ten = Arc::new(SharedTenancy::default());
    ten.configure(
        &TenancyConfig::parse("1:w=1,class=interactive;2:w=1,class=interactive").unwrap(),
    );
    let (job_tx, sched) = qos_sched("qos-retune", ten.clone(), 2, 200_000);
    let sched_h = std::thread::spawn(move || sched.run());

    // Phase 1: four tenant-1 jobs -> two full batches, four SFQ charges.
    let (tx1, rx1) = channel();
    let now = Instant::now();
    for i in 0..4u64 {
        job_tx.send(qos_item(100 + i, 1, now, tx1.clone())).unwrap();
    }
    for _ in 0..4 {
        let c = rx1.recv_timeout(Duration::from_secs(5)).expect("phase-1 job completes");
        assert!(!matches!(c.output, JobOutput::Failed(_)), "phase 1 failed: {:?}", c.output);
    }

    // Retune mid-run: new registry generation, fresh ledger.
    ten.configure(
        &TenancyConfig::parse("1:w=2,class=interactive;2:w=2,class=interactive").unwrap(),
    );

    // Phase 2: tenant 2 first into the queue, tenant 1 right behind; the
    // 200ms batching window holds the single-item batch until both are
    // queued, so one contended batch carries both and its internal order
    // is the rank order.
    let (tx2, rx2) = channel();
    let base = Instant::now();
    job_tx.send(qos_item(201, 2, base, tx2.clone())).unwrap();
    job_tx.send(qos_item(202, 1, base + Duration::from_micros(500), tx2.clone())).unwrap();
    let first = rx2.recv_timeout(Duration::from_secs(5)).expect("phase-2 first completion");
    assert_eq!(
        first.query, 202,
        "retune must reset the SFQ ledger: tenant 1 ranks first on a fresh ledger, \
         so its item dispatches ahead of tenant 2's despite the later arrival"
    );
    let second = rx2.recv_timeout(Duration::from_secs(5)).expect("phase-2 second completion");
    assert_eq!(second.query, 201);

    drop(job_tx);
    sched_h.join().expect("scheduler thread exits");
}

/// PR9 satellite regression: admission-control shedding is bounded and
/// newest-first.  With a breached Interactive item needing one row of
/// budget, exactly one Batch-class victim — the *newest* — is shed; the
/// two older Batch items (the most sunk queueing investment) survive the
/// breach and complete normally alongside the Interactive item.  (PR8
/// shed the entire Batch backlog here.)
#[test]
fn admission_shed_is_bounded_and_newest_first() {
    let _guard = common::serial();
    let ten = Arc::new(SharedTenancy::default());
    ten.configure(
        &TenancyConfig::parse("1:w=1,class=interactive,deadline_ms=10;2:w=1,class=batch")
            .unwrap(),
    );
    let (job_tx, sched) = qos_sched("qos-shed", ten, 8, 0);

    // Seed the whole scenario before the scheduler thread starts, so the
    // first dispatch pass sees the full queue: three Batch-class items
    // (oldest to newest) and an Interactive item 50ms past its 10ms
    // deadline — already breached on arrival.
    let now = Instant::now();
    let (tx, rx) = channel();
    job_tx.send(qos_item(301, 2, now - Duration::from_millis(100), tx.clone())).unwrap();
    job_tx.send(qos_item(302, 2, now - Duration::from_millis(80), tx.clone())).unwrap();
    job_tx.send(qos_item(303, 2, now - Duration::from_millis(60), tx.clone())).unwrap();
    job_tx.send(qos_item(401, 1, now - Duration::from_millis(50), tx.clone())).unwrap();
    drop(tx);
    let sched_h = std::thread::spawn(move || sched.run());

    let mut outcomes: HashMap<u64, JobOutput> = HashMap::new();
    for _ in 0..4 {
        let c = rx.recv_timeout(Duration::from_secs(5)).expect("every item gets a completion");
        outcomes.insert(c.query, c.output);
    }
    match outcomes.get(&303) {
        Some(JobOutput::Failed(msg)) => assert!(
            msg.contains("shed by admission control"),
            "newest Batch item must be shed by admission control, got: {msg}"
        ),
        other => panic!("newest Batch item must be shed, got {other:?}"),
    }
    for q in [301, 302, 401] {
        assert!(
            !matches!(outcomes.get(&q), Some(JobOutput::Failed(_)) | None),
            "older Batch work and the Interactive item must survive a bounded shed; \
             query {q} got {:?}",
            outcomes.get(&q)
        );
    }

    drop(job_tx);
    sched_h.join().expect("scheduler thread exits");
}
