//! PR8 multi-tenant QoS: the tenant stamp threads through every layer
//! (workload → graph scheduler → engine scheduler → KV ledger), the
//! `TEOLA_*` knob surface round-trips through `PlatformConfig`, and —
//! the determinism bar — a *disabled* tenancy registry makes the stamp
//! completely inert: outputs are bit-identical whether queries carry
//! real tenant ids or run untenanted.
//!
//! Everything runs on the sim backend (deterministic, no artifacts).

use std::time::Duration;

use teola::bench::{apply_env_knobs, tenant_mix_prepared};
use teola::engines::sim::ExecBackend;
use teola::engines::{EngineKind, QueryId, TenantId, UNTENANTED};
use teola::scheduler::tenancy::TenancyConfig;
use teola::scheduler::{Platform, PlatformConfig};
use teola::serving::{run_load_tenants, TENANT_HEAVY, TENANT_LIGHT};
use teola::workload::{MultiTenantTrace, TenantLoad};

mod common;

/// Restores the captured `TEOLA_*` variables on drop, so a panicking
/// assertion can't leak knob settings into the other tests of this
/// binary (they all run under `common::serial()`).
struct EnvGuard {
    saved: Vec<(&'static str, Option<String>)>,
}

impl EnvGuard {
    fn capture(keys: &'static [&'static str]) -> EnvGuard {
        EnvGuard { saved: keys.iter().map(|k| (*k, std::env::var(k).ok())).collect() }
    }
}

impl Drop for EnvGuard {
    fn drop(&mut self) {
        for (k, v) in &self.saved {
            match v {
                Some(v) => std::env::set_var(k, v),
                None => std::env::remove_var(k),
            }
        }
    }
}

/// Satellite: every `TEOLA_*` environment knob — including the new
/// `TEOLA_TENANCY` — parses onto `PlatformConfig` through the single
/// shared surface (`bench::apply_env_knobs`), and unset knobs leave the
/// config untouched.  The tenancy spec string additionally round-trips
/// `parse → to_spec → parse` unchanged.
#[test]
fn env_knobs_round_trip_through_config() {
    let _guard = common::serial();
    const KEYS: &[&str] = &[
        "TEOLA_BACKEND",
        "TEOLA_BATCH_WINDOW_US",
        "TEOLA_PREFIX_SLOTS",
        "TEOLA_CONTINUOUS",
        "TEOLA_KV_TOKENS",
        "TEOLA_KV_WATERMARK",
        "TEOLA_KV_WATERMARK_LLM",
        "TEOLA_WCP",
        "TEOLA_PIPELINE",
        "TEOLA_TENANCY",
    ];
    let _env = EnvGuard::capture(KEYS);

    let spec = "1:w=4,class=interactive,deadline_ms=250;2:w=1,class=batch,kv_pct=60";
    std::env::set_var("TEOLA_BACKEND", "sim");
    std::env::set_var("TEOLA_BATCH_WINDOW_US", "1234");
    std::env::set_var("TEOLA_PREFIX_SLOTS", "5");
    std::env::set_var("TEOLA_CONTINUOUS", "off");
    std::env::set_var("TEOLA_KV_TOKENS", "4096");
    std::env::set_var("TEOLA_KV_WATERMARK", "70");
    std::env::set_var("TEOLA_KV_WATERMARK_LLM", "55");
    std::env::set_var("TEOLA_WCP", "off");
    std::env::set_var("TEOLA_PIPELINE", "off");
    std::env::set_var("TEOLA_TENANCY", spec);

    let mut cfg = PlatformConfig::default_with("artifacts", "llm-lite");
    apply_env_knobs(&mut cfg);
    assert_eq!(cfg.backend, ExecBackend::Sim);
    assert_eq!(cfg.batch_window_us, 1234);
    assert_eq!(cfg.prefix_slots, 5);
    assert!(!cfg.continuous);
    assert_eq!(cfg.kv_tokens_per_instance, Some(4096));
    assert_eq!(cfg.kv_watermark, 70);
    assert!(
        cfg.kv_watermark_overrides.contains(&(EngineKind::Llm, 55)),
        "per-kind watermark override must land: {:?}",
        cfg.kv_watermark_overrides
    );
    assert!(!cfg.wcp);
    assert!(!cfg.pipeline);
    assert_eq!(cfg.tenancy, TenancyConfig::parse(spec).unwrap());
    // The spec grammar is its own snapshot format: to_spec -> parse is
    // the identity, and this spec renders back verbatim.
    assert_eq!(cfg.tenancy.to_spec(), spec);
    assert_eq!(TenancyConfig::parse(&cfg.tenancy.to_spec()).unwrap(), cfg.tenancy);

    // With every knob unset, apply_env_knobs must be a no-op.
    for k in KEYS {
        std::env::remove_var(k);
    }
    let dfl = PlatformConfig::default_with("artifacts", "llm-lite");
    let mut fresh = PlatformConfig::default_with("artifacts", "llm-lite");
    apply_env_knobs(&mut fresh);
    assert_eq!(fresh.backend, dfl.backend);
    assert_eq!(fresh.batch_window_us, dfl.batch_window_us);
    assert_eq!(fresh.prefix_slots, dfl.prefix_slots);
    assert_eq!(fresh.continuous, dfl.continuous);
    assert_eq!(fresh.kv_tokens_per_instance, dfl.kv_tokens_per_instance);
    assert_eq!(fresh.kv_watermark, dfl.kv_watermark);
    assert_eq!(fresh.kv_watermark_overrides, dfl.kv_watermark_overrides);
    assert_eq!(fresh.wcp, dfl.wcp);
    assert_eq!(fresh.pipeline, dfl.pipeline);
    assert_eq!(fresh.tenancy, dfl.tenancy);
}

/// The runtime registry round-trips: a config set at startup is what
/// `tenancy_snapshot` reports, `set_tenancy`/`restore_tenancy` flip the
/// live state, and the snapshot re-renders to a parseable spec string.
#[test]
fn tenancy_config_round_trips_through_platform() {
    let _guard = common::serial();
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.warm = false;
    cfg.tenancy = TenancyConfig::parse("3:w=2,class=batch,kv_pct=25").unwrap();
    let platform = Platform::start(&cfg).expect("platform");

    assert!(platform.tenancy_enabled());
    let snap = platform.tenancy_snapshot();
    assert_eq!(snap, cfg.tenancy);
    assert_eq!(TenancyConfig::parse(&snap.to_spec()).unwrap(), snap);

    platform.set_tenancy(&TenancyConfig::default());
    assert!(!platform.tenancy_enabled());
    platform.restore_tenancy(&snap);
    assert!(platform.tenancy_enabled());
    assert_eq!(platform.tenancy_snapshot(), snap);
    platform.shutdown();
}

/// Satellite (PR7 handoff x PR8): with tenancy *and* pipelining on (the
/// default config pipelines), a mixed two-tenant trace completes end to
/// end and every query — including the successor jobs the serving
/// instance hands off engine-side — stays accounted to its tenant: the
/// per-tenant report recovers exactly the issued counts of the trace.
/// No deadlines are configured, so admission control never sheds and
/// completion must be total.
#[test]
fn tenancy_on_accounts_every_query_to_its_tenant() {
    let _guard = common::serial();
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.warm = false;
    let platform = Platform::start(&cfg).expect("platform");
    let ten = TenancyConfig::parse("1:w=4,class=interactive;2:w=1,class=batch").unwrap();
    platform.set_tenancy(&ten);

    let loads = [
        TenantLoad { tenant: TENANT_LIGHT, rate: 200.0, n: 5 },
        TenantLoad { tenant: TENANT_HEAVY, rate: 200.0, n: 10 },
    ];
    let trace = MultiTenantTrace::generate(&loads, 0x8E8);
    let tenant_seq: Vec<TenantId> = trace.arrivals.iter().map(|(_, t)| *t).collect();
    let report = run_load_tenants(
        &platform,
        tenant_mix_prepared(&tenant_seq, 0x8E8),
        &trace.arrivals,
        &ten,
        |i| 0x8E8_0000 + i as QueryId,
    )
    .expect("trace");
    platform.shutdown();

    assert_eq!(report.outputs.len(), 15, "no deadline -> nothing shed");
    assert_eq!(report.tenants.len(), 2, "one report per tenant");
    let light = &report.tenants[0];
    let heavy = &report.tenants[1];
    assert_eq!(
        (light.tenant, light.issued, light.completed, light.shed),
        (TENANT_LIGHT, 5, 5, 0)
    );
    assert_eq!(
        (heavy.tenant, heavy.issued, heavy.completed, heavy.shed),
        (TENANT_HEAVY, 10, 10, 0)
    );
    // No deadline means every completion meets its (vacuous) SLO.
    assert!((light.goodput - 1.0).abs() < 1e-9);
    assert!((heavy.goodput - 1.0).abs() < 1e-9);
}

/// Tentpole determinism bar: with the registry *disabled* (the default),
/// the tenant stamp is invisible — the same seeded trace produces
/// bit-identical outputs whether queries carry their real tenant ids or
/// all run [`UNTENANTED`].  This pins the off-path of every PR8 touch
/// point (queue ranks, fair charging, shedding, quota eviction) to the
/// tenant-blind behavior.
#[test]
fn disabled_tenancy_makes_the_tenant_stamp_inert() {
    let _guard = common::serial();
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.warm = false;
    let platform = Platform::start(&cfg).expect("platform");
    assert!(!platform.tenancy_enabled(), "tenancy must default off");

    let loads = [
        TenantLoad { tenant: TENANT_LIGHT, rate: 150.0, n: 6 },
        TenantLoad { tenant: TENANT_HEAVY, rate: 150.0, n: 6 },
    ];
    let trace = MultiTenantTrace::generate(&loads, 0x8E9);
    let tenant_seq: Vec<TenantId> = trace.arrivals.iter().map(|(_, t)| *t).collect();
    let ten = TenancyConfig::default();

    // Half 1: queries stamped with their real tenants, registry off.
    teola::scheduler::wcp::reset_latency_feedback();
    let stamped = run_load_tenants(
        &platform,
        tenant_mix_prepared(&tenant_seq, 0x8E9),
        &trace.arrivals,
        &ten,
        |i| 0x8E9_0000 + i as QueryId,
    )
    .expect("stamped half");

    // Half 2: identical graphs and arrival offsets, every query
    // untenanted (fresh query ids; let queued FreeQuery cleanup land).
    let blank: Vec<(Duration, TenantId)> =
        trace.arrivals.iter().map(|(d, _)| (*d, UNTENANTED)).collect();
    teola::scheduler::wcp::reset_latency_feedback();
    std::thread::sleep(Duration::from_millis(50));
    let untenanted = run_load_tenants(
        &platform,
        tenant_mix_prepared(&tenant_seq, 0x8E9),
        &blank,
        &ten,
        |i| 0x8E9_4000 + i as QueryId,
    )
    .expect("untenanted half");
    platform.shutdown();

    assert_eq!(stamped.outputs.len(), 12);
    assert_eq!(
        stamped.outputs, untenanted.outputs,
        "disabled tenancy must make the tenant stamp invisible in outputs"
    );
}
