//! PR7 cross-engine pipelining: direct successor handoff must change
//! *where* downstream jobs are injected (instance thread → target engine
//! queue, skipping the graph-scheduler bounce), never *what* they
//! compute.  The determinism bar is bit-identical outputs between
//! pipeline off and on over the same seeded trace; the mechanism bar is
//! a strictly lower mean dispatch-hop count when handoff is on.
//!
//! Everything runs on the sim backend (deterministic, no artifacts).

use teola::apps::AppKind;
use teola::scheduler::{Platform, PlatformConfig};
use teola::serving::run_pipeline_comparison;

mod common;

/// One platform for both paper apps: search-gen routes its aux
/// Expand/Summary calls at llm-small, so the pool carries both engines —
/// the same topology `teola pipeline-bench` uses.
fn pipeline_platform() -> Platform {
    let mut cfg = PlatformConfig::sim("llm-lite").with_llm("llm-small", 2, 8);
    cfg.warm = false;
    Platform::start(&cfg).expect("platform")
}

/// Tentpole determinism + mechanism bar on the seeded doc-QA trace:
/// outputs bit-identical off vs on, mean dispatch hops strictly lower
/// with handoff on (every eligible single-input successor is injected
/// engine-side instead of re-entering the graph scheduler).
#[test]
fn doc_qa_outputs_identical_and_hops_strictly_lower() {
    let _guard = common::serial();
    let platform = pipeline_platform();
    let (off, on) =
        run_pipeline_comparison(&platform, AppKind::DocQaAdvanced, 24, 150.0, 0x9C7)
            .expect("trace");
    platform.shutdown();

    assert_eq!(off.outputs.len(), 24);
    assert_eq!(
        on.outputs, off.outputs,
        "pipelining must be invisible in outputs (doc-qa-advanced)"
    );
    assert!(
        on.mean_dispatch_hops() < off.mean_dispatch_hops(),
        "direct handoff must strictly cut dispatch hops: on {:.2} vs off {:.2}",
        on.mean_dispatch_hops(),
        off.mean_dispatch_hops()
    );
}

/// Same bars on search-gen, whose chain crosses three engine kinds
/// (web-search → llm aux calls → rerank → llm synthesis) and exercises
/// the llm→embed and llm→llm handoff templates.
#[test]
fn search_gen_outputs_identical_and_hops_strictly_lower() {
    let _guard = common::serial();
    let platform = pipeline_platform();
    let (off, on) =
        run_pipeline_comparison(&platform, AppKind::SearchGen, 24, 150.0, 0x9C8)
            .expect("trace");
    platform.shutdown();

    assert_eq!(off.outputs.len(), 24);
    assert_eq!(
        on.outputs, off.outputs,
        "pipelining must be invisible in outputs (search-gen)"
    );
    assert!(
        on.mean_dispatch_hops() < off.mean_dispatch_hops(),
        "direct handoff must strictly cut dispatch hops: on {:.2} vs off {:.2}",
        on.mean_dispatch_hops(),
        off.mean_dispatch_hops()
    );
}

/// Pipelining-on is itself reproducible: two on-runs over the same seed
/// and fixed query ids emit identical outputs — handoff injection points
/// and speculative prefill must not introduce run-to-run nondeterminism
/// in results (latency may vary; values may not).
#[test]
fn pipeline_on_runs_are_reproducible() {
    let _guard = common::serial();
    let platform = pipeline_platform();
    let (_, first) =
        run_pipeline_comparison(&platform, AppKind::DocQaAdvanced, 12, 150.0, 0x7A11)
            .expect("trace");
    let (_, second) =
        run_pipeline_comparison(&platform, AppKind::DocQaAdvanced, 12, 150.0, 0x7A11)
            .expect("trace");
    platform.shutdown();
    assert_eq!(first.outputs, second.outputs, "on-path outputs must be reproducible");
}
