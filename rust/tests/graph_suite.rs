//! Graph-optimizer test suite: every Fig. 2 app under every pass
//! combination, baseline transforms, and structural expectations from the
//! paper (no engines/artifacts required — pure graph level).

use teola::apps::{bind_answer_tokens, AppKind};
use teola::baselines::autogen::agentize;
use teola::baselines::prefix_cache::apply_prefix_cache;
use teola::baselines::Scheme;
use teola::engines::profile::ProfileRegistry;
use teola::graph::pgraph::build_pgraph;
use teola::graph::primitive::{PayloadSpec, PrimKind};
use teola::graph::template::QueryConfig;
use teola::graph::{run_passes, EGraph, OptFlags};

fn profiles() -> ProfileRegistry {
    ProfileRegistry::with_defaults()
}

fn flag_combos() -> Vec<OptFlags> {
    vec![
        OptFlags::all(),
        OptFlags::none(),
        OptFlags::parallelization_only(),
        OptFlags::pipelining_only(),
    ]
}

#[test]
fn every_app_under_every_flag_combo_is_acyclic() {
    let p = profiles();
    for app in AppKind::all() {
        for core in ["llm-lite", "llm-small", "llm-medium", "llm-large"] {
            let mut t = app.template(core);
            bind_answer_tokens(&mut t, 20);
            for (qi, seed) in [3u64, 17, 99].iter().enumerate() {
                let q = QueryConfig::example(*seed);
                for flags in flag_combos() {
                    let g = build_pgraph(&t, &q).unwrap();
                    let g = run_passes(g, flags, &p)
                        .unwrap_or_else(|e| panic!("{} {} q{}: {e}", app.name(), core, qi));
                    let e = EGraph::new(g).unwrap();
                    assert!(e.len() >= 2);
                    assert_eq!(e.depths[e.graph.output], 0);
                }
            }
        }
    }
}

#[test]
fn advanced_rag_optimized_matches_fig6_structure() {
    // Fig. 6: partial prefills for instruction+question, 3 partial
    // decodings feeding 3 embeddings, refine chain of 3 synthesis calls.
    let mut t = AppKind::DocQaAdvanced.template("llm-small");
    bind_answer_tokens(&mut t, 20);
    let q = QueryConfig::example(41);
    let g = build_pgraph(&t, &q).unwrap();
    let g = run_passes(g, OptFlags::all(), &profiles()).unwrap();

    let count = |k: PrimKind| g.nodes.iter().filter(|n| n.kind == k).count();
    assert_eq!(count(PrimKind::PartialDecoding), 3, "3 expanded queries stream");
    assert!(count(PrimKind::PartialPrefilling) >= 3, "refine calls pre-prefill");
    assert!(count(PrimKind::FullPrefilling) >= 3);
    // Pass 4 split the expanded-queries embedding into per-segment embeds.
    let seg_embeds = g
        .nodes
        .iter()
        .filter(|n| {
            n.kind == PrimKind::Embedding
                && n.payload.deps().iter().any(|d| {
                    g.nodes[*d].kind == PrimKind::PartialDecoding
                })
        })
        .count();
    assert_eq!(seg_embeds, 3);
}

#[test]
fn coarse_graph_has_no_decomposed_prefills() {
    let mut t = AppKind::DocQaAdvanced.template("llm-small");
    bind_answer_tokens(&mut t, 20);
    let q = QueryConfig::example(42);
    let g = build_pgraph(&t, &q).unwrap();
    let g = run_passes(g, OptFlags::none(), &profiles()).unwrap();
    assert_eq!(
        g.nodes.iter().filter(|n| n.kind == PrimKind::PartialPrefilling).count(),
        0
    );
    assert_eq!(
        g.nodes.iter().filter(|n| n.kind == PrimKind::PartialDecoding).count(),
        0
    );
}

#[test]
fn optimization_reduces_critical_path_for_advanced_rag() {
    let mut t = AppKind::DocQaAdvanced.template("llm-small");
    bind_answer_tokens(&mut t, 20);
    let q = QueryConfig::example(43);
    let coarse = EGraph::new(
        run_passes(build_pgraph(&t, &q).unwrap(), OptFlags::none(), &profiles()).unwrap(),
    )
    .unwrap();
    let opt = EGraph::new(
        run_passes(build_pgraph(&t, &q).unwrap(), OptFlags::all(), &profiles()).unwrap(),
    )
    .unwrap();
    // Pass 1 removes module barriers: sources (independent roots) increase.
    assert!(opt.sources().len() > coarse.sources().len());
}

#[test]
fn prefix_cache_shares_only_within_engine_and_instruction() {
    let mut t = AppKind::ContextualRetrieval.template("llm-small");
    bind_answer_tokens(&mut t, 16);
    let mut q = QueryConfig::example(44);
    q.doc_chunks.truncate(4);
    let mut g = build_pgraph(&t, &q).unwrap();
    let clones = apply_prefix_cache(&mut g);
    // 4 contextualize calls share one instruction -> 3 clones;
    // synthesis instruction is unique -> no clone there.
    assert_eq!(clones, 3);
    assert!(g.topo_order().is_ok());
    // Clones chain after the donor prefill.
    for n in &g.nodes {
        if let PayloadSpec::ClonePrefix { after, .. } = &n.payload {
            assert!(matches!(
                g.nodes[*after].kind,
                PrimKind::Prefilling | PrimKind::PartialPrefilling | PrimKind::FullPrefilling
            ));
        }
    }
}

#[test]
fn autogen_strictly_serializes_agents() {
    for app in AppKind::all() {
        let mut t = app.template("llm-small");
        bind_answer_tokens(&mut t, 16);
        let a = agentize(&t);
        let q = QueryConfig::example(45);
        let g = build_pgraph(&a, &q).unwrap();
        // With template edges intact (AutoGen runs unoptimized), the graph
        // must still be acyclic and hop components must appear.
        assert!(g.topo_order().is_ok(), "{}", app.name());
        let hops = a.components.iter().filter(|c| c.name.starts_with("agent-hop")).count();
        assert!(hops >= 1, "{}", app.name());
    }
}

#[test]
fn schemes_build_identical_output_arity() {
    // Different schemes must deliver the same *semantic* output shape for
    // the same query (row counts of the final answer value are checked at
    // runtime; here: same output node kind).
    let p = profiles();
    let mut t = AppKind::DocQaNaive.template("llm-lite");
    bind_answer_tokens(&mut t, 12);
    let q = QueryConfig::example(46);
    let kinds: Vec<PrimKind> = Scheme::all()
        .iter()
        .map(|s| {
            let e = s.build(&t, &q, &p).unwrap();
            e.graph.nodes[e.graph.output].kind
        })
        .collect();
    assert!(kinds.iter().all(|k| *k == PrimKind::Decoding));
}

#[test]
fn guard_propagates_from_condition_to_web_search_only() {
    let mut t = AppKind::SearchGen.template("llm-medium");
    bind_answer_tokens(&mut t, 16);
    let q = QueryConfig::example(47);
    let g = build_pgraph(&t, &q).unwrap();
    for n in &g.nodes {
        match n.kind {
            PrimKind::WebSearching => assert!(n.guard.is_some()),
            PrimKind::Prefilling | PrimKind::Decoding => {
                assert!(n.guard.is_none(), "LLM calls must not be gated")
            }
            _ => {}
        }
    }
}

#[test]
fn pass2_stage_count_follows_profile_knee() {
    use teola::engines::profile::OpProfile;
    let mut p = profiles();
    // Force a small max-efficient batch of 4.
    p.register(
        "embedder",
        "embed",
        OpProfile::new(vec![(1, 1000), (4, 1300), (8, 2600), (16, 5200)]),
    );
    let mut t = AppKind::DocQaNaive.template("llm-lite");
    bind_answer_tokens(&mut t, 12);
    let mut q = QueryConfig::example(48);
    q.doc_chunks = (0..12).map(|i| vec![5 + i as i32; 20]).collect();
    let g = build_pgraph(&t, &q).unwrap();
    let g = run_passes(g, OptFlags::pipelining_only(), &p).unwrap();
    // 12 chunks at knee 4 -> 3 embedding stages (+1 query embed).
    let embeds = g.nodes.iter().filter(|n| n.kind == PrimKind::Embedding).count();
    assert_eq!(embeds, 4, "3 doc stages + query embed");
    let ingests = g.nodes.iter().filter(|n| n.kind == PrimKind::Ingestion).count();
    assert_eq!(ingests, 3, "co-split ingestion stages");
}

#[test]
fn depths_give_llm_synthesis_lowest_priority_order() {
    // In naive RAG, indexing embeds sit deeper (earlier) than the final
    // combiner decode — Algorithm 2 would prefer them for batch slots.
    let mut t = AppKind::DocQaNaive.template("llm-lite");
    bind_answer_tokens(&mut t, 12);
    let q = QueryConfig::example(49);
    let g = run_passes(build_pgraph(&t, &q).unwrap(), OptFlags::all(), &profiles()).unwrap();
    let e = EGraph::new(g).unwrap();
    let embed_depth = e
        .graph
        .nodes
        .iter()
        .filter(|n| n.kind == PrimKind::Embedding)
        .map(|n| e.depths[n.id])
        .max()
        .unwrap();
    assert!(embed_depth > e.depths[e.graph.output]);
}
