//! PR10 speculation & runtime graph growth, end-to-end on the real
//! platform (simulated backend):
//!
//!  * speculation is output-invariant — the same query produces
//!    bit-identical `Value`s with the knob off and on, for the agentic
//!    runtime-growth app and for the mixed guard-heavy trace;
//!  * runtime tool fan-out actually spawns N subgraphs (engine-op count
//!    equals the deterministic fan) and runs them *concurrently* when
//!    speculation is on — wall-clock strictly separates the parallel
//!    schedule from the chained off-mode schedule;
//!  * the off half of the comparison harness never counts a speculative
//!    cancellation.

use std::sync::Mutex;
use std::time::Duration;

use teola::apps::{agentic_tools, bind_answer_tokens};
use teola::baselines::Scheme;
use teola::graph::template::{Component, ComponentKind, QueryConfig, WorkflowTemplate};
use teola::scheduler::{Platform, PlatformConfig};
use teola::serving::run_spec_comparison;

// Platform is !Send (Rc manifest): tests in this binary serialize.
static SERIAL: Mutex<()> = Mutex::new(());

fn spec_platform() -> Platform {
    let cfg = PlatformConfig::sim("llm-lite").with_llm("llm-small", 2, 8);
    Platform::start(&cfg).unwrap()
}

/// Mirror of the runner's deterministic fan decision for an `Expand`
/// node whose input is the literal question (`DataRef::Const`): the
/// stand-in for the LLM's emitted tool list.
fn fanout_fan(qid: u64, question: &[i32], max_fan: usize) -> usize {
    let mut h: u64 = qid ^ 0xD1B5_4A32_D192_ED03;
    for t in question {
        h = h.wrapping_mul(31).wrapping_add(*t as u64);
    }
    1 + (h % max_fan.max(1) as u64) as usize
}

/// The agentic app's outputs and spawned-subgraph shape are identical
/// with speculation off and on — runtime growth changes the schedule
/// (chained vs concurrent tools), never what any node computes.
#[test]
fn agentic_tools_outputs_identical_on_off() {
    let _g = SERIAL.lock().unwrap();
    let platform = spec_platform();
    platform.set_policy(Scheme::Teola.policy());
    let mut q = QueryConfig::example(0xA6E);
    q.answer_tokens = 8;
    let build = || {
        let mut t = agentic_tools("llm-lite");
        bind_answer_tokens(&mut t, q.answer_tokens);
        Scheme::Teola.build(&t, &q, &platform.profiles).unwrap()
    };
    let qid = 0xA6E_0001;
    platform.set_speculation(false);
    let (v_off, m_off) = platform.run_query(qid, build()).unwrap();
    // Let the first run's FreeQuery cleanup land before reusing the id.
    std::thread::sleep(Duration::from_millis(50));
    platform.set_speculation(true);
    let (v_on, m_on) = platform.run_query(qid, build()).unwrap();
    assert_eq!(v_off, v_on, "speculation must not change outputs");
    assert_eq!(
        m_off.n_engine_ops, m_on.n_engine_ops,
        "both modes must spawn the same tool subgraphs"
    );
    // plan (prefill + decode) + >=1 spawned tool + confirm (prefill +
    // decode): the runtime-grown subgraph really executed.
    assert!(m_on.n_engine_ops >= 5, "got {} engine ops", m_on.n_engine_ops);
    platform.shutdown();
}

/// Parallelism: a fan of 4 runtime-spawned 20ms tool calls completes in
/// far less than the chained 80ms when speculation dispatches them
/// concurrently.  The fanout-only workflow makes the fan a pure
/// function of (query id, question) so the test pins fan = 4, and the
/// sim tool engine sleeps exactly `cost_us` per batch — the two
/// schedules are separated by whole tool windows, not noise.
#[test]
fn runtime_fanout_runs_tools_concurrently() {
    let _g = SERIAL.lock().unwrap();
    let platform = spec_platform();
    platform.set_policy(Scheme::Teola.policy());
    let q = QueryConfig::example(0xFA4);
    let qid = (0..256u64)
        .map(|i| 0xFA4_0000 + i)
        .find(|&id| fanout_fan(id, &q.question, 4) == 4)
        .expect("some id in the range yields fan 4");
    let build = || {
        let mut t = WorkflowTemplate::new("fanout-only");
        let f = t.add(Component {
            name: "fan".into(),
            kind: ComponentKind::ToolFanout {
                name: "call_api".into(),
                cost_us: 20_000,
                max_fan: 4,
            },
            engine: "tool".into(),
            batchable: true,
            splittable: false,
        });
        t.chain(&[f]);
        Scheme::Teola.build(&t, &q, &platform.profiles).unwrap()
    };

    platform.set_speculation(false);
    let t0 = std::time::Instant::now();
    let (v_off, m_off) = platform.run_query(qid, build()).unwrap();
    let ms_off = t0.elapsed().as_secs_f64() * 1e3;
    std::thread::sleep(Duration::from_millis(50));

    platform.set_speculation(true);
    let t0 = std::time::Instant::now();
    let (v_on, m_on) = platform.run_query(qid, build()).unwrap();
    let ms_on = t0.elapsed().as_secs_f64() * 1e3;

    assert_eq!(v_off, v_on, "fan-out scheduling must not change the output");
    assert_eq!(m_off.n_engine_ops, 4, "all 4 spawned tools ran (off)");
    assert_eq!(m_on.n_engine_ops, 4, "all 4 spawned tools ran (on)");
    // Chained: 4 sequential 20ms windows.  Concurrent: at worst two
    // waves across the tool engine's instances.
    assert!(
        ms_off >= 75.0,
        "chained schedule must pay every tool window: {ms_off:.1}ms"
    );
    assert!(
        ms_on < 65.0,
        "concurrent schedule must overlap tool windows: {ms_on:.1}ms"
    );
    assert!(ms_on < ms_off, "parallel fan-out must beat the chain");
    platform.shutdown();
}

/// The comparison harness replays the same seeded guard-heavy + agentic
/// trace with speculation off then on: outputs must be bit-identical
/// and the off half must never count a speculative cancellation.
#[test]
fn spec_comparison_outputs_bit_identical() {
    let _g = SERIAL.lock().unwrap();
    let platform = spec_platform();
    platform.set_policy(Scheme::Teola.policy());
    let (off, on) = run_spec_comparison(&platform, 6, 40.0, 0x51).unwrap();
    assert_eq!(off.outputs.len(), 6);
    assert_eq!(off.outputs, on.outputs, "speculation must be output-invariant");
    assert_eq!(
        off.total_speculative_cancelled(),
        0,
        "the off half can never cancel a speculation"
    );
    platform.shutdown();
}
