//! All five Figure-2 applications end-to-end on the real platform, under
//! Teola and baseline schemes.

use once_cell::sync::Lazy;
use std::sync::Mutex;

use teola::apps::AppKind;
use teola::baselines::Scheme;
use teola::bench::{platform_for_all, run_single, TraceRun};
use teola::scheduler::Platform;
use teola::workload::{Dataset, DatasetKind};

fn have_artifacts() -> bool {
    let ok = teola::runtime::default_artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
    }
    ok
}

// Platform is !Send (Rc manifest) so it cannot live in a static; tests in
// this binary serialize via this mutex and each builds a platform scoped
// to the app it exercises.
static SERIAL: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

fn run_app(app: AppKind, scheme: Scheme, dataset: DatasetKind, seed: u64) -> (f64, usize) {
    let core = "llm-lite"; // fastest variant keeps CI latency sane
    let mut cfg = platform_for_all(&[app], core);
    cfg.warm = false; // lazy-compile only the buckets the app touches
    let platform = Platform::start(&cfg).unwrap();
    let mut ds = Dataset::new(dataset, seed);
    let mut q = ds.sample();
    q.answer_tokens = q.answer_tokens.min(12);
    if q.doc_chunks.len() > 6 {
        q.doc_chunks.truncate(6);
    }
    let run = TraceRun {
        app,
        scheme,
        dataset,
        core_llm: core.into(),
        rate: 1.0,
        n_queries: 1,
        seed,
    };
    let (ms, m) = run_single(&platform, &run, &q).unwrap();
    platform.shutdown();
    (ms, m.n_engine_ops)
}

#[test]
fn search_gen_teola_and_baseline() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    let (ms_t, ops_t) = run_app(AppKind::SearchGen, Scheme::Teola, DatasetKind::WebQuestions, 1);
    let (ms_b, _) = run_app(AppKind::SearchGen, Scheme::LlamaDistTO, DatasetKind::WebQuestions, 1);
    assert!(ms_t > 0.0 && ms_b > 0.0);
    assert!(ops_t >= 4, "proxy, judge, (web), synth: got {ops_t}");
}

#[test]
fn doc_qa_naive_all_schemes() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    for scheme in Scheme::all() {
        let (ms, ops) = run_app(AppKind::DocQaNaive, scheme, DatasetKind::TruthfulQa, 2);
        assert!(ms > 0.0, "{}", scheme.name());
        assert!(ops >= 7, "{}: {ops}", scheme.name());
    }
}

#[test]
fn doc_qa_advanced_teola() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    let (ms, ops) = run_app(AppKind::DocQaAdvanced, Scheme::Teola, DatasetKind::TruthfulQa, 3);
    assert!(ms > 0.0);
    // expansion (pf+dec) + per-segment embeds + search + rerank +
    // refine chain (3x pf+dec) + indexing ops
    assert!(ops >= 10, "got {ops}");
}

#[test]
fn contextual_retrieval_teola() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    let (ms, ops) = run_app(
        AppKind::ContextualRetrieval,
        Scheme::Teola,
        DatasetKind::FinQaBench,
        4,
    );
    assert!(ms > 0.0);
    assert!(ops >= 12, "6 chunks contextualized + retrieval: got {ops}");
}

#[test]
fn agent_app_teola_and_autogen() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    let (ms_t, _) = run_app(AppKind::Agent, Scheme::Teola, DatasetKind::WebQuestions, 5);
    let (ms_a, _) = run_app(AppKind::Agent, Scheme::AutoGen, DatasetKind::WebQuestions, 5);
    assert!(ms_t > 0.0 && ms_a > 0.0);
}
