//! All five Figure-2 applications end-to-end on the real platform, under
//! Teola and baseline schemes.
//!
//! Every app runs unconditionally on the simulated backend, so plain
//! `cargo test` exercises the full two-tier scheduler for each `AppKind`;
//! the XLA variants additionally run when `artifacts/` exists.

use std::sync::Mutex;

use teola::apps::AppKind;
use teola::baselines::Scheme;
use teola::bench::{platform_for_all, run_single, TraceRun};
use teola::engines::ExecBackend;
use teola::scheduler::Platform;
use teola::workload::{Dataset, DatasetKind};

fn have_artifacts() -> bool {
    // Requires both artifacts on disk and a real (non-stub) XLA crate.
    let ok = teola::runtime::xla_backend_available();
    if !ok {
        eprintln!("skipping XLA variant: no artifacts or XLA crate stubbed");
    }
    ok
}

// Platform is !Send (Rc manifest) so it cannot live in a static; tests in
// this binary serialize via this mutex and each builds a platform scoped
// to the app it exercises.
static SERIAL: Mutex<()> = Mutex::new(());

fn run_app(
    app: AppKind,
    scheme: Scheme,
    dataset: DatasetKind,
    seed: u64,
    backend: ExecBackend,
) -> (f64, usize) {
    let core = "llm-lite"; // fastest variant keeps CI latency sane
    let mut cfg = platform_for_all(&[app], core);
    cfg.warm = false; // lazy-compile only the buckets the app touches
    cfg.backend = backend;
    let platform = Platform::start(&cfg).unwrap();
    let mut ds = Dataset::new(dataset, seed);
    let mut q = ds.sample();
    q.answer_tokens = q.answer_tokens.min(12);
    if q.doc_chunks.len() > 6 {
        q.doc_chunks.truncate(6);
    }
    let run = TraceRun {
        app,
        scheme,
        dataset,
        core_llm: core.into(),
        rate: 1.0,
        n_queries: 1,
        seed,
    };
    let (ms, m) = run_single(&platform, &run, &q).unwrap();
    platform.shutdown();
    (ms, m.n_engine_ops)
}

fn run_app_sim(app: AppKind, scheme: Scheme, dataset: DatasetKind, seed: u64) -> (f64, usize) {
    run_app(app, scheme, dataset, seed, ExecBackend::Sim)
}

// ---- simulated backend: always runs (plain `cargo test`) ----

#[test]
fn sim_search_gen_teola_and_baseline() {
    let _g = SERIAL.lock().unwrap();
    let (ms_t, ops_t) = run_app_sim(AppKind::SearchGen, Scheme::Teola, DatasetKind::WebQuestions, 1);
    let (ms_b, _) = run_app_sim(AppKind::SearchGen, Scheme::LlamaDistTO, DatasetKind::WebQuestions, 1);
    assert!(ms_t > 0.0 && ms_b > 0.0);
    assert!(ops_t >= 4, "proxy, judge, (web), synth: got {ops_t}");
}

#[test]
fn sim_doc_qa_naive_all_schemes() {
    let _g = SERIAL.lock().unwrap();
    for scheme in Scheme::all() {
        let (ms, ops) = run_app_sim(AppKind::DocQaNaive, scheme, DatasetKind::TruthfulQa, 2);
        assert!(ms > 0.0, "{}", scheme.name());
        assert!(ops >= 7, "{}: {ops}", scheme.name());
    }
}

#[test]
fn sim_doc_qa_advanced_teola() {
    let _g = SERIAL.lock().unwrap();
    let (ms, ops) = run_app_sim(AppKind::DocQaAdvanced, Scheme::Teola, DatasetKind::TruthfulQa, 3);
    assert!(ms > 0.0);
    // expansion (pf+dec) + per-segment embeds + search + rerank +
    // refine chain (3x pf+dec) + indexing ops
    assert!(ops >= 10, "got {ops}");
}

#[test]
fn sim_contextual_retrieval_teola() {
    let _g = SERIAL.lock().unwrap();
    let (ms, ops) = run_app_sim(
        AppKind::ContextualRetrieval,
        Scheme::Teola,
        DatasetKind::FinQaBench,
        4,
    );
    assert!(ms > 0.0);
    assert!(ops >= 12, "6 chunks contextualized + retrieval: got {ops}");
}

#[test]
fn sim_agent_app_teola_and_autogen() {
    let _g = SERIAL.lock().unwrap();
    let (ms_t, _) = run_app_sim(AppKind::Agent, Scheme::Teola, DatasetKind::WebQuestions, 5);
    let (ms_a, _) = run_app_sim(AppKind::Agent, Scheme::AutoGen, DatasetKind::WebQuestions, 5);
    assert!(ms_t > 0.0 && ms_a > 0.0);
}

// ---- XLA backend: needs `make artifacts` ----

#[test]
fn xla_search_gen_teola_and_baseline() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    let (ms_t, ops_t) =
        run_app(AppKind::SearchGen, Scheme::Teola, DatasetKind::WebQuestions, 1, ExecBackend::Xla);
    let (ms_b, _) = run_app(
        AppKind::SearchGen,
        Scheme::LlamaDistTO,
        DatasetKind::WebQuestions,
        1,
        ExecBackend::Xla,
    );
    assert!(ms_t > 0.0 && ms_b > 0.0);
    assert!(ops_t >= 4, "proxy, judge, (web), synth: got {ops_t}");
}

#[test]
fn xla_doc_qa_naive_all_schemes() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    for scheme in Scheme::all() {
        let (ms, ops) =
            run_app(AppKind::DocQaNaive, scheme, DatasetKind::TruthfulQa, 2, ExecBackend::Xla);
        assert!(ms > 0.0, "{}", scheme.name());
        assert!(ops >= 7, "{}: {ops}", scheme.name());
    }
}

#[test]
fn xla_doc_qa_advanced_teola() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    let (ms, ops) =
        run_app(AppKind::DocQaAdvanced, Scheme::Teola, DatasetKind::TruthfulQa, 3, ExecBackend::Xla);
    assert!(ms > 0.0);
    assert!(ops >= 10, "got {ops}");
}

#[test]
fn xla_contextual_retrieval_teola() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    let (ms, ops) = run_app(
        AppKind::ContextualRetrieval,
        Scheme::Teola,
        DatasetKind::FinQaBench,
        4,
        ExecBackend::Xla,
    );
    assert!(ms > 0.0);
    assert!(ops >= 12, "6 chunks contextualized + retrieval: got {ops}");
}

#[test]
fn xla_agent_app_teola_and_autogen() {
    if !have_artifacts() {
        return;
    }
    let _g = SERIAL.lock().unwrap();
    let (ms_t, _) =
        run_app(AppKind::Agent, Scheme::Teola, DatasetKind::WebQuestions, 5, ExecBackend::Xla);
    let (ms_a, _) =
        run_app(AppKind::Agent, Scheme::AutoGen, DatasetKind::WebQuestions, 5, ExecBackend::Xla);
    assert!(ms_t > 0.0 && ms_a > 0.0);
}
