//! Engine-death liveness: when the *last* live instance of an engine
//! dies, queued work must fail with an engine-dead error surfaced as a
//! `TeolaError` by the query runner — never hang waiting for a
//! completion that cannot come.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use teola::engines::instance::Instance;
use teola::engines::profile::ProfileRegistry;
use teola::engines::{Batch, Completion, EngineJob, ExecMode, InstanceEvent, JobOutput};
use teola::graph::pgraph::{build_pgraph, instr_tokens};
use teola::graph::template::*;
use teola::graph::{run_passes, EGraph, OptFlags};
use teola::scheduler::{BatchPolicy, EngineScheduler, QueryRunner, QueueItem};

/// An instance whose worker thread is already gone: every send fails.
fn dead_instance() -> Instance {
    let (tx, rx) = channel::<Batch>();
    drop(rx);
    Instance { sender: tx, handle: std::thread::spawn(|| {}) }
}

/// Spawn an engine scheduler named `name` whose only instance is dead;
/// returns the job sender and the scheduler thread handle (plus the event
/// sender, kept alive so the scheduler's event loop stays connected).
fn dead_engine(
    name: &str,
) -> (Sender<QueueItem>, std::thread::JoinHandle<()>, Sender<InstanceEvent>) {
    let (ev_tx, ev_rx) = channel::<InstanceEvent>();
    let (job_tx, job_rx) = channel::<QueueItem>();
    let sched = EngineScheduler::new(
        name.to_string(),
        vec![dead_instance()],
        ev_rx,
        job_rx,
        Arc::new(AtomicU8::new(BatchPolicy::TopoAware.to_u8())),
        Arc::new(AtomicUsize::new(8)),
        Arc::new(AtomicBool::new(true)),
        Arc::new(AtomicU64::new(0)),
        Arc::new(AtomicUsize::new(8)),
        Arc::new(AtomicBool::new(true)),
        ExecMode::Stepped,
    );
    let h = std::thread::spawn(move || sched.run());
    (job_tx, h, ev_tx)
}

fn one_shot_egraph(llm: &str) -> EGraph {
    let mut t = WorkflowTemplate::new("liveness");
    t.add(Component {
        name: "gen".into(),
        kind: ComponentKind::LlmGenerate {
            variant: llm.into(),
            mode: SynthesisMode::OneShot,
            prompt: vec![
                PromptPart::Instruction(instr_tokens("liveness", 12)),
                PromptPart::Question,
            ],
            out_tokens: 8,
            segments: 1,
            fan: 1,
        },
        engine: llm.into(),
        batchable: false,
        splittable: false,
    });
    let q = QueryConfig::example(17);
    let g = build_pgraph(&t, &q).unwrap();
    let g = run_passes(g, OptFlags::all(), &ProfileRegistry::with_defaults()).unwrap();
    EGraph::new(g).unwrap()
}

#[test]
fn query_errors_instead_of_hanging_when_last_instance_dies() {
    let (job_tx, sched_h, _ev_tx) = dead_engine("llm-lite");
    let egraph = one_shot_egraph("llm-lite");
    let mut routers = HashMap::new();
    routers.insert("llm-lite".to_string(), job_tx);

    // Run the query on its own thread and bound the wait: a regression
    // here means the runner blocks forever on a dead engine.
    let (res_tx, res_rx) = channel();
    std::thread::spawn(move || {
        let runner = QueryRunner::new(71, egraph, routers, 3);
        let _ = res_tx.send(runner.run());
    });
    let res = res_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("query must complete (with an error), not hang");
    let err = res.expect_err("dead engine must surface an error");
    let msg = err.to_string();
    assert!(msg.contains("dead"), "unexpected error: {msg}");

    // The scheduler itself must also exit once its job channel closes.
    sched_h.join().expect("scheduler thread exits");
}

#[test]
fn queued_and_later_items_both_fail_fast_on_dead_engine() {
    let (job_tx, sched_h, _ev_tx) = dead_engine("llm-test");

    let send_prefill = |q: u64| -> Receiver<Completion> {
        let (tx, rx) = channel();
        job_tx
            .send(QueueItem {
                query: q,
                node: 1,
                depth: 0,
                bundle: (q, 1),
                arrival: Instant::now(),
                rows: 1,
                prefix: None,
                wcp_us: 0,
                job: EngineJob::Prefill {
                    seq: (q, 0),
                    tokens: vec![7; 8],
                    offset: 0,
                    prefix: None,
                },
                reply: tx,
            })
            .unwrap();
        rx
    };

    // The item that triggers the death is failed...
    let rx1 = send_prefill(1);
    let c1 = rx1.recv_timeout(Duration::from_secs(5)).expect("first item fails fast");
    assert!(matches!(c1.output, JobOutput::Failed(_)), "got {:?}", c1.output);

    // ...and so is any item arriving after the engine is already dead.
    let rx2 = send_prefill(2);
    let c2 = rx2.recv_timeout(Duration::from_secs(5)).expect("later item fails fast");
    assert!(matches!(c2.output, JobOutput::Failed(_)), "got {:?}", c2.output);

    drop(job_tx);
    sched_h.join().expect("scheduler thread exits");
}
