//! Engine-death liveness: when the *last* live instance of an engine
//! dies, queued work must fail with an engine-dead error surfaced as a
//! `TeolaError` by the query runner — never hang waiting for a
//! completion that cannot come.  PR5 extends the suite to
//! token-denominated KV accounting: the fail-fast path holds in token
//! mode, and a dying instance's reserved tokens are released before its
//! batch is requeued, so the surviving instance serves the revived queue
//! against real (not phantom) capacity.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use teola::engines::instance::{spawn_stepped_instance, Instance};
use teola::engines::llm::SeqStore;
use teola::engines::profile::ProfileRegistry;
use teola::engines::sim::SimLlmExecutor;
use teola::engines::{Batch, Completion, EngineJob, ExecMode, InstanceEvent, JobOutput};
use teola::graph::pgraph::{build_pgraph, instr_tokens};
use teola::graph::template::*;
use teola::graph::{run_passes, EGraph, OptFlags};
use teola::scheduler::{BatchPolicy, EngineScheduler, QueryRunner, QueueItem};

/// An instance whose worker thread is already gone: every send fails.
fn dead_instance() -> Instance {
    let (tx, rx) = channel::<Batch>();
    drop(rx);
    Instance { sender: tx, handle: std::thread::spawn(|| {}) }
}

/// Spawn an engine scheduler named `name` over the given instances with a
/// per-instance KV token budget (0 = legacy row mode); returns the job
/// sender and the scheduler thread handle (plus the event sender, kept
/// alive so the scheduler's event loop stays connected).
fn engine_with(
    name: &str,
    instances: Vec<Instance>,
    ev_rx: Receiver<InstanceEvent>,
    kv_tokens: usize,
) -> (Sender<QueueItem>, std::thread::JoinHandle<()>) {
    let (job_tx, job_rx) = channel::<QueueItem>();
    let sched = EngineScheduler::new(
        name.to_string(),
        instances,
        ev_rx,
        job_rx,
        Arc::new(AtomicU8::new(BatchPolicy::TopoAware.to_u8())),
        Arc::new(AtomicUsize::new(8)),
        Arc::new(AtomicBool::new(true)),
        Arc::new(AtomicU64::new(0)),
        Arc::new(AtomicUsize::new(8)),
        Arc::new(AtomicBool::new(true)),
        Arc::new(AtomicUsize::new(kv_tokens)),
        Arc::new(AtomicUsize::new(0)),
        ExecMode::Stepped,
        Arc::new(teola::scheduler::tenancy::SharedTenancy::default()),
        Arc::new(AtomicBool::new(true)),
        Arc::new(teola::scheduler::stats::SchedCounters::new()),
    );
    let h = std::thread::spawn(move || sched.run());
    (job_tx, h)
}

/// Dead-engine shorthand: one already-dead instance, row mode.
fn dead_engine(
    name: &str,
) -> (Sender<QueueItem>, std::thread::JoinHandle<()>, Sender<InstanceEvent>) {
    let (ev_tx, ev_rx) = channel::<InstanceEvent>();
    let (job_tx, h) = engine_with(name, vec![dead_instance()], ev_rx, 0);
    (job_tx, h, ev_tx)
}

fn one_shot_egraph(llm: &str) -> EGraph {
    let mut t = WorkflowTemplate::new("liveness");
    t.add(Component {
        name: "gen".into(),
        kind: ComponentKind::LlmGenerate {
            variant: llm.into(),
            mode: SynthesisMode::OneShot,
            prompt: vec![
                PromptPart::Instruction(instr_tokens("liveness", 12)),
                PromptPart::Question,
            ],
            out_tokens: 8,
            segments: 1,
            fan: 1,
        },
        engine: llm.into(),
        batchable: false,
        splittable: false,
    });
    let q = QueryConfig::example(17);
    let g = build_pgraph(&t, &q).unwrap();
    let g = run_passes(g, OptFlags::all(), &ProfileRegistry::with_defaults()).unwrap();
    EGraph::new(g).unwrap()
}

fn prefill_item(q: u64, n_tokens: usize, reply: Sender<Completion>) -> QueueItem {
    QueueItem {
        query: q,
        node: 1,
        depth: 0,
        bundle: (q, 1),
        arrival: Instant::now(),
        rows: 1,
        tokens: n_tokens,
        wcp_discounted: false,
        prefix: None,
        wcp_us: 0,
        tenant: teola::engines::UNTENANTED,
        job: EngineJob::Prefill {
            seq: (q, 0),
            tokens: vec![7; n_tokens],
            offset: 0,
            prefix: None,
        },
        reply,
        successors: Vec::new(),
    }
}

#[test]
fn query_errors_instead_of_hanging_when_last_instance_dies() {
    let (job_tx, sched_h, _ev_tx) = dead_engine("llm-lite");
    let egraph = one_shot_egraph("llm-lite");
    let mut routers = HashMap::new();
    routers.insert("llm-lite".to_string(), job_tx);

    // Run the query on its own thread and bound the wait: a regression
    // here means the runner blocks forever on a dead engine.
    let (res_tx, res_rx) = channel();
    std::thread::spawn(move || {
        let runner = QueryRunner::new(71, egraph, routers, 3);
        let _ = res_tx.send(runner.run());
    });
    let res = res_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("query must complete (with an error), not hang");
    let err = res.expect_err("dead engine must surface an error");
    let msg = err.to_string();
    assert!(msg.contains("dead"), "unexpected error: {msg}");

    // The scheduler itself must also exit once its job channel closes.
    sched_h.join().expect("scheduler thread exits");
}

#[test]
fn queued_and_later_items_both_fail_fast_on_dead_engine() {
    let (job_tx, sched_h, _ev_tx) = dead_engine("llm-test");

    let send_prefill = |q: u64| -> Receiver<Completion> {
        let (tx, rx) = channel();
        job_tx.send(prefill_item(q, 8, tx)).unwrap();
        rx
    };

    // The item that triggers the death is failed...
    let rx1 = send_prefill(1);
    let c1 = rx1.recv_timeout(Duration::from_secs(5)).expect("first item fails fast");
    assert!(matches!(c1.output, JobOutput::Failed(_)), "got {:?}", c1.output);

    // ...and so is any item arriving after the engine is already dead.
    let rx2 = send_prefill(2);
    let c2 = rx2.recv_timeout(Duration::from_secs(5)).expect("later item fails fast");
    assert!(matches!(c2.output, JobOutput::Failed(_)), "got {:?}", c2.output);

    drop(job_tx);
    sched_h.join().expect("scheduler thread exits");
}

/// Token-mode fail-fast: the dead-engine liveness contract is unchanged
/// under token-denominated KV accounting.
#[test]
fn dead_engine_fails_fast_under_token_accounting() {
    let (ev_tx, ev_rx) = channel::<InstanceEvent>();
    let (job_tx, sched_h) = engine_with("llm-kv-dead", vec![dead_instance()], ev_rx, 256);
    let _keep_events_alive = ev_tx;

    let (tx, rx) = channel();
    job_tx.send(prefill_item(1, 32, tx)).unwrap();
    let c = rx.recv_timeout(Duration::from_secs(5)).expect("token-mode item fails fast");
    assert!(matches!(c.output, JobOutput::Failed(_)), "got {:?}", c.output);

    drop(job_tx);
    sched_h.join().expect("scheduler thread exits");
}

/// PR5 bugfix coverage: instance 0 is dead, instance 1 is live, and the
/// per-instance token budget only fits one admission wave at a time.  If
/// the death path failed to release the dead instance's reservations (or
/// charged the unsent batch anyway), the surviving instance's capacity
/// would be phantom-occupied and later waves could never dispatch — the
/// receive below would time out instead of draining every completion.
#[test]
fn dead_instance_releases_tokens_and_live_instance_serves_requeue() {
    let (ev_tx, ev_rx) = channel::<InstanceEvent>();
    let store: SeqStore = Arc::new(Mutex::new(HashMap::new()));
    let prefix_slots = Arc::new(AtomicUsize::new(0));
    let (ready_tx, ready_rx) = channel();
    let store_c = store.clone();
    let live = spawn_stepped_instance(
        1,
        "kv-live-1".into(),
        move || {
            Ok::<_, teola::error::TeolaError>(SimLlmExecutor::new(
                "llm-lite", store_c, 3, 2, 1024, prefix_slots,
            ))
        },
        ev_tx.clone(),
        ready_tx,
    );
    ready_rx.recv().expect("live instance ready");

    // Budget of 40 tokens per instance: each 32-token prefill occupies
    // most of it, so waves must retire before the next can dispatch.
    let (job_tx, sched_h) =
        engine_with("llm-kv-requeue", vec![dead_instance(), live], ev_rx, 40);

    let (tx, rx) = channel();
    for q in 0..6u64 {
        job_tx.send(prefill_item(q, 32, tx.clone())).unwrap();
    }
    drop(tx);

    // Every prefill completes through the surviving instance — 6 waves
    // of ~1 admission each, all within the bounded wait.
    let mut done = 0;
    while done < 6 {
        let c = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("live instance must keep serving after peer death");
        assert!(
            !matches!(c.output, JobOutput::Failed(_)),
            "unexpected failure: {:?}",
            c.output
        );
        done += 1;
    }

    drop(job_tx);
    sched_h.join().expect("scheduler thread exits");
}
