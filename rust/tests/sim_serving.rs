//! Concurrent serving driver over the simulated backend: Poisson load,
//! metric sanity, batching-policy comparison, iteration-level continuous
//! batching vs the legacy run-to-completion path, and determinism — all
//! without artifacts, on plain `cargo test`.  Trace/workload setup comes
//! from the shared harness in `tests/common/`.

mod common;

use common::{prepared_one_shot, prepared_with_tokens, serial};
use teola::apps::{bind_answer_tokens, AppKind};
use teola::baselines::Scheme;
use teola::scheduler::{BatchPolicy, Platform, PlatformConfig};
use teola::serving::run_load_prepared;
use teola::workload::{Dataset, DatasetKind, PoissonTrace};

#[test]
fn sim_poisson_64_queries_complete_with_monotone_metrics() {
    let _g = serial();
    let platform = Platform::start(&PlatformConfig::sim("llm-lite")).unwrap();
    platform.set_policy(BatchPolicy::TopoAware);

    let n = 64;
    let trace = PoissonTrace::generate(400.0, n, 0x5E4);
    let prepared = prepared_one_shot(n, 8, 0x5E4);
    let report = run_load_prepared(&platform, prepared, &trace.arrivals).unwrap();
    platform.shutdown();

    // All queries completed (no deadlock) with sane latencies.
    assert_eq!(report.latencies_ms.len(), n);
    assert_eq!(report.outputs.len(), n);
    assert!(report.latencies_ms.iter().all(|&l| l > 0.0));
    assert!(report.qps > 0.0);
    assert!(report.wall_s < 60.0, "sim load run took {:.1}s", report.wall_s);

    // Percentiles are ordered.
    let s = &report.e2e_ms;
    assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max, "{s:?}");

    // Per-query metric monotonicity on a strictly sequential chain:
    // time queued + time executing can never exceed end-to-end time
    // (1 ms slack for micros truncation).
    for (i, m) in report.metrics.iter().enumerate() {
        assert!(
            m.queue_us + m.exec_us <= m.e2e_us + 1_000,
            "query {i}: queue {} + exec {} > e2e {}",
            m.queue_us,
            m.exec_us,
            m.e2e_us
        );
        assert!(m.n_engine_ops >= 2, "query {i}: prefill + decode expected");
    }
}

#[test]
fn sim_topo_batching_no_worse_than_per_invocation() {
    let _g = serial();
    let platform = Platform::start(&PlatformConfig::sim("llm-lite")).unwrap();

    // High enough arrival rate that queues build and cross-query batching
    // matters; identical seeded trace for both policies.
    let n = 48;
    let rate = 300.0;
    let seed = 0xBA7C4;
    let trace = PoissonTrace::generate(rate, n, seed);

    platform.set_policy(BatchPolicy::PerInvocation);
    let po = run_load_prepared(&platform, prepared_one_shot(n, 16, seed), &trace.arrivals)
        .unwrap();

    platform.set_policy(BatchPolicy::TopoAware);
    let topo = run_load_prepared(&platform, prepared_one_shot(n, 16, seed), &trace.arrivals)
        .unwrap();

    platform.shutdown();

    // Topology-aware batching shares decode iterations across queries, so
    // under contention its latency must be at least as good as
    // per-invocation scheduling.  Expected margin is ~3-4x; comparing
    // medians with 1.5x slack keeps the invariant robust to wall-clock
    // noise spikes on loaded CI runners.
    assert!(
        topo.e2e_ms.p50 <= po.e2e_ms.p50 * 1.5,
        "topo p50 {:.1} ms vs per-invocation p50 {:.1} ms",
        topo.e2e_ms.p50,
        po.e2e_ms.p50
    );
}

#[test]
fn sim_continuous_batching_cuts_p95_on_mixed_decodes() {
    let _g = serial();

    // One LLM instance so head-of-line blocking is visible: under the
    // legacy run-to-completion path a short decode arriving while a long
    // decode holds the instance waits out its entire tail; with
    // iteration-level admission it joins the in-flight batch and retires
    // after its own few iterations.
    let mut cfg = PlatformConfig::sim("llm-lite");
    cfg.llms[0].instances = 1;
    let platform = Platform::start(&cfg).unwrap();
    platform.set_policy(BatchPolicy::TopoAware);

    // Mixed workload on one seeded Poisson trace: queries 7 and 23 decode
    // 128 tokens, the rest 8-16 — so p95 lands on the worst *short*
    // query, the one the legacy path strands behind a long decode.
    let n = 40;
    let rate = 120.0;
    let seed = 0xC0817;
    let out_tokens =
        |i: usize| if i == 7 || i == 23 { 128 } else { 8 + (i % 9) };
    let trace = PoissonTrace::generate(rate, n, seed);

    platform.set_continuous(false);
    let legacy =
        run_load_prepared(&platform, prepared_with_tokens(n, seed, out_tokens), &trace.arrivals)
            .unwrap();

    platform.set_continuous(true);
    let cont =
        run_load_prepared(&platform, prepared_with_tokens(n, seed, out_tokens), &trace.arrivals)
            .unwrap();

    platform.shutdown();

    assert_eq!(legacy.latencies_ms.len(), n);
    assert_eq!(cont.latencies_ms.len(), n);
    // Continuous batching must strictly beat the run-to-completion path
    // at the tail on the same seed (expected margin is several-fold; the
    // strict inequality is the acceptance bar).
    assert!(
        cont.e2e_ms.p95 < legacy.e2e_ms.p95,
        "continuous p95 {:.1} ms should beat legacy p95 {:.1} ms",
        cont.e2e_ms.p95,
        legacy.e2e_ms.p95
    );
}

#[test]
fn sim_runs_are_deterministic_for_fixed_seed_and_query_id() {
    let _g = serial();

    let mut ds = Dataset::new(DatasetKind::TruthfulQa, 99);
    let mut q = ds.sample();
    q.doc_chunks.truncate(4);
    q.answer_tokens = 8;

    let run_once = || {
        let platform = Platform::start(&PlatformConfig::sim("llm-lite")).unwrap();
        let mut t = AppKind::DocQaNaive.template("llm-lite");
        bind_answer_tokens(&mut t, q.answer_tokens);
        let e = Scheme::Teola.build(&t, &q, &platform.profiles).unwrap();
        let (out, m) = platform.run_query(4242, e).unwrap();
        platform.shutdown();
        (out, m.n_engine_ops)
    };

    let (out_a, ops_a) = run_once();
    let (out_b, ops_b) = run_once();
    assert_eq!(ops_a, ops_b);
    assert_eq!(out_a, out_b, "sim outputs must be reproducible");
    assert!(!out_a.rows().is_empty());
}
