//! PR9 scheduler hot-path coverage over the `sched-bench` harness and
//! the batched-draining run loop:
//!
//!  * a 10k-query zero-cost burst loses and duplicates nothing — the
//!    harness errors on a missed completion (timeout) or a readable
//!    completion after full drain, and the dispatch counters must
//!    account for exactly the burst;
//!  * two identical `sched-bench` runs are bit-for-bit deterministic
//!    (same seeded stamps in, same dispatch order and counter profile
//!    out), and the incremental/exact comparison harness agrees;
//!  * batched event draining never starves a low-rate engine: a trickle
//!    of single jobs dispatches promptly even though the run loop
//!    drains arrivals in batches.
//!
//! The hot-path counters are process-global, so every test here runs
//! under `common::serial()`.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use teola::engines::instance::Instance;
use teola::engines::{
    Batch, Completion, EngineJob, ExecMode, ExecTiming, InstanceEvent, JobOutput,
};
use teola::scheduler::tenancy::SharedTenancy;
use teola::scheduler::{BatchPolicy, EngineScheduler, QueueItem};
use teola::serving::{run_sched_bench, run_sched_comparison};

mod common;

/// Satellite 4a: the 10k-query zero-cost burst drains with zero lost and
/// zero duplicated dispatches.  `run_sched_bench` itself errors on a
/// lost (timed-out) or duplicated (still-readable) completion; on top of
/// that the completion order must cover every enqueued `(query, node)`
/// exactly once and the counters must account for exactly the burst.
#[test]
fn zero_cost_burst_loses_and_duplicates_nothing() {
    let _guard = common::serial();
    const N: usize = 10_000;
    let report = run_sched_bench(N, 0x9CA, true).expect("burst must drain cleanly");
    assert_eq!(report.completion_order.len(), N);
    assert_eq!(report.stats.jobs_dispatched, N as u64, "every job dispatched exactly once");
    let unique: HashSet<(u64, usize)> = report.completion_order.iter().copied().collect();
    assert_eq!(unique.len(), N, "a repeated (query, node) means a duplicated dispatch");
    for key in &report.completion_order {
        assert!(
            key.0 >= 0x9CA_0000 && key.0 < 0x9CA_0000 + (N as u64 / 4) && (1..=4).contains(&key.1),
            "completion outside the enqueued burst: {key:?}"
        );
    }
}

/// Satellite 4b: determinism — two identical `sched-bench` runs choose
/// the same dispatch order and the same work profile (the wall-clock
/// fields may differ; the ordering surface may not), and the
/// exact-vs-incremental comparison harness (which errors on the first
/// divergent dispatch) passes on the same seed.
#[test]
fn sched_bench_runs_are_deterministic() {
    let _guard = common::serial();
    let a = run_sched_bench(2_000, 0xD5, true).expect("first run");
    let b = run_sched_bench(2_000, 0xD5, true).expect("second run");
    assert_eq!(
        a.completion_order, b.completion_order,
        "identical (n, seed, incremental) runs must dispatch in the same order"
    );
    assert_eq!(a.stats.dispatch_loops, b.stats.dispatch_loops);
    assert_eq!(a.stats.batches_formed, b.stats.batches_formed);
    assert_eq!(a.stats.jobs_dispatched, b.stats.jobs_dispatched);

    let (exact, incremental) =
        run_sched_comparison(2_000, 0xD5).expect("exact and incremental orders must agree");
    assert_eq!(exact.completion_order, a.completion_order);
    assert_eq!(incremental.completion_order, a.completion_order);
}

/// Minimal loopback scheduler for the starvation test: one instance that
/// completes jobs instantly, full-batch dispatch, no window.
fn trickle_sched() -> (Sender<QueueItem>, std::thread::JoinHandle<()>) {
    let (ev_tx, ev_rx) = channel::<InstanceEvent>();
    let (batch_tx, batch_rx) = channel::<Batch>();
    let handle = std::thread::spawn(move || {
        for batch in batch_rx {
            let mut retired = 0usize;
            for (ctx, job) in batch.jobs {
                retired += job.slot_rows();
                let _ = ctx.reply.send(Completion {
                    query: ctx.query,
                    node: ctx.node,
                    output: JobOutput::Unit,
                    timing: ExecTiming::default(),
                });
            }
            let _ = ev_tx.send(InstanceEvent {
                instance: 0,
                resident: 0,
                retired,
                retired_tokens: 0,
                resident_added: 0,
                resident_freed: 0,
            });
        }
    });
    let (job_tx, job_rx) = channel::<QueueItem>();
    let sched = EngineScheduler::new(
        "trickle".to_string(),
        vec![Instance { sender: batch_tx, handle }],
        ev_rx,
        job_rx,
        Arc::new(AtomicU8::new(BatchPolicy::TopoAware.to_u8())),
        Arc::new(AtomicUsize::new(8)),
        Arc::new(AtomicBool::new(false)),
        Arc::new(AtomicU64::new(0)),
        Arc::new(AtomicUsize::new(0)),
        Arc::new(AtomicBool::new(true)),
        Arc::new(AtomicUsize::new(0)),
        Arc::new(AtomicUsize::new(0)),
        ExecMode::FullBatch,
        Arc::new(SharedTenancy::default()),
        Arc::new(AtomicBool::new(true)),
        Arc::new(teola::scheduler::stats::SchedCounters::new()),
    );
    let h = std::thread::spawn(move || sched.run());
    (job_tx, h)
}

/// Satellite 4c: batched draining must not trade latency for throughput
/// on a low-rate engine.  Jobs trickle in one at a time (each sent only
/// after the previous completed, so the drain loop never sees more than
/// one pending arrival) and every single-job dispatch must complete
/// promptly — a run loop that waited to accumulate a fuller drain batch
/// would time out here.
#[test]
fn batched_draining_never_starves_a_low_rate_engine() {
    let _guard = common::serial();
    let (job_tx, sched_h) = trickle_sched();
    for q in 0..20u64 {
        let (tx, rx) = channel();
        job_tx
            .send(QueueItem {
                query: q,
                node: 1,
                depth: 0,
                bundle: (q, 1),
                arrival: Instant::now(),
                rows: 1,
                tokens: 1,
                wcp_discounted: false,
                prefix: None,
                wcp_us: 1000,
                tenant: teola::engines::UNTENANTED,
                job: EngineJob::ToolCall { name: "trickle".into(), cost_us: 0 },
                reply: tx,
                successors: Vec::new(),
            })
            .unwrap();
        let c = rx
            .recv_timeout(Duration::from_secs(2))
            .expect("a lone low-rate job must dispatch promptly, not wait for a fuller batch");
        assert_eq!(c.query, q);
        assert!(!matches!(c.output, JobOutput::Failed(_)), "got {:?}", c.output);
        std::thread::sleep(Duration::from_millis(1));
    }
    drop(job_tx);
    sched_h.join().expect("scheduler thread exits");
}
