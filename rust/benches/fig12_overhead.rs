//! Figure 12 + §7.4 overhead analysis: latency breakdown of Teola's
//! execution critical path for advanced-RAG doc QA across request rates —
//! graph optimization, queueing, engine execution, and the residual
//! (communication + host control flow).
//!
//! Paper: graph-opt 1.3-3% of total, communication 3.1-6.2%, queueing
//! dominating as rates grow.

use teola::apps::AppKind;
use teola::baselines::Scheme;
use teola::bench::{platform_for, run_trace, scaled, BenchTable, TraceRun};
use teola::scheduler::Platform;
use teola::workload::DatasetKind;

fn main() {
    if !teola::bench::backend_available() {
        eprintln!("fig12: no artifacts and TEOLA_BACKEND!=sim; skipping");
        return;
    }
    let app = AppKind::DocQaAdvanced;
    let dataset = DatasetKind::TruthfulQa;
    let core = "llm-small";
    let cfg = platform_for(app, core);
    let platform = Platform::start(&cfg).expect("platform");

    let rates: Vec<f64> = if teola::bench::quick() { vec![1.0] } else { vec![1.0, 2.0, 4.0, 8.0] };
    let n = scaled(12);

    let mut table = BenchTable::new(
        "fig12_overhead",
        &["rate_rps", "e2e_ms", "opt_%", "queue_%", "exec_%", "comm+host_%"],
    );
    table.note("app", app.name());
    table.note("core_llm", core);
    table.note(
        "note",
        "exec sums batched engine time credited per completion; comm+host is the residual",
    );

    for &rate in &rates {
        let run = TraceRun {
            app,
            scheme: Scheme::Teola,
            dataset,
            core_llm: core.into(),
            rate,
            n_queries: n,
            seed: 0xF12,
        };
        let r = run_trace(&platform, &run).expect("trace");
        let e2e = r.summary_ms.mean * 1000.0; // us
        let opt = r.mean_opt_us;
        let queue = r.mean_queue_us;
        // exec can exceed wall-span contributions because batched rows each
        // credit the full batch time; clamp the displayed share.
        let exec = r.mean_exec_us.min(e2e - opt - queue.min(e2e));
        let resid = (e2e - opt - queue - exec).max(0.0);
        let pct = |v: f64| format!("{:.1}", 100.0 * v / e2e.max(1.0));
        table.row(vec![
            format!("{rate}"),
            format!("{:.1}", e2e / 1000.0),
            pct(opt),
            pct(queue),
            pct(exec),
            pct(resid),
        ]);
    }
    platform.shutdown();
    table.print();
    table.write_json().expect("json");
    println!("\nfig12 OK (paper: opt 1.3-3%, comm 3.1-6.2%, queueing grows with rate)");
}
