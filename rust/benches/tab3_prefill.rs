//! Table 3: execution-efficiency cost of decomposed prefilling.
//!
//! Compares partial+full prefilling (Teola's Pass 3 engine path) against a
//! single complete prefill for three input splits, on the llama-2-7B
//! analog (llm-small).  The paper's splits 200+800 / 850+850 / 2500+500
//! (of 1000/1700/3000 tokens) are scaled into our 256-position KV budget
//! preserving the partial:full ratios.  Expected shape: decomposition is
//! a few percent slower in engine-seconds — the cost end-to-end
//! parallelism buys back.

use std::rc::Rc;
use std::time::Instant;

use teola::bench::BenchTable;
use teola::runtime::{HostTensor, Manifest, XlaContext};

fn kv_zeros(m: &Manifest, variant: &str) -> (Vec<usize>, Vec<f32>) {
    let info = &m.models[variant];
    let shape = vec![
        info.layers,
        2,
        1,
        info.n_heads,
        info.max_seq,
        info.d_model / info.n_heads,
    ];
    let n = shape.iter().product();
    (shape, vec![0.0f32; n])
}

/// One prefill call of `len` tokens at `offset` via the smallest covering
/// bucket; returns (kv_out, elapsed_us).
fn prefill(
    ctx: &mut XlaContext,
    m: &Manifest,
    variant: &str,
    kv: (Vec<usize>, Vec<f32>),
    offset: usize,
    len: usize,
) -> ((Vec<usize>, Vec<f32>), u64) {
    let chunk = m
        .prefill_buckets(variant)
        .into_iter()
        .filter(|(b, c)| *b == 1 && *c >= len)
        .map(|(_, c)| c)
        .min()
        .expect("bucket");
    let mut tokens = vec![0i32; chunk];
    for (i, t) in tokens.iter_mut().enumerate().take(len) {
        *t = 5 + (i as i32 * 7) % 1000;
    }
    let artifact = format!("{variant}__prefill__b1_c{chunk}");
    let t0 = Instant::now();
    let out = ctx
        .run(
            &artifact,
            Some(variant),
            &[
                HostTensor::i32(vec![1, chunk], tokens),
                HostTensor::f32(kv.0.clone(), kv.1),
                HostTensor::i32(vec![1], vec![offset as i32]),
                HostTensor::i32(vec![1], vec![len as i32]),
            ],
        )
        .expect("prefill");
    let us = t0.elapsed().as_micros() as u64;
    let kv_out = out[0].to_vec::<f32>().expect("kv");
    ((kv.0, kv_out), us)
}

fn main() {
    let dir = teola::runtime::default_artifacts_dir();
    if !teola::runtime::xla_backend_available() {
        eprintln!("tab3: no artifacts or XLA crate stubbed; skipping");
        return;
    }
    let m = Rc::new(Manifest::load(&dir).expect("manifest"));
    let variant = "llm-small";
    let mut ctx = XlaContext::new(m.clone()).expect("ctx");

    // Paper splits scaled into the 256-token KV budget, preserving the
    // partial:full ratios (0.2/0.8, 0.5/0.5, 0.83/0.17).  Every length is
    // an exact AOT bucket so both paths compute the same token count.
    let cases: [(usize, usize); 3] = [(16, 48), (64, 64), (160, 32)];
    let reps = if teola::bench::quick() { 3 } else { 10 };

    let mut table = BenchTable::new(
        "tab3_prefill",
        &[
            "partial_ms(tokens)",
            "full_ms(tokens)",
            "total_ms(tokens)",
            "single_ms(tokens)",
            "slowdown_%",
        ],
    );
    table.note("variant", variant);
    table.note("reps", &reps.to_string());

    // Warm-up: compile every bucket the cases touch before timing.
    for (p_len, f_len) in cases {
        let kv0 = kv_zeros(&m, variant);
        let (kv1, _) = prefill(&mut ctx, &m, variant, kv0, 0, p_len);
        let _ = prefill(&mut ctx, &m, variant, kv1, p_len, f_len);
        let kv0 = kv_zeros(&m, variant);
        let _ = prefill(&mut ctx, &m, variant, kv0, 0, p_len + f_len);
    }

    for (p_len, f_len) in cases {
        let total = p_len + f_len;
        let mut t_partial = 0u64;
        let mut t_full = 0u64;
        let mut t_single = 0u64;
        for _ in 0..reps {
            let kv0 = kv_zeros(&m, variant);
            let (kv1, us_p) = prefill(&mut ctx, &m, variant, kv0, 0, p_len);
            let (_kv2, us_f) = prefill(&mut ctx, &m, variant, kv1, p_len, f_len);
            t_partial += us_p;
            t_full += us_f;
            let kv0 = kv_zeros(&m, variant);
            let (_kv, us_s) = prefill(&mut ctx, &m, variant, kv0, 0, total);
            t_single += us_s;
        }
        let pm = t_partial as f64 / reps as f64 / 1000.0;
        let fm = t_full as f64 / reps as f64 / 1000.0;
        let sm = t_single as f64 / reps as f64 / 1000.0;
        let tm = pm + fm;
        table.row(vec![
            format!("{pm:.2} ({p_len})"),
            format!("{fm:.2} ({f_len})"),
            format!("{tm:.2} ({total})"),
            format!("{sm:.2} ({total})"),
            format!("{:+.2}", 100.0 * (tm - sm) / sm),
        ]);
    }
    table.print();
    table.write_json().expect("json");
    println!("\ntab3 OK (paper: decomposed prefilling is 3.11%-12.12% slower)");
}
