//! Figure 11: ablation of topology-aware batching (advanced RAG, same
//! setting as Fig. 10).  Arms: topology-aware vs blind FIFO batching, both
//! over the fully optimized Teola e-graph.  Paper: ~1.15x single query,
//! up to 19.2% mean-latency reduction under multi-query load.

use teola::apps::AppKind;
use teola::baselines::Scheme;
use teola::bench::{ms, platform_for, run_single, run_trace, scaled, speedup, BenchTable, TraceRun};
use teola::scheduler::{BatchPolicy, Platform};
use teola::util::stats::Summary;
use teola::workload::{Dataset, DatasetKind};

fn main() {
    if !teola::bench::backend_available() {
        eprintln!("fig11: no artifacts and TEOLA_BACKEND!=sim; skipping");
        return;
    }
    let app = AppKind::DocQaAdvanced;
    let dataset = DatasetKind::TruthfulQa;
    let core = "llm-small";
    let cfg = platform_for(app, core);
    let platform = Platform::start(&cfg).expect("platform");

    let mut table = BenchTable::new(
        "fig11_ablation_sched",
        &["setting", "batching", "mean_ms", "speedup"],
    );
    table.note("app", app.name());
    table.note("core_llm", core);

    let arms = [("topology-aware", BatchPolicy::TopoAware), ("blind FIFO", BatchPolicy::BlindTO)];

    // Single-query (averaged): depth-aware fusing inside one query.
    let reps = if teola::bench::quick() { 2 } else { 6 };
    let mut single = Vec::new();
    for (_name, policy) in arms {
        let mut ds = Dataset::new(dataset, 0xF11);
        let mut lats = Vec::new();
        for _ in 0..reps {
            let q = ds.sample();
            let run = TraceRun {
                app,
                scheme: Scheme::Teola,
                dataset,
                core_llm: core.into(),
                rate: 1.0,
                n_queries: 1,
                seed: 0xF11,
            };
            platform.set_policy(policy);
            // run_single resets policy from the scheme; override after.
            let (lat, _m) = {
                platform.set_policy(policy);
                let (e, _) = teola::bench::build_egraph(&platform, &run, &q).unwrap();
                platform.set_policy(policy);
                let t0 = std::time::Instant::now();
                platform.run_query(teola::bench::next_query_id(), e).unwrap();
                (t0.elapsed().as_secs_f64() * 1000.0, ())
            };
            lats.push(lat);
        }
        single.push(Summary::of(&lats).mean);
    }
    table.row(vec![
        "single-query".into(),
        "topology-aware".into(),
        ms(single[0]),
        speedup(single[1], single[0]),
    ]);
    table.row(vec![
        "single-query".into(),
        "blind FIFO".into(),
        ms(single[1]),
        "1.00x".into(),
    ]);

    // Multi-query load.
    let rates: Vec<f64> = if teola::bench::quick() { vec![1.0] } else { vec![1.0, 2.0, 4.0] };
    let n = scaled(12);
    for &rate in &rates {
        let mut means = Vec::new();
        for (_name, policy) in arms {
            let run = TraceRun {
                app,
                scheme: Scheme::Teola,
                dataset,
                core_llm: core.into(),
                rate,
                n_queries: n,
                seed: 0xF11 + rate as u64,
            };
            // run_trace sets the scheme policy; override by running and
            // flipping the policy first (set_policy is sticky).
            platform.set_policy(policy);
            let r = run_trace_with_policy(&platform, &run, policy);
            means.push(r);
        }
        table.row(vec![
            format!("rate-{rate}"),
            "topology-aware".into(),
            ms(means[0]),
            speedup(means[1], means[0]),
        ]);
        table.row(vec![
            format!("rate-{rate}"),
            "blind FIFO".into(),
            ms(means[1]),
            "1.00x".into(),
        ]);
    }
    platform.shutdown();
    table.print();
    table.write_json().expect("json");
    println!("\nfig11 OK (paper: ~1.15x single query; up to 19.2% under load)");
}

fn run_trace_with_policy(
    platform: &Platform,
    run: &TraceRun,
    policy: BatchPolicy,
) -> f64 {
    use teola::bench::{build_egraph, next_query_id};
    use teola::workload::PoissonTrace;
    let trace = PoissonTrace::generate(run.rate, run.n_queries, run.seed);
    let mut ds = Dataset::new(run.dataset, run.seed ^ 0xDA7A);
    let mut prepared = Vec::new();
    for _ in 0..run.n_queries {
        let q = ds.sample();
        let (e, _) = build_egraph(platform, run, &q).expect("egraph");
        prepared.push(e);
    }
    platform.set_policy(policy);
    let start = std::time::Instant::now();
    let mut handles = Vec::new();
    for (i, e) in prepared.into_iter().enumerate() {
        if let Some(w) = trace.arrivals[i].checked_sub(start.elapsed()) {
            std::thread::sleep(w);
        }
        handles.push(platform.spawn_query(next_query_id(), e));
    }
    let lats: Vec<f64> = handles
        .into_iter()
        .map(|h| h.join().unwrap().expect("q").1.e2e_us as f64 / 1000.0)
        .collect();
    let _ = run_single; // (link the shared helpers)
    Summary::of(&lats).mean
}
