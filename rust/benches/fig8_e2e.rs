//! Figure 8: end-to-end latency of the four applications under five
//! orchestration schemes across request rates.
//!
//! Paper rows: search-gen (web_questions/HotpotQA), doc QA naive RAG
//! (FinQABench/TruthfulQA), doc QA advanced RAG, contextual retrieval;
//! schemes LlamaDist(PO), LlamaDist(TO), LlamaDistPC, AutoGen, Teola.
//! Expected shape: Teola wins everywhere (up to ~2x on advanced RAG);
//! PO beats TO at low rates and loses at high rates.

use teola::apps::AppKind;
use teola::baselines::Scheme;
use teola::bench::{ms, platform_for_all, run_trace, scaled, speedup, BenchTable, TraceRun};
use teola::scheduler::Platform;
use teola::workload::DatasetKind;

fn main() {
    if !teola::bench::backend_available() {
        eprintln!("fig8: no artifacts and TEOLA_BACKEND!=sim; skipping");
        return;
    }
    let quick = teola::bench::quick();
    // (app, dataset, core llm) rows; llm size mirrors the paper's rows
    // scaled to this testbed (llm-small == llama-2-7B analog, etc.).
    // (app, dataset, core llm, rates) rows; llm size mirrors the paper's
    // rows scaled to this testbed; rates load the 2-instance LLM pools to
    // paper-equivalent utilization (engine seconds here are ~100x smaller
    // than the paper's GPU seconds, so rates are correspondingly higher).
    let rows: Vec<(AppKind, DatasetKind, &str, [f64; 3])> = if quick {
        vec![(AppKind::DocQaNaive, DatasetKind::TruthfulQa, "llm-lite", [4.0, 4.0, 4.0])]
    } else {
        vec![
            (AppKind::SearchGen, DatasetKind::WebQuestions, "llm-small", [2.0, 4.0, 8.0]),
            (AppKind::DocQaNaive, DatasetKind::TruthfulQa, "llm-small", [2.0, 4.0, 8.0]),
            (AppKind::DocQaAdvanced, DatasetKind::TruthfulQa, "llm-small", [1.0, 2.0, 4.0]),
            (AppKind::ContextualRetrieval, DatasetKind::FinQaBench, "llm-small", [0.5, 1.0, 2.0]),
        ]
    };
    let n_queries = scaled(16);

    let mut table = BenchTable::new(
        "fig8_e2e",
        &["app", "dataset", "rate_rps", "scheme", "mean_ms", "p90_ms", "teola_speedup"],
    );
    table.note("queries_per_point", &n_queries.to_string());

    let all_apps: Vec<AppKind> = rows.iter().map(|(a, _, _, _)| *a).collect();
    let core0 = rows[0].2;
    let cfg = platform_for_all(&all_apps, core0);
    let platform = Platform::start(&cfg).expect("platform");
    for (app, dataset, core, rates) in &rows {
        let rates = if quick { &rates[..1] } else { &rates[..] };
        for &rate in rates {
            let mut results: Vec<(Scheme, f64, f64)> = Vec::new();
            for scheme in Scheme::all() {
                let run = TraceRun {
                    app: *app,
                    scheme,
                    dataset: *dataset,
                    core_llm: (*core).into(),
                    rate,
                    n_queries,
                    seed: 0xF18 + rate as u64,
                };
                let r = run_trace(&platform, &run).expect("trace");
                results.push((scheme, r.summary_ms.mean, r.summary_ms.p90));
            }
            let teola_mean = results
                .iter()
                .find(|(s, _, _)| *s == Scheme::Teola)
                .map(|(_, m, _)| *m)
                .unwrap_or(0.0);
            for (scheme, mean, p90) in results {
                table.row(vec![
                    app.name().into(),
                    dataset.name().into(),
                    format!("{rate}"),
                    scheme.name().into(),
                    ms(mean),
                    ms(p90),
                    speedup(mean, teola_mean),
                ]);
            }
        }
    }
    platform.shutdown();

    table.print();
    table.write_json().expect("json");
    println!("\nfig8 OK (paper: Teola up to 2.09x; PO < TO at high rate)");
}
