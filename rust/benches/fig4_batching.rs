//! Figure 4: request-level vs application-level scheduling and execution.
//!
//! (a) Embedding engine: 48 chunk-embedding requests executed at the
//!     request-preferred batch size (4) vs the application-aware maximum
//!     efficient batch (16) — total completion time comparison.
//! (b) LLM engine, tree-based synthesis (3 leaves + 1 combiner from two
//!     queries): blind batch-of-2 FIFO vs depth-aware batching.

use std::sync::mpsc::channel;
use std::time::Instant;

use teola::apps::AppKind;
use teola::baselines::Scheme;
use teola::bench::{ms, platform_for, run_single, speedup, BenchTable, TraceRun};
use teola::engines::EngineJob;
use teola::scheduler::{BatchPolicy, Platform, QueueItem};
use teola::workload::{Dataset, DatasetKind};

/// (a): push `n` single-chunk embed jobs through the embedding scheduler
/// with a given slot budget and measure total completion time.
fn embed_total_time(platform: &Platform, n: usize, policy: BatchPolicy) -> f64 {
    platform.set_policy(policy);
    let routers = platform.routers();
    let embed = routers.get("embedder").expect("embedder route");
    let (tx, rx) = channel();
    let t0 = Instant::now();
    for i in 0..n {
        let chunk: Vec<i32> = (0..48).map(|j| 4 + ((i * 48 + j) % 1500) as i32).collect();
        embed
            .send(QueueItem {
                query: 9_000 + i as u64,
                node: i,
                depth: 1,
                bundle: (0, i as u64 / 4), // request-level bundles of 4
                arrival: Instant::now(),
                rows: 1,
                tokens: 1,
                wcp_discounted: false,
                prefix: None,
                wcp_us: 0,
                tenant: teola::engines::UNTENANTED,
                job: EngineJob::Embed { chunks: vec![chunk] },
                reply: tx.clone(),
                successors: Vec::new(),
            })
            .unwrap();
    }
    drop(tx);
    let mut done = 0;
    while done < n {
        rx.recv().expect("completion");
        done += 1;
    }
    t0.elapsed().as_secs_f64() * 1000.0
}

fn main() {
    if !teola::bench::backend_available() {
        eprintln!("fig4: no artifacts and TEOLA_BACKEND!=sim; skipping");
        return;
    }
    let skip_a = std::env::var("TEOLA_FIG4_SKIP_A").is_ok();
    let core = "llm-small";
    let mut table = BenchTable::new(
        "fig4_batching",
        &["experiment", "policy", "total_ms", "speedup"],
    );

    // ---- (a) embedding engine ----
    if !skip_a {
        let cfg = platform_for(AppKind::DocQaNaive, core);
        let platform = Platform::start(&cfg).expect("platform");
        platform.set_engine_slots("embedder", 4); // request-level batch
        let t_req = embed_total_time(&platform, 48, BatchPolicy::PerInvocation);
        platform.set_engine_slots("embedder", 16); // app-aware max efficient
        let t_app = embed_total_time(&platform, 48, BatchPolicy::TopoAware);
        platform.shutdown();

        table.row(vec![
            "embed-48-chunks".into(),
            "request-level bs=4".into(),
            ms(t_req),
            "1.00x".into(),
        ]);
        table.row(vec![
            "embed-48-chunks".into(),
            "app-level bs=16".into(),
            ms(t_app),
            speedup(t_req, t_app),
        ]);
    }

    // ---- (b) LLM engine, Fig. 7 scenario ----
    // Query 1 holds primitives A (depth 3) and B (depth 1); query 2 holds
    // H (depth 3).  With a max batch of 2 on one instance, blind FIFO
    // batches [A, B] and leaves H waiting; topology-aware batches [A, H]
    // (B's delay does not bottleneck query 1, cf. Fig. 7).  We measure the
    // mean completion time of the depth-3 nodes — the graph-advancing
    // work of both queries.
    {
        let mut cfg = platform_for(AppKind::DocQaNaive, core);
        for spec in &mut cfg.llms {
            spec.instances = 1;
            spec.max_slots = 2;
        }
        // The Fig. 7 snapshot is defined in row slots (max batch of 2):
        // keep legacy row accounting so token-denominated admission
        // doesn't widen the batch.
        cfg.kv_tokens_per_instance = Some(0);
        let platform = Platform::start(&cfg).expect("platform");
        let mut qbase = 21u64;
        let mut run_fig7 = |policy: BatchPolicy| -> f64 {
            let q1 = qbase;
            let q2 = qbase + 1;
            qbase += 2;
            let routers = platform.routers();
            let llm = routers.get(core).expect("llm route");
            let (tx, rx) = channel();

            // Prefill three sequences (A, B, H) so decodes have KV state.
            platform.set_policy(BatchPolicy::BlindTO);
            for (node, query, seq) in [(0usize, q1, 0u32), (1, q1, 1), (2, q2, 0)] {
                llm.send(QueueItem {
                    query,
                    node,
                    depth: 9,
                    bundle: (query, node as u64),
                    arrival: Instant::now(),
                    rows: 1,
                    tokens: 64,
                    wcp_discounted: false,
                    prefix: None,
                    wcp_us: 0,
                    tenant: teola::engines::UNTENANTED,
                    job: EngineJob::Prefill {
                        seq: (query, seq),
                        tokens: (0..64).map(|i| 5 + i % 900).collect(),
                        offset: 0,
                        prefix: None,
                    },
                    reply: tx.clone(),
                    successors: Vec::new(),
                })
                .unwrap();
            }
            let mut first = std::collections::HashMap::new();
            for _ in 0..3 {
                let c = rx.recv().unwrap();
                if let teola::engines::JobOutput::Tokens(t) = &c.output {
                    first.insert((c.query, c.node), t[0]);
                }
            }

            // Inject the decode jobs A (q1,d3), B (q1,d1), H (q2,d3)
            // while the engine is busy so they queue together; a dummy
            // warm decode occupies the instance first.
            platform.set_policy(policy);
            let mk = |query: u64, node: usize, depth: u32, seq: u32, tok: i32| QueueItem {
                query,
                node,
                depth,
                bundle: (query, node as u64),
                arrival: Instant::now(),
                rows: 1,
                tokens: 1,
                wcp_discounted: false,
                prefix: None,
                wcp_us: 0,
                tenant: teola::engines::UNTENANTED,
                job: EngineJob::Decode {
                    seq: (query, seq),
                    first_token: tok,
                    segments: vec![teola::engines::SegmentSpec { node, len: 20 }],
                },
                reply: tx.clone(),
                successors: Vec::new(),
            };
            // Occupy the instance so A, B and H queue together (the
            // paper's Fig. 7 snapshot has all three pending at once).
            let dummy_q = q2 + 100;
            llm.send(QueueItem {
                query: dummy_q,
                node: 0,
                depth: 9,
                bundle: (dummy_q, 0),
                arrival: Instant::now(),
                rows: 1,
                tokens: 1,
                wcp_discounted: false,
                prefix: None,
                wcp_us: 0,
                tenant: teola::engines::UNTENANTED,
                job: EngineJob::Prefill {
                    seq: (dummy_q, 0),
                    tokens: (0..32).map(|i| 5 + i % 900).collect(),
                    offset: 0,
                    prefix: None,
                },
                reply: tx.clone(),
                successors: Vec::new(),
            })
            .unwrap();
            std::thread::sleep(std::time::Duration::from_millis(2));
            let t0 = Instant::now();
            llm.send(mk(q1, 10, 3, 0, first[&(q1, 0)])).unwrap(); // A
            llm.send(mk(q1, 11, 1, 1, first[&(q1, 1)])).unwrap(); // B
            llm.send(mk(q2, 12, 3, 0, first[&(q2, 2)])).unwrap(); // H
            let mut deep_done = Vec::new();
            let mut got = 0;
            // 3 decode completions + 1 dummy prefill completion
            let mut seen_dummy = false;
            while got < 3 || !seen_dummy {
                if got >= 3 && !seen_dummy {
                    // drain the dummy
                    let c = rx.recv().unwrap();
                    if c.query == dummy_q {
                        seen_dummy = true;
                    }
                    continue;
                }
                let c = rx.recv().unwrap();
                if c.query == dummy_q {
                    seen_dummy = true;
                    continue;
                }
                if matches!(c.output, teola::engines::JobOutput::TokenBatch(_)) {
                    got += 1;
                    if c.node == 10 || c.node == 12 {
                        deep_done.push(t0.elapsed().as_secs_f64() * 1000.0);
                    }
                }
            }
            deep_done.iter().sum::<f64>() / deep_done.len() as f64
        };

        let t_blind = run_fig7(BatchPolicy::BlindTO);
        let t_topo = run_fig7(BatchPolicy::TopoAware);
        drop(run_fig7);
        platform.shutdown();
        table.row(vec![
            "llm-fig7-deep-nodes".into(),
            "blind bs=2 (FIFO)".into(),
            ms(t_blind),
            "1.00x".into(),
        ]);
        table.row(vec![
            "llm-fig7-deep-nodes".into(),
            "topology-aware".into(),
            ms(t_topo),
            speedup(t_blind, t_topo),
        ]);
    }

    table.print();
    table.write_json().expect("json");
    println!("\nfig4 OK (paper: (a) 1.3x with bs=16; (b) 1.4x with depth-aware batching)");
}
