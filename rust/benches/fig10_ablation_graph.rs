//! Figure 10: ablation of the graph-optimization passes on advanced-RAG
//! doc QA.  Left: single-query latency; right: mean latency under load.
//! Arms: full Teola, w/o parallelization (Pass 1+3 off), w/o pipelining
//! (Pass 2+4 off), no optimization.

use teola::apps::{bind_answer_tokens, AppKind};
use teola::bench::{
    ms, next_query_id, platform_for, scaled, speedup, BenchTable, TraceRun,
};
use teola::engines::profile::ProfileRegistry;
use teola::graph::egraph::EGraph;
use teola::graph::pgraph::build_pgraph;
use teola::graph::{run_passes, OptFlags};
use teola::scheduler::{BatchPolicy, Platform};
use teola::util::stats::Summary;
use teola::workload::{Dataset, DatasetKind, PoissonTrace};

const ARMS: [(&str, fn() -> OptFlags); 4] = [
    ("Teola (all passes)", OptFlags::all),
    ("w/o pipelining", OptFlags::parallelization_only),
    ("w/o parallelization", OptFlags::pipelining_only),
    ("no graph opt", OptFlags::none),
];

fn build(app: AppKind, core: &str, q: &teola::graph::template::QueryConfig, flags: OptFlags, profiles: &ProfileRegistry) -> EGraph {
    let mut t = app.template(core);
    bind_answer_tokens(&mut t, q.answer_tokens);
    let g = build_pgraph(&t, q).expect("pgraph");
    let g = run_passes(g, flags, profiles).expect("passes");
    EGraph::new(g).expect("egraph")
}

fn main() {
    if !teola::bench::backend_available() {
        eprintln!("fig10: no artifacts and TEOLA_BACKEND!=sim; skipping");
        return;
    }
    let app = AppKind::DocQaAdvanced;
    let dataset = DatasetKind::TruthfulQa;
    // Paper uses llama-30B; llm-small keeps the sweep tractable on this
    // single-core testbed while preserving the relative pass effects.
    let core = "llm-small";
    let cfg = platform_for(app, core);
    let platform = Platform::start(&cfg).expect("platform");
    platform.set_policy(BatchPolicy::TopoAware);
    let profiles = ProfileRegistry::with_defaults();

    let mut table = BenchTable::new(
        "fig10_ablation_graph",
        &["setting", "arm", "mean_ms", "vs_full"],
    );
    table.note("app", app.name());
    table.note("core_llm", core);

    // ---- left: single-query latency, averaged ----
    let reps = if teola::bench::quick() { 2 } else { 6 };
    let mut single: Vec<(usize, f64)> = Vec::new();
    for (ai, (_name, flags)) in ARMS.iter().enumerate() {
        let mut lats = Vec::new();
        let mut ds = Dataset::new(dataset, 0xF10);
        for _ in 0..reps {
            let q = ds.sample();
            let e = build(app, core, &q, flags(), &profiles);
            let t0 = std::time::Instant::now();
            platform.run_query(next_query_id(), e).expect("query");
            lats.push(t0.elapsed().as_secs_f64() * 1000.0);
        }
        single.push((ai, Summary::of(&lats).mean));
    }
    let full = single[0].1;
    for (ai, mean) in &single {
        table.row(vec![
            "single-query".into(),
            ARMS[*ai].0.into(),
            ms(*mean),
            speedup(*mean, full),
        ]);
    }

    // ---- right: latency under load ----
    let rates: Vec<f64> = if teola::bench::quick() { vec![1.0] } else { vec![1.0, 2.0, 4.0] };
    let n = scaled(12);
    for &rate in &rates {
        let mut arm_means = Vec::new();
        for (_name, flags) in ARMS.iter() {
            let trace = PoissonTrace::generate(rate, n, 0xF10);
            let mut ds = Dataset::new(dataset, 0xF10);
            let mut prepared = Vec::new();
            for _ in 0..n {
                let q = ds.sample();
                prepared.push(build(app, core, &q, flags(), &profiles));
            }
            let start = std::time::Instant::now();
            let mut handles = Vec::new();
            for (i, e) in prepared.into_iter().enumerate() {
                if let Some(w) = trace.arrivals[i].checked_sub(start.elapsed()) {
                    std::thread::sleep(w);
                }
                handles.push(platform.spawn_query(next_query_id(), e));
            }
            let lats: Vec<f64> = handles
                .into_iter()
                .map(|h| h.join().unwrap().expect("q").1.e2e_us as f64 / 1000.0)
                .collect();
            arm_means.push(Summary::of(&lats).mean);
        }
        let full = arm_means[0];
        for (ai, mean) in arm_means.iter().enumerate() {
            table.row(vec![
                format!("rate-{rate}"),
                ARMS[ai].0.into(),
                ms(*mean),
                speedup(*mean, full),
            ]);
        }
    }
    platform.shutdown();

    let _ = TraceRun {
        app,
        scheme: teola::baselines::Scheme::Teola,
        dataset,
        core_llm: core.into(),
        rate: 1.0,
        n_queries: 1,
        seed: 0,
    };
    table.print();
    table.write_json().expect("json");
    println!("\nfig10 OK (paper: both parallelization and pipelining reduce latency)");
}
