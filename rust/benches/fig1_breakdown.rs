//! Figure 1: latency breakdown of each task module for the four apps,
//! executed with module-sequential orchestration (the LlamaIndex analog),
//! with the LLM synthesizing module split into prefilling and decoding.
//!
//! Regenerates the paper's stacked-bar data as percentage rows.

use std::collections::HashMap;

use teola::apps::AppKind;
use teola::baselines::Scheme;
use teola::bench::{ms, platform_for_all, run_single, BenchTable, TraceRun};
use teola::scheduler::Platform;
use teola::workload::{Dataset, DatasetKind};

fn main() {
    if !teola::bench::backend_available() {
        eprintln!("fig1: no artifacts and TEOLA_BACKEND!=sim; skipping");
        return;
    }
    let apps = [
        (AppKind::SearchGen, DatasetKind::WebQuestions),
        (AppKind::DocQaNaive, DatasetKind::TruthfulQa),
        (AppKind::DocQaAdvanced, DatasetKind::TruthfulQa),
        (AppKind::ContextualRetrieval, DatasetKind::FinQaBench),
    ];
    let core = "llm-small";
    let mut table = BenchTable::new(
        "fig1_breakdown",
        &["app", "module", "class", "exec_ms", "share_%"],
    );
    table.note("scheme", "LlamaDist (module-sequential, TO)");
    table.note("core_llm", core);

    let all_apps: Vec<AppKind> = apps.iter().map(|(a, _)| *a).collect();
    let cfg = platform_for_all(&all_apps, core);
    let platform = Platform::start(&cfg).expect("platform");
    for (app, dataset) in apps {
        let run = TraceRun {
            app,
            scheme: Scheme::LlamaDistTO,
            dataset,
            core_llm: core.into(),
            rate: 1.0,
            n_queries: 1,
            seed: 7,
        };
        // Average over a few queries.
        let reps = if teola::bench::quick() { 1 } else { 3 };
        let mut acc: HashMap<(usize, &'static str), u64> = HashMap::new();
        let mut ds = Dataset::new(dataset, 7);
        for _ in 0..reps {
            let q = ds.sample();
            let (_lat, m) = run_single(&platform, &run, &q).expect("query");
            for (k, v) in m.per_component_us {
                *acc.entry(k).or_default() += v;
            }
        }
        let total: u64 = acc.values().sum();
        let template = app.template(core);
        let mut keys: Vec<_> = acc.keys().copied().collect();
        keys.sort();
        for (comp, class) in keys {
            let v = acc[&(comp, class)];
            let name = template
                .components
                .get(comp)
                .map(|c| c.name.clone())
                .unwrap_or_else(|| format!("comp{comp}"));
            table.row(vec![
                app.name().into(),
                name,
                class.into(),
                ms(v as f64 / 1000.0 / reps as f64),
                format!("{:.1}", 100.0 * v as f64 / total.max(1) as f64),
            ]);
        }
    }
    platform.shutdown();
    table.print();
    table.write_json().expect("write json");
    println!("\nfig1 OK (expect: non-LLM modules take a large share; >50% for doc QA)");
}
