//! Figure 9: co-located applications — naive and advanced RAG doc QA
//! sharing the same infrastructure, Teola vs LlamaDistPC, average latency
//! per app.  Paper: 1.2x-1.55x speedup across the two apps.

use teola::apps::AppKind;
use teola::baselines::Scheme;
use teola::bench::{
    build_egraph, ms, next_query_id, platform_for_all, scaled, speedup, BenchTable, TraceRun,
};
use teola::scheduler::Platform;
use teola::util::stats::Summary;
use teola::workload::{Dataset, DatasetKind, PoissonTrace};

/// Run both apps concurrently at `rate` each; returns (naive mean ms,
/// advanced mean ms).
fn run_colocated(platform: &Platform, scheme: Scheme, rate: f64, n_each: usize, seed: u64) -> (f64, f64) {
    platform.set_policy(scheme.policy());
    let core = "llm-small";
    let dataset = DatasetKind::TruthfulQa;
    let apps = [AppKind::DocQaNaive, AppKind::DocQaAdvanced];

    // Interleave two independent Poisson streams.
    let mut events: Vec<(std::time::Duration, usize)> = Vec::new();
    for (ai, _) in apps.iter().enumerate() {
        let trace = PoissonTrace::generate(rate, n_each, seed + ai as u64);
        events.extend(trace.arrivals.into_iter().map(|t| (t, ai)));
    }
    events.sort();

    let mut datasets = [Dataset::new(dataset, seed), Dataset::new(dataset, seed ^ 0xA)];
    let mut prepared = Vec::new();
    for (due, ai) in events {
        let q = datasets[ai].sample();
        let run = TraceRun {
            app: apps[ai],
            scheme,
            dataset,
            core_llm: core.into(),
            rate,
            n_queries: 1,
            seed,
        };
        let (e, _) = build_egraph(platform, &run, &q).expect("egraph");
        prepared.push((due, ai, e));
    }

    let start = std::time::Instant::now();
    let mut handles = Vec::new();
    for (due, ai, e) in prepared {
        if let Some(wait) = due.checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        handles.push((ai, platform.spawn_query(next_query_id(), e)));
    }
    let mut lat: [Vec<f64>; 2] = [Vec::new(), Vec::new()];
    for (ai, h) in handles {
        let (_out, m) = h.join().unwrap().expect("query");
        lat[ai].push(m.e2e_us as f64 / 1000.0);
    }
    (Summary::of(&lat[0]).mean, Summary::of(&lat[1]).mean)
}

fn main() {
    if !teola::bench::backend_available() {
        eprintln!("fig9: no artifacts and TEOLA_BACKEND!=sim; skipping");
        return;
    }
    let core = "llm-small";
    let cfg = platform_for_all(&[AppKind::DocQaNaive, AppKind::DocQaAdvanced], core);
    let platform = Platform::start(&cfg).expect("platform");

    // Paper: 3 rps per app on GPUs; scaled to this CPU testbed.
    let rate = 3.0;
    let n_each = scaled(16);

    let (pc_naive, pc_adv) = run_colocated(&platform, Scheme::LlamaDistPC, rate, n_each, 0x901);
    let (te_naive, te_adv) = run_colocated(&platform, Scheme::Teola, rate, n_each, 0x901);
    platform.shutdown();

    let mut table = BenchTable::new(
        "fig9_colocation",
        &["app", "LlamaDistPC_ms", "Teola_ms", "speedup"],
    );
    table.note("rate_per_app_rps", &rate.to_string());
    table.note("queries_per_app", &n_each.to_string());
    table.row(vec![
        "doc-qa-naive".into(),
        ms(pc_naive),
        ms(te_naive),
        speedup(pc_naive, te_naive),
    ]);
    table.row(vec![
        "doc-qa-advanced".into(),
        ms(pc_adv),
        ms(te_adv),
        speedup(pc_adv, te_adv),
    ]);
    table.print();
    table.write_json().expect("json");
    println!("\nfig9 OK (paper: Teola 1.2x-1.55x over LlamaDistPC when co-located)");
}
