//! Benchmark driver + reporting harness (criterion is unavailable offline;
//! every `benches/*.rs` target uses this module with `harness = false`).
//!
//! Provides: trace execution (open-loop Poisson over a running Platform),
//! single-query timing, table printing in the paper's row/series format,
//! and machine-readable JSON dumps under `bench_results/`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::apps::{bind_answer_tokens, AppKind};
use crate::baselines::Scheme;
use crate::engines::profile::ProfileRegistry;
use crate::engines::sim::ExecBackend;
use crate::engines::QueryId;
use crate::error::Result;
use crate::graph::egraph::EGraph;
use crate::graph::pgraph::{build_pgraph, instr_tokens};
use crate::graph::template::{
    Component, ComponentKind, PromptPart, QueryConfig, SynthesisMode, WorkflowTemplate,
};
use crate::graph::{run_passes, OptFlags};
use crate::json::{num, obj, s, Json};
use crate::scheduler::graph_sched::QueryMetrics;
use crate::scheduler::{Platform, PlatformConfig};
use crate::util::stats::Summary;
use crate::workload::{Dataset, DatasetKind};

static NEXT_QUERY: AtomicU64 = AtomicU64::new(1);

/// Unique query id across a bench process.
pub fn next_query_id() -> QueryId {
    NEXT_QUERY.fetch_add(1, Ordering::Relaxed)
}

/// `TEOLA_BENCH_QUICK=1` shrinks sweeps for smoke runs.
pub fn quick() -> bool {
    std::env::var("TEOLA_BENCH_QUICK").map_or(false, |v| v != "0" && !v.is_empty())
}

/// Scale a query count down in quick mode.
pub fn scaled(n: usize) -> usize {
    if quick() {
        (n / 3).max(2)
    } else {
        n
    }
}

/// One trace-run request.
#[derive(Debug, Clone)]
pub struct TraceRun {
    pub app: AppKind,
    pub scheme: Scheme,
    pub dataset: DatasetKind,
    pub core_llm: String,
    pub rate: f64,
    pub n_queries: usize,
    pub seed: u64,
}

/// Aggregated result of a trace run.
#[derive(Debug, Clone)]
pub struct TraceResult {
    pub latencies_ms: Vec<f64>,
    pub summary_ms: Summary,
    pub mean_opt_us: f64,
    pub mean_queue_us: f64,
    pub mean_exec_us: f64,
    pub wall_s: f64,
}

/// Build the e-graph for one (scheme, app, query), measuring optimization
/// time into `QueryMetrics::opt_us` later.
pub fn build_egraph(
    platform: &Platform,
    run: &TraceRun,
    q: &QueryConfig,
) -> Result<(crate::graph::egraph::EGraph, u64)> {
    let t0 = Instant::now();
    let mut t = run.app.template(&run.core_llm);
    bind_answer_tokens(&mut t, q.answer_tokens);
    let e = run.scheme.build(&t, q, &platform.profiles)?;
    Ok((e, t0.elapsed().as_micros() as u64))
}

/// Execute one query synchronously; returns (latency_ms, metrics).
pub fn run_single(platform: &Platform, run: &TraceRun, q: &QueryConfig) -> Result<(f64, QueryMetrics)> {
    platform.set_policy(run.scheme.policy());
    let (e, opt_us) = build_egraph(platform, run, q)?;
    let qid = next_query_id();
    let t0 = Instant::now();
    let (_out, mut m) = platform.run_query(qid, e)?;
    m.opt_us = opt_us;
    m.e2e_us = t0.elapsed().as_micros() as u64;
    Ok((m.e2e_us as f64 / 1000.0, m))
}

/// Open-loop Poisson trace over the platform; queries run on their own
/// threads, arrivals follow the trace schedule.  Thin wrapper over the
/// serving driver (`serving::run_load`) keeping the historical result
/// shape used by the figure benches.
pub fn run_trace(platform: &Platform, run: &TraceRun) -> Result<TraceResult> {
    let report = crate::serving::run_load(platform, run)?;
    Ok(TraceResult {
        summary_ms: report.e2e_ms.clone(),
        mean_opt_us: report.mean_opt_us(),
        mean_queue_us: report.mean_queue_us(),
        mean_exec_us: report.mean_exec_us(),
        wall_s: report.wall_s,
        latencies_ms: report.latencies_ms,
    })
}

/// One-shot workflow (instruction + question -> `out_tokens` decode) —
/// the building block of the heterogeneous PR4 trace and the shared test
/// harness (`tests/common/`).
pub fn one_shot_template(
    llm: &str,
    instr_name: &str,
    instr_len: usize,
    out_tokens: usize,
) -> WorkflowTemplate {
    let mut t = WorkflowTemplate::new("one-shot");
    t.add(Component {
        name: "gen".into(),
        kind: ComponentKind::LlmGenerate {
            variant: llm.into(),
            mode: SynthesisMode::OneShot,
            prompt: vec![
                PromptPart::Instruction(instr_tokens(instr_name, instr_len)),
                PromptPart::Question,
            ],
            out_tokens,
            segments: 1,
            fan: 1,
        },
        engine: llm.into(),
        batchable: false,
        splittable: false,
    });
    t
}

/// Build `n` optimized e-graphs from the seeded dataset, one workflow
/// template per query index.
pub fn prepared_graphs(
    n: usize,
    seed: u64,
    template_of: impl Fn(usize) -> WorkflowTemplate,
) -> Vec<(EGraph, u64)> {
    let profiles = ProfileRegistry::with_defaults();
    let mut ds = Dataset::new(DatasetKind::WebQuestions, seed);
    (0..n)
        .map(|i| {
            let t = template_of(i);
            let q = ds.sample();
            let g = build_pgraph(&t, &q).unwrap();
            let g = run_passes(g, OptFlags::all(), &profiles).unwrap();
            (EGraph::new(g).unwrap(), 0u64)
        })
        .collect()
}

/// The heterogeneous sim trace behind `BENCH_PR4.json` and
/// `tests/wcp_scheduling.rs`: mostly short RAG-style queries (8-16
/// token decodes) with a long-tail minority (every 8th query decodes
/// 128 tokens), so arrival-order scheduling strands the long critical
/// paths behind bursts of short work and weighted-critical-path
/// ordering has something to win.
pub fn hetero_prepared(n: usize, seed: u64) -> Vec<(EGraph, u64)> {
    prepared_graphs(n, seed, |i| {
        let out_tokens = if i % 8 == 3 { 128 } else { 8 + i % 9 };
        one_shot_template("llm-lite", "hetero", 24, out_tokens)
    })
}

/// The PR5 token-accounting variant of the mixed 8-16/128-token trace
/// (`BENCH_PR5.json`, `tests/kv_accounting.rs`): only queries 7 and 23
/// decode 128 tokens, so the p95 of a 40-query run lands on the worst
/// *short* query — the one that row-slot accounting strands behind slot
/// exhaustion while its KV demand is a few dozen tokens.
pub fn kv_hetero_prepared(n: usize, seed: u64) -> Vec<(EGraph, u64)> {
    prepared_graphs(n, seed, |i| {
        let out_tokens = if i == 7 || i == 23 { 128 } else { 8 + i % 9 };
        one_shot_template("llm-lite", "hetero", 24, out_tokens)
    })
}

/// The PR8 multi-tenant trace behind `BENCH_PR8.json` and
/// `tests/tenancy.rs`: one e-graph per arrival of a
/// `workload::MultiTenantTrace`, keyed by the arrival's tenant — the
/// light tenant ([`crate::serving::TENANT_LIGHT`]) issues short
/// interactive queries (8-16 token decodes), every other tenant issues
/// long 64-token batch decodes.  All queries share one instruction
/// prefix so prefix warming stays tenant-neutral.
pub fn tenant_mix_prepared(
    tenants: &[crate::engines::TenantId],
    seed: u64,
) -> Vec<(EGraph, u64)> {
    prepared_graphs(tenants.len(), seed, |i| {
        let out_tokens = if tenants[i] == crate::serving::TENANT_LIGHT {
            8 + i % 9
        } else {
            64
        };
        one_shot_template("llm-lite", "hetero", 24, out_tokens)
    })
}

/// Build `n` fully optimized e-graphs of one paper application from the
/// seeded dataset (Teola scheme, default profiles) — the trace behind
/// the PR7 pipeline comparison.  No platform needed: graph construction
/// is pure, so the same (app, core_llm, n, seed) always yields the same
/// graphs and fixed query ids make runs comparable bit-for-bit.
pub fn app_prepared(app: AppKind, core_llm: &str, n: usize, seed: u64) -> Vec<(EGraph, u64)> {
    let profiles = ProfileRegistry::with_defaults();
    let mut ds = Dataset::new(DatasetKind::WebQuestions, seed);
    (0..n)
        .map(|_| {
            let q = ds.sample();
            let mut t = app.template(core_llm);
            bind_answer_tokens(&mut t, q.answer_tokens);
            let e = Scheme::Teola.build(&t, &q, &profiles).unwrap();
            (e, 0u64)
        })
        .collect()
}

/// The PR10 speculation trace behind `BENCH_PR10.json` and
/// `tests/speculation.rs`: a seeded mix of guard-heavy `search-gen`
/// queries (proxy -> judge -> Condition -> guarded web-search ->
/// synthesize, ~70% guard-pass) with every third query an
/// `agentic-tools` workflow (plan LLM -> runtime tool fan-out ->
/// confirm LLM).  The guard-heavy majority gives branch speculation
/// its p95 win (the 35 ms search RTT overlaps the judge decode); the
/// agentic minority exercises runtime graph growth under load.
pub fn spec_mix_prepared(core_llm: &str, n: usize, seed: u64) -> Vec<(EGraph, u64)> {
    let profiles = ProfileRegistry::with_defaults();
    let mut ds = Dataset::new(DatasetKind::WebQuestions, seed);
    (0..n)
        .map(|i| {
            let q = ds.sample();
            let app =
                if i % 3 == 2 { AppKind::AgenticTools } else { AppKind::SearchGen };
            let mut t = app.template(core_llm);
            bind_answer_tokens(&mut t, q.answer_tokens);
            let e = Scheme::Teola.build(&t, &q, &profiles).unwrap();
            (e, 0u64)
        })
        .collect()
}

/// True when a Platform can start: either the simulated backend was
/// selected via `TEOLA_BACKEND=sim`, or the XLA backend is fully usable
/// (real crate linked *and* artifacts present).  The figure benches gate
/// on this instead of a raw artifacts check so they run end-to-end on the
/// sim backend too.
pub fn backend_available() -> bool {
    matches!(ExecBackend::from_env(), Some(ExecBackend::Sim))
        || crate::runtime::xla_backend_available()
}

/// Platform config covering one app (core LLM + its aux models).  Honors
/// the `TEOLA_BACKEND` environment override.
pub fn platform_for(app: AppKind, core_llm: &str) -> PlatformConfig {
    platform_for_all(std::slice::from_ref(&app), core_llm)
}

/// Platform config covering several apps at once (co-location).  Honors
/// the `TEOLA_BACKEND` environment override.
pub fn platform_for_all(apps: &[AppKind], core_llm: &str) -> PlatformConfig {
    let mut cfg = PlatformConfig::default_with("artifacts", core_llm);
    for app in apps {
        for aux in app.aux_llms() {
            cfg = cfg.with_llm(aux, 2, 8);
        }
    }
    apply_env_knobs(&mut cfg);
    cfg
}

/// Apply every `TEOLA_*` environment knob onto a platform config — the
/// single parsing surface shared by the bench harnesses, the CLI, and
/// the knob round-trip test (`tests/tenancy.rs`), so a knob added here is
/// automatically honored everywhere.  Unset variables leave the config
/// untouched; unparseable values warn and are ignored.
pub fn apply_env_knobs(cfg: &mut PlatformConfig) {
    if let Some(backend) = ExecBackend::from_env() {
        cfg.backend = backend;
    }
    // Scheduler knobs for bench sweeps: dynamic-batching window and the
    // continuous-batching toggle (both also runtime-switchable on the
    // Platform).
    if let Ok(v) = std::env::var("TEOLA_BATCH_WINDOW_US") {
        match v.parse() {
            Ok(us) => cfg.batch_window_us = us,
            Err(_) => eprintln!(
                "warning: unparseable TEOLA_BATCH_WINDOW_US={v:?}; keeping {}",
                cfg.batch_window_us
            ),
        }
    }
    if let Ok(v) = std::env::var("TEOLA_PREFIX_SLOTS") {
        match v.parse() {
            Ok(n) => cfg.prefix_slots = n,
            Err(_) => eprintln!(
                "warning: unparseable TEOLA_PREFIX_SLOTS={v:?}; keeping {}",
                cfg.prefix_slots
            ),
        }
    }
    if let Ok(v) = std::env::var("TEOLA_CONTINUOUS") {
        // Same token set as the CLI's --continuous flag.
        match v.trim().to_ascii_lowercase().as_str() {
            "1" | "on" | "true" => cfg.continuous = true,
            "0" | "off" | "false" => cfg.continuous = false,
            "" => {}
            other => eprintln!(
                "warning: unknown TEOLA_CONTINUOUS={other:?} (want on|off); ignoring"
            ),
        }
    }
    if let Ok(v) = std::env::var("TEOLA_KV_TOKENS") {
        // Per-instance KV token budget: 0 = legacy row-slot accounting,
        // empty = keep the derived default.
        match v.trim() {
            "" => {}
            t => match t.parse() {
                Ok(n) => cfg.kv_tokens_per_instance = Some(n),
                Err(_) => eprintln!(
                    "warning: unparseable TEOLA_KV_TOKENS={v:?} (want a token count); ignoring"
                ),
            },
        }
    }
    if let Ok(v) = std::env::var("TEOLA_KV_WATERMARK") {
        // Persistent-residency watermark as a percent of the KV budget:
        // 0 = residency off (PR5 release-at-retirement), empty = keep the
        // config default.
        match v.trim() {
            "" => {}
            t => match t.parse() {
                Ok(pct) => cfg.kv_watermark = pct,
                Err(_) => eprintln!(
                    "warning: unparseable TEOLA_KV_WATERMARK={v:?} (want a percent); ignoring"
                ),
            },
        }
    }
    // Per-engine-kind residency watermark overrides (percent), e.g.
    // TEOLA_KV_WATERMARK_LLM=60; only the LLM kind acts on a watermark
    // today, the others are parsed for forward compatibility.
    for (suffix, kind) in [
        ("LLM", crate::engines::EngineKind::Llm),
        ("EMBEDDING", crate::engines::EngineKind::Embedding),
        ("RERANKER", crate::engines::EngineKind::Reranker),
        ("VECTORDB", crate::engines::EngineKind::VectorDb),
        ("WEBSEARCH", crate::engines::EngineKind::WebSearch),
        ("TOOL", crate::engines::EngineKind::Tool),
    ] {
        let var = format!("TEOLA_KV_WATERMARK_{suffix}");
        if let Ok(v) = std::env::var(&var) {
            match v.trim() {
                "" => {}
                t => match t.parse::<u8>() {
                    Ok(pct) => cfg.kv_watermark_overrides.push((kind, pct)),
                    Err(_) => eprintln!(
                        "warning: unparseable {var}={v:?} (want a percent 0-100); ignoring"
                    ),
                },
            }
        }
    }
    if let Ok(v) = std::env::var("TEOLA_WCP") {
        // Same token set as the CLI's --wcp flag.
        match v.trim().to_ascii_lowercase().as_str() {
            "1" | "on" | "true" => cfg.wcp = true,
            "0" | "off" | "false" => cfg.wcp = false,
            "" => {}
            other => {
                eprintln!("warning: unknown TEOLA_WCP={other:?} (want on|off); ignoring")
            }
        }
    }
    if let Ok(v) = std::env::var("TEOLA_SCHED_INCREMENTAL") {
        // Same token set as the CLI's --sched-incremental flag: toggles the
        // bucket-heap hot path versus the exact sort-rebuild fallback.
        match v.trim().to_ascii_lowercase().as_str() {
            "1" | "on" | "true" => cfg.sched_incremental = true,
            "0" | "off" | "false" => cfg.sched_incremental = false,
            "" => {}
            other => eprintln!(
                "warning: unknown TEOLA_SCHED_INCREMENTAL={other:?} (want on|off); ignoring"
            ),
        }
    }
    if let Ok(v) = std::env::var("TEOLA_PIPELINE") {
        // Same token set as the CLI's --pipeline flag.
        match v.trim().to_ascii_lowercase().as_str() {
            "1" | "on" | "true" => cfg.pipeline = true,
            "0" | "off" | "false" => cfg.pipeline = false,
            "" => {}
            other => {
                eprintln!("warning: unknown TEOLA_PIPELINE={other:?} (want on|off); ignoring")
            }
        }
    }
    if let Ok(v) = std::env::var("TEOLA_SPECULATION") {
        // Same token set as the CLI's --speculate flag: speculative
        // branch dispatch + discounted-rank scheduling for guarded
        // subgraphs.  Off keeps the dispatch path bit-identical.
        match v.trim().to_ascii_lowercase().as_str() {
            "1" | "on" | "true" => cfg.speculation = true,
            "0" | "off" | "false" => cfg.speculation = false,
            "" => {}
            other => eprintln!(
                "warning: unknown TEOLA_SPECULATION={other:?} (want on|off); ignoring"
            ),
        }
    }
    if let Ok(v) = std::env::var("TEOLA_SPEC_THRESHOLD") {
        // Minimum guard-pass probability before a branch is worth
        // speculating on; empty keeps the config default.
        match v.trim() {
            "" => {}
            t => match t.parse::<f64>() {
                Ok(p) if (0.0..=1.0).contains(&p) => cfg.spec_threshold = p,
                _ => eprintln!(
                    "warning: unparseable TEOLA_SPEC_THRESHOLD={v:?} (want 0..=1); ignoring"
                ),
            },
        }
    }
    if let Ok(v) = std::env::var("TEOLA_TENANCY") {
        // Multi-tenant QoS registry; same spec grammar as the CLI's
        // --tenants flag ("off", "on", or "<id>:w=..,class=..;..").
        match crate::scheduler::tenancy::TenancyConfig::parse(&v) {
            Ok(t) => cfg.tenancy = t,
            Err(e) => {
                eprintln!("warning: bad TEOLA_TENANCY={v:?}: {e}; ignoring")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Reporting
// ---------------------------------------------------------------------------

/// A printable/serializable result table (one per paper artifact).
#[derive(Debug, Clone)]
pub struct BenchTable {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub meta: Vec<(String, String)>,
}

impl BenchTable {
    /// New table with column headers.  Every table records which backend
    /// produced it, so simulated numbers are never mistaken for measured
    /// XLA results in bench_results/ JSON dumps.
    pub fn new(name: &str, columns: &[&str]) -> BenchTable {
        let backend = match ExecBackend::from_env() {
            Some(ExecBackend::Sim) => "sim (DeviceModel simulation)",
            _ => "xla (AOT artifacts)",
        };
        BenchTable {
            name: name.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
            meta: vec![("backend".to_string(), backend.to_string())],
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    /// Attach free-form metadata (settings, units).
    pub fn note(&mut self, k: &str, v: &str) {
        self.meta.push((k.to_string(), v.to_string()));
    }

    /// Pretty-print in the paper's rows/series format.
    pub fn print(&self) {
        println!("\n== {} ==", self.name);
        for (k, v) in &self.meta {
            println!("   {k}: {v}");
        }
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain([c.len()])
                    .max()
                    .unwrap_or(4)
            })
            .collect();
        let line = |cells: &[String]| {
            let mut s = String::from("  ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{s}");
        };
        line(&self.columns);
        line(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<String>>(),
        );
        for r in &self.rows {
            line(r);
        }
    }

    /// Dump to `bench_results/<name>.json`.
    pub fn write_json(&self) -> std::io::Result<()> {
        std::fs::create_dir_all("bench_results")?;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| Json::Arr(r.iter().map(|c| s(c)).collect()))
            .collect();
        let meta: Vec<Json> = self
            .meta
            .iter()
            .map(|(k, v)| obj(vec![("k", s(k)), ("v", s(v))]))
            .collect();
        let doc = obj(vec![
            ("name", s(&self.name)),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| s(c)).collect()),
            ),
            ("rows", Json::Arr(rows)),
            ("meta", Json::Arr(meta)),
            ("unix_time", num(now_unix() as f64)),
        ]);
        std::fs::write(
            format!("bench_results/{}.json", self.name),
            doc.to_string(),
        )
    }
}

fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Format milliseconds.
pub fn ms(v: f64) -> String {
    format!("{v:.1}")
}

/// Format a speedup factor.
pub fn speedup(base: f64, new: f64) -> String {
    if new > 0.0 {
        format!("{:.2}x", base / new)
    } else {
        "-".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = BenchTable::new("unit-test-table", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("unit", "ms");
        assert_eq!(t.rows.len(), 1);
        t.print();
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(speedup(2.0, 1.0), "2.00x");
        assert_eq!(speedup(1.0, 0.0), "-");
    }

    #[test]
    fn unique_query_ids() {
        let a = next_query_id();
        let b = next_query_id();
        assert_ne!(a, b);
    }
}
