//! Benchmark driver + reporting harness (criterion is unavailable offline;
//! every `benches/*.rs` target uses this module with `harness = false`).
//!
//! Provides: trace execution (open-loop Poisson over a running Platform),
//! single-query timing, table printing in the paper's row/series format,
//! and machine-readable JSON dumps under `bench_results/`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::apps::{bind_answer_tokens, AppKind};
use crate::baselines::Scheme;
use crate::engines::QueryId;
use crate::error::Result;
use crate::graph::template::QueryConfig;
use crate::json::{num, obj, s, Json};
use crate::scheduler::graph_sched::QueryMetrics;
use crate::scheduler::{Platform, PlatformConfig};
use crate::util::stats::Summary;
use crate::workload::{Dataset, DatasetKind, PoissonTrace};

static NEXT_QUERY: AtomicU64 = AtomicU64::new(1);

/// Unique query id across a bench process.
pub fn next_query_id() -> QueryId {
    NEXT_QUERY.fetch_add(1, Ordering::Relaxed)
}

/// `TEOLA_BENCH_QUICK=1` shrinks sweeps for smoke runs.
pub fn quick() -> bool {
    std::env::var("TEOLA_BENCH_QUICK").map_or(false, |v| v != "0" && !v.is_empty())
}

/// Scale a query count down in quick mode.
pub fn scaled(n: usize) -> usize {
    if quick() {
        (n / 3).max(2)
    } else {
        n
    }
}

/// One trace-run request.
#[derive(Debug, Clone)]
pub struct TraceRun {
    pub app: AppKind,
    pub scheme: Scheme,
    pub dataset: DatasetKind,
    pub core_llm: String,
    pub rate: f64,
    pub n_queries: usize,
    pub seed: u64,
}

/// Aggregated result of a trace run.
#[derive(Debug, Clone)]
pub struct TraceResult {
    pub latencies_ms: Vec<f64>,
    pub summary_ms: Summary,
    pub mean_opt_us: f64,
    pub mean_queue_us: f64,
    pub mean_exec_us: f64,
    pub wall_s: f64,
}

/// Build the e-graph for one (scheme, app, query), measuring optimization
/// time into `QueryMetrics::opt_us` later.
pub fn build_egraph(
    platform: &Platform,
    run: &TraceRun,
    q: &QueryConfig,
) -> Result<(crate::graph::egraph::EGraph, u64)> {
    let t0 = Instant::now();
    let mut t = run.app.template(&run.core_llm);
    bind_answer_tokens(&mut t, q.answer_tokens);
    let e = run.scheme.build(&t, q, &platform.profiles)?;
    Ok((e, t0.elapsed().as_micros() as u64))
}

/// Execute one query synchronously; returns (latency_ms, metrics).
pub fn run_single(platform: &Platform, run: &TraceRun, q: &QueryConfig) -> Result<(f64, QueryMetrics)> {
    platform.set_policy(run.scheme.policy());
    let (e, opt_us) = build_egraph(platform, run, q)?;
    let qid = next_query_id();
    let t0 = Instant::now();
    let (_out, mut m) = platform.run_query(qid, e)?;
    m.opt_us = opt_us;
    m.e2e_us = t0.elapsed().as_micros() as u64;
    Ok((m.e2e_us as f64 / 1000.0, m))
}

/// Open-loop Poisson trace over the platform; queries run on their own
/// threads, arrivals follow the trace schedule.
pub fn run_trace(platform: &Platform, run: &TraceRun) -> Result<TraceResult> {
    platform.set_policy(run.scheme.policy());
    let trace = PoissonTrace::generate(run.rate, run.n_queries, run.seed);
    let mut dataset = Dataset::new(run.dataset, run.seed ^ 0xDA7A);

    // Pre-build all e-graphs (construction is not part of the serving
    // path being measured; its cost is recorded separately as opt time).
    let mut prepared = Vec::with_capacity(run.n_queries);
    for _ in 0..run.n_queries {
        let q = dataset.sample();
        let (e, opt_us) = build_egraph(platform, run, &q)?;
        prepared.push((e, opt_us));
    }

    let start = Instant::now();
    let mut handles = Vec::with_capacity(run.n_queries);
    for (i, (e, opt_us)) in prepared.into_iter().enumerate() {
        let due = trace.arrivals[i];
        if let Some(wait) = due.checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        let qid = next_query_id();
        handles.push((opt_us, platform.spawn_query(qid, e)));
    }

    let mut latencies = Vec::with_capacity(run.n_queries);
    let mut opt_sum = 0u64;
    let mut queue_sum = 0u64;
    let mut exec_sum = 0u64;
    for (opt_us, h) in handles {
        let (_out, m) = h.join().expect("query thread")?;
        latencies.push(m.e2e_us as f64 / 1000.0);
        opt_sum += opt_us;
        queue_sum += m.queue_us;
        exec_sum += m.exec_us;
    }
    let wall_s = start.elapsed().as_secs_f64();
    let n = run.n_queries.max(1) as f64;
    Ok(TraceResult {
        summary_ms: Summary::of(&latencies),
        latencies_ms: latencies,
        mean_opt_us: opt_sum as f64 / n,
        mean_queue_us: queue_sum as f64 / n,
        mean_exec_us: exec_sum as f64 / n,
        wall_s,
    })
}

/// Platform config covering one app (core LLM + its aux models).
pub fn platform_for(app: AppKind, core_llm: &str) -> PlatformConfig {
    let mut cfg = PlatformConfig::default_with("artifacts", core_llm);
    for aux in app.aux_llms() {
        cfg = cfg.with_llm(aux, 2, 8);
    }
    cfg
}

/// Platform config covering several apps at once (co-location).
pub fn platform_for_all(apps: &[AppKind], core_llm: &str) -> PlatformConfig {
    let mut cfg = PlatformConfig::default_with("artifacts", core_llm);
    for app in apps {
        for aux in app.aux_llms() {
            cfg = cfg.with_llm(aux, 2, 8);
        }
    }
    cfg
}

// ---------------------------------------------------------------------------
// Reporting
// ---------------------------------------------------------------------------

/// A printable/serializable result table (one per paper artifact).
#[derive(Debug, Clone)]
pub struct BenchTable {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub meta: Vec<(String, String)>,
}

impl BenchTable {
    /// New table with column headers.
    pub fn new(name: &str, columns: &[&str]) -> BenchTable {
        BenchTable {
            name: name.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
            meta: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    /// Attach free-form metadata (settings, units).
    pub fn note(&mut self, k: &str, v: &str) {
        self.meta.push((k.to_string(), v.to_string()));
    }

    /// Pretty-print in the paper's rows/series format.
    pub fn print(&self) {
        println!("\n== {} ==", self.name);
        for (k, v) in &self.meta {
            println!("   {k}: {v}");
        }
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain([c.len()])
                    .max()
                    .unwrap_or(4)
            })
            .collect();
        let line = |cells: &[String]| {
            let mut s = String::from("  ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{s}");
        };
        line(&self.columns);
        line(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<String>>(),
        );
        for r in &self.rows {
            line(r);
        }
    }

    /// Dump to `bench_results/<name>.json`.
    pub fn write_json(&self) -> std::io::Result<()> {
        std::fs::create_dir_all("bench_results")?;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| Json::Arr(r.iter().map(|c| s(c)).collect()))
            .collect();
        let meta: Vec<Json> = self
            .meta
            .iter()
            .map(|(k, v)| obj(vec![("k", s(k)), ("v", s(v))]))
            .collect();
        let doc = obj(vec![
            ("name", s(&self.name)),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| s(c)).collect()),
            ),
            ("rows", Json::Arr(rows)),
            ("meta", Json::Arr(meta)),
            ("unix_time", num(now_unix() as f64)),
        ]);
        std::fs::write(
            format!("bench_results/{}.json", self.name),
            doc.to_string(),
        )
    }
}

fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Format milliseconds.
pub fn ms(v: f64) -> String {
    format!("{v:.1}")
}

/// Format a speedup factor.
pub fn speedup(base: f64, new: f64) -> String {
    if new > 0.0 {
        format!("{:.2}x", base / new)
    } else {
        "-".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = BenchTable::new("unit-test-table", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("unit", "ms");
        assert_eq!(t.rows.len(), 1);
        t.print();
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(speedup(2.0, 1.0), "2.00x");
        assert_eq!(speedup(1.0, 0.0), "-");
    }

    #[test]
    fn unique_query_ids() {
        let a = next_query_id();
        let b = next_query_id();
        assert_ne!(a, b);
    }
}
