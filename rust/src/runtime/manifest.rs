//! `artifacts/manifest.json` reader: the contract between the AOT pipeline
//! (python/compile/aot.py) and the Rust runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Result, TeolaError};
use crate::json::Json;

/// Element type of a tensor in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(TeolaError::Manifest(format!("unknown dtype {other}"))),
        }
    }
}

/// One named tensor signature (input or output of an artifact).
#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled executable bucket.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub op: String,
    pub variant: String,
    pub file: String,
    pub n_weights: usize,
    pub batch: usize,
    /// Prefill chunk length; 0 for non-prefill ops.
    pub chunk: usize,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Model metadata (weights file + dims).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub kind: String, // "llm" | "embed" | "score"
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub weights_file: String,
    pub n_weights: usize,
}

/// Special token ids shared with python/compile/configs.py.
#[derive(Debug, Clone, Copy)]
pub struct SpecialTokens {
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub sep: i32,
}

/// Parsed manifest: the full artifact index.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab: usize,
    pub special: SpecialTokens,
    pub models: HashMap<String, ModelInfo>,
    pub artifacts: HashMap<String, ArtifactInfo>,
}

fn sig_list(v: &Json) -> Result<Vec<TensorSig>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| TeolaError::Manifest("sig list not an array".into()))?;
    arr.iter()
        .map(|e| {
            Ok(TensorSig {
                name: e
                    .get("name")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
                shape: e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| TeolaError::Manifest("missing shape".into()))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: DType::parse(
                    e.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
                )?,
            })
        })
        .collect()
}

impl Manifest {
    /// In-memory manifest for the simulated backend: model geometry and
    /// special tokens without any artifacts on disk.  Dims are the AOT
    /// pipeline's test-scale defaults (python/compile/configs.py); the sim
    /// executors only consume `d_model`/`max_seq`/`special`, so a sim
    /// platform needs no `artifacts/` directory at all.
    pub fn synthetic() -> Manifest {
        let special = SpecialTokens { pad: 0, bos: 1, eos: 2, sep: 3 };
        let mut models = HashMap::new();
        let mut add = |name: &str, kind: &str, d_model: usize, max_seq: usize| {
            models.insert(
                name.to_string(),
                ModelInfo {
                    name: name.to_string(),
                    kind: kind.to_string(),
                    layers: 2,
                    d_model,
                    n_heads: 2,
                    vocab: 2048,
                    max_seq,
                    weights_file: String::new(),
                    n_weights: 0,
                },
            );
        };
        for v in ["llm-lite", "llm-small", "llm-medium", "llm-large"] {
            add(v, "llm", 64, 256);
        }
        add("embedder", "embed", 64, 64);
        add("reranker", "score", 64, 96);
        Manifest {
            dir: PathBuf::from("<sim>"),
            vocab: 2048,
            special,
            models,
            artifacts: HashMap::new(),
        }
    }

    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let root = Json::parse(&text).map_err(TeolaError::Manifest)?;

        let special_j = root
            .get("special_tokens")
            .ok_or_else(|| TeolaError::Manifest("missing special_tokens".into()))?;
        let tok = |k: &str| -> i32 {
            special_j.get(k).and_then(Json::as_f64).unwrap_or(0.0) as i32
        };
        let special = SpecialTokens {
            pad: tok("pad"),
            bos: tok("bos"),
            eos: tok("eos"),
            sep: tok("sep"),
        };

        let mut models = HashMap::new();
        if let Some(obj) = root.get("models").and_then(Json::as_obj) {
            for (name, m) in obj {
                let g = |k: &str| m.get(k).and_then(Json::as_usize).unwrap_or(0);
                models.insert(
                    name.clone(),
                    ModelInfo {
                        name: name.clone(),
                        kind: m
                            .get("kind")
                            .and_then(Json::as_str)
                            .unwrap_or("llm")
                            .to_string(),
                        layers: g("layers"),
                        d_model: g("d_model"),
                        n_heads: g("n_heads"),
                        vocab: g("vocab"),
                        max_seq: g("max_seq"),
                        weights_file: m
                            .get("weights")
                            .and_then(Json::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        n_weights: g("n_weights"),
                    },
                );
            }
        }

        let mut artifacts = HashMap::new();
        for a in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| TeolaError::Manifest("missing artifacts".into()))?
        {
            let name = a
                .get("artifact")
                .and_then(Json::as_str)
                .ok_or_else(|| TeolaError::Manifest("artifact missing name".into()))?
                .to_string();
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    op: a.get("op").and_then(Json::as_str).unwrap_or("").to_string(),
                    variant: a
                        .get("variant")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    n_weights: a.get("n_weights").and_then(Json::as_usize).unwrap_or(0),
                    batch: a.get("batch").and_then(Json::as_usize).unwrap_or(0),
                    chunk: a.get("chunk").and_then(Json::as_usize).unwrap_or(0),
                    inputs: sig_list(
                        a.get("inputs")
                            .ok_or_else(|| TeolaError::Manifest("no inputs".into()))?,
                    )?,
                    outputs: sig_list(
                        a.get("outputs")
                            .ok_or_else(|| TeolaError::Manifest("no outputs".into()))?,
                    )?,
                },
            );
        }

        Ok(Manifest { dir, vocab: root.get("vocab").and_then(Json::as_usize).unwrap_or(0), special, models, artifacts })
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let info = self
            .artifacts
            .get(name)
            .ok_or_else(|| TeolaError::Manifest(format!("unknown artifact {name}")))?;
        Ok(self.dir.join(&info.file))
    }

    /// Absolute path of a model's TWB1 weights file.
    pub fn weights_path(&self, model: &str) -> Result<PathBuf> {
        let info = self
            .models
            .get(model)
            .ok_or_else(|| TeolaError::Manifest(format!("unknown model {model}")))?;
        Ok(self.dir.join(&info.weights_file))
    }

    /// All prefill buckets (batch, chunk) available for a variant, ascending.
    pub fn prefill_buckets(&self, variant: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .artifacts
            .values()
            .filter(|a| a.variant == variant && a.op == "prefill")
            .map(|a| (a.batch, a.chunk))
            .collect();
        v.sort();
        v
    }

    /// All decode batch sizes for a variant, ascending.
    pub fn decode_batches(&self, variant: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.variant == variant && a.op == "decode")
            .map(|a| a.batch)
            .collect();
        v.sort();
        v
    }

    /// All encoder batch sizes for a variant (op = embed | score).
    pub fn encoder_batches(&self, variant: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.variant == variant && (a.op == "embed" || a.op == "score"))
            .map(|a| a.batch)
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_manifest_covers_all_sim_engines() {
        let m = Manifest::synthetic();
        for v in ["llm-lite", "llm-small", "llm-medium", "llm-large", "embedder", "reranker"] {
            assert!(m.models.contains_key(v), "{v} missing");
        }
        assert_eq!(m.special.sep, 3);
        assert_eq!(m.special.eos, 2);
        assert!(m.vocab >= 2048);
        // No artifacts: the sim backend never touches the filesystem.
        assert!(m.artifacts.is_empty());
    }
}
