//! Stub of the `xla` crate's PJRT surface used by `runtime::exec`.
//!
//! The offline build environment has neither the crates.io `xla` crate nor
//! a PJRT plugin to link against, so the real AOT execution path cannot be
//! compiled here.  This shim keeps the XLA code path *compiling* with the
//! exact call surface `exec.rs` uses; every entry point fails at runtime
//! with a clear error pointing at the simulated backend
//! (`engines::sim::ExecBackend::Sim`), which is what `cargo test` and the
//! benches exercise.  To restore real artifact execution, replace the
//! `use crate::runtime::xla_stub::...` import in `exec.rs` with the real
//! crate — no other code changes are needed.

use std::fmt;

/// Whether a real XLA/PJRT implementation is linked.  The stub sets this
/// to `false`; gates (`bench::backend_available`, the `xla_*` integration
/// tests, `Platform::start`) consult it so the XLA path skips or fails
/// fast instead of starting a platform whose engines can never execute.
/// Set to `true` when swapping in the real crate.
pub const AVAILABLE: bool = false;

/// Error type mirroring `xla::Error` as far as we consume it.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA backend unavailable in this build (the `xla` crate is \
         stubbed); run with ExecBackend::Sim, or link the real crate in \
         runtime/exec.rs"
    )))
}

/// Element types we ever inspect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host-native element types transferable to/from literals and buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// PJRT client handle (per engine-instance thread in the real backend).
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client — always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a lowered computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    /// Upload a host buffer to the device.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// Device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Sync the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute over borrowed argument buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Host-side literal (tuple or tensor).
pub struct Literal;

impl Literal {
    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Element type of the literal.
    pub fn ty(&self) -> Result<ElementType> {
        unavailable("Literal::ty")
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("XLA backend unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal.to_vec::<f32>().is_err());
        assert!(Literal.to_tuple().is_err());
    }
}
