//! TWB1 weight-file reader (the format python/compile/weights.py writes).
//!
//! Layout (all integers little-endian u32):
//!   magic "TWB1" | count | { name_len, name, dtype, ndim, dims.., f32 data }

use std::io::Read;
use std::path::Path;

use crate::error::{Result, TeolaError};

/// One weight tensor on the host.
#[derive(Debug, Clone)]
pub struct WeightTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Read every tensor of a TWB1 file, in file (== AOT parameter) order.
pub fn read_weights(path: impl AsRef<Path>) -> Result<Vec<WeightTensor>> {
    let mut f = std::fs::File::open(path.as_ref())?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse_weights(&buf)
}

fn rd_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    if *pos + 4 > buf.len() {
        return Err(TeolaError::Weights("truncated u32".into()));
    }
    let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

/// Parse a TWB1 byte buffer.
pub fn parse_weights(buf: &[u8]) -> Result<Vec<WeightTensor>> {
    if buf.len() < 8 || &buf[..4] != b"TWB1" {
        return Err(TeolaError::Weights("bad magic".into()));
    }
    let mut pos = 4;
    let count = rd_u32(buf, &mut pos)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = rd_u32(buf, &mut pos)? as usize;
        if pos + nlen > buf.len() {
            return Err(TeolaError::Weights("truncated name".into()));
        }
        let name = String::from_utf8(buf[pos..pos + nlen].to_vec())
            .map_err(|_| TeolaError::Weights("bad name utf8".into()))?;
        pos += nlen;
        let dtype = rd_u32(buf, &mut pos)?;
        if dtype != 0 {
            return Err(TeolaError::Weights(format!("unsupported dtype {dtype}")));
        }
        let ndim = rd_u32(buf, &mut pos)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(rd_u32(buf, &mut pos)? as usize);
        }
        let elems: usize = shape.iter().product();
        let nbytes = elems * 4;
        if pos + nbytes > buf.len() {
            return Err(TeolaError::Weights(format!("truncated data for {name}")));
        }
        let mut data = vec![0f32; elems];
        for (i, chunk) in buf[pos..pos + nbytes].chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        pos += nbytes;
        out.push(WeightTensor { name, shape, data });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_file() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"TWB1");
        b.extend_from_slice(&1u32.to_le_bytes());
        let name = b"w";
        b.extend_from_slice(&(name.len() as u32).to_le_bytes());
        b.extend_from_slice(name);
        b.extend_from_slice(&0u32.to_le_bytes()); // dtype f32
        b.extend_from_slice(&2u32.to_le_bytes()); // ndim
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&3u32.to_le_bytes());
        for i in 0..6 {
            b.extend_from_slice(&(i as f32).to_le_bytes());
        }
        b
    }

    #[test]
    fn parses_tiny_file() {
        let ws = parse_weights(&tiny_file()).unwrap();
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].name, "w");
        assert_eq!(ws[0].shape, vec![2, 3]);
        assert_eq!(ws[0].data, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = tiny_file();
        b[0] = b'X';
        assert!(parse_weights(&b).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let b = tiny_file();
        assert!(parse_weights(&b[..b.len() - 4]).is_err());
    }
}
