//! PJRT runtime bridge: manifest, weights, and per-thread execution.
//!
//! Python/JAX runs only at build time (`make artifacts`); this module loads
//! the AOT HLO-text artifacts and executes them on the PJRT CPU client from
//! the Rust request path.

pub mod exec;
pub mod manifest;
pub mod weights;
pub mod xla_stub;

pub use exec::{HostTensor, XlaContext};
pub use manifest::{ArtifactInfo, Manifest, ModelInfo, SpecialTokens};

use std::path::PathBuf;

/// Resolve the artifacts directory: `$TEOLA_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("TEOLA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True when the XLA backend can actually execute: a real XLA/PJRT crate
/// is linked (not the stub) *and* an artifacts manifest exists.
pub fn xla_backend_available() -> bool {
    xla_stub::AVAILABLE && default_artifacts_dir().join("manifest.json").exists()
}
