//! Thread-local XLA execution context.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so every
//! engine *instance* owns its own `XlaContext` on its own OS thread —
//! which also mirrors the paper's testbed where each engine instance owns a
//! GPU.  Host data crosses threads as plain `Vec<f32>`/`Vec<i32>`; literals
//! and device buffers never leave the owning thread.
//!
//! In this offline build the crate is replaced by `runtime::xla_stub`
//! (same call surface, fails at runtime); the simulated backend
//! (`engines::sim`) is the executable path.  Swap the import below for the
//! real crate to restore AOT artifact execution.

use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use crate::runtime::xla_stub::{
    self as xla, ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
};

use crate::error::{Result, TeolaError};
use crate::runtime::manifest::Manifest;
use crate::runtime::weights::read_weights;

/// Host-side tensor (what crosses thread boundaries).
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    /// F32 tensor constructor (panics on shape/data mismatch).
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    /// I32 tensor constructor (panics on shape/data mismatch).
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    /// Borrow the f32 payload.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => Err(TeolaError::Engine("expected f32 tensor".into())),
        }
    }

    /// Borrow the i32 payload.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => Err(TeolaError::Engine("expected i32 tensor".into())),
        }
    }

    /// Tensor shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }
}

/// One engine instance's XLA state: client + lazily compiled executables +
/// device-resident weight buffers per model.
pub struct XlaContext {
    client: PjRtClient,
    manifest: Rc<Manifest>,
    executables: HashMap<String, Rc<PjRtLoadedExecutable>>,
    weights: HashMap<String, Rc<Vec<PjRtBuffer>>>,
}

impl XlaContext {
    /// Create a CPU-PJRT context bound to this thread.
    pub fn new(manifest: Rc<Manifest>) -> Result<XlaContext> {
        let client = PjRtClient::cpu()?;
        Ok(XlaContext { client, manifest, executables: HashMap::new(), weights: HashMap::new() })
    }

    /// The manifest this context serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(&mut self, artifact: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.get(artifact) {
            return Ok(e.clone());
        }
        let path = self.manifest.artifact_path(artifact)?;
        let exe = Rc::new(compile_hlo_file(&self.client, &path)?);
        self.executables.insert(artifact.to_string(), exe.clone());
        Ok(exe)
    }

    /// Upload a model's weights once; cached as device buffers thereafter.
    pub fn model_weights(&mut self, model: &str) -> Result<Rc<Vec<PjRtBuffer>>> {
        if let Some(w) = self.weights.get(model) {
            return Ok(w.clone());
        }
        let path = self.manifest.weights_path(model)?;
        let tensors = read_weights(&path)?;
        let mut bufs = Vec::with_capacity(tensors.len());
        for t in &tensors {
            bufs.push(self.client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
        }
        let rc = Rc::new(bufs);
        self.weights.insert(model.to_string(), rc.clone());
        Ok(rc)
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &HostTensor) -> Result<PjRtBuffer> {
        Ok(match t {
            HostTensor::F32 { shape, data } => {
                self.client.buffer_from_host_buffer(data, shape, None)?
            }
            HostTensor::I32 { shape, data } => {
                self.client.buffer_from_host_buffer(data, shape, None)?
            }
        })
    }

    /// Run an artifact: `weights ++ activations` in AOT parameter order.
    /// All lowered modules return a single tuple; this syncs it to the host
    /// and decomposes it into per-output literals.
    pub fn run(
        &mut self,
        artifact: &str,
        model: Option<&str>,
        activations: &[HostTensor],
    ) -> Result<Vec<Literal>> {
        let exe = self.executable(artifact)?;
        let mut args: Vec<PjRtBuffer> = Vec::new();
        if let Some(m) = model {
            let w = self.model_weights(m)?;
            // Re-wrap: execute_b borrows, so collect refs below instead.
            let mut refs: Vec<&PjRtBuffer> = w.iter().collect();
            for a in activations {
                args.push(self.upload(a)?);
            }
            refs.extend(args.iter());
            let out = exe.execute_b(&refs)?;
            return untuple(out);
        }
        for a in activations {
            args.push(self.upload(a)?);
        }
        let refs: Vec<&PjRtBuffer> = args.iter().collect();
        let out = exe.execute_b(&refs)?;
        untuple(out)
    }

    /// Pre-compile a set of artifacts (used at engine start to avoid
    /// first-request latency spikes).
    pub fn warm(&mut self, artifacts: &[String]) -> Result<()> {
        for a in artifacts {
            self.executable(a)?;
        }
        Ok(())
    }
}

fn untuple(out: Vec<Vec<PjRtBuffer>>) -> Result<Vec<Literal>> {
    let buf = out
        .first()
        .and_then(|r| r.first())
        .ok_or_else(|| TeolaError::Engine("empty execution result".into()))?;
    let lit = buf.to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

/// Load HLO text, parse into a module proto and compile on the client.
pub fn compile_hlo_file(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| TeolaError::Manifest("non-utf8 path".into()))?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Convert a literal to `Vec<f32>`.
pub fn literal_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Convert a literal to `Vec<i32>`.
pub fn literal_i32(lit: &Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Element type helper for shape assertions in tests.
pub fn literal_elem_type(lit: &Literal) -> Result<ElementType> {
    Ok(lit.ty()?)
}
