//! Batch-formation policies for the lower-tier engine schedulers.
//!
//! * `TopoAware` — Algorithm 2: bucket the queue by query, sort buckets by
//!   earliest arrival, inside each bucket prefer the *deepest* primitives
//!   (the ones whose completion unblocks the most downstream work), fill
//!   up to the slot budget.
//! * `BlindTO` — throughput-oriented FIFO dynamic batching up to the
//!   pre-tuned max batch (the paper's TO baseline).
//! * `PerInvocation` — latency-oriented bundles: all requests of one
//!   invocation are scheduled together and nothing else joins the batch
//!   (the paper's PO baseline).
//!
//! Under `TopoAware` the bucket *order* has two modes, selected by the
//! `wcp` flag (paper §8): weighted-critical-path ordering ranks query
//! buckets by descending remaining critical-path device time (the
//! `QueueItem::wcp_us` stamp from the graph scheduler's `WcpTracker`)
//! plus an aging term so short-tail queries cannot starve; with `wcp`
//! off, buckets fall back to earliest-arrival order (Algorithm 2 as
//! written).
//!
//! Packing is denominated by a [`SlotUnit`]: legacy **row** slots (the
//! pre-tuned max batch rows) or **KV tokens** (`QueueItem::tokens`, the
//! job's KV-cache growth).  Token packing is first-fit with skip-over,
//! so one oversized prefill cannot block a window of short requests —
//! the shorts pack around it and the oversized item waits for a drained
//! instance (or goes out alone under the full-batch path, where the
//! executor chunks it internally).

use std::collections::BTreeMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engines::{
    Completion, EngineJob, JobOutput, PrefixFp, QueryId, SegmentSpec, SeqId, TenantId,
};
use crate::scheduler::stats::SchedCounters;
use crate::scheduler::tenancy::{TenantRank, TenantRanks};

/// Invocation-bundle identity: `(query, node)`.  Kept as a structured key
/// — the packed `(query << 20) | node` form collided when a node id
/// reached 2^20 and bled into the query bits, silently merging unrelated
/// invocations into one PO bundle.
pub type BundleId = (QueryId, u64);

/// Batch-compatibility class of a job: prefill-type and decode-type LLM
/// work never share a batch (a decode joining a prefill batch would wait
/// behind compute-bound prefills — the head-of-line blocking vLLM avoids
/// by separating prefill and decode iterations).
pub fn job_class(job: &EngineJob) -> u8 {
    match job {
        EngineJob::Prefill { .. } | EngineJob::ClonePrefix { .. } => 1,
        EngineJob::Decode { .. } => 2,
        _ => 0,
    }
}

/// Scheduling policy of an engine scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    TopoAware,
    BlindTO,
    PerInvocation,
}

impl BatchPolicy {
    /// Encode for the atomic policy handle.
    pub fn to_u8(self) -> u8 {
        match self {
            BatchPolicy::TopoAware => 0,
            BatchPolicy::BlindTO => 1,
            BatchPolicy::PerInvocation => 2,
        }
    }

    /// Decode from the atomic policy handle.
    pub fn from_u8(v: u8) -> BatchPolicy {
        match v {
            1 => BatchPolicy::BlindTO,
            2 => BatchPolicy::PerInvocation,
            _ => BatchPolicy::TopoAware,
        }
    }
}

/// Capacity denomination of batch packing and instance load accounting:
/// legacy row slots, or the token-budgeted KV mode (PR5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SlotUnit {
    /// One unit per model row (`QueueItem::rows`) — the historical
    /// `max_slots` semantics; the TO/PO baselines always use this.
    #[default]
    Rows,
    /// One unit per KV token (`QueueItem::tokens`): a 2048-token prefill
    /// costs 256x an 8-token one instead of the same row slot.
    Tokens,
}

impl SlotUnit {
    /// Budget cost of one queued item in this denomination (never 0, so
    /// admission and retirement stay balanced for empty payloads).
    pub fn cost(self, it: &QueueItem) -> usize {
        match self {
            SlotUnit::Rows => it.rows.max(1),
            SlotUnit::Tokens => it.tokens.max(1),
        }
    }
}

/// Successor job shape a [`SuccessorPlan`] can materialize from a
/// predecessor's completion output alone.  Only shapes whose *entire*
/// remaining input is the predecessor output qualify — anything needing
/// graph-scheduler state (rerank post-selection, prefill offset
/// bookkeeping) re-enters the dispatch loop as before.
#[derive(Debug, Clone)]
pub enum SuccessorTemplate {
    /// Decode continuing the predecessor prefill's sequence: the prefill
    /// completion's next-token seeds the decode, everything else is
    /// static at lowering time.
    Decode { seq: SeqId, segments: Vec<SegmentSpec> },
    /// Embed the predecessor completion's token rows (streamed partial
    /// results: one decode segment's tokens feed embedding the moment
    /// the segment completes).
    Embed,
}

/// Direct cross-engine handoff plan (the pipelining tentpole): attached
/// by the graph scheduler to a [`QueueItem`] whose downstream node has a
/// single unresolved input, and materialized at the *instance* thread
/// the moment the triggering completion is emitted — the successor job
/// enters the target engine's admission queue without bouncing through
/// the graph scheduler's dispatch loop (Parrot-style producer-side
/// pre-registration).  The WCP stamp rides across the handoff; the KV
/// token estimate is recomputed from the materialized job (identical to
/// what the graph scheduler would have stamped, since the template
/// fixes the job shape).
#[derive(Debug, Clone)]
pub struct SuccessorPlan {
    /// Completion node id that triggers this plan: the emitting node
    /// itself, or one decode segment's partial-output marker.
    pub on_node: usize,
    /// The downstream node being handed off.
    pub node: usize,
    /// Reverse-topological depth of the successor node.
    pub depth: u32,
    /// The target engine's admission queue.
    pub engine: Sender<QueueItem>,
    pub template: SuccessorTemplate,
    /// Remaining critical-path stamp carried across the handoff.
    pub wcp_us: u64,
    /// Owning tenant of the parent request: the materialized successor is
    /// accounted to the same tenant's fair-queueing ledger, KV quota and
    /// admission class as its parent (multi-tenant QoS).
    pub tenant: TenantId,
    /// Fired-once latch, set by the instance thread when the trigger
    /// completion materializes this plan: duplicate stream deliveries
    /// must not inject the successor twice (a double decode admission
    /// would corrupt the sequence state).
    pub fired: std::cell::Cell<bool>,
}

/// Build the successor's queue item from the triggering completion's
/// output.  Returns `None` when the output shape cannot feed the
/// template (the instance thread then fails the successor loudly rather
/// than letting the query hang — the graph scheduler has already ceded
/// the node).  Pure so the handoff path is unit-testable without an
/// engine.
pub fn materialize_successor(
    plan: &SuccessorPlan,
    query: QueryId,
    output: &JobOutput,
    reply: &Sender<Completion>,
) -> Option<QueueItem> {
    let job = match (&plan.template, output) {
        (SuccessorTemplate::Decode { seq, segments }, JobOutput::Tokens(toks)) => {
            EngineJob::Decode {
                seq: *seq,
                first_token: *toks.first()?,
                segments: segments.clone(),
            }
        }
        (SuccessorTemplate::Embed, JobOutput::Tokens(toks)) => {
            if toks.is_empty() {
                return None;
            }
            EngineJob::Embed { chunks: vec![toks.clone()] }
        }
        (SuccessorTemplate::Embed, JobOutput::TokenBatch(rows)) => {
            if rows.is_empty() {
                return None;
            }
            EngineJob::Embed { chunks: rows.clone() }
        }
        _ => return None,
    };
    Some(QueueItem {
        query,
        node: plan.node,
        depth: plan.depth,
        bundle: (query, plan.node as u64),
        arrival: Instant::now(),
        rows: job.rows(),
        tokens: job.kv_tokens(),
        wcp_discounted: false,
        prefix: None,
        wcp_us: plan.wcp_us,
        tenant: plan.tenant,
        job,
        reply: reply.clone(),
        successors: Vec::new(),
    })
}

/// One queued primitive-node request.
#[derive(Debug)]
pub struct QueueItem {
    pub query: QueryId,
    pub node: usize,
    /// Reverse-topological depth (Algorithm 2 priority).
    pub depth: u32,
    /// Invocation bundle id (PO bundles; Teola uses one bundle per node).
    pub bundle: BundleId,
    pub arrival: Instant,
    pub rows: usize,
    /// KV token estimate of the job (`EngineJob::kv_tokens`), stamped by
    /// the graph scheduler from the same token surface the WCP cost
    /// estimates weigh.  Drives `SlotUnit::Tokens` packing and the
    /// engine scheduler's per-instance `KvBudget` reservations.
    pub tokens: usize,
    /// Whether the prefix-residency WCP discount has been applied to
    /// `wcp_us` (at most once per item; see
    /// `engine_sched::rediscount_resident_prefixes`).
    pub wcp_discounted: bool,
    /// Shared-prompt-prefix fingerprint of a prefill job (None for every
    /// other job kind): the engine scheduler's routing signal.
    pub prefix: Option<PrefixFp>,
    /// Remaining critical-path device time of the owning query at dispatch
    /// time (microseconds; the graph scheduler's `WcpTracker` stamp).
    /// Drives weighted-critical-path bucket ordering; the engine scheduler
    /// may discount it when the item's prefix is already resident.
    pub wcp_us: u64,
    /// Owning tenant of the request (multi-tenant QoS): consulted by the
    /// ranked batch-formation variants to order query buckets *between*
    /// tenants (start-time fair queueing + deadline boost) while WCP /
    /// arrival order is preserved *within* each tenant.
    pub tenant: TenantId,
    pub job: EngineJob,
    pub reply: Sender<Completion>,
    /// Direct-handoff plans for ready successors (pipelining; empty when
    /// the gate is off — the off path is bit-for-bit the PR6 behavior).
    pub successors: Vec<SuccessorPlan>,
}

/// Aging weight of weighted-critical-path ordering: every microsecond a
/// bucket has waited counts as this many microseconds of remaining path,
/// so a short-tail query under sustained long-query load overtakes a
/// fresh long query after `path_gap / WCP_AGING_WEIGHT` of queueing —
/// bounded starvation instead of strict longest-path-first.  At 2, a
/// long query can jump at most half its own remaining device time's
/// worth of queued short work — enough to start its tail promptly, while
/// a displaced short query waits at most `path_gap / 2` extra.
pub const WCP_AGING_WEIGHT: u64 = 2;

/// Effective bucket priority under weighted-critical-path ordering:
/// remaining path plus the aging bonus.  Pure so starvation-freedom is
/// unit-testable.
pub fn wcp_priority_us(remaining_path_us: u64, waited: Duration) -> u64 {
    let waited_us = waited.as_micros().min(u64::MAX as u128) as u64;
    remaining_path_us.saturating_add(waited_us.saturating_mul(WCP_AGING_WEIGHT))
}

/// Form the next batch according to `policy`, removing the chosen items
/// from `queue`.  `budget` is the engine's capacity per dispatch in
/// `unit` denomination: pre-tuned max batch rows (`SlotUnit::Rows`, the
/// legacy mode and always what the baselines get) or the per-instance KV
/// token budget (`SlotUnit::Tokens`).  `wcp` selects
/// weighted-critical-path bucket ordering under `TopoAware` (the
/// baselines ignore it).  Returns an empty vec when nothing fits.
pub fn form_batch(
    queue: &mut Vec<QueueItem>,
    policy: BatchPolicy,
    budget: usize,
    wcp: bool,
    unit: SlotUnit,
) -> Vec<QueueItem> {
    form_batch_ranked(queue, policy, budget, wcp, unit, None)
}

/// [`form_batch`] with an optional per-tenant rank map (multi-tenant
/// QoS).  With `Some(ranks)` under `TopoAware`, query buckets are ordered
/// by their tenant's `(deadline-boost, SFQ virtual start)` rank *first*
/// and WCP/arrival order second — fair queueing between tenants, WCP
/// within each.  `None` is bit-for-bit the tenant-blind path; the FIFO
/// baselines ignore ranks entirely.
pub fn form_batch_ranked(
    queue: &mut Vec<QueueItem>,
    policy: BatchPolicy,
    budget: usize,
    wcp: bool,
    unit: SlotUnit,
    ranks: Option<&TenantRanks>,
) -> Vec<QueueItem> {
    if queue.is_empty() {
        return Vec::new();
    }
    match policy {
        BatchPolicy::BlindTO => {
            // FIFO by arrival until slots run out, restricted to the
            // oldest item's class.
            let mut order: Vec<usize> = (0..queue.len()).collect();
            order.sort_by_key(|&i| queue[i].arrival);
            let class = job_class(&queue[order[0]].job);
            order.retain(|&i| job_class(&queue[i].job) == class);
            take_budget(queue, order, budget, false, true, unit)
        }
        BatchPolicy::PerInvocation => {
            // Oldest bundle only.
            let first = queue
                .iter()
                .min_by_key(|it| it.arrival)
                .map(|it| it.bundle)
                .unwrap();
            let order: Vec<usize> =
                (0..queue.len()).filter(|&i| queue[i].bundle == first).collect();
            take_budget(queue, order, usize::MAX, false, true, unit)
        }
        BatchPolicy::TopoAware => {
            // Algorithm 2 Event 2, restricted to the highest-priority
            // item's class.
            let mut order = topo_order(queue, wcp, ranks);
            if let Some(&first) = order.first() {
                let class = job_class(&queue[first].job);
                order.retain(|&i| job_class(&queue[i].job) == class);
            }
            take_budget(queue, order, budget, true, true, unit)
        }
    }
}

/// Continuous-admission path (stepped engines only): choose the next
/// items, in topology-aware priority order, to join a *partially
/// occupied* instance mid-flight, bounded by its spare budget (`unit`
/// denomination).  Unlike [`form_batch`] there is no job-class
/// restriction — the stepped executor interleaves chunked-prefill calls
/// and decode iterations internally — and an oversized item is never
/// admitted over budget (it waits for a drained instance with the full
/// budget); smaller items behind it first-fit into the spare capacity.
pub fn form_continuous_admission(
    queue: &mut Vec<QueueItem>,
    spare: usize,
    wcp: bool,
    unit: SlotUnit,
) -> Vec<QueueItem> {
    form_continuous_admission_ranked(queue, spare, wcp, unit, None)
}

/// [`form_continuous_admission`] with the optional per-tenant rank map
/// (see [`form_batch_ranked`]); `None` is the tenant-blind path.
pub fn form_continuous_admission_ranked(
    queue: &mut Vec<QueueItem>,
    spare: usize,
    wcp: bool,
    unit: SlotUnit,
    ranks: Option<&TenantRanks>,
) -> Vec<QueueItem> {
    if queue.is_empty() || spare == 0 {
        return Vec::new();
    }
    let order = topo_order(queue, wcp, ranks);
    take_budget(queue, order, spare, true, false, unit)
}

/// True when the queue's priority head can only ever run *alone on a
/// drained instance*: its cost exceeds the whole per-dispatch budget, so
/// no spare-capacity continuous admission can ever take it.  The engine
/// scheduler stops feeding new work into mid-flight instances while this
/// holds — otherwise skip-over packing would admit shorter items around
/// the oversized head forever and starve it (a real risk in token
/// denomination, where a long prefill can exceed a small `kv_tokens`
/// budget; row-mode LLM jobs are single-row and never trigger it).
pub fn head_needs_drained_instance(
    queue: &[QueueItem],
    policy: BatchPolicy,
    wcp: bool,
    budget: usize,
    unit: SlotUnit,
) -> bool {
    head_needs_drained_instance_ranked(queue, policy, wcp, budget, unit, None)
}

/// [`head_needs_drained_instance`] consulting the ranked head (see
/// [`form_batch_ranked`]); `None` is the tenant-blind path.
pub fn head_needs_drained_instance_ranked(
    queue: &[QueueItem],
    policy: BatchPolicy,
    wcp: bool,
    budget: usize,
    unit: SlotUnit,
    ranks: Option<&TenantRanks>,
) -> bool {
    head_index_ranked(queue, policy, wcp, ranks)
        .map_or(false, |h| unit.cost(&queue[h]) > budget)
}

/// Index of the item `form_batch` would dispatch first under `policy` —
/// the queue's head in priority order.  The engine scheduler reads its
/// prefix fingerprint *before* forming a batch so instance choice (prefix
/// affinity) can precede batch formation.
pub fn head_index(queue: &[QueueItem], policy: BatchPolicy, wcp: bool) -> Option<usize> {
    head_index_ranked(queue, policy, wcp, None)
}

/// [`head_index`] with the optional per-tenant rank map (see
/// [`form_batch_ranked`]); `None` is the tenant-blind path.
pub fn head_index_ranked(
    queue: &[QueueItem],
    policy: BatchPolicy,
    wcp: bool,
    ranks: Option<&TenantRanks>,
) -> Option<usize> {
    if queue.is_empty() {
        return None;
    }
    match policy {
        BatchPolicy::TopoAware => topo_order(queue, wcp, ranks).first().copied(),
        BatchPolicy::BlindTO | BatchPolicy::PerInvocation => (0..queue.len())
            .min_by_key(|&i| queue[i].arrival),
    }
}

/// Algorithm 2's priority order over the whole queue: bucket by query,
/// order buckets by weighted-critical-path priority (descending
/// remaining-path + aging; `wcp` on) or earliest arrival (`wcp` off),
/// then sweep buckets taking each bucket's highest-depth nodes first, so
/// other queries' contributive primitives come before a query's
/// lower-depth siblings (Fig. 7); the sweep continues level by level —
/// idle slots help nobody.
///
/// With `ranks` set (multi-tenant QoS), the bucket's tenant rank —
/// `(deadline-boost, SFQ virtual start, tenant)`, ascending — dominates
/// the ordering; WCP/arrival order is preserved *within* each tenant.  A
/// tenant missing from the map sorts last (it has no fair-queueing claim
/// this pass).  `None` keeps the tenant-blind order bit-for-bit.
fn topo_order(queue: &[QueueItem], wcp: bool, ranks: Option<&TenantRanks>) -> Vec<usize> {
    let mut buckets: BTreeMap<QueryId, Vec<usize>> = BTreeMap::new();
    for (i, it) in queue.iter().enumerate() {
        buckets.entry(it.query).or_default().push(i);
    }
    let now = Instant::now();
    // BTreeMap iteration is query-ascending, and both sorts below are
    // stable, so full ties break deterministically by query id.
    let mut bucket_list: Vec<(TenantRank, Instant, u64, Vec<usize>)> = buckets
        .into_values()
        .map(|idxs| {
            let earliest = idxs.iter().map(|&i| queue[i].arrival).min().unwrap();
            let effective = if wcp {
                // The freshest upper bound on the query's remaining path
                // is the largest stamp among its queued items.
                let path = idxs.iter().map(|&i| queue[i].wcp_us).max().unwrap_or(0);
                wcp_priority_us(path, now.saturating_duration_since(earliest))
            } else {
                0
            };
            // All items of one query share a tenant (stamped at spawn).
            let rank = match ranks {
                Some(r) => {
                    let t = queue[idxs[0]].tenant;
                    r.get(&t).copied().unwrap_or((u64::MAX, u64::MAX, t))
                }
                None => (0, 0, 0),
            };
            (rank, earliest, effective, idxs)
        })
        .collect();
    if wcp {
        bucket_list.sort_by(|a, b| a.0.cmp(&b.0).then(b.2.cmp(&a.2)).then(a.1.cmp(&b.1)));
    } else {
        bucket_list.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
    }
    let mut order = Vec::new();
    let mut remaining: Vec<Vec<usize>> =
        bucket_list.into_iter().map(|(_, _, _, idxs)| idxs).collect();
    while remaining.iter().any(|b| !b.is_empty()) {
        for bucket in remaining.iter_mut() {
            if bucket.is_empty() {
                continue;
            }
            let maxd = bucket.iter().map(|&i| queue[i].depth).max().unwrap();
            let mut level: Vec<usize> = bucket
                .iter()
                .copied()
                .filter(|&i| queue[i].depth == maxd)
                .collect();
            bucket.retain(|&i| queue[i].depth != maxd);
            level.sort_by_key(|&i| queue[i].arrival);
            order.extend(level);
        }
    }
    order
}

/// Remove items in `order` while the budget (rows or KV tokens, per
/// `unit`) lasts — first-fit.  `skip_over` lets the topology-aware
/// policy pass over an oversized item to admit later smaller ones
/// (packing; this is what keeps one oversized prefill from blocking a
/// window of short requests); FIFO policies stop at the first overflow.
/// `admit_oversized` lets a single item exceeding the whole budget go
/// out alone (the engine splits internally); the continuous-admission
/// path disables it because a mid-flight instance has only its spare
/// capacity.
fn take_budget(
    queue: &mut Vec<QueueItem>,
    order: Vec<usize>,
    budget: usize,
    skip_over: bool,
    admit_oversized: bool,
    unit: SlotUnit,
) -> Vec<QueueItem> {
    let mut left = budget;
    let mut chosen: Vec<usize> = Vec::new();
    for i in order {
        let cost = unit.cost(&queue[i]);
        if cost <= left {
            left -= cost;
            chosen.push(i);
        } else if chosen.is_empty() && admit_oversized {
            // Oversized single item: admit alone (engine splits internally).
            chosen.push(i);
            left = 0;
        } else if !skip_over {
            break;
        }
        if left == 0 {
            break;
        }
    }
    chosen.sort_unstable();
    chosen.reverse();
    chosen.into_iter().map(|i| queue.swap_remove(i)).collect()
}

/// Per-query bucket of the incremental priority structure: the member
/// slot ids, their depth-grouped dispatch levels (depth descending, each
/// level `(arrival, seq)`-sorted — exactly one round of the Algorithm 2
/// sweep), and the cached cross-bucket ordering aggregates.  `dirty`
/// marks the lazy-invalidation state: levels and aggregates are rebuilt
/// on the next ordering call, not at mutation time.
#[derive(Debug)]
struct Bucket {
    ids: Vec<usize>,
    levels: Vec<(u32, Vec<usize>)>,
    earliest: Instant,
    max_wcp: u64,
    tenant: TenantId,
    dirty: bool,
}

impl Bucket {
    fn rebuild(&mut self, slots: &[Option<QueueItem>], seqs: &[u64]) {
        let item = |id: usize| slots[id].as_ref().expect("bucket id must be live");
        self.ids.sort_by(|&a, &b| {
            let (ia, ib) = (item(a), item(b));
            ib.depth
                .cmp(&ia.depth)
                .then(ia.arrival.cmp(&ib.arrival))
                .then(seqs[a].cmp(&seqs[b]))
        });
        self.levels.clear();
        for &id in &self.ids {
            let d = item(id).depth;
            match self.levels.last_mut() {
                Some((ld, lvl)) if *ld == d => lvl.push(id),
                _ => self.levels.push((d, vec![id])),
            }
        }
        self.earliest = self.ids.iter().map(|&id| item(id).arrival).min().expect("non-empty");
        self.max_wcp = self.ids.iter().map(|&id| item(id).wcp_us).max().unwrap_or(0);
        self.tenant = item(self.ids[0]).tenant;
        self.dirty = false;
    }

    /// Cross-bucket ordering key at a shared `now` — the exact
    /// per-bucket tuple [`topo_order`] computes: `(tenant rank,
    /// effective WCP priority, earliest arrival)`.  The aging term is
    /// recomputed *every call* (never cached): buckets compared at the
    /// same `now` see the same formula as the sort-based path, so the
    /// order is bit-identical by construction.
    fn key(&self, now: Instant, wcp: bool, ranks: Option<&TenantRanks>) -> (TenantRank, u64, Instant) {
        let effective = if wcp {
            wcp_priority_us(self.max_wcp, now.saturating_duration_since(self.earliest))
        } else {
            0
        };
        let rank = match ranks {
            Some(r) => r.get(&self.tenant).copied().unwrap_or((u64::MAX, u64::MAX, self.tenant)),
            None => (0, 0, 0),
        };
        (rank, effective, self.earliest)
    }
}

/// Ascending bucket-key comparator shared by the sorted and scanning
/// paths: tenant rank first, then *descending* effective WCP priority,
/// then earliest arrival — the exact [`topo_order`] comparator (with
/// `wcp` off every effective priority is 0 and the middle term is a
/// no-op, collapsing to the arrival comparator).
fn cmp_bucket_keys(
    a: &(TenantRank, u64, Instant),
    b: &(TenantRank, u64, Instant),
) -> std::cmp::Ordering {
    a.0.cmp(&b.0).then(b.1.cmp(&a.1)).then(a.2.cmp(&b.2))
}

/// Incremental priority structure for the engine scheduler's hot
/// dispatch path (PR9): a slot arena of queued items plus per-query
/// buckets whose dispatch levels are cached across calls and rebuilt
/// lazily — only buckets touched by an enqueue / requeue since the last
/// ordering pass re-sort their members, and the `TopoAware` head is
/// found by an `O(queries)` scan instead of a full `O(n log n)` sort.
///
/// **Equivalence contract** (property-tested in
/// `tests/prop_invariants.rs`): every ordering decision is identical to
/// running the sort-based [`head_index_ranked`] / [`form_batch_ranked`]
/// / [`form_continuous_admission_ranked`] over a plain
/// `Vec<QueueItem>`, whenever arrivals are distinct (always true in
/// real runs — items are stamped with distinct `Instant::now()`
/// arrivals).  Full ties are broken by the insertion sequence number,
/// where the `Vec` path's tie-break is an unobservable artifact of its
/// `swap_remove` permutation history.  Passing `incremental = false` to
/// the ordering calls forces the exact fallback: every bucket is
/// rebuilt from scratch and the full sorted order is materialized, so
/// the two modes differ only in work done, never in output.
#[derive(Debug, Default)]
pub struct SchedQueue {
    slots: Vec<Option<QueueItem>>,
    seqs: Vec<u64>,
    free: Vec<usize>,
    len: usize,
    next_seq: u64,
    buckets: BTreeMap<QueryId, Bucket>,
    /// Hot-path counter sink (order builds, bucket rebuilds).  A
    /// default queue gets its own private instance; the engine
    /// scheduler swaps in its platform's shared handle so concurrent
    /// harnesses never cross-talk (PR10).
    counters: Arc<SchedCounters>,
}

impl SchedQueue {
    pub fn new() -> SchedQueue {
        SchedQueue::default()
    }

    /// Report hot-path counts into `c` instead of the private default.
    pub fn set_counters(&mut self, c: Arc<SchedCounters>) {
        self.counters = c;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue an item.  `O(1)` plus a lazy dirty mark on its query's
    /// bucket — no sorting happens until the next ordering call.
    pub fn push(&mut self, it: QueueItem) {
        let (query, arrival, tenant) = (it.query, it.arrival, it.tenant);
        let id = match self.free.pop() {
            Some(id) => {
                self.slots[id] = Some(it);
                self.seqs[id] = self.next_seq;
                id
            }
            None => {
                self.slots.push(Some(it));
                self.seqs.push(self.next_seq);
                self.slots.len() - 1
            }
        };
        self.next_seq += 1;
        self.len += 1;
        let b = self.buckets.entry(query).or_insert_with(|| Bucket {
            ids: Vec::new(),
            levels: Vec::new(),
            earliest: arrival,
            max_wcp: 0,
            tenant,
            dirty: true,
        });
        b.ids.push(id);
        b.dirty = true;
    }

    /// Iterate every queued item (arena order; use for aggregation, not
    /// for dispatch order).
    pub fn iter(&self) -> impl Iterator<Item = &QueueItem> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    /// Iterate `(slot id, item)` pairs — ids are stable handles for
    /// [`SchedQueue::remove`].
    pub fn iter_ids(&self) -> impl Iterator<Item = (usize, &QueueItem)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|it| (i, it)))
    }

    /// Drain every item in insertion order (deterministic; used by the
    /// engine-dead fail path).
    pub fn drain_all(&mut self) -> Vec<QueueItem> {
        let mut ids: Vec<usize> =
            (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect();
        ids.sort_by_key(|&i| self.seqs[i]);
        ids.into_iter().map(|i| self.remove(i)).collect()
    }

    /// Remove one item by slot id.  Keeps the owning bucket's cached
    /// levels valid in place (removal preserves relative order) and
    /// refreshes its aggregates only when the removed item defined them.
    pub fn remove(&mut self, id: usize) -> QueueItem {
        let it = self.slots[id].take().expect("remove of a live slot id");
        self.free.push(id);
        self.len -= 1;
        let slots = &self.slots;
        if let Some(b) = self.buckets.get_mut(&it.query) {
            b.ids.retain(|&x| x != id);
            if b.ids.is_empty() {
                self.buckets.remove(&it.query);
            } else if !b.dirty {
                for (_, lvl) in b.levels.iter_mut() {
                    lvl.retain(|&x| x != id);
                }
                b.levels.retain(|(_, lvl)| !lvl.is_empty());
                if it.arrival <= b.earliest || it.wcp_us >= b.max_wcp {
                    let item = |x: usize| slots[x].as_ref().expect("bucket id must be live");
                    b.earliest =
                        b.ids.iter().map(|&x| item(x).arrival).min().expect("non-empty");
                    b.max_wcp = b.ids.iter().map(|&x| item(x).wcp_us).max().unwrap_or(0);
                }
            }
        }
        it
    }

    /// Apply a WCP restamp to every item; `f` returns whether it changed
    /// the item's stamp.  Only the touched buckets' ordering aggregates
    /// are refreshed — cached levels stay valid (they order by depth and
    /// arrival, never by WCP).  Returns the number of changed items.
    pub fn restamp_wcp(&mut self, mut f: impl FnMut(&mut QueueItem) -> bool) -> usize {
        let mut touched: Vec<QueryId> = Vec::new();
        for slot in self.slots.iter_mut() {
            if let Some(it) = slot {
                if f(it) {
                    touched.push(it.query);
                }
            }
        }
        let slots = &self.slots;
        for q in &touched {
            if let Some(b) = self.buckets.get_mut(q) {
                if !b.dirty {
                    let item = |x: usize| slots[x].as_ref().expect("bucket id must be live");
                    b.max_wcp = b.ids.iter().map(|&x| item(x).wcp_us).max().unwrap_or(0);
                }
            }
        }
        touched.len()
    }

    /// Rebuild dirty buckets (all buckets when `force` — the exact
    /// fallback path).
    fn ensure_built(&mut self, force: bool) {
        let (slots, seqs) = (&self.slots, &self.seqs);
        for b in self.buckets.values_mut() {
            if b.dirty || force {
                b.rebuild(slots, seqs);
                self.counters.count_bucket_rebuild();
            }
        }
    }

    /// The full Algorithm 2 priority order over every queued item, as
    /// slot ids.  With `incremental` only dirty buckets re-sort; the
    /// cross-bucket key sort runs every call so the WCP aging term is
    /// always computed fresh at one shared `now`.
    fn full_order(&mut self, wcp: bool, ranks: Option<&TenantRanks>, incremental: bool) -> Vec<usize> {
        self.ensure_built(!incremental);
        self.counters.count_order_build();
        let now = Instant::now();
        let mut keys: Vec<(QueryId, (TenantRank, u64, Instant))> =
            self.buckets.iter().map(|(&q, b)| (q, b.key(now, wcp, ranks))).collect();
        // BTreeMap iteration is query-ascending and the sort is stable,
        // so full ties break by query id — as in `topo_order`.
        keys.sort_by(|a, b| cmp_bucket_keys(&a.1, &b.1));
        let mut order = Vec::with_capacity(self.len);
        let mut round = 0;
        loop {
            let mut any = false;
            for (q, _) in &keys {
                if let Some((_, lvl)) = self.buckets[q].levels.get(round) {
                    order.extend_from_slice(lvl);
                    any = true;
                }
            }
            if !any {
                break;
            }
            round += 1;
        }
        order
    }

    /// Live slot ids in `(arrival, seq)` order — the FIFO baselines'
    /// dispatch order.
    fn fifo_order(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.iter_ids().map(|(i, _)| i).collect();
        ids.sort_by(|&a, &b| {
            let (ia, ib) = (self.slots[a].as_ref().unwrap(), self.slots[b].as_ref().unwrap());
            ia.arrival.cmp(&ib.arrival).then(self.seqs[a].cmp(&self.seqs[b]))
        });
        ids
    }

    /// The item `form_batch` would dispatch first — the priority head.
    /// Under `TopoAware` with `incremental`, this is an `O(queries)`
    /// strict-first-min scan over cached bucket keys (no sort, no order
    /// materialization); the exact fallback materializes the full sorted
    /// order and takes its first element.  Both agree by construction:
    /// a strict-min scan over ascending query ids returns the first
    /// element of the stable sort.
    pub fn head(
        &mut self,
        policy: BatchPolicy,
        wcp: bool,
        ranks: Option<&TenantRanks>,
        incremental: bool,
    ) -> Option<&QueueItem> {
        if self.is_empty() {
            return None;
        }
        let id = match policy {
            BatchPolicy::TopoAware => {
                if incremental {
                    self.ensure_built(false);
                    let now = Instant::now();
                    let mut best: Option<(&Bucket, (TenantRank, u64, Instant))> = None;
                    for b in self.buckets.values() {
                        let k = b.key(now, wcp, ranks);
                        match &best {
                            Some((_, bk)) if cmp_bucket_keys(&k, bk).is_lt() => {
                                best = Some((b, k))
                            }
                            None => best = Some((b, k)),
                            _ => {}
                        }
                    }
                    best.and_then(|(b, _)| b.levels.first().and_then(|(_, lvl)| lvl.first()))
                        .copied()
                } else {
                    self.full_order(wcp, ranks, false).first().copied()
                }
            }
            BatchPolicy::BlindTO | BatchPolicy::PerInvocation => self
                .iter_ids()
                .fold(None::<(usize, &QueueItem)>, |best, (i, it)| match best {
                    Some((bi, bit))
                        if (bit.arrival, self.seqs[bi]) <= (it.arrival, self.seqs[i]) =>
                    {
                        Some((bi, bit))
                    }
                    _ => Some((i, it)),
                })
                .map(|(i, _)| i),
        };
        id.map(|i| self.slots[i].as_ref().expect("head id must be live"))
    }

    /// [`form_batch_ranked`] over the incremental structure: same
    /// policies, same class restriction, same first-fit budget walk —
    /// the chosen items are removed and returned in priority order.
    pub fn form_batch(
        &mut self,
        policy: BatchPolicy,
        budget: usize,
        wcp: bool,
        unit: SlotUnit,
        ranks: Option<&TenantRanks>,
        incremental: bool,
    ) -> Vec<QueueItem> {
        if self.is_empty() {
            return Vec::new();
        }
        let order = match policy {
            BatchPolicy::BlindTO => {
                let mut order = self.fifo_order();
                let class = job_class(&self.slots[order[0]].as_ref().unwrap().job);
                order.retain(|&i| job_class(&self.slots[i].as_ref().unwrap().job) == class);
                return self.take_ids(order, budget, false, true, unit);
            }
            BatchPolicy::PerInvocation => {
                let order = self.fifo_order();
                let first = self.slots[order[0]].as_ref().unwrap().bundle;
                let order: Vec<usize> = order
                    .into_iter()
                    .filter(|&i| self.slots[i].as_ref().unwrap().bundle == first)
                    .collect();
                return self.take_ids(order, usize::MAX, false, true, unit);
            }
            BatchPolicy::TopoAware => {
                let mut order = self.full_order(wcp, ranks, incremental);
                if let Some(&first) = order.first() {
                    let class = job_class(&self.slots[first].as_ref().unwrap().job);
                    order.retain(|&i| job_class(&self.slots[i].as_ref().unwrap().job) == class);
                }
                order
            }
        };
        self.take_ids(order, budget, true, true, unit)
    }

    /// [`form_continuous_admission_ranked`] over the incremental
    /// structure: spare-capacity packing with skip-over and no oversized
    /// admission.
    pub fn form_continuous(
        &mut self,
        spare: usize,
        wcp: bool,
        unit: SlotUnit,
        ranks: Option<&TenantRanks>,
        incremental: bool,
    ) -> Vec<QueueItem> {
        if self.is_empty() || spare == 0 {
            return Vec::new();
        }
        let order = self.full_order(wcp, ranks, incremental);
        self.take_ids(order, spare, true, false, unit)
    }

    /// The [`take_budget`] first-fit walk over slot ids.
    fn take_ids(
        &mut self,
        order: Vec<usize>,
        budget: usize,
        skip_over: bool,
        admit_oversized: bool,
        unit: SlotUnit,
    ) -> Vec<QueueItem> {
        let mut left = budget;
        let mut chosen: Vec<usize> = Vec::new();
        for id in order {
            let cost = unit.cost(self.slots[id].as_ref().expect("ordered id must be live"));
            if cost <= left {
                left -= cost;
                chosen.push(id);
            } else if chosen.is_empty() && admit_oversized {
                chosen.push(id);
                left = 0;
            } else if !skip_over {
                break;
            }
            if left == 0 {
                break;
            }
        }
        chosen.into_iter().map(|id| self.remove(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn item(query: u64, node: usize, depth: u32, rows: usize, t0: Instant, ms: u64) -> QueueItem {
        let (tx, _rx) = channel();
        // leak the receiver so sends don't fail in tests that inspect items
        std::mem::forget(_rx);
        QueueItem {
            query,
            node,
            depth,
            bundle: (query, 0),
            arrival: t0 + Duration::from_millis(ms),
            rows,
            tokens: rows,
            wcp_discounted: false,
            prefix: None,
            wcp_us: 0,
            tenant: crate::engines::UNTENANTED,
            job: EngineJob::ToolCall { name: "t".into(), cost_us: 0 },
            reply: tx,
            successors: Vec::new(),
        }
    }

    fn token_item(query: u64, node: usize, tokens: usize, t0: Instant, ms: u64) -> QueueItem {
        let mut it = item(query, node, 2, 1, t0, ms);
        it.tokens = tokens;
        it
    }

    #[test]
    fn materialize_successor_builds_exact_jobs_and_fails_closed() {
        let (tx, rx) = channel();
        std::mem::forget(rx);
        let (etx, erx) = channel();
        std::mem::forget(erx);
        let plan = SuccessorPlan {
            on_node: 4,
            node: 5,
            depth: 2,
            engine: etx,
            template: SuccessorTemplate::Decode {
                seq: (9, 0),
                segments: vec![SegmentSpec { node: 5, len: 8 }],
            },
            wcp_us: 1234,
            tenant: 7,
            fired: std::cell::Cell::new(false),
        };
        let it = materialize_successor(&plan, 9, &JobOutput::Tokens(vec![42]), &tx).unwrap();
        assert_eq!((it.query, it.node, it.wcp_us), (9, 5, 1234));
        assert_eq!(it.tenant, 7, "handoff successor accounted to the parent's tenant");
        assert_eq!(it.tokens, 8, "decode estimate is the planned segment sum");
        match &it.job {
            EngineJob::Decode { seq, first_token, segments } => {
                assert_eq!((*seq, *first_token, segments.len()), ((9, 0), 42, 1));
            }
            other => panic!("wrong job {other:?}"),
        }
        // Shape mismatch fails closed (instance fails the node loudly).
        assert!(materialize_successor(&plan, 9, &JobOutput::Embeddings(Vec::new()), &tx).is_none());
        assert!(materialize_successor(&plan, 9, &JobOutput::Tokens(Vec::new()), &tx).is_none());
        let embed = SuccessorPlan { template: SuccessorTemplate::Embed, ..plan };
        let it = materialize_successor(
            &embed,
            9,
            &JobOutput::TokenBatch(vec![vec![1, 2], vec![3]]),
            &tx,
        )
        .unwrap();
        match &it.job {
            EngineJob::Embed { chunks } => assert_eq!(chunks.len(), 2),
            other => panic!("wrong job {other:?}"),
        }
        let it = materialize_successor(&embed, 9, &JobOutput::Tokens(vec![7, 8]), &tx).unwrap();
        match &it.job {
            EngineJob::Embed { chunks } => assert_eq!(chunks, &vec![vec![7, 8]]),
            other => panic!("wrong job {other:?}"),
        }
    }

    #[test]
    fn topo_aware_prefers_deep_nodes_across_queries() {
        let t0 = Instant::now();
        // Query 1 (earliest): node A depth 3, node B depth 1.
        // Query 2: node H depth 3.
        let mut q = vec![
            item(1, 10, 3, 1, t0, 0),
            item(1, 11, 1, 1, t0, 1),
            item(2, 20, 3, 1, t0, 2),
        ];
        let batch = form_batch(&mut q, BatchPolicy::TopoAware, 2, false, SlotUnit::Rows);
        let picked: Vec<(u64, usize)> = batch.iter().map(|i| (i.query, i.node)).collect();
        // Fig. 7: A (deep, query 1) + H (deep, query 2); B waits.
        assert!(picked.contains(&(1, 10)));
        assert!(picked.contains(&(2, 20)));
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].node, 11);
    }

    #[test]
    fn blind_to_is_fifo() {
        let t0 = Instant::now();
        let mut q = vec![
            item(1, 10, 3, 1, t0, 0),
            item(1, 11, 1, 1, t0, 1),
            item(2, 20, 3, 1, t0, 2),
        ];
        let batch = form_batch(&mut q, BatchPolicy::BlindTO, 2, false, SlotUnit::Rows);
        let picked: Vec<usize> = batch.iter().map(|i| i.node).collect();
        assert!(picked.contains(&10) && picked.contains(&11));
    }

    #[test]
    fn per_invocation_takes_single_bundle() {
        let t0 = Instant::now();
        let mut q = vec![
            item(1, 10, 3, 1, t0, 0),
            item(1, 11, 1, 1, t0, 0),
            item(2, 20, 3, 1, t0, 1),
        ];
        let batch = form_batch(&mut q, BatchPolicy::PerInvocation, 64, false, SlotUnit::Rows);
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|i| i.query == 1));
    }

    #[test]
    fn row_budget_respected() {
        let t0 = Instant::now();
        let mut q = vec![
            item(1, 1, 2, 6, t0, 0),
            item(1, 2, 2, 6, t0, 1),
            item(2, 3, 2, 3, t0, 2),
        ];
        let batch = form_batch(&mut q, BatchPolicy::TopoAware, 10, false, SlotUnit::Rows);
        let rows: usize = batch.iter().map(|i| i.rows).sum();
        assert!(rows <= 10);
        // skip-over admits the 3-row item from query 2.
        assert!(batch.iter().any(|i| i.query == 2));
    }

    #[test]
    fn continuous_admission_respects_spare_budget_and_skips_oversized() {
        let t0 = Instant::now();
        let mut q = vec![
            item(1, 1, 2, 6, t0, 0),
            item(2, 2, 2, 3, t0, 1),
            item(3, 3, 2, 1, t0, 2),
        ];
        // 4 spare slots on a mid-flight instance: the 6-row item cannot
        // join (no oversized admission), the 3- and 1-row items pack in.
        let batch = form_continuous_admission(&mut q, 4, false, SlotUnit::Rows);
        let rows: usize = batch.iter().map(|i| i.rows).sum();
        assert_eq!(rows, 4);
        assert_eq!(batch.len(), 2);
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].rows, 6);
        // Zero spare admits nothing.
        assert!(form_continuous_admission(&mut q, 0, false, SlotUnit::Rows).is_empty());
    }

    #[test]
    fn head_index_matches_form_batch_order() {
        let t0 = Instant::now();
        let q = vec![
            item(1, 10, 1, 1, t0, 0),
            item(1, 11, 3, 1, t0, 1),
            item(2, 20, 2, 1, t0, 2),
        ];
        // TopoAware: earliest query's deepest node leads.
        assert_eq!(head_index(&q, BatchPolicy::TopoAware, false), Some(1));
        // FIFO policies: oldest arrival leads.
        assert_eq!(head_index(&q, BatchPolicy::BlindTO, false), Some(0));
        assert_eq!(head_index(&[], BatchPolicy::TopoAware, false), None);
    }

    #[test]
    fn token_packing_skips_oversized_prefill_for_shorts() {
        let t0 = Instant::now();
        // One 128-token prefill ahead of four 8-token jobs; a mid-flight
        // instance has 48 spare tokens.  The oversized item must not
        // block the window: the shorts first-fit in, the oversized item
        // waits for a drained instance.
        let mut q = vec![
            token_item(1, 1, 128, t0, 0),
            token_item(2, 2, 8, t0, 1),
            token_item(3, 3, 8, t0, 2),
            token_item(4, 4, 8, t0, 3),
            token_item(5, 5, 8, t0, 4),
        ];
        let admitted = form_continuous_admission(&mut q, 48, false, SlotUnit::Tokens);
        let cost: usize = admitted.iter().map(|i| i.tokens).sum();
        assert_eq!(admitted.len(), 4, "all four shorts join");
        assert_eq!(cost, 32);
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].tokens, 128, "oversized prefill left queued");
    }

    #[test]
    fn token_budget_admits_many_short_rows_where_row_budget_would_not() {
        let t0 = Instant::now();
        // Six 8-token single-row jobs against a budget of 64: row
        // denomination at the historical max batch of 2 takes two, token
        // denomination takes all six — short prefills no longer burn a
        // full row slot each.
        let mk = || (0..6).map(|i| token_item(10 + i as u64, i, 8, t0, i as u64)).collect();
        let mut q: Vec<QueueItem> = mk();
        let by_rows = form_batch(&mut q, BatchPolicy::TopoAware, 2, false, SlotUnit::Rows);
        assert_eq!(by_rows.len(), 2);
        let mut q: Vec<QueueItem> = mk();
        let by_tokens = form_batch(&mut q, BatchPolicy::TopoAware, 64, false, SlotUnit::Tokens);
        assert_eq!(by_tokens.len(), 6);
        assert!(q.is_empty());
    }

    #[test]
    fn oversized_head_demands_a_drained_instance() {
        let t0 = Instant::now();
        // The 128-token prefill is the priority head (oldest); with a
        // 64-token budget it can never join a mid-flight instance, so
        // the scheduler must stop continuous admission and let an
        // instance drain — otherwise the shorts behind it would be
        // packed around it forever (starvation).
        let q = vec![
            token_item(1, 1, 128, t0, 0),
            token_item(2, 2, 8, t0, 1),
        ];
        assert!(head_needs_drained_instance(&q, BatchPolicy::TopoAware, false, 64, SlotUnit::Tokens));
        // A head that fits the budget never gates.
        let q = vec![token_item(2, 2, 8, t0, 0), token_item(1, 1, 128, t0, 1)];
        assert!(!head_needs_drained_instance(&q, BatchPolicy::TopoAware, false, 64, SlotUnit::Tokens));
        // Row mode: single-row LLM jobs never trigger the gate.
        assert!(!head_needs_drained_instance(&q, BatchPolicy::TopoAware, false, 8, SlotUnit::Rows));
        assert!(!head_needs_drained_instance(&[], BatchPolicy::TopoAware, false, 8, SlotUnit::Tokens));
    }

    #[test]
    fn oversized_token_item_admitted_alone_in_full_batch() {
        let t0 = Instant::now();
        let mut q = vec![token_item(1, 1, 500, t0, 0), token_item(2, 2, 8, t0, 1)];
        let batch = form_batch(&mut q, BatchPolicy::TopoAware, 64, false, SlotUnit::Tokens);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].tokens, 500, "oversized goes out alone; executor chunks it");
    }

    #[test]
    fn oversized_item_admitted_alone() {
        let t0 = Instant::now();
        let mut q = vec![item(1, 1, 2, 100, t0, 0), item(2, 2, 2, 1, t0, 1)];
        let batch = form_batch(&mut q, BatchPolicy::TopoAware, 16, false, SlotUnit::Rows);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rows, 100);
    }

    fn tenant_item(tenant: TenantId, query: u64, node: usize, t0: Instant, ms: u64) -> QueueItem {
        let mut it = item(query, node, 2, 1, t0, ms);
        it.tenant = tenant;
        it
    }

    #[test]
    fn tenant_ranks_dominate_bucket_order_but_preserve_order_within_tenant() {
        let t0 = Instant::now();
        // Tenant 2's query arrived *later* but holds the lower SFQ virtual
        // start (it is behind on served work), so its bucket goes first;
        // tenant 1's two queries keep their arrival order between them.
        let q = vec![
            tenant_item(1, 10, 1, t0, 0),
            tenant_item(1, 11, 2, t0, 1),
            tenant_item(2, 20, 3, t0, 2),
        ];
        let mut ranks = TenantRanks::new();
        ranks.insert(1, (1, 500, 1));
        ranks.insert(2, (1, 100, 2));
        let order = topo_order(&q, false, Some(&ranks));
        let picked: Vec<u64> = order.iter().map(|&i| q[i].query).collect();
        assert_eq!(picked, vec![20, 10, 11]);
        // A deadline-boosted tenant (boost 0) overtakes any unboosted one
        // regardless of virtual start.
        ranks.insert(1, (0, 500, 1));
        let order = topo_order(&q, false, Some(&ranks));
        let picked: Vec<u64> = order.iter().map(|&i| q[i].query).collect();
        assert_eq!(picked, vec![10, 11, 20]);
        // No ranks = bit-identical to the tenant-blind arrival order.
        let order = topo_order(&q, false, None);
        let picked: Vec<u64> = order.iter().map(|&i| q[i].query).collect();
        assert_eq!(picked, vec![10, 11, 20]);
    }

    #[test]
    fn unranked_tenant_sorts_last_and_ranked_head_tracks_ranks() {
        let t0 = Instant::now();
        let q = vec![
            tenant_item(9, 90, 1, t0, 0), // not in the rank map
            tenant_item(2, 20, 2, t0, 1),
        ];
        let mut ranks = TenantRanks::new();
        ranks.insert(2, (1, 100, 2));
        assert_eq!(head_index_ranked(&q, BatchPolicy::TopoAware, false, Some(&ranks)), Some(1));
        // Tenant-blind head is the earliest arrival.
        assert_eq!(head_index(&q, BatchPolicy::TopoAware, false), Some(0));
    }

    /// Construct the same logical item twice (ordering-relevant fields
    /// are deterministic given `t0`; the reply channels differ but never
    /// participate in ordering).
    fn twin_items(t0: Instant) -> (Vec<QueueItem>, Vec<QueueItem>) {
        let mk = || {
            vec![
                item(3, 30, 2, 2, t0, 0),
                item(1, 10, 3, 1, t0, 1),
                item(1, 11, 1, 1, t0, 2),
                item(2, 20, 3, 4, t0, 3),
                item(2, 21, 3, 1, t0, 4),
                item(1, 12, 3, 1, t0, 5),
            ]
        };
        (mk(), mk())
    }

    #[test]
    fn sched_queue_matches_vec_path_across_policies_and_modes() {
        let t0 = Instant::now();
        for policy in [BatchPolicy::TopoAware, BatchPolicy::BlindTO, BatchPolicy::PerInvocation] {
            for wcp in [false, true] {
                for incremental in [false, true] {
                    let (vec_items, sq_items) = twin_items(t0);
                    let mut vq: Vec<QueueItem> = vec_items;
                    let mut sq = SchedQueue::new();
                    for it in sq_items {
                        sq.push(it);
                    }
                    assert_eq!(
                        head_index(&vq, policy, wcp).map(|i| (vq[i].query, vq[i].node)),
                        sq.head(policy, wcp, None, incremental).map(|it| (it.query, it.node)),
                        "head mismatch: {policy:?} wcp={wcp} incr={incremental}"
                    );
                    // Drain both to empty via repeated batch formation:
                    // every batch must pick the same item set.
                    while !vq.is_empty() {
                        let vb: Vec<(u64, usize)> =
                            form_batch(&mut vq, policy, 4, wcp, SlotUnit::Rows)
                                .iter()
                                .map(|i| (i.query, i.node))
                                .collect();
                        let mut sb: Vec<(u64, usize)> = sq
                            .form_batch(policy, 4, wcp, SlotUnit::Rows, None, incremental)
                            .iter()
                            .map(|i| (i.query, i.node))
                            .collect();
                        let mut vb_sorted = vb.clone();
                        vb_sorted.sort_unstable();
                        sb.sort_unstable();
                        assert_eq!(
                            vb_sorted, sb,
                            "batch mismatch: {policy:?} wcp={wcp} incr={incremental}"
                        );
                    }
                    assert!(sq.is_empty(), "queues drain in lockstep");
                }
            }
        }
    }

    #[test]
    fn sched_queue_removal_and_restamp_keep_cached_aggregates_fresh() {
        let t0 = Instant::now();
        let mut sq = SchedQueue::new();
        sq.push(item(1, 10, 2, 1, t0, 0));
        sq.push(item(2, 20, 2, 1, t0, 1));
        sq.push(item(2, 21, 3, 1, t0, 2));
        assert_eq!(sq.len(), 3);
        // Build the cache, then remove query 2's deep head: the cached
        // level must shrink in place and the next head come from the
        // surviving items.
        let head = sq.head(BatchPolicy::TopoAware, false, None, true).unwrap();
        assert_eq!((head.query, head.node), (1, 10), "earliest bucket leads");
        let id = sq.iter_ids().find(|(_, it)| it.node == 21).map(|(i, _)| i).unwrap();
        let removed = sq.remove(id);
        assert_eq!(removed.node, 21);
        assert_eq!(sq.len(), 2);
        // WCP restamp through the incremental path: boost query 2 far
        // above query 1 — the cached max_wcp aggregate must refresh and
        // flip the head without any enqueue having dirtied the bucket.
        let n = sq.restamp_wcp(|it| {
            if it.query == 2 {
                it.wcp_us = 1_000_000_000;
                true
            } else {
                false
            }
        });
        assert_eq!(n, 1);
        let head = sq.head(BatchPolicy::TopoAware, true, None, true).unwrap();
        assert_eq!((head.query, head.node), (2, 20), "restamped bucket overtakes");
        // Slot reuse after removal keeps iteration consistent.
        sq.push(item(3, 30, 1, 1, t0, 3));
        assert_eq!(sq.len(), 3);
        assert_eq!(sq.iter().count(), 3);
        let drained = sq.drain_all();
        assert_eq!(drained.len(), 3);
        assert!(sq.is_empty());
    }
}
