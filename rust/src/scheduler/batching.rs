//! Batch-formation policies for the lower-tier engine schedulers.
//!
//! * `TopoAware` — Algorithm 2: bucket the queue by query, sort buckets by
//!   earliest arrival, inside each bucket prefer the *deepest* primitives
//!   (the ones whose completion unblocks the most downstream work), fill
//!   up to the slot budget.
//! * `BlindTO` — throughput-oriented FIFO dynamic batching up to the
//!   pre-tuned max batch (the paper's TO baseline).
//! * `PerInvocation` — latency-oriented bundles: all requests of one
//!   invocation are scheduled together and nothing else joins the batch
//!   (the paper's PO baseline).
//!
//! Under `TopoAware` the bucket *order* has two modes, selected by the
//! `wcp` flag (paper §8): weighted-critical-path ordering ranks query
//! buckets by descending remaining critical-path device time (the
//! `QueueItem::wcp_us` stamp from the graph scheduler's `WcpTracker`)
//! plus an aging term so short-tail queries cannot starve; with `wcp`
//! off, buckets fall back to earliest-arrival order (Algorithm 2 as
//! written).
//!
//! Packing is denominated by a [`SlotUnit`]: legacy **row** slots (the
//! pre-tuned max batch rows) or **KV tokens** (`QueueItem::tokens`, the
//! job's KV-cache growth).  Token packing is first-fit with skip-over,
//! so one oversized prefill cannot block a window of short requests —
//! the shorts pack around it and the oversized item waits for a drained
//! instance (or goes out alone under the full-batch path, where the
//! executor chunks it internally).

use std::collections::BTreeMap;
use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use crate::engines::{
    Completion, EngineJob, JobOutput, PrefixFp, QueryId, SegmentSpec, SeqId, TenantId,
};
use crate::scheduler::tenancy::{TenantRank, TenantRanks};

/// Invocation-bundle identity: `(query, node)`.  Kept as a structured key
/// — the packed `(query << 20) | node` form collided when a node id
/// reached 2^20 and bled into the query bits, silently merging unrelated
/// invocations into one PO bundle.
pub type BundleId = (QueryId, u64);

/// Batch-compatibility class of a job: prefill-type and decode-type LLM
/// work never share a batch (a decode joining a prefill batch would wait
/// behind compute-bound prefills — the head-of-line blocking vLLM avoids
/// by separating prefill and decode iterations).
pub fn job_class(job: &EngineJob) -> u8 {
    match job {
        EngineJob::Prefill { .. } | EngineJob::ClonePrefix { .. } => 1,
        EngineJob::Decode { .. } => 2,
        _ => 0,
    }
}

/// Scheduling policy of an engine scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    TopoAware,
    BlindTO,
    PerInvocation,
}

impl BatchPolicy {
    /// Encode for the atomic policy handle.
    pub fn to_u8(self) -> u8 {
        match self {
            BatchPolicy::TopoAware => 0,
            BatchPolicy::BlindTO => 1,
            BatchPolicy::PerInvocation => 2,
        }
    }

    /// Decode from the atomic policy handle.
    pub fn from_u8(v: u8) -> BatchPolicy {
        match v {
            1 => BatchPolicy::BlindTO,
            2 => BatchPolicy::PerInvocation,
            _ => BatchPolicy::TopoAware,
        }
    }
}

/// Capacity denomination of batch packing and instance load accounting:
/// legacy row slots, or the token-budgeted KV mode (PR5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SlotUnit {
    /// One unit per model row (`QueueItem::rows`) — the historical
    /// `max_slots` semantics; the TO/PO baselines always use this.
    #[default]
    Rows,
    /// One unit per KV token (`QueueItem::tokens`): a 2048-token prefill
    /// costs 256x an 8-token one instead of the same row slot.
    Tokens,
}

impl SlotUnit {
    /// Budget cost of one queued item in this denomination (never 0, so
    /// admission and retirement stay balanced for empty payloads).
    pub fn cost(self, it: &QueueItem) -> usize {
        match self {
            SlotUnit::Rows => it.rows.max(1),
            SlotUnit::Tokens => it.tokens.max(1),
        }
    }
}

/// Successor job shape a [`SuccessorPlan`] can materialize from a
/// predecessor's completion output alone.  Only shapes whose *entire*
/// remaining input is the predecessor output qualify — anything needing
/// graph-scheduler state (rerank post-selection, prefill offset
/// bookkeeping) re-enters the dispatch loop as before.
#[derive(Debug, Clone)]
pub enum SuccessorTemplate {
    /// Decode continuing the predecessor prefill's sequence: the prefill
    /// completion's next-token seeds the decode, everything else is
    /// static at lowering time.
    Decode { seq: SeqId, segments: Vec<SegmentSpec> },
    /// Embed the predecessor completion's token rows (streamed partial
    /// results: one decode segment's tokens feed embedding the moment
    /// the segment completes).
    Embed,
}

/// Direct cross-engine handoff plan (the pipelining tentpole): attached
/// by the graph scheduler to a [`QueueItem`] whose downstream node has a
/// single unresolved input, and materialized at the *instance* thread
/// the moment the triggering completion is emitted — the successor job
/// enters the target engine's admission queue without bouncing through
/// the graph scheduler's dispatch loop (Parrot-style producer-side
/// pre-registration).  The WCP stamp rides across the handoff; the KV
/// token estimate is recomputed from the materialized job (identical to
/// what the graph scheduler would have stamped, since the template
/// fixes the job shape).
#[derive(Debug, Clone)]
pub struct SuccessorPlan {
    /// Completion node id that triggers this plan: the emitting node
    /// itself, or one decode segment's partial-output marker.
    pub on_node: usize,
    /// The downstream node being handed off.
    pub node: usize,
    /// Reverse-topological depth of the successor node.
    pub depth: u32,
    /// The target engine's admission queue.
    pub engine: Sender<QueueItem>,
    pub template: SuccessorTemplate,
    /// Remaining critical-path stamp carried across the handoff.
    pub wcp_us: u64,
    /// Owning tenant of the parent request: the materialized successor is
    /// accounted to the same tenant's fair-queueing ledger, KV quota and
    /// admission class as its parent (multi-tenant QoS).
    pub tenant: TenantId,
    /// Fired-once latch, set by the instance thread when the trigger
    /// completion materializes this plan: duplicate stream deliveries
    /// must not inject the successor twice (a double decode admission
    /// would corrupt the sequence state).
    pub fired: std::cell::Cell<bool>,
}

/// Build the successor's queue item from the triggering completion's
/// output.  Returns `None` when the output shape cannot feed the
/// template (the instance thread then fails the successor loudly rather
/// than letting the query hang — the graph scheduler has already ceded
/// the node).  Pure so the handoff path is unit-testable without an
/// engine.
pub fn materialize_successor(
    plan: &SuccessorPlan,
    query: QueryId,
    output: &JobOutput,
    reply: &Sender<Completion>,
) -> Option<QueueItem> {
    let job = match (&plan.template, output) {
        (SuccessorTemplate::Decode { seq, segments }, JobOutput::Tokens(toks)) => {
            EngineJob::Decode {
                seq: *seq,
                first_token: *toks.first()?,
                segments: segments.clone(),
            }
        }
        (SuccessorTemplate::Embed, JobOutput::Tokens(toks)) => {
            if toks.is_empty() {
                return None;
            }
            EngineJob::Embed { chunks: vec![toks.clone()] }
        }
        (SuccessorTemplate::Embed, JobOutput::TokenBatch(rows)) => {
            if rows.is_empty() {
                return None;
            }
            EngineJob::Embed { chunks: rows.clone() }
        }
        _ => return None,
    };
    Some(QueueItem {
        query,
        node: plan.node,
        depth: plan.depth,
        bundle: (query, plan.node as u64),
        arrival: Instant::now(),
        rows: job.rows(),
        tokens: job.kv_tokens(),
        wcp_discounted: false,
        prefix: None,
        wcp_us: plan.wcp_us,
        tenant: plan.tenant,
        job,
        reply: reply.clone(),
        successors: Vec::new(),
    })
}

/// One queued primitive-node request.
#[derive(Debug)]
pub struct QueueItem {
    pub query: QueryId,
    pub node: usize,
    /// Reverse-topological depth (Algorithm 2 priority).
    pub depth: u32,
    /// Invocation bundle id (PO bundles; Teola uses one bundle per node).
    pub bundle: BundleId,
    pub arrival: Instant,
    pub rows: usize,
    /// KV token estimate of the job (`EngineJob::kv_tokens`), stamped by
    /// the graph scheduler from the same token surface the WCP cost
    /// estimates weigh.  Drives `SlotUnit::Tokens` packing and the
    /// engine scheduler's per-instance `KvBudget` reservations.
    pub tokens: usize,
    /// Whether the prefix-residency WCP discount has been applied to
    /// `wcp_us` (at most once per item; see
    /// `engine_sched::rediscount_resident_prefixes`).
    pub wcp_discounted: bool,
    /// Shared-prompt-prefix fingerprint of a prefill job (None for every
    /// other job kind): the engine scheduler's routing signal.
    pub prefix: Option<PrefixFp>,
    /// Remaining critical-path device time of the owning query at dispatch
    /// time (microseconds; the graph scheduler's `WcpTracker` stamp).
    /// Drives weighted-critical-path bucket ordering; the engine scheduler
    /// may discount it when the item's prefix is already resident.
    pub wcp_us: u64,
    /// Owning tenant of the request (multi-tenant QoS): consulted by the
    /// ranked batch-formation variants to order query buckets *between*
    /// tenants (start-time fair queueing + deadline boost) while WCP /
    /// arrival order is preserved *within* each tenant.
    pub tenant: TenantId,
    pub job: EngineJob,
    pub reply: Sender<Completion>,
    /// Direct-handoff plans for ready successors (pipelining; empty when
    /// the gate is off — the off path is bit-for-bit the PR6 behavior).
    pub successors: Vec<SuccessorPlan>,
}

/// Aging weight of weighted-critical-path ordering: every microsecond a
/// bucket has waited counts as this many microseconds of remaining path,
/// so a short-tail query under sustained long-query load overtakes a
/// fresh long query after `path_gap / WCP_AGING_WEIGHT` of queueing —
/// bounded starvation instead of strict longest-path-first.  At 2, a
/// long query can jump at most half its own remaining device time's
/// worth of queued short work — enough to start its tail promptly, while
/// a displaced short query waits at most `path_gap / 2` extra.
pub const WCP_AGING_WEIGHT: u64 = 2;

/// Effective bucket priority under weighted-critical-path ordering:
/// remaining path plus the aging bonus.  Pure so starvation-freedom is
/// unit-testable.
pub fn wcp_priority_us(remaining_path_us: u64, waited: Duration) -> u64 {
    let waited_us = waited.as_micros().min(u64::MAX as u128) as u64;
    remaining_path_us.saturating_add(waited_us.saturating_mul(WCP_AGING_WEIGHT))
}

/// Form the next batch according to `policy`, removing the chosen items
/// from `queue`.  `budget` is the engine's capacity per dispatch in
/// `unit` denomination: pre-tuned max batch rows (`SlotUnit::Rows`, the
/// legacy mode and always what the baselines get) or the per-instance KV
/// token budget (`SlotUnit::Tokens`).  `wcp` selects
/// weighted-critical-path bucket ordering under `TopoAware` (the
/// baselines ignore it).  Returns an empty vec when nothing fits.
pub fn form_batch(
    queue: &mut Vec<QueueItem>,
    policy: BatchPolicy,
    budget: usize,
    wcp: bool,
    unit: SlotUnit,
) -> Vec<QueueItem> {
    form_batch_ranked(queue, policy, budget, wcp, unit, None)
}

/// [`form_batch`] with an optional per-tenant rank map (multi-tenant
/// QoS).  With `Some(ranks)` under `TopoAware`, query buckets are ordered
/// by their tenant's `(deadline-boost, SFQ virtual start)` rank *first*
/// and WCP/arrival order second — fair queueing between tenants, WCP
/// within each.  `None` is bit-for-bit the tenant-blind path; the FIFO
/// baselines ignore ranks entirely.
pub fn form_batch_ranked(
    queue: &mut Vec<QueueItem>,
    policy: BatchPolicy,
    budget: usize,
    wcp: bool,
    unit: SlotUnit,
    ranks: Option<&TenantRanks>,
) -> Vec<QueueItem> {
    if queue.is_empty() {
        return Vec::new();
    }
    match policy {
        BatchPolicy::BlindTO => {
            // FIFO by arrival until slots run out, restricted to the
            // oldest item's class.
            let mut order: Vec<usize> = (0..queue.len()).collect();
            order.sort_by_key(|&i| queue[i].arrival);
            let class = job_class(&queue[order[0]].job);
            order.retain(|&i| job_class(&queue[i].job) == class);
            take_budget(queue, order, budget, false, true, unit)
        }
        BatchPolicy::PerInvocation => {
            // Oldest bundle only.
            let first = queue
                .iter()
                .min_by_key(|it| it.arrival)
                .map(|it| it.bundle)
                .unwrap();
            let order: Vec<usize> =
                (0..queue.len()).filter(|&i| queue[i].bundle == first).collect();
            take_budget(queue, order, usize::MAX, false, true, unit)
        }
        BatchPolicy::TopoAware => {
            // Algorithm 2 Event 2, restricted to the highest-priority
            // item's class.
            let mut order = topo_order(queue, wcp, ranks);
            if let Some(&first) = order.first() {
                let class = job_class(&queue[first].job);
                order.retain(|&i| job_class(&queue[i].job) == class);
            }
            take_budget(queue, order, budget, true, true, unit)
        }
    }
}

/// Continuous-admission path (stepped engines only): choose the next
/// items, in topology-aware priority order, to join a *partially
/// occupied* instance mid-flight, bounded by its spare budget (`unit`
/// denomination).  Unlike [`form_batch`] there is no job-class
/// restriction — the stepped executor interleaves chunked-prefill calls
/// and decode iterations internally — and an oversized item is never
/// admitted over budget (it waits for a drained instance with the full
/// budget); smaller items behind it first-fit into the spare capacity.
pub fn form_continuous_admission(
    queue: &mut Vec<QueueItem>,
    spare: usize,
    wcp: bool,
    unit: SlotUnit,
) -> Vec<QueueItem> {
    form_continuous_admission_ranked(queue, spare, wcp, unit, None)
}

/// [`form_continuous_admission`] with the optional per-tenant rank map
/// (see [`form_batch_ranked`]); `None` is the tenant-blind path.
pub fn form_continuous_admission_ranked(
    queue: &mut Vec<QueueItem>,
    spare: usize,
    wcp: bool,
    unit: SlotUnit,
    ranks: Option<&TenantRanks>,
) -> Vec<QueueItem> {
    if queue.is_empty() || spare == 0 {
        return Vec::new();
    }
    let order = topo_order(queue, wcp, ranks);
    take_budget(queue, order, spare, true, false, unit)
}

/// True when the queue's priority head can only ever run *alone on a
/// drained instance*: its cost exceeds the whole per-dispatch budget, so
/// no spare-capacity continuous admission can ever take it.  The engine
/// scheduler stops feeding new work into mid-flight instances while this
/// holds — otherwise skip-over packing would admit shorter items around
/// the oversized head forever and starve it (a real risk in token
/// denomination, where a long prefill can exceed a small `kv_tokens`
/// budget; row-mode LLM jobs are single-row and never trigger it).
pub fn head_needs_drained_instance(
    queue: &[QueueItem],
    policy: BatchPolicy,
    wcp: bool,
    budget: usize,
    unit: SlotUnit,
) -> bool {
    head_needs_drained_instance_ranked(queue, policy, wcp, budget, unit, None)
}

/// [`head_needs_drained_instance`] consulting the ranked head (see
/// [`form_batch_ranked`]); `None` is the tenant-blind path.
pub fn head_needs_drained_instance_ranked(
    queue: &[QueueItem],
    policy: BatchPolicy,
    wcp: bool,
    budget: usize,
    unit: SlotUnit,
    ranks: Option<&TenantRanks>,
) -> bool {
    head_index_ranked(queue, policy, wcp, ranks)
        .map_or(false, |h| unit.cost(&queue[h]) > budget)
}

/// Index of the item `form_batch` would dispatch first under `policy` —
/// the queue's head in priority order.  The engine scheduler reads its
/// prefix fingerprint *before* forming a batch so instance choice (prefix
/// affinity) can precede batch formation.
pub fn head_index(queue: &[QueueItem], policy: BatchPolicy, wcp: bool) -> Option<usize> {
    head_index_ranked(queue, policy, wcp, None)
}

/// [`head_index`] with the optional per-tenant rank map (see
/// [`form_batch_ranked`]); `None` is the tenant-blind path.
pub fn head_index_ranked(
    queue: &[QueueItem],
    policy: BatchPolicy,
    wcp: bool,
    ranks: Option<&TenantRanks>,
) -> Option<usize> {
    if queue.is_empty() {
        return None;
    }
    match policy {
        BatchPolicy::TopoAware => topo_order(queue, wcp, ranks).first().copied(),
        BatchPolicy::BlindTO | BatchPolicy::PerInvocation => (0..queue.len())
            .min_by_key(|&i| queue[i].arrival),
    }
}

/// Algorithm 2's priority order over the whole queue: bucket by query,
/// order buckets by weighted-critical-path priority (descending
/// remaining-path + aging; `wcp` on) or earliest arrival (`wcp` off),
/// then sweep buckets taking each bucket's highest-depth nodes first, so
/// other queries' contributive primitives come before a query's
/// lower-depth siblings (Fig. 7); the sweep continues level by level —
/// idle slots help nobody.
///
/// With `ranks` set (multi-tenant QoS), the bucket's tenant rank —
/// `(deadline-boost, SFQ virtual start, tenant)`, ascending — dominates
/// the ordering; WCP/arrival order is preserved *within* each tenant.  A
/// tenant missing from the map sorts last (it has no fair-queueing claim
/// this pass).  `None` keeps the tenant-blind order bit-for-bit.
fn topo_order(queue: &[QueueItem], wcp: bool, ranks: Option<&TenantRanks>) -> Vec<usize> {
    let mut buckets: BTreeMap<QueryId, Vec<usize>> = BTreeMap::new();
    for (i, it) in queue.iter().enumerate() {
        buckets.entry(it.query).or_default().push(i);
    }
    let now = Instant::now();
    // BTreeMap iteration is query-ascending, and both sorts below are
    // stable, so full ties break deterministically by query id.
    let mut bucket_list: Vec<(TenantRank, Instant, u64, Vec<usize>)> = buckets
        .into_values()
        .map(|idxs| {
            let earliest = idxs.iter().map(|&i| queue[i].arrival).min().unwrap();
            let effective = if wcp {
                // The freshest upper bound on the query's remaining path
                // is the largest stamp among its queued items.
                let path = idxs.iter().map(|&i| queue[i].wcp_us).max().unwrap_or(0);
                wcp_priority_us(path, now.saturating_duration_since(earliest))
            } else {
                0
            };
            // All items of one query share a tenant (stamped at spawn).
            let rank = match ranks {
                Some(r) => {
                    let t = queue[idxs[0]].tenant;
                    r.get(&t).copied().unwrap_or((u64::MAX, u64::MAX, t))
                }
                None => (0, 0, 0),
            };
            (rank, earliest, effective, idxs)
        })
        .collect();
    if wcp {
        bucket_list.sort_by(|a, b| a.0.cmp(&b.0).then(b.2.cmp(&a.2)).then(a.1.cmp(&b.1)));
    } else {
        bucket_list.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
    }
    let mut order = Vec::new();
    let mut remaining: Vec<Vec<usize>> =
        bucket_list.into_iter().map(|(_, _, _, idxs)| idxs).collect();
    while remaining.iter().any(|b| !b.is_empty()) {
        for bucket in remaining.iter_mut() {
            if bucket.is_empty() {
                continue;
            }
            let maxd = bucket.iter().map(|&i| queue[i].depth).max().unwrap();
            let mut level: Vec<usize> = bucket
                .iter()
                .copied()
                .filter(|&i| queue[i].depth == maxd)
                .collect();
            bucket.retain(|&i| queue[i].depth != maxd);
            level.sort_by_key(|&i| queue[i].arrival);
            order.extend(level);
        }
    }
    order
}

/// Remove items in `order` while the budget (rows or KV tokens, per
/// `unit`) lasts — first-fit.  `skip_over` lets the topology-aware
/// policy pass over an oversized item to admit later smaller ones
/// (packing; this is what keeps one oversized prefill from blocking a
/// window of short requests); FIFO policies stop at the first overflow.
/// `admit_oversized` lets a single item exceeding the whole budget go
/// out alone (the engine splits internally); the continuous-admission
/// path disables it because a mid-flight instance has only its spare
/// capacity.
fn take_budget(
    queue: &mut Vec<QueueItem>,
    order: Vec<usize>,
    budget: usize,
    skip_over: bool,
    admit_oversized: bool,
    unit: SlotUnit,
) -> Vec<QueueItem> {
    let mut left = budget;
    let mut chosen: Vec<usize> = Vec::new();
    for i in order {
        let cost = unit.cost(&queue[i]);
        if cost <= left {
            left -= cost;
            chosen.push(i);
        } else if chosen.is_empty() && admit_oversized {
            // Oversized single item: admit alone (engine splits internally).
            chosen.push(i);
            left = 0;
        } else if !skip_over {
            break;
        }
        if left == 0 {
            break;
        }
    }
    chosen.sort_unstable();
    chosen.reverse();
    chosen.into_iter().map(|i| queue.swap_remove(i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn item(query: u64, node: usize, depth: u32, rows: usize, t0: Instant, ms: u64) -> QueueItem {
        let (tx, _rx) = channel();
        // leak the receiver so sends don't fail in tests that inspect items
        std::mem::forget(_rx);
        QueueItem {
            query,
            node,
            depth,
            bundle: (query, 0),
            arrival: t0 + Duration::from_millis(ms),
            rows,
            tokens: rows,
            wcp_discounted: false,
            prefix: None,
            wcp_us: 0,
            tenant: crate::engines::UNTENANTED,
            job: EngineJob::ToolCall { name: "t".into(), cost_us: 0 },
            reply: tx,
            successors: Vec::new(),
        }
    }

    fn token_item(query: u64, node: usize, tokens: usize, t0: Instant, ms: u64) -> QueueItem {
        let mut it = item(query, node, 2, 1, t0, ms);
        it.tokens = tokens;
        it
    }

    #[test]
    fn materialize_successor_builds_exact_jobs_and_fails_closed() {
        let (tx, rx) = channel();
        std::mem::forget(rx);
        let (etx, erx) = channel();
        std::mem::forget(erx);
        let plan = SuccessorPlan {
            on_node: 4,
            node: 5,
            depth: 2,
            engine: etx,
            template: SuccessorTemplate::Decode {
                seq: (9, 0),
                segments: vec![SegmentSpec { node: 5, len: 8 }],
            },
            wcp_us: 1234,
            tenant: 7,
            fired: std::cell::Cell::new(false),
        };
        let it = materialize_successor(&plan, 9, &JobOutput::Tokens(vec![42]), &tx).unwrap();
        assert_eq!((it.query, it.node, it.wcp_us), (9, 5, 1234));
        assert_eq!(it.tenant, 7, "handoff successor accounted to the parent's tenant");
        assert_eq!(it.tokens, 8, "decode estimate is the planned segment sum");
        match &it.job {
            EngineJob::Decode { seq, first_token, segments } => {
                assert_eq!((*seq, *first_token, segments.len()), ((9, 0), 42, 1));
            }
            other => panic!("wrong job {other:?}"),
        }
        // Shape mismatch fails closed (instance fails the node loudly).
        assert!(materialize_successor(&plan, 9, &JobOutput::Embeddings(Vec::new()), &tx).is_none());
        assert!(materialize_successor(&plan, 9, &JobOutput::Tokens(Vec::new()), &tx).is_none());
        let embed = SuccessorPlan { template: SuccessorTemplate::Embed, ..plan };
        let it = materialize_successor(
            &embed,
            9,
            &JobOutput::TokenBatch(vec![vec![1, 2], vec![3]]),
            &tx,
        )
        .unwrap();
        match &it.job {
            EngineJob::Embed { chunks } => assert_eq!(chunks.len(), 2),
            other => panic!("wrong job {other:?}"),
        }
        let it = materialize_successor(&embed, 9, &JobOutput::Tokens(vec![7, 8]), &tx).unwrap();
        match &it.job {
            EngineJob::Embed { chunks } => assert_eq!(chunks, &vec![vec![7, 8]]),
            other => panic!("wrong job {other:?}"),
        }
    }

    #[test]
    fn topo_aware_prefers_deep_nodes_across_queries() {
        let t0 = Instant::now();
        // Query 1 (earliest): node A depth 3, node B depth 1.
        // Query 2: node H depth 3.
        let mut q = vec![
            item(1, 10, 3, 1, t0, 0),
            item(1, 11, 1, 1, t0, 1),
            item(2, 20, 3, 1, t0, 2),
        ];
        let batch = form_batch(&mut q, BatchPolicy::TopoAware, 2, false, SlotUnit::Rows);
        let picked: Vec<(u64, usize)> = batch.iter().map(|i| (i.query, i.node)).collect();
        // Fig. 7: A (deep, query 1) + H (deep, query 2); B waits.
        assert!(picked.contains(&(1, 10)));
        assert!(picked.contains(&(2, 20)));
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].node, 11);
    }

    #[test]
    fn blind_to_is_fifo() {
        let t0 = Instant::now();
        let mut q = vec![
            item(1, 10, 3, 1, t0, 0),
            item(1, 11, 1, 1, t0, 1),
            item(2, 20, 3, 1, t0, 2),
        ];
        let batch = form_batch(&mut q, BatchPolicy::BlindTO, 2, false, SlotUnit::Rows);
        let picked: Vec<usize> = batch.iter().map(|i| i.node).collect();
        assert!(picked.contains(&10) && picked.contains(&11));
    }

    #[test]
    fn per_invocation_takes_single_bundle() {
        let t0 = Instant::now();
        let mut q = vec![
            item(1, 10, 3, 1, t0, 0),
            item(1, 11, 1, 1, t0, 0),
            item(2, 20, 3, 1, t0, 1),
        ];
        let batch = form_batch(&mut q, BatchPolicy::PerInvocation, 64, false, SlotUnit::Rows);
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|i| i.query == 1));
    }

    #[test]
    fn row_budget_respected() {
        let t0 = Instant::now();
        let mut q = vec![
            item(1, 1, 2, 6, t0, 0),
            item(1, 2, 2, 6, t0, 1),
            item(2, 3, 2, 3, t0, 2),
        ];
        let batch = form_batch(&mut q, BatchPolicy::TopoAware, 10, false, SlotUnit::Rows);
        let rows: usize = batch.iter().map(|i| i.rows).sum();
        assert!(rows <= 10);
        // skip-over admits the 3-row item from query 2.
        assert!(batch.iter().any(|i| i.query == 2));
    }

    #[test]
    fn continuous_admission_respects_spare_budget_and_skips_oversized() {
        let t0 = Instant::now();
        let mut q = vec![
            item(1, 1, 2, 6, t0, 0),
            item(2, 2, 2, 3, t0, 1),
            item(3, 3, 2, 1, t0, 2),
        ];
        // 4 spare slots on a mid-flight instance: the 6-row item cannot
        // join (no oversized admission), the 3- and 1-row items pack in.
        let batch = form_continuous_admission(&mut q, 4, false, SlotUnit::Rows);
        let rows: usize = batch.iter().map(|i| i.rows).sum();
        assert_eq!(rows, 4);
        assert_eq!(batch.len(), 2);
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].rows, 6);
        // Zero spare admits nothing.
        assert!(form_continuous_admission(&mut q, 0, false, SlotUnit::Rows).is_empty());
    }

    #[test]
    fn head_index_matches_form_batch_order() {
        let t0 = Instant::now();
        let q = vec![
            item(1, 10, 1, 1, t0, 0),
            item(1, 11, 3, 1, t0, 1),
            item(2, 20, 2, 1, t0, 2),
        ];
        // TopoAware: earliest query's deepest node leads.
        assert_eq!(head_index(&q, BatchPolicy::TopoAware, false), Some(1));
        // FIFO policies: oldest arrival leads.
        assert_eq!(head_index(&q, BatchPolicy::BlindTO, false), Some(0));
        assert_eq!(head_index(&[], BatchPolicy::TopoAware, false), None);
    }

    #[test]
    fn token_packing_skips_oversized_prefill_for_shorts() {
        let t0 = Instant::now();
        // One 128-token prefill ahead of four 8-token jobs; a mid-flight
        // instance has 48 spare tokens.  The oversized item must not
        // block the window: the shorts first-fit in, the oversized item
        // waits for a drained instance.
        let mut q = vec![
            token_item(1, 1, 128, t0, 0),
            token_item(2, 2, 8, t0, 1),
            token_item(3, 3, 8, t0, 2),
            token_item(4, 4, 8, t0, 3),
            token_item(5, 5, 8, t0, 4),
        ];
        let admitted = form_continuous_admission(&mut q, 48, false, SlotUnit::Tokens);
        let cost: usize = admitted.iter().map(|i| i.tokens).sum();
        assert_eq!(admitted.len(), 4, "all four shorts join");
        assert_eq!(cost, 32);
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].tokens, 128, "oversized prefill left queued");
    }

    #[test]
    fn token_budget_admits_many_short_rows_where_row_budget_would_not() {
        let t0 = Instant::now();
        // Six 8-token single-row jobs against a budget of 64: row
        // denomination at the historical max batch of 2 takes two, token
        // denomination takes all six — short prefills no longer burn a
        // full row slot each.
        let mk = || (0..6).map(|i| token_item(10 + i as u64, i, 8, t0, i as u64)).collect();
        let mut q: Vec<QueueItem> = mk();
        let by_rows = form_batch(&mut q, BatchPolicy::TopoAware, 2, false, SlotUnit::Rows);
        assert_eq!(by_rows.len(), 2);
        let mut q: Vec<QueueItem> = mk();
        let by_tokens = form_batch(&mut q, BatchPolicy::TopoAware, 64, false, SlotUnit::Tokens);
        assert_eq!(by_tokens.len(), 6);
        assert!(q.is_empty());
    }

    #[test]
    fn oversized_head_demands_a_drained_instance() {
        let t0 = Instant::now();
        // The 128-token prefill is the priority head (oldest); with a
        // 64-token budget it can never join a mid-flight instance, so
        // the scheduler must stop continuous admission and let an
        // instance drain — otherwise the shorts behind it would be
        // packed around it forever (starvation).
        let q = vec![
            token_item(1, 1, 128, t0, 0),
            token_item(2, 2, 8, t0, 1),
        ];
        assert!(head_needs_drained_instance(&q, BatchPolicy::TopoAware, false, 64, SlotUnit::Tokens));
        // A head that fits the budget never gates.
        let q = vec![token_item(2, 2, 8, t0, 0), token_item(1, 1, 128, t0, 1)];
        assert!(!head_needs_drained_instance(&q, BatchPolicy::TopoAware, false, 64, SlotUnit::Tokens));
        // Row mode: single-row LLM jobs never trigger the gate.
        assert!(!head_needs_drained_instance(&q, BatchPolicy::TopoAware, false, 8, SlotUnit::Rows));
        assert!(!head_needs_drained_instance(&[], BatchPolicy::TopoAware, false, 8, SlotUnit::Tokens));
    }

    #[test]
    fn oversized_token_item_admitted_alone_in_full_batch() {
        let t0 = Instant::now();
        let mut q = vec![token_item(1, 1, 500, t0, 0), token_item(2, 2, 8, t0, 1)];
        let batch = form_batch(&mut q, BatchPolicy::TopoAware, 64, false, SlotUnit::Tokens);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].tokens, 500, "oversized goes out alone; executor chunks it");
    }

    #[test]
    fn oversized_item_admitted_alone() {
        let t0 = Instant::now();
        let mut q = vec![item(1, 1, 2, 100, t0, 0), item(2, 2, 2, 1, t0, 1)];
        let batch = form_batch(&mut q, BatchPolicy::TopoAware, 16, false, SlotUnit::Rows);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rows, 100);
    }

    fn tenant_item(tenant: TenantId, query: u64, node: usize, t0: Instant, ms: u64) -> QueueItem {
        let mut it = item(query, node, 2, 1, t0, ms);
        it.tenant = tenant;
        it
    }

    #[test]
    fn tenant_ranks_dominate_bucket_order_but_preserve_order_within_tenant() {
        let t0 = Instant::now();
        // Tenant 2's query arrived *later* but holds the lower SFQ virtual
        // start (it is behind on served work), so its bucket goes first;
        // tenant 1's two queries keep their arrival order between them.
        let q = vec![
            tenant_item(1, 10, 1, t0, 0),
            tenant_item(1, 11, 2, t0, 1),
            tenant_item(2, 20, 3, t0, 2),
        ];
        let mut ranks = TenantRanks::new();
        ranks.insert(1, (1, 500, 1));
        ranks.insert(2, (1, 100, 2));
        let order = topo_order(&q, false, Some(&ranks));
        let picked: Vec<u64> = order.iter().map(|&i| q[i].query).collect();
        assert_eq!(picked, vec![20, 10, 11]);
        // A deadline-boosted tenant (boost 0) overtakes any unboosted one
        // regardless of virtual start.
        ranks.insert(1, (0, 500, 1));
        let order = topo_order(&q, false, Some(&ranks));
        let picked: Vec<u64> = order.iter().map(|&i| q[i].query).collect();
        assert_eq!(picked, vec![10, 11, 20]);
        // No ranks = bit-identical to the tenant-blind arrival order.
        let order = topo_order(&q, false, None);
        let picked: Vec<u64> = order.iter().map(|&i| q[i].query).collect();
        assert_eq!(picked, vec![10, 11, 20]);
    }

    #[test]
    fn unranked_tenant_sorts_last_and_ranked_head_tracks_ranks() {
        let t0 = Instant::now();
        let q = vec![
            tenant_item(9, 90, 1, t0, 0), // not in the rank map
            tenant_item(2, 20, 2, t0, 1),
        ];
        let mut ranks = TenantRanks::new();
        ranks.insert(2, (1, 100, 2));
        assert_eq!(head_index_ranked(&q, BatchPolicy::TopoAware, false, Some(&ranks)), Some(1));
        // Tenant-blind head is the earliest arrival.
        assert_eq!(head_index(&q, BatchPolicy::TopoAware, false), Some(0));
    }
}
