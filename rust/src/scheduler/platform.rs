//! Platform: provisions engines + engine schedulers and runs queries.
//!
//! This is the deployment surface (paper §3.1 offline stage ①): register
//! execution engines with instance counts and latency profiles, then serve
//! queries online.  Mirrors the paper's testbed shape — each non-LLM
//! engine gets one instance, each LLM two, unless overridden.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::engines::profile::ProfileRegistry;
use crate::engines::search::{Corpus, NetModel};
use crate::engines::sim::ExecBackend;
use crate::engines::{llm, search, vector_db, ExecMode, QueryId};
use crate::engines::embedding::spawn_embedding_engine;
use crate::engines::reranker::spawn_reranker_engine;
use crate::error::Result;
use crate::graph::egraph::EGraph;
use crate::graph::value::Value;
use crate::runtime::Manifest;
use crate::scheduler::batching::{BatchPolicy, QueueItem};
use crate::scheduler::engine_sched::EngineScheduler;
use crate::scheduler::graph_sched::{QueryMetrics, QueryRunner};
use crate::scheduler::tenancy::{SharedTenancy, TenancyConfig, TenantId, UNTENANTED};

/// One engine pool to provision.
#[derive(Debug, Clone)]
pub struct EngineSpec {
    /// Engine name used by primitives ("llm-small", "embedder", ...).
    pub name: String,
    pub instances: usize,
    /// Slot budget per dispatch (max efficient batch rows).
    pub max_slots: usize,
}

/// Platform configuration.
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Execution substrate for model-based engines: XLA artifacts or the
    /// simulated backend (no artifacts required).
    pub backend: ExecBackend,
    /// LLM variants to provision (paper: two instances each).
    pub llms: Vec<EngineSpec>,
    pub embedder: EngineSpec,
    pub reranker: EngineSpec,
    pub vdb_instances: usize,
    pub web_instances: usize,
    pub tool_instances: usize,
    pub policy: BatchPolicy,
    /// Iteration-level continuous batching on the LLM engines: admit new
    /// work into partially occupied instances between decode iterations.
    /// Only active under `TopoAware` (the `BlindTO`/`PerInvocation`
    /// baselines always use the legacy full-batch path); switchable at
    /// runtime via [`Platform::set_continuous`].
    pub continuous: bool,
    /// Dynamic-batching accumulation window, microseconds; switchable at
    /// runtime via [`Platform::set_batch_window_us`].
    pub batch_window_us: u64,
    /// Per-instance resident-prefix budget for cross-query KV prefix
    /// routing on the LLM engines: each instance keeps up to this many
    /// shared instruction prefixes in an LRU registry, and the engine
    /// scheduler routes prefills to an instance already holding their
    /// prefix.  0 disables routing and caching entirely; switchable at
    /// runtime via [`Platform::set_prefix_slots`].
    pub prefix_slots: usize,
    /// Weighted-critical-path scheduling (paper §8): under `TopoAware`,
    /// engine schedulers order query buckets by descending remaining
    /// critical-path device time (with aging) instead of arrival, so a
    /// query whose workflow tail is long gets engine slots first.  The
    /// TO/PO baselines ignore it; switchable at runtime via
    /// [`Platform::set_wcp`].
    pub wcp: bool,
    /// Per-instance KV token budget on the LLM engines (token-denominated
    /// admission, PR5): `None` derives the backward-compatible default
    /// `max_slots x` the variant's profile `max_seq` per engine,
    /// `Some(0)` keeps the legacy row-slot accounting (the TO/PO
    /// baselines always run row mode regardless), `Some(n)` sets an
    /// explicit budget.  Switchable at runtime via
    /// [`Platform::set_kv_tokens`].
    pub kv_tokens_per_instance: Option<usize>,
    /// Persistent-KV-residency watermark on the LLM engines, as a percent
    /// of each instance's KV token budget (PR6).  0 (the default)
    /// disables residency entirely — prefill KV is released at job
    /// retirement exactly as before.  A non-zero value keeps retired
    /// sequences' KV resident against their `SeqId` until `FreeQuery`,
    /// charges decode admission incrementally (one token per produced
    /// iteration plus any swap-in), and evicts the lowest-priority
    /// resident sequences whenever occupancy crosses
    /// `capacity * watermark / 100`.  Switchable at runtime via
    /// [`Platform::set_kv_watermark`].
    pub kv_watermark: usize,
    /// Per-engine-kind overrides of the residency watermark (percent):
    /// the last entry matching an engine's kind wins over the global
    /// `kv_watermark` at provisioning time.  Only LLM engines act on a
    /// watermark today, so only `EngineKind::Llm` entries are effective;
    /// other kinds are accepted for forward compatibility.  Set via
    /// `TEOLA_KV_WATERMARK_<KIND>` in the bench harness, or retuned per
    /// engine at runtime via [`Platform::set_kv_watermark_of`].
    pub kv_watermark_overrides: Vec<(crate::engines::EngineKind, u8)>,
    /// Cross-engine pipelining (PR7): query runners attach successor
    /// plans to dispatched jobs so the serving instance injects the
    /// downstream job (prefill -> decode, decode segment -> embed)
    /// directly into the target engine's queue, skipping the
    /// graph-scheduler round-trip; not-yet-ready monolithic LLM prefills
    /// may speculatively prefill their constant template prefix.  Only
    /// active under `TopoAware` (the baselines keep the classic loop);
    /// switchable at runtime via [`Platform::set_pipeline`].  Off, the
    /// dispatch path is bit-for-bit the pre-PR7 loop.
    pub pipeline: bool,
    /// Multi-tenant QoS (PR8): tenant registry with per-tenant fair-queue
    /// weights, SLO classes (`Interactive`/`Batch` with optional deadline)
    /// and soft KV quotas.  Disabled (the default) the dispatch stack is
    /// bit-for-bit identical to single-tenant operation; enabled, the LLM
    /// engine schedulers layer start-time fair queueing across tenants on
    /// top of WCP ordering within each tenant, shed `Batch` work when an
    /// `Interactive` deadline is breached, and watermark eviction prefers
    /// over-quota tenants.  Set via `TEOLA_TENANCY` / `run --tenants`;
    /// switchable at runtime via [`Platform::set_tenancy`].
    pub tenancy: TenancyConfig,
    /// Speculative branch dispatch + dynamic fan-out (PR10): query
    /// runners dispatch ready nodes of a guard's likely branch while the
    /// guard is still unresolved (stamped fully discounted so they only
    /// fill spare engine capacity), confirm them in place or cancel them
    /// (queue purge + seq abort + fair-share refund) on resolution, weigh
    /// unresolved guarded subpaths by branch probability in the WCP
    /// estimate, and run runtime-grown tool fan-outs concurrently.  Only
    /// active under `TopoAware`; off, dispatch is bit-for-bit the
    /// pre-PR10 guard-blocking path.  Set via `TEOLA_SPECULATION` /
    /// `run --speculate`; switchable at runtime via
    /// [`Platform::set_speculation`].
    pub speculation: bool,
    /// Minimum branch probability for speculative dispatch (PR10).
    pub spec_threshold: f64,
    /// Incremental scheduler priority maintenance (PR9): engine
    /// schedulers keep per-query dispatch levels cached across passes
    /// and rebuild only buckets touched since the last ordering call,
    /// with the `TopoAware` head found by an O(queries) scan.  `false`
    /// forces the exact rebuild-and-sort fallback on every call — the
    /// two modes are output-identical by construction (property-tested),
    /// differing only in orchestration overhead.  Set via
    /// `TEOLA_SCHED_INCREMENTAL` / `run --sched-incremental`; switchable
    /// at runtime via [`Platform::set_sched_incremental`].
    pub sched_incremental: bool,
    /// Pre-compile all artifact buckets at startup (XLA backend only; the
    /// sim backend has nothing to compile and ignores this).
    pub warm: bool,
    pub corpus_docs: usize,
    pub net: NetModel,
}

impl PlatformConfig {
    /// Testbed-shaped default: one core LLM variant + llm-small judge.
    pub fn default_with(artifacts_dir: impl Into<std::path::PathBuf>, core_llm: &str) -> Self {
        PlatformConfig {
            artifacts_dir: artifacts_dir.into(),
            backend: ExecBackend::Xla,
            llms: vec![
                EngineSpec { name: core_llm.into(), instances: 2, max_slots: 8 },
            ],
            embedder: EngineSpec { name: "embedder".into(), instances: 1, max_slots: 16 },
            reranker: EngineSpec { name: "reranker".into(), instances: 1, max_slots: 16 },
            vdb_instances: 1,
            web_instances: 2,
            tool_instances: 2,
            policy: BatchPolicy::TopoAware,
            continuous: true,
            batch_window_us: 3_000,
            prefix_slots: 8,
            wcp: true,
            kv_tokens_per_instance: None,
            kv_watermark: 0,
            kv_watermark_overrides: Vec::new(),
            pipeline: true,
            tenancy: TenancyConfig::default(),
            speculation: false,
            spec_threshold: 0.5,
            sched_incremental: true,
            warm: true,
            corpus_docs: 400,
            net: NetModel::default(),
        }
    }

    /// Simulated-backend testbed: same engine topology, no artifacts
    /// directory needed.
    pub fn sim(core_llm: &str) -> Self {
        let mut cfg = Self::default_with("artifacts", core_llm);
        cfg.backend = ExecBackend::Sim;
        cfg
    }

    /// Add another LLM pool (e.g. the judge/proxy model).
    pub fn with_llm(mut self, name: &str, instances: usize, max_slots: usize) -> Self {
        if !self.llms.iter().any(|l| l.name == name) {
            self.llms.push(EngineSpec { name: name.into(), instances, max_slots });
        }
        self
    }

    /// Override the batching policy.
    pub fn with_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }
}

/// A running platform: engine schedulers + routing table.
pub struct Platform {
    routers: HashMap<String, Sender<QueueItem>>,
    sched_handles: Vec<JoinHandle<()>>,
    policy: Arc<AtomicU8>,
    slots: HashMap<String, Arc<AtomicUsize>>,
    continuous: Arc<AtomicBool>,
    batch_window_us: Arc<AtomicU64>,
    prefix_slots: Arc<AtomicUsize>,
    wcp: Arc<AtomicBool>,
    /// Per-LLM-engine KV token budget handles (shared by the engine
    /// scheduler and its executors' admission ledgers).
    kv_tokens: HashMap<String, Arc<AtomicUsize>>,
    /// The derived per-engine defaults (`max_slots x profile max_seq`),
    /// restored by `set_kv_tokens(None)`.
    kv_defaults: HashMap<String, usize>,
    /// Per-LLM-engine persistent-residency watermark handles (percent of
    /// KV capacity; 0 = off), each shared by that engine's scheduler and
    /// executors so a per-engine retune applies to dispatch charging,
    /// admission and eviction at once.
    kv_watermarks: HashMap<String, Arc<AtomicUsize>>,
    /// The global watermark value (what [`Platform::kv_watermark`]
    /// reports); non-LLM engine schedulers share this handle, and
    /// [`Platform::set_kv_watermark`] writes it through to every
    /// per-engine handle.
    kv_watermark_base: Arc<AtomicUsize>,
    /// Cross-engine pipelining switch read by `run_query`/`spawn_query`
    /// when constructing runners (see `PlatformConfig::pipeline`).
    pipeline: Arc<AtomicBool>,
    /// Shared multi-tenant QoS registry (see `PlatformConfig::tenancy`),
    /// consulted by every engine scheduler and LLM executor.
    tenancy: Arc<SharedTenancy>,
    /// Incremental-priority switch shared by every engine scheduler (see
    /// `PlatformConfig::sched_incremental`).
    sched_incremental: Arc<AtomicBool>,
    /// Speculative branch dispatch switch read at runner construction
    /// (see `PlatformConfig::speculation`).
    speculation: Arc<AtomicBool>,
    /// Minimum branch probability for speculative dispatch.
    spec_threshold: f64,
    /// Per-platform hot-path counter sink, shared by every engine
    /// scheduler and query runner this platform spawns — concurrent
    /// platforms (or benches) in one process no longer cross-talk
    /// through process-global counters.
    counters: Arc<crate::scheduler::stats::SchedCounters>,
    pub profiles: ProfileRegistry,
    pub manifest: Rc<Manifest>,
    pub sep: i32,
}

impl Platform {
    /// Provision all engines and start their schedulers.
    pub fn start(cfg: &PlatformConfig) -> Result<Platform> {
        let manifest = match cfg.backend {
            ExecBackend::Sim => Rc::new(Manifest::synthetic()),
            ExecBackend::Xla => {
                // Fail fast instead of spawning instances whose executor
                // init can never succeed (dead engines would hang queries).
                if !crate::runtime::xla_stub::AVAILABLE {
                    return Err(crate::error::TeolaError::Xla(
                        "XLA backend not linked in this build (runtime/xla_stub.rs); \
                         use ExecBackend::Sim or link the real `xla` crate"
                            .into(),
                    ));
                }
                Rc::new(Manifest::load(&cfg.artifacts_dir)?)
            }
        };
        let profiles = ProfileRegistry::with_defaults();
        let mut routers = HashMap::new();
        let mut sched_handles = Vec::new();
        let mut slots: HashMap<String, Arc<AtomicUsize>> = HashMap::new();
        let policy = Arc::new(AtomicU8::new(cfg.policy.to_u8()));
        let continuous = Arc::new(AtomicBool::new(cfg.continuous));
        let batch_window_us = Arc::new(AtomicU64::new(cfg.batch_window_us));
        let prefix_slots = Arc::new(AtomicUsize::new(cfg.prefix_slots));
        let wcp = Arc::new(AtomicBool::new(cfg.wcp));
        let pipeline = Arc::new(AtomicBool::new(cfg.pipeline));
        let tenancy = Arc::new(SharedTenancy::new(&cfg.tenancy));
        let sched_incremental = Arc::new(AtomicBool::new(cfg.sched_incremental));
        let speculation = Arc::new(AtomicBool::new(cfg.speculation));
        let counters = Arc::new(crate::scheduler::stats::SchedCounters::new());
        // Residency watermark: the global value, with the last matching
        // per-kind override winning for engines of that kind.
        let kv_watermark_base = Arc::new(AtomicUsize::new(cfg.kv_watermark));
        let wm_for_kind = |kind: crate::engines::EngineKind| -> usize {
            cfg.kv_watermark_overrides
                .iter()
                .rev()
                .find(|(k, _)| *k == kind)
                .map(|(_, pct)| *pct as usize)
                .unwrap_or(cfg.kv_watermark)
        };
        let mut kv_watermarks: HashMap<String, Arc<AtomicUsize>> = HashMap::new();
        // Instances ack on this channel once their executor (including any
        // warm-up compilation) is constructed; start() blocks on all acks
        // so serving never races against compilation.
        let (ready_tx, ready_rx) = channel::<()>();
        let mut expected_ready = 0usize;

        let mut kv_tokens: HashMap<String, Arc<AtomicUsize>> = HashMap::new();
        let mut kv_defaults: HashMap<String, usize> = HashMap::new();
        let sched_tenancy = tenancy.clone();
        let sched_incremental_h = sched_incremental.clone();
        let sched_counters = counters.clone();
        let mut spawn_sched = |name: String,
                               instances: Vec<crate::engines::instance::Instance>,
                               event_rx,
                               max_slots: usize,
                               kv: Arc<AtomicUsize>,
                               wm: Arc<AtomicUsize>,
                               mode: ExecMode| {
            let (job_tx, job_rx) = channel::<QueueItem>();
            let slot_handle = Arc::new(AtomicUsize::new(max_slots));
            let sched = EngineScheduler::new(
                name.clone(),
                instances,
                event_rx,
                job_rx,
                policy.clone(),
                slot_handle.clone(),
                continuous.clone(),
                batch_window_us.clone(),
                prefix_slots.clone(),
                wcp.clone(),
                kv,
                wm,
                mode,
                sched_tenancy.clone(),
                sched_incremental_h.clone(),
                sched_counters.clone(),
            );
            let h = std::thread::Builder::new()
                .name(format!("sched-{name}"))
                .spawn(move || sched.run())
                .expect("spawn scheduler");
            slots.insert(name.clone(), slot_handle);
            routers.insert(name, job_tx);
            sched_handles.push(h);
        };
        // Non-LLM engines are row-denominated for good (no KV cache to
        // budget): their schedulers get a pinned zero handle.
        let row_mode = Arc::new(AtomicUsize::new(0));

        for spec in &cfg.llms {
            // Token-denominated KV budget: explicit, or derived as
            // `max_slots x` the variant's profiled max sequence length —
            // the budget a fully packed row-slot batch of maximal
            // sequences would need, so the default is backward-shaped.
            let derived = spec.max_slots
                * manifest.models.get(&spec.name).map(|m| m.max_seq).unwrap_or(256);
            let budget = cfg.kv_tokens_per_instance.unwrap_or(derived);
            let kv = Arc::new(AtomicUsize::new(budget));
            kv_tokens.insert(spec.name.clone(), kv.clone());
            kv_defaults.insert(spec.name.clone(), derived);
            let wm = Arc::new(AtomicUsize::new(wm_for_kind(crate::engines::EngineKind::Llm)));
            kv_watermarks.insert(spec.name.clone(), wm.clone());
            let (free_tx, free_rx) = channel();
            let (instances, _store) = llm::spawn_llm_engine(
                manifest.clone(),
                &spec.name,
                spec.instances,
                cfg.warm,
                cfg.backend,
                free_tx,
                ready_tx.clone(),
                prefix_slots.clone(),
                kv.clone(),
                wm.clone(),
                tenancy.clone(),
            );
            expected_ready += instances.len();
            spawn_sched(
                spec.name.clone(),
                instances,
                free_rx,
                spec.max_slots,
                kv,
                wm,
                ExecMode::Stepped,
            );
        }
        {
            let (free_tx, free_rx) = channel();
            let instances = spawn_embedding_engine(
                manifest.clone(),
                &cfg.embedder.name,
                cfg.embedder.instances,
                cfg.warm,
                cfg.backend,
                free_tx,
                ready_tx.clone(),
            );
            expected_ready += instances.len();
            spawn_sched(
                cfg.embedder.name.clone(),
                instances,
                free_rx,
                cfg.embedder.max_slots,
                row_mode.clone(),
                kv_watermark_base.clone(),
                ExecMode::FullBatch,
            );
        }
        {
            let (free_tx, free_rx) = channel();
            let instances = spawn_reranker_engine(
                manifest.clone(),
                &cfg.reranker.name,
                cfg.reranker.instances,
                cfg.warm,
                cfg.backend,
                free_tx,
                ready_tx.clone(),
            );
            expected_ready += instances.len();
            spawn_sched(
                cfg.reranker.name.clone(),
                instances,
                free_rx,
                cfg.reranker.max_slots,
                row_mode.clone(),
                kv_watermark_base.clone(),
                ExecMode::FullBatch,
            );
        }
        {
            let (free_tx, free_rx) = channel();
            let (instances, _store) =
                vector_db::spawn_vector_db(cfg.vdb_instances, free_tx, ready_tx.clone());
            expected_ready += instances.len();
            spawn_sched(
                "vdb".into(),
                instances,
                free_rx,
                64,
                row_mode.clone(),
                kv_watermark_base.clone(),
                ExecMode::FullBatch,
            );
        }
        let corpus = Arc::new(Corpus::synthetic(cfg.corpus_docs, 48, manifest.vocab.max(64), 11));
        {
            let (free_tx, free_rx) = channel();
            let instances = search::spawn_search_engine(
                corpus.clone(),
                cfg.net,
                cfg.web_instances,
                free_tx,
                ready_tx.clone(),
            );
            expected_ready += instances.len();
            spawn_sched(
                "web".into(),
                instances,
                free_rx,
                16,
                row_mode.clone(),
                kv_watermark_base.clone(),
                ExecMode::FullBatch,
            );
        }
        {
            let (free_tx, free_rx) = channel();
            let instances = search::spawn_search_engine(
                corpus,
                NetModel { base_us: 20_000, per_result_us: 0, jitter: 0.2 },
                cfg.tool_instances,
                free_tx,
                ready_tx.clone(),
            );
            expected_ready += instances.len();
            spawn_sched(
                "tool".into(),
                instances,
                free_rx,
                16,
                row_mode.clone(),
                kv_watermark_base.clone(),
                ExecMode::FullBatch,
            );
        }

        // Block until every instance finished executor construction
        // (incl. warm-up compiles) so serving starts on a quiet machine.
        drop(ready_tx);
        for _ in 0..expected_ready {
            let _ = ready_rx.recv();
        }

        let sep = manifest.special.sep;
        Ok(Platform {
            routers,
            sched_handles,
            policy,
            slots,
            continuous,
            batch_window_us,
            prefix_slots,
            wcp,
            kv_tokens,
            kv_defaults,
            kv_watermarks,
            kv_watermark_base,
            pipeline,
            tenancy,
            sched_incremental,
            speculation,
            spec_threshold: cfg.spec_threshold,
            counters,
            profiles,
            manifest,
            sep,
        })
    }

    /// Switch every engine scheduler's batching policy at runtime (bench
    /// harnesses flip this per scheme without re-warming the engines).
    pub fn set_policy(&self, p: BatchPolicy) {
        self.policy.store(p.to_u8(), Ordering::Relaxed);
    }

    /// Toggle iteration-level continuous batching on the stepped (LLM)
    /// engines at runtime; off means every engine uses the legacy
    /// run-to-completion dispatch path.
    pub fn set_continuous(&self, on: bool) {
        self.continuous.store(on, Ordering::Relaxed);
    }

    /// Retune the dynamic-batching accumulation window at runtime
    /// (microseconds; applies to every engine scheduler).
    pub fn set_batch_window_us(&self, us: u64) {
        self.batch_window_us.store(us, Ordering::Relaxed);
    }

    /// Retune the per-instance resident-prefix budget at runtime (0
    /// disables cross-query KV prefix routing and caching; applies to the
    /// LLM engine schedulers and their executors' registries at once).
    pub fn set_prefix_slots(&self, n: usize) {
        self.prefix_slots.store(n, Ordering::Relaxed);
    }

    /// Toggle weighted-critical-path bucket ordering at runtime (applies
    /// to every engine scheduler; only effective under `TopoAware`).
    pub fn set_wcp(&self, on: bool) {
        self.wcp.store(on, Ordering::Relaxed);
    }

    /// Toggle incremental scheduler priority maintenance at runtime
    /// (applies to every engine scheduler; `false` forces the exact
    /// rebuild-and-sort fallback — output-identical, more work).
    pub fn set_sched_incremental(&self, on: bool) {
        self.sched_incremental.store(on, Ordering::Relaxed);
    }

    /// Retune the per-instance KV token budget on every LLM engine at
    /// runtime: `Some(0)` falls back to legacy row-slot accounting,
    /// `Some(n)` sets an explicit token budget, `None` restores each
    /// engine's derived default (`max_slots x profile max_seq`).  The
    /// handles are shared with the executors' admission ledgers, so the
    /// retune applies to scheduling and admission at once.
    pub fn set_kv_tokens(&self, budget: Option<usize>) {
        for (name, h) in &self.kv_tokens {
            let v = budget.unwrap_or_else(|| self.kv_defaults.get(name).copied().unwrap_or(0));
            h.store(v, Ordering::Relaxed);
        }
    }

    /// Retune the persistent-residency watermark at runtime (percent of
    /// each LLM instance's KV token budget; 0 switches residency off and
    /// restores PR5 release-at-retirement semantics).  Writes through to
    /// every per-engine handle (clearing any per-engine override); the
    /// handles are shared by the LLM engine schedulers and their
    /// executors, so the flip applies to dispatch charging, admission
    /// and eviction at once.
    pub fn set_kv_watermark(&self, pct: usize) {
        self.kv_watermark_base.store(pct, Ordering::Relaxed);
        for h in self.kv_watermarks.values() {
            h.store(pct, Ordering::Relaxed);
        }
    }

    /// Current global persistent-residency watermark (percent; 0 = off).
    /// Per-engine overrides may diverge — see
    /// [`Platform::kv_watermark_of`].
    pub fn kv_watermark(&self) -> usize {
        self.kv_watermark_base.load(Ordering::Relaxed)
    }

    /// Retune one LLM engine's residency watermark at runtime without
    /// touching the others; no-op (returns false) for engines without a
    /// watermark handle (the encoders etc.).
    pub fn set_kv_watermark_of(&self, engine: &str, pct: usize) -> bool {
        match self.kv_watermarks.get(engine) {
            Some(h) => {
                h.store(pct, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Current residency watermark of one LLM engine.
    pub fn kv_watermark_of(&self, engine: &str) -> Option<usize> {
        self.kv_watermarks.get(engine).map(|h| h.load(Ordering::Relaxed))
    }

    /// Snapshot the global watermark plus every per-engine value, so a
    /// comparison harness that pins the knob can restore the caller's
    /// exact configuration — including per-engine overrides — afterward.
    pub fn kv_watermark_snapshot(&self) -> (usize, Vec<(String, usize)>) {
        (
            self.kv_watermark_base.load(Ordering::Relaxed),
            self.kv_watermarks
                .iter()
                .map(|(name, h)| (name.clone(), h.load(Ordering::Relaxed)))
                .collect(),
        )
    }

    /// Restore watermarks captured by [`Platform::kv_watermark_snapshot`].
    pub fn restore_kv_watermarks(&self, snapshot: &(usize, Vec<(String, usize)>)) {
        self.kv_watermark_base.store(snapshot.0, Ordering::Relaxed);
        for (name, v) in &snapshot.1 {
            if let Some(h) = self.kv_watermarks.get(name) {
                h.store(*v, Ordering::Relaxed);
            }
        }
    }

    /// Toggle cross-engine pipelining at runtime (direct successor
    /// handoff + speculative template prefill; only effective under
    /// `TopoAware`).  Runners snapshot the flag at construction, so the
    /// flip applies to queries started after the call.
    pub fn set_pipeline(&self, on: bool) {
        self.pipeline.store(on, Ordering::Relaxed);
    }

    /// Whether cross-engine pipelining is currently requested (the
    /// effective state also requires the `TopoAware` policy).
    pub fn pipeline(&self) -> bool {
        self.pipeline.load(Ordering::Relaxed)
    }

    /// Toggle speculative branch dispatch at runtime (only effective
    /// under `TopoAware`).  Runners snapshot the flag at construction, so
    /// the flip applies to queries started after the call.
    pub fn set_speculation(&self, on: bool) {
        self.speculation.store(on, Ordering::Relaxed);
    }

    /// Whether speculative branch dispatch is currently requested (the
    /// effective state also requires the `TopoAware` policy).
    pub fn speculation(&self) -> bool {
        self.speculation.load(Ordering::Relaxed)
    }

    /// This platform's hot-path counter sink (sched/graph counters for
    /// its engine schedulers and query runners).
    pub fn counters(&self) -> Arc<crate::scheduler::stats::SchedCounters> {
        self.counters.clone()
    }

    /// Reconfigure multi-tenant QoS at runtime: replaces the tenant
    /// registry (weights, SLO classes, KV quotas) and flips fair queueing
    /// + admission control on or off.  The handle is shared by every
    /// engine scheduler and LLM executor, so the change applies to
    /// dispatch ordering, shedding and eviction at once.  Like the other
    /// PR knobs it is only effective under `TopoAware`; disabled, the
    /// dispatch path is bit-for-bit the single-tenant one.
    pub fn set_tenancy(&self, cfg: &TenancyConfig) {
        self.tenancy.configure(cfg);
    }

    /// Whether multi-tenant QoS is currently enabled.
    pub fn tenancy_enabled(&self) -> bool {
        self.tenancy.enabled()
    }

    /// Snapshot the current tenancy configuration so a comparison harness
    /// that pins the knob can restore the caller's exact registry.
    pub fn tenancy_snapshot(&self) -> TenancyConfig {
        self.tenancy.snapshot()
    }

    /// Restore a configuration captured by [`Platform::tenancy_snapshot`].
    pub fn restore_tenancy(&self, snapshot: &TenancyConfig) {
        self.tenancy.configure(snapshot);
    }

    /// Current KV token budget of one LLM engine (None for engines
    /// without token accounting, e.g. the encoders).
    pub fn kv_tokens_of(&self, engine: &str) -> Option<usize> {
        self.kv_tokens.get(engine).map(|h| h.load(Ordering::Relaxed))
    }

    /// Snapshot every LLM engine's current KV token budget, so a
    /// comparison harness that pins the knob can restore the caller's
    /// configuration (derived or explicit) instead of clobbering it.
    pub fn kv_tokens_snapshot(&self) -> Vec<(String, usize)> {
        self.kv_tokens
            .iter()
            .map(|(name, h)| (name.clone(), h.load(Ordering::Relaxed)))
            .collect()
    }

    /// Restore budgets captured by [`Platform::kv_tokens_snapshot`].
    pub fn restore_kv_tokens(&self, snapshot: &[(String, usize)]) {
        for (name, v) in snapshot {
            if let Some(h) = self.kv_tokens.get(name) {
                h.store(*v, Ordering::Relaxed);
            }
        }
    }

    /// Retune one engine's slot budget (max batch rows) at runtime.
    pub fn set_engine_slots(&self, engine: &str, slots: usize) {
        if let Some(h) = self.slots.get(engine) {
            h.store(slots.max(1), Ordering::Relaxed);
        }
    }

    /// Routing table clone for query runners.
    pub fn routers(&self) -> HashMap<String, Sender<QueueItem>> {
        self.routers.clone()
    }

    /// Effective pipelining state for runners constructed now: the flag
    /// is on AND the batching policy is `TopoAware` (the baselines keep
    /// the classic dispatch loop, mirroring the other PR knobs).
    fn pipeline_effective(&self) -> bool {
        self.pipeline.load(Ordering::Relaxed)
            && BatchPolicy::from_u8(self.policy.load(Ordering::Relaxed))
                == BatchPolicy::TopoAware
    }

    /// Effective speculation state for runners constructed now: the flag
    /// is on AND the batching policy is `TopoAware` (baselines keep the
    /// classic guard-blocking dispatch loop).
    fn speculation_effective(&self) -> bool {
        self.speculation.load(Ordering::Relaxed)
            && BatchPolicy::from_u8(self.policy.load(Ordering::Relaxed))
                == BatchPolicy::TopoAware
    }

    /// Execute one query's e-graph synchronously on the calling thread.
    pub fn run_query(&self, query: QueryId, egraph: EGraph) -> Result<(Value, QueryMetrics)> {
        let runner = QueryRunner::new(query, egraph, self.routers(), self.sep)
            .with_pipeline(self.pipeline_effective())
            .with_speculation(self.speculation_effective(), self.spec_threshold)
            .with_counters(self.counters.clone());
        let t0 = Instant::now();
        let (v, mut m) = runner.run()?;
        m.e2e_us = t0.elapsed().as_micros() as u64;
        Ok((v, m))
    }

    /// Spawn a query on its own thread (the paper's per-query scheduling
    /// thread); join the handle for the result.
    pub fn spawn_query(
        &self,
        query: QueryId,
        egraph: EGraph,
    ) -> JoinHandle<Result<(Value, QueryMetrics)>> {
        self.spawn_query_as(query, egraph, UNTENANTED)
    }

    /// Spawn a query stamped with a tenant identity: every job the runner
    /// dispatches (including requeues and pipelined successor handoffs)
    /// carries the tenant through the engine schedulers' fair-queueing,
    /// admission-control and KV-quota paths.  With tenancy disabled the
    /// stamp is inert.
    pub fn spawn_query_as(
        &self,
        query: QueryId,
        egraph: EGraph,
        tenant: TenantId,
    ) -> JoinHandle<Result<(Value, QueryMetrics)>> {
        let routers = self.routers();
        let sep = self.sep;
        let pipeline = self.pipeline_effective();
        let speculate = self.speculation_effective();
        let spec_threshold = self.spec_threshold;
        let counters = self.counters.clone();
        std::thread::Builder::new()
            .name(format!("query-{query}"))
            .spawn(move || {
                let runner = QueryRunner::new(query, egraph, routers, sep)
                    .with_pipeline(pipeline)
                    .with_speculation(speculate, spec_threshold)
                    .with_counters(counters)
                    .with_tenant(tenant);
                let t0 = Instant::now();
                let (v, mut m) = runner.run()?;
                m.e2e_us = t0.elapsed().as_micros() as u64;
                Ok((v, m))
            })
            .expect("spawn query thread")
    }

    /// Graceful shutdown: drop queues and join scheduler threads.
    pub fn shutdown(self) {
        drop(self.routers);
        for h in self.sched_handles {
            let _ = h.join();
        }
    }
}
