//! Weighted critical-path (WCP) estimation (paper §8, "exploitation of
//! critical path"): per-query remaining critical-path *device time*.
//!
//! Algorithm 2 orders query buckets by arrival; the §8 discussion argues
//! engine slots should instead go to the query whose *remaining workflow*
//! is longest — its critical path lower-bounds its completion time, so
//! delaying it delays the application tail one-for-one, while short-tail
//! queries can catch up in the parallel slack.  The graph scheduler builds
//! a [`WcpTracker`] per query: every node gets a `DeviceModel`-weighted
//! cost estimate, `path_us[v]` is the longest cost-weighted path from `v`
//! to the sink, and the query's remaining critical path is the maximum
//! `path_us` over incomplete nodes — recomputed incrementally as nodes
//! complete (a child can never finish before its parents, so an
//! incomplete node's entire downstream path is still outstanding and the
//! static `path_us` stays exact).
//!
//! The tracker's `remaining_us()` is stamped onto every dispatched
//! [`crate::scheduler::batching::QueueItem`]; the engine schedulers order
//! query buckets by it (descending, with an aging term — see
//! `batching::wcp_priority_us`) when the `wcp` knob is on.
//!
//! **Measured-latency feedback**: the static estimates are built from
//! the `DeviceModel` cost surface with coarse fallbacks for
//! runtime-sized inputs, so they drift from what the machine actually
//! delivers.  Every engine completion feeds its measured `ExecTiming`
//! back through [`observe_latency`], which keeps a per-(engine,
//! op-class) EWMA of the measured/static ratio; [`node_cost_us`]
//! multiplies the static estimate by that clamped correction factor, so
//! later queries' critical-path weights track observed latencies.  The
//! correction only re-weights cross-query comparisons — it is never
//! charged anywhere — and a tracker snapshots its costs at build time,
//! so the monotone non-increasing invariant is unaffected.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::engines::profile::DeviceModel;
use crate::engines::NodeId;
use crate::graph::egraph::EGraph;
use crate::graph::primitive::{DataRef, PayloadSpec, Primitive};

/// Token estimate for a prompt part whose rows are produced at runtime
/// (upstream node outputs are unknown at graph-build time).
const FALLBACK_PART_TOKENS: usize = 24;
/// Row estimate for an encoder input of unknown (runtime) cardinality.
const FALLBACK_ROWS: usize = 8;
/// Host-side service calls (vector DB ops) — cheap but not free.
const VDB_COST_US: u64 = 2_000;
/// Web search carries the simulated network envelope (`NetModel` base).
const WEB_COST_US: u64 = 35_000;
/// KV prefix clone: host-side copy, far below a prefill.
const CLONE_COST_US: u64 = 500;

fn part_tokens(r: &DataRef) -> usize {
    match r {
        DataRef::Const(rows) => rows.iter().map(|row| row.len()).sum(),
        DataRef::Node(_) | DataRef::NodeSlice(_, _, _) => FALLBACK_PART_TOKENS,
    }
}

fn part_rows(r: &DataRef) -> usize {
    r.static_rows().unwrap_or(FALLBACK_ROWS)
}

/// EWMA smoothing factor of the measured-latency feedback.
const EWMA_ALPHA: f64 = 0.2;
/// Correction-factor clamp: measured `exec_us` is the *batched* call
/// time shared by every row of the call (and falls back to residency
/// time for streamed jobs), so single samples can swing wildly; the
/// clamp keeps one outlier from inverting cross-query comparisons.
const CORRECTION_MIN: f64 = 0.25;
const CORRECTION_MAX: f64 = 4.0;

/// Per-(engine, op-class) EWMA of measured/static latency ratios.
/// Process-global: every query runner feeds it and every later
/// `WcpTracker` build reads it (a Mutex'd map — completions are rare
/// relative to scheduling work).
static FEEDBACK: Mutex<Option<HashMap<(String, &'static str), f64>>> = Mutex::new(None);

/// Op-class of a primitive for the latency feedback ("prefill",
/// "decode", "encoder", "service"; host-evaluated primitives are "host"
/// and never observed).
pub fn cost_class(node: &Primitive) -> &'static str {
    match &node.payload {
        PayloadSpec::Prefill { .. } => "prefill",
        PayloadSpec::Decode { .. } => "decode",
        PayloadSpec::Embed { .. } | PayloadSpec::Rerank { .. } => "encoder",
        PayloadSpec::Ingest { .. }
        | PayloadSpec::VectorSearch { .. }
        | PayloadSpec::WebSearch { .. }
        | PayloadSpec::Tool { .. }
        | PayloadSpec::ClonePrefix { .. } => "service",
        PayloadSpec::Condition { .. }
        | PayloadSpec::Aggregate { .. }
        | PayloadSpec::Expand { .. }
        | PayloadSpec::PartialDecode { .. } => "host",
    }
}

/// Feed one measured engine latency into the per-(engine, class) EWMA.
/// Zero measurements and zero static estimates are ignored (nothing to
/// correct against).
pub fn observe_latency(node: &Primitive, measured_us: u64) {
    let static_us = static_node_cost_us(node);
    if static_us == 0 || measured_us == 0 {
        return;
    }
    let ratio =
        (measured_us as f64 / static_us as f64).clamp(CORRECTION_MIN, CORRECTION_MAX);
    let mut guard = FEEDBACK.lock().unwrap();
    let map = guard.get_or_insert_with(HashMap::new);
    let entry = map
        .entry((node.engine.clone(), cost_class(node)))
        .or_insert(1.0);
    *entry += EWMA_ALPHA * (ratio - *entry);
}

/// Current correction factor for an (engine, op-class); 1.0 until
/// observations arrive.  The map holds a handful of (engine, class)
/// pairs, so a borrowed linear scan beats hashing an allocated
/// `String` key on this per-node hot path (`WcpTracker::new` calls it
/// once per primitive at every query start).
pub fn latency_correction(engine: &str, class: &'static str) -> f64 {
    let guard = FEEDBACK.lock().unwrap();
    let Some(map) = guard.as_ref() else { return 1.0 };
    map.iter()
        .find(|((e, c), _)| e == engine && *c == class)
        .map(|(_, v)| *v)
        .unwrap_or(1.0)
        .clamp(CORRECTION_MIN, CORRECTION_MAX)
}

/// Drop every latency observation, returning all corrections to 1.0.
/// The comparison harnesses (`run_wcp_comparison`, `run_kv_comparison`)
/// call this before each half so the 'off' half's observations cannot
/// train estimates only the 'on' half reads — each experiment varies
/// exactly one knob, and seeded replays stay order-independent.
pub fn reset_latency_feedback() {
    let mut guard = FEEDBACK.lock().unwrap();
    *guard = None;
}

/// `DeviceModel`-weighted cost estimate of one primitive node,
/// microseconds, corrected by the measured-latency EWMA for the node's
/// (engine, op-class).  Estimates only need to be *relatively* right —
/// they weigh critical-path comparisons across queries, they are never
/// charged anywhere.
pub fn node_cost_us(node: &Primitive) -> u64 {
    let stat = static_node_cost_us(node);
    if stat == 0 {
        return 0;
    }
    (stat as f64 * latency_correction(&node.engine, cost_class(node))) as u64
}

/// Static (build-time) cost estimate of one primitive node,
/// microseconds, straight from the `DeviceModel` cost surface with
/// coarse fallbacks for runtime-unknown inputs.
pub fn static_node_cost_us(node: &Primitive) -> u64 {
    match &node.payload {
        PayloadSpec::Prefill { parts, .. } => {
            let dm = DeviceModel::for_engine(&node.engine);
            let tokens: usize = parts.iter().map(part_tokens).sum();
            dm.prefill_us(1, tokens.max(1))
        }
        PayloadSpec::Decode { segments, .. } => {
            let dm = DeviceModel::for_engine(&node.engine);
            let planned: usize = segments.iter().map(|(_, l)| *l).sum();
            dm.decode_step_us(1).saturating_mul(planned.max(1) as u64)
        }
        PayloadSpec::Embed { sources } => {
            let dm = DeviceModel::for_engine(&node.engine);
            dm.encoder_us(sources.iter().map(part_rows).sum::<usize>().max(1))
        }
        PayloadSpec::Rerank { candidates, .. } => {
            let dm = DeviceModel::for_engine(&node.engine);
            dm.encoder_us(candidates.iter().map(part_rows).sum::<usize>().max(1))
        }
        PayloadSpec::Ingest { .. } | PayloadSpec::VectorSearch { .. } => VDB_COST_US,
        PayloadSpec::WebSearch { .. } => WEB_COST_US,
        PayloadSpec::Tool { cost_us, .. } => *cost_us,
        PayloadSpec::ClonePrefix { .. } => CLONE_COST_US,
        // Host-side control flow is evaluated inline by the graph
        // scheduler; partial-decode markers complete from a stream the
        // decode node already pays for.
        // Runtime fan-out: the spawned tool subgraph is unknown at build
        // time; one tool invocation is the lower bound (the tracker's
        // `grow` folds the real fan-out in once it materializes).
        PayloadSpec::Expand { cost_us, .. } => *cost_us,
        // Host-side control flow is evaluated inline by the graph
        // scheduler; partial-decode markers complete from a stream the
        // decode node already pays for.
        PayloadSpec::Condition { .. }
        | PayloadSpec::Aggregate { .. }
        | PayloadSpec::PartialDecode { .. } => 0,
    }
}

/// Per-query remaining-critical-path tracker.
///
/// Invariant (see `tests/prop_invariants.rs`): `remaining_us()` is
/// monotonically non-increasing as nodes complete, and reaches 0 when all
/// nodes have.  Guard resolution ([`WcpTracker::resolve_guard`]) and
/// runtime graph growth ([`WcpTracker::grow`]) sit *outside* that
/// invariant: a confirmed guard restores a probability-discounted
/// subpath to full weight and growth adds new work, so both may raise
/// the estimate — the graph scheduler restamps queued items through
/// `RestampWcp` when they do.
#[derive(Debug)]
pub struct WcpTracker {
    /// Longest effective-cost-weighted path from node v to the sink
    /// (includes v's own cost).  Recomputed on guard resolution and
    /// growth; between those events completion order cannot change it
    /// because no descendant of an incomplete node can be complete.
    path_us: Vec<u64>,
    /// Snapshot of each node's own cost estimate, taken when the node
    /// entered the tracker (EWMA corrections observed later re-weight
    /// *later* queries, never a live tracker).
    base_cost: Vec<u64>,
    /// Each node's guard, mirrored from the primitives.
    guard: Vec<Option<(NodeId, bool)>>,
    /// Probability the node's guard passes (`prob_true` of the guarding
    /// condition, or its complement for `want == false`; 1.0 unguarded).
    guard_prob: Vec<f64>,
    /// Forward edges, mirrored so resolution/growth can recompute paths
    /// without holding the e-graph.
    children: Vec<Vec<NodeId>>,
    /// Cached topological order of the mirrored graph.
    order: Vec<NodeId>,
    /// Resolved condition outcomes (`resolve_guard`).
    resolved: HashMap<NodeId, bool>,
    /// Probability-weighted mode (PR10, speculation on): unresolved
    /// guarded subpaths count at `guard_prob` weight instead of full
    /// cost.  Off = the pre-PR10 pessimistic upper bound, bit-identical.
    weighted: bool,
    done: Vec<bool>,
    remaining: u64,
}

impl WcpTracker {
    /// Estimate paths over an e-graph (one pass in reverse topo order),
    /// in the classic pessimistic mode: guarded subpaths count at full
    /// cost until [`WcpTracker::resolve_guard`] prunes a refuted branch.
    pub fn new(egraph: &EGraph) -> WcpTracker {
        WcpTracker::build(egraph, false)
    }

    /// Probability-weighted variant (speculation on): an unresolved
    /// guarded subpath counts at its guard's pass probability, so a
    /// 10%-likely expensive branch no longer dominates the query's rank.
    pub fn new_weighted(egraph: &EGraph) -> WcpTracker {
        WcpTracker::build(egraph, true)
    }

    fn build(egraph: &EGraph, weighted: bool) -> WcpTracker {
        let n = egraph.len();
        let mut w = WcpTracker {
            path_us: vec![0u64; n],
            base_cost: (0..n).map(|v| node_cost_us(&egraph.graph.nodes[v])).collect(),
            guard: (0..n).map(|v| egraph.graph.nodes[v].guard).collect(),
            guard_prob: vec![1.0; n],
            children: egraph.children.clone(),
            order: egraph.graph.topo_order().unwrap_or_default(),
            resolved: HashMap::new(),
            weighted,
            done: vec![false; n],
            remaining: 0,
        };
        for v in 0..n {
            w.guard_prob[v] = guard_pass_prob(egraph, w.guard[v]);
        }
        w.recompute();
        w
    }

    /// Effective own-cost of node `v` under the current guard knowledge:
    /// full cost when unguarded or confirmed, zero when refuted, and —
    /// in weighted mode — probability-scaled while unresolved.
    fn effective_cost(&self, v: NodeId) -> u64 {
        match self.guard[v] {
            None => self.base_cost[v],
            Some((g, want)) => match self.resolved.get(&g) {
                Some(&outcome) if outcome == want => self.base_cost[v],
                Some(_) => 0,
                None if self.weighted => {
                    (self.base_cost[v] as f64 * self.guard_prob[v]) as u64
                }
                None => self.base_cost[v],
            },
        }
    }

    /// Full reverse-topo path recomputation; sets `remaining` to the
    /// incomplete frontier (no monotone clamp — callers that must not
    /// raise the estimate clamp themselves, as `complete` does).
    fn recompute(&mut self) {
        for i in (0..self.order.len()).rev() {
            let v = self.order[i];
            let downstream =
                self.children[v].iter().map(|&c| self.path_us[c]).max().unwrap_or(0);
            self.path_us[v] = self.effective_cost(v).saturating_add(downstream);
        }
        self.remaining = self.frontier();
    }

    fn frontier(&self) -> u64 {
        self.path_us
            .iter()
            .zip(&self.done)
            .filter(|(_, d)| !**d)
            .map(|(p, _)| *p)
            .max()
            .unwrap_or(0)
    }

    /// Remaining critical-path device time of the query, microseconds.
    pub fn remaining_us(&self) -> u64 {
        self.remaining
    }

    /// Static root-to-sink path estimate through `v`.
    pub fn path_us(&self, v: NodeId) -> u64 {
        self.path_us.get(v).copied().unwrap_or(0)
    }

    /// Mark a node complete and refresh the remaining-path estimate.
    /// Idempotent; clamped so the estimate never increases.
    pub fn complete(&mut self, v: NodeId) {
        if v >= self.done.len() || self.done[v] {
            return;
        }
        self.done[v] = true;
        let frontier = self.frontier();
        self.remaining = self.remaining.min(frontier);
    }

    /// Fold a condition's resolved outcome into the path estimates: the
    /// refuted branch's cost is pruned the moment the guard resolves,
    /// and (weighted mode) the confirmed branch's discount is lifted —
    /// so this is the one completion-adjacent event that may *raise*
    /// `remaining_us()`.  Returns the new estimate so the caller can
    /// restamp queued items.
    pub fn resolve_guard(&mut self, cond: NodeId, outcome: bool) -> u64 {
        self.resolved.insert(cond, outcome);
        self.recompute();
        self.remaining
    }

    /// Absorb runtime graph growth: the e-graph appended nodes (and may
    /// have given existing nodes new children).  Existing nodes keep
    /// their snapshot costs and completion state; new nodes enter at
    /// their current cost estimate.  `remaining_us()` typically rises —
    /// new work exists — and the caller restamps queued items.
    pub fn grow(&mut self, egraph: &EGraph) -> u64 {
        let old = self.base_cost.len();
        let n = egraph.len();
        for v in old..n {
            self.base_cost.push(node_cost_us(&egraph.graph.nodes[v]));
            self.guard.push(egraph.graph.nodes[v].guard);
            self.guard_prob.push(guard_pass_prob(egraph, egraph.graph.nodes[v].guard));
            self.done.push(false);
            self.path_us.push(0);
        }
        self.children = egraph.children.clone();
        self.order = egraph.graph.topo_order().unwrap_or_default();
        self.recompute();
        self.remaining
    }
}

/// Probability that `guard` passes, from the guarding condition's
/// `prob_true` (complemented for `want == false`); 1.0 when unguarded
/// or the guard is not a condition node.
pub fn guard_pass_prob(egraph: &EGraph, guard: Option<(NodeId, bool)>) -> f64 {
    let Some((g, want)) = guard else { return 1.0 };
    match egraph.graph.nodes.get(g).map(|n| &n.payload) {
        Some(PayloadSpec::Condition { prob_true, .. }) => {
            let p = prob_true.clamp(0.0, 1.0);
            if want {
                p
            } else {
                1.0 - p
            }
        }
        _ => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::pgraph::{build_pgraph, instr_tokens};
    use crate::graph::template::*;

    fn one_shot_egraph_on(variant: &str, out_tokens: usize) -> EGraph {
        let mut t = WorkflowTemplate::new("wcp");
        t.add(Component {
            name: "gen".into(),
            kind: ComponentKind::LlmGenerate {
                variant: variant.into(),
                mode: SynthesisMode::OneShot,
                prompt: vec![
                    PromptPart::Instruction(instr_tokens("i", 16)),
                    PromptPart::Question,
                ],
                out_tokens,
                segments: 1,
                fan: 0,
            },
            engine: variant.into(),
            batchable: false,
            splittable: false,
        });
        let q = QueryConfig::example(5);
        EGraph::new(build_pgraph(&t, &q).unwrap()).unwrap()
    }

    fn one_shot_egraph(out_tokens: usize) -> EGraph {
        one_shot_egraph_on("llm-lite", out_tokens)
    }

    #[test]
    fn longer_decode_means_longer_path() {
        let short = WcpTracker::new(&one_shot_egraph(8));
        let long = WcpTracker::new(&one_shot_egraph(96));
        assert!(short.remaining_us() > 0);
        assert!(
            long.remaining_us() > short.remaining_us(),
            "96-token tail {} must out-weigh 8-token tail {}",
            long.remaining_us(),
            short.remaining_us()
        );
    }

    #[test]
    fn remaining_shrinks_as_nodes_complete_and_ends_at_zero() {
        let e = one_shot_egraph(8);
        let mut w = WcpTracker::new(&e);
        let order = e.graph.topo_order().unwrap();
        let mut prev = w.remaining_us();
        for v in order {
            w.complete(v);
            assert!(w.remaining_us() <= prev, "remaining grew at node {v}");
            prev = w.remaining_us();
        }
        assert_eq!(w.remaining_us(), 0);
        // Idempotent on repeat completion.
        w.complete(0);
        assert_eq!(w.remaining_us(), 0);
    }

    #[test]
    fn latency_feedback_corrects_estimates() {
        // A dedicated engine name keeps this test's observations out of
        // the llm-lite estimates other tests compare.
        let e = one_shot_egraph_on("ewma-test-llm", 8);
        let decode = e
            .graph
            .nodes
            .iter()
            .find(|n| matches!(n.payload, PayloadSpec::Decode { .. }))
            .expect("one-shot workflow has a decode node");
        assert_eq!(cost_class(decode), "decode");
        assert_eq!(latency_correction("ewma-test-llm", "decode"), 1.0);
        let stat = static_node_cost_us(decode);
        assert!(stat > 0);
        assert_eq!(node_cost_us(decode), stat, "no observations -> no correction");

        // Consistently observing 2x the static estimate converges the
        // correction toward 2.0 and scales the estimate with it.
        for _ in 0..60 {
            observe_latency(decode, stat * 2);
        }
        let c = latency_correction("ewma-test-llm", "decode");
        assert!((1.8..=2.0).contains(&c), "EWMA converged to {c}");
        let corrected = node_cost_us(decode);
        assert!(
            corrected > stat * 17 / 10 && corrected <= stat * 2,
            "corrected {corrected} vs static {stat}"
        );

        // One absurd outlier is clamped, never inverting comparisons.
        observe_latency(decode, stat.saturating_mul(1_000));
        assert!(latency_correction("ewma-test-llm", "decode") <= 4.0);

        // Zero measurements are ignored (nothing to correct against).
        observe_latency(decode, 0);
        assert!(latency_correction("ewma-test-llm", "decode") >= 1.0);
    }

    #[test]
    fn source_path_covers_whole_chain() {
        let e = one_shot_egraph(8);
        let w = WcpTracker::new(&e);
        let src = e.sources()[0];
        assert_eq!(w.path_us(src), w.remaining_us());
        assert_eq!(w.path_us(usize::MAX), 0);
    }

    /// Search-gen e-graph (judge condition guarding the web branch) for
    /// the guard-resolution tests.
    fn guarded_egraph() -> (EGraph, NodeId) {
        let t = crate::apps::search_gen("llm-lite");
        let q = QueryConfig::example(7);
        let e = EGraph::new(build_pgraph(&t, &q).unwrap()).unwrap();
        let cond = e
            .graph
            .nodes
            .iter()
            .position(|n| matches!(n.payload, PayloadSpec::Condition { .. }))
            .expect("search-gen has a judge condition");
        (e, cond)
    }

    #[test]
    fn weighted_mode_discounts_unresolved_guarded_branch() {
        let (e, cond) = guarded_egraph();
        let classic = WcpTracker::new(&e);
        let mut weighted = WcpTracker::new_weighted(&e);
        // The guarded web branch sits on the critical path (its 35ms
        // network envelope dominates), so discounting it by the guard's
        // pass probability strictly lowers the unresolved estimate.
        assert!(
            weighted.remaining_us() < classic.remaining_us(),
            "weighted {} must undercut classic {} while the guard is open",
            weighted.remaining_us(),
            classic.remaining_us()
        );
        // Confirming the guard lifts the discount: the weighted estimate
        // rises back to exactly the classic post-confirmation value (the
        // two modes must agree once no probability mass is left).
        let before = weighted.remaining_us();
        let mut classic2 = WcpTracker::new(&e);
        let c_rem = classic2.resolve_guard(cond, true);
        let w_rem = weighted.resolve_guard(cond, true);
        assert_eq!(w_rem, c_rem, "modes must agree after resolution");
        assert!(w_rem >= before, "confirmation cannot lower the weighted estimate");
    }

    #[test]
    fn refuted_guard_prunes_branch_in_both_modes() {
        let (e, cond) = guarded_egraph();
        let mut classic = WcpTracker::new(&e);
        let mut weighted = WcpTracker::new_weighted(&e);
        let full = classic.remaining_us();
        let c_rem = classic.resolve_guard(cond, false);
        let w_rem = weighted.resolve_guard(cond, false);
        assert_eq!(w_rem, c_rem, "modes must agree after resolution");
        assert!(
            c_rem < full,
            "pruning the refuted web branch must shrink the path ({c_rem} vs {full})"
        );
    }

    #[test]
    fn grow_absorbs_appended_nodes_and_raises_remaining() {
        let mut e = one_shot_egraph(8);
        let mut w = WcpTracker::new(&e);
        let before = w.remaining_us();
        // Hang a tool call off the current sink, then a barrier join —
        // the shape expand_node() appends at runtime.
        let sink = e.len() - 1;
        let blank = |kind, payload, engine: &str, hard: Vec<usize>| crate::graph::primitive::Primitive {
            id: 0,
            kind,
            engine: engine.into(),
            component: 0,
            batchable: true,
            splittable: false,
            payload,
            hard_deps: hard,
            guard: None,
        };
        let base = e.len();
        let ids = e
            .append(vec![
                blank(
                    crate::graph::primitive::PrimKind::ToolCalling,
                    PayloadSpec::Tool { name: "call_api#0".into(), cost_us: 50_000 },
                    "tool",
                    vec![sink],
                ),
                blank(
                    crate::graph::primitive::PrimKind::Aggregate,
                    PayloadSpec::Aggregate {
                        parts: vec![DataRef::Node(base)],
                        mode: crate::graph::primitive::AggregateMode::Barrier,
                    },
                    "",
                    Vec::new(),
                ),
            ])
            .unwrap();
        assert_eq!(ids, vec![base, base + 1]);
        let after = w.grow(&e);
        assert!(
            after > before,
            "50ms of appended tool work must raise the estimate ({after} vs {before})"
        );
        assert!(w.path_us(base) >= 50_000);
        // Completing everything still drains to zero over the grown graph.
        for v in e.graph.topo_order().unwrap() {
            w.complete(v);
        }
        assert_eq!(w.remaining_us(), 0);
    }
}
