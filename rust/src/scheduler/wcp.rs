//! Weighted critical-path (WCP) estimation (paper §8, "exploitation of
//! critical path"): per-query remaining critical-path *device time*.
//!
//! Algorithm 2 orders query buckets by arrival; the §8 discussion argues
//! engine slots should instead go to the query whose *remaining workflow*
//! is longest — its critical path lower-bounds its completion time, so
//! delaying it delays the application tail one-for-one, while short-tail
//! queries can catch up in the parallel slack.  The graph scheduler builds
//! a [`WcpTracker`] per query: every node gets a `DeviceModel`-weighted
//! cost estimate, `path_us[v]` is the longest cost-weighted path from `v`
//! to the sink, and the query's remaining critical path is the maximum
//! `path_us` over incomplete nodes — recomputed incrementally as nodes
//! complete (a child can never finish before its parents, so an
//! incomplete node's entire downstream path is still outstanding and the
//! static `path_us` stays exact).
//!
//! The tracker's `remaining_us()` is stamped onto every dispatched
//! [`crate::scheduler::batching::QueueItem`]; the engine schedulers order
//! query buckets by it (descending, with an aging term — see
//! `batching::wcp_priority_us`) when the `wcp` knob is on.

use crate::engines::profile::DeviceModel;
use crate::engines::NodeId;
use crate::graph::egraph::EGraph;
use crate::graph::primitive::{DataRef, PayloadSpec, Primitive};

/// Token estimate for a prompt part whose rows are produced at runtime
/// (upstream node outputs are unknown at graph-build time).
const FALLBACK_PART_TOKENS: usize = 24;
/// Row estimate for an encoder input of unknown (runtime) cardinality.
const FALLBACK_ROWS: usize = 8;
/// Host-side service calls (vector DB ops) — cheap but not free.
const VDB_COST_US: u64 = 2_000;
/// Web search carries the simulated network envelope (`NetModel` base).
const WEB_COST_US: u64 = 35_000;
/// KV prefix clone: host-side copy, far below a prefill.
const CLONE_COST_US: u64 = 500;

fn part_tokens(r: &DataRef) -> usize {
    match r {
        DataRef::Const(rows) => rows.iter().map(|row| row.len()).sum(),
        DataRef::Node(_) | DataRef::NodeSlice(_, _, _) => FALLBACK_PART_TOKENS,
    }
}

fn part_rows(r: &DataRef) -> usize {
    r.static_rows().unwrap_or(FALLBACK_ROWS)
}

/// `DeviceModel`-weighted cost estimate of one primitive node,
/// microseconds.  Estimates only need to be *relatively* right — they
/// weigh critical-path comparisons across queries, they are never charged
/// anywhere — so runtime-unknown inputs use coarse fallbacks.
pub fn node_cost_us(node: &Primitive) -> u64 {
    match &node.payload {
        PayloadSpec::Prefill { parts, .. } => {
            let dm = DeviceModel::for_engine(&node.engine);
            let tokens: usize = parts.iter().map(part_tokens).sum();
            dm.prefill_us(1, tokens.max(1))
        }
        PayloadSpec::Decode { segments, .. } => {
            let dm = DeviceModel::for_engine(&node.engine);
            let planned: usize = segments.iter().map(|(_, l)| *l).sum();
            dm.decode_step_us(1).saturating_mul(planned.max(1) as u64)
        }
        PayloadSpec::Embed { sources } => {
            let dm = DeviceModel::for_engine(&node.engine);
            dm.encoder_us(sources.iter().map(part_rows).sum::<usize>().max(1))
        }
        PayloadSpec::Rerank { candidates, .. } => {
            let dm = DeviceModel::for_engine(&node.engine);
            dm.encoder_us(candidates.iter().map(part_rows).sum::<usize>().max(1))
        }
        PayloadSpec::Ingest { .. } | PayloadSpec::VectorSearch { .. } => VDB_COST_US,
        PayloadSpec::WebSearch { .. } => WEB_COST_US,
        PayloadSpec::Tool { cost_us, .. } => *cost_us,
        PayloadSpec::ClonePrefix { .. } => CLONE_COST_US,
        // Host-side control flow is evaluated inline by the graph
        // scheduler; partial-decode markers complete from a stream the
        // decode node already pays for.
        PayloadSpec::Condition { .. }
        | PayloadSpec::Aggregate { .. }
        | PayloadSpec::PartialDecode { .. } => 0,
    }
}

/// Per-query remaining-critical-path tracker.
///
/// Invariant (see `tests/prop_invariants.rs`): `remaining_us()` is
/// monotonically non-increasing as nodes complete, and reaches 0 when all
/// nodes have.
#[derive(Debug)]
pub struct WcpTracker {
    /// Longest cost-weighted path from node v to the sink (includes v's
    /// own cost).  Static: completion order cannot change it because no
    /// descendant of an incomplete node can be complete.
    path_us: Vec<u64>,
    done: Vec<bool>,
    remaining: u64,
}

impl WcpTracker {
    /// Estimate paths over an e-graph (one pass in reverse topo order).
    pub fn new(egraph: &EGraph) -> WcpTracker {
        let n = egraph.len();
        let mut path_us = vec![0u64; n];
        if let Ok(order) = egraph.graph.topo_order() {
            for &v in order.iter().rev() {
                let downstream =
                    egraph.children[v].iter().map(|&c| path_us[c]).max().unwrap_or(0);
                path_us[v] = node_cost_us(&egraph.graph.nodes[v]).saturating_add(downstream);
            }
        }
        let remaining = path_us.iter().copied().max().unwrap_or(0);
        WcpTracker { path_us, done: vec![false; n], remaining }
    }

    /// Remaining critical-path device time of the query, microseconds.
    pub fn remaining_us(&self) -> u64 {
        self.remaining
    }

    /// Static root-to-sink path estimate through `v`.
    pub fn path_us(&self, v: NodeId) -> u64 {
        self.path_us.get(v).copied().unwrap_or(0)
    }

    /// Mark a node complete and refresh the remaining-path estimate.
    /// Idempotent; clamped so the estimate never increases.
    pub fn complete(&mut self, v: NodeId) {
        if v >= self.done.len() || self.done[v] {
            return;
        }
        self.done[v] = true;
        let frontier = self
            .path_us
            .iter()
            .zip(&self.done)
            .filter(|(_, d)| !**d)
            .map(|(p, _)| *p)
            .max()
            .unwrap_or(0);
        self.remaining = self.remaining.min(frontier);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::pgraph::{build_pgraph, instr_tokens};
    use crate::graph::template::*;

    fn one_shot_egraph(out_tokens: usize) -> EGraph {
        let mut t = WorkflowTemplate::new("wcp");
        t.add(Component {
            name: "gen".into(),
            kind: ComponentKind::LlmGenerate {
                variant: "llm-lite".into(),
                mode: SynthesisMode::OneShot,
                prompt: vec![
                    PromptPart::Instruction(instr_tokens("i", 16)),
                    PromptPart::Question,
                ],
                out_tokens,
                segments: 1,
                fan: 0,
            },
            engine: "llm-lite".into(),
            batchable: false,
            splittable: false,
        });
        let q = QueryConfig::example(5);
        EGraph::new(build_pgraph(&t, &q).unwrap()).unwrap()
    }

    #[test]
    fn longer_decode_means_longer_path() {
        let short = WcpTracker::new(&one_shot_egraph(8));
        let long = WcpTracker::new(&one_shot_egraph(96));
        assert!(short.remaining_us() > 0);
        assert!(
            long.remaining_us() > short.remaining_us(),
            "96-token tail {} must out-weigh 8-token tail {}",
            long.remaining_us(),
            short.remaining_us()
        );
    }

    #[test]
    fn remaining_shrinks_as_nodes_complete_and_ends_at_zero() {
        let e = one_shot_egraph(8);
        let mut w = WcpTracker::new(&e);
        let order = e.graph.topo_order().unwrap();
        let mut prev = w.remaining_us();
        for v in order {
            w.complete(v);
            assert!(w.remaining_us() <= prev, "remaining grew at node {v}");
            prev = w.remaining_us();
        }
        assert_eq!(w.remaining_us(), 0);
        // Idempotent on repeat completion.
        w.complete(0);
        assert_eq!(w.remaining_us(), 0);
    }

    #[test]
    fn source_path_covers_whole_chain() {
        let e = one_shot_egraph(8);
        let w = WcpTracker::new(&e);
        let src = e.sources()[0];
        assert_eq!(w.path_us(src), w.remaining_us());
        assert_eq!(w.path_us(usize::MAX), 0);
    }
}
