//! Scheduler hot-path counters (PR9, per-scheduler since PR10).
//!
//! The `sched-bench` harness isolates orchestration overhead per query
//! (the paper's fig. 12 differentiator) by deltaing these counters
//! around a run: dispatch passes and loop iterations say how often the
//! engine scheduler woke and formed batches, order builds / bucket
//! rebuilds expose the incremental priority structure's work avoidance,
//! lock acquisitions count the remaining mutex traffic on the dispatch
//! path (the tenancy spec table), and `dispatch_ns` integrates wall
//! time spent inside `EngineScheduler::dispatch` — the numerator of
//! µs-of-orchestration-per-query.
//!
//! All counters are relaxed atomics: they are monotone event counts
//! with no cross-counter ordering requirement, so the hot path pays one
//! uncontended `fetch_add` per event.  PR9 made them process-global
//! statics, which meant two bench harnesses in one test binary
//! cross-talked through each other's deltas; PR10 moves them into a
//! shareable [`SchedCounters`] handle that each `Platform` (and each
//! raw bench scheduler) owns privately, while the free functions keep
//! feeding a process-global instance for call sites with no handle.

use std::sync::atomic::{AtomicU64, Ordering};

/// One set of scheduler hot-path counters.  Clone the `Arc` wrapping it
/// into every scheduler/runner that should report into the same bucket;
/// independent harnesses hold independent instances, so their deltas
/// never cross-talk even when run concurrently in one process.
#[derive(Debug, Default)]
pub struct SchedCounters {
    /// `EngineScheduler::dispatch` entries (one per wakeup with work).
    dispatch_passes: AtomicU64,
    /// Inner dispatch-loop iterations (batch-formation attempts).
    dispatch_loops: AtomicU64,
    /// Full priority-order materializations (cross-bucket key sort + sweep).
    order_builds: AtomicU64,
    /// Per-query bucket rebuilds (lazy invalidation hits).
    bucket_rebuilds: AtomicU64,
    /// Mutex acquisitions on the dispatch path (tenancy spec-table clones).
    lock_acqs: AtomicU64,
    /// Batches handed to an instance.
    batches_formed: AtomicU64,
    /// Jobs dispatched inside those batches.
    jobs_dispatched: AtomicU64,
    /// Nanoseconds spent inside `EngineScheduler::dispatch`.
    dispatch_ns: AtomicU64,
    /// Graph-scheduler blocking wakeups (completion `recv` calls).
    graph_wakeups: AtomicU64,
    /// Completions absorbed per those wakeups (batched draining: this
    /// exceeds `graph_wakeups` whenever a wakeup drains more than one).
    graph_completions: AtomicU64,
}

impl SchedCounters {
    pub const fn new() -> Self {
        SchedCounters {
            dispatch_passes: AtomicU64::new(0),
            dispatch_loops: AtomicU64::new(0),
            order_builds: AtomicU64::new(0),
            bucket_rebuilds: AtomicU64::new(0),
            lock_acqs: AtomicU64::new(0),
            batches_formed: AtomicU64::new(0),
            jobs_dispatched: AtomicU64::new(0),
            dispatch_ns: AtomicU64::new(0),
            graph_wakeups: AtomicU64::new(0),
            graph_completions: AtomicU64::new(0),
        }
    }

    pub fn count_dispatch_pass(&self) {
        self.dispatch_passes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_dispatch_loop(&self) {
        self.dispatch_loops.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_order_build(&self) {
        self.order_builds.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_bucket_rebuild(&self) {
        self.bucket_rebuilds.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_lock_acq(&self) {
        self.lock_acqs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_batch(&self, jobs: usize) {
        self.batches_formed.fetch_add(1, Ordering::Relaxed);
        self.jobs_dispatched.fetch_add(jobs as u64, Ordering::Relaxed);
    }

    pub fn add_dispatch_ns(&self, ns: u64) {
        self.dispatch_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count_graph_wakeup(&self) {
        self.graph_wakeups.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_graph_completions(&self, n: u64) {
        self.graph_completions.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> SchedStats {
        SchedStats {
            dispatch_passes: self.dispatch_passes.load(Ordering::Relaxed),
            dispatch_loops: self.dispatch_loops.load(Ordering::Relaxed),
            order_builds: self.order_builds.load(Ordering::Relaxed),
            bucket_rebuilds: self.bucket_rebuilds.load(Ordering::Relaxed),
            lock_acqs: self.lock_acqs.load(Ordering::Relaxed),
            batches_formed: self.batches_formed.load(Ordering::Relaxed),
            jobs_dispatched: self.jobs_dispatched.load(Ordering::Relaxed),
            dispatch_ns: self.dispatch_ns.load(Ordering::Relaxed),
            graph_wakeups: self.graph_wakeups.load(Ordering::Relaxed),
            graph_completions: self.graph_completions.load(Ordering::Relaxed),
        }
    }
}

/// Fallback instance fed by the free functions below, for call sites
/// that predate per-scheduler counters or deliberately want a
/// process-wide view.
static GLOBAL: SchedCounters = SchedCounters::new();

/// The process-global counter set (what the free functions feed).
pub fn global() -> &'static SchedCounters {
    &GLOBAL
}

pub fn count_dispatch_pass() {
    GLOBAL.count_dispatch_pass();
}

pub fn count_dispatch_loop() {
    GLOBAL.count_dispatch_loop();
}

pub fn count_order_build() {
    GLOBAL.count_order_build();
}

pub fn count_bucket_rebuild() {
    GLOBAL.count_bucket_rebuild();
}

pub fn count_lock_acq() {
    GLOBAL.count_lock_acq();
}

pub fn count_batch(jobs: usize) {
    GLOBAL.count_batch(jobs);
}

pub fn add_dispatch_ns(ns: u64) {
    GLOBAL.add_dispatch_ns(ns);
}

pub fn count_graph_wakeup() {
    GLOBAL.count_graph_wakeup();
}

pub fn count_graph_completions(n: u64) {
    GLOBAL.count_graph_completions(n);
}

/// Point-in-time snapshot of every counter; delta two snapshots to
/// attribute work to a bounded run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedStats {
    pub dispatch_passes: u64,
    pub dispatch_loops: u64,
    pub order_builds: u64,
    pub bucket_rebuilds: u64,
    pub lock_acqs: u64,
    pub batches_formed: u64,
    pub jobs_dispatched: u64,
    pub dispatch_ns: u64,
    pub graph_wakeups: u64,
    pub graph_completions: u64,
}

/// Snapshot of the process-global counter set.
pub fn snapshot() -> SchedStats {
    GLOBAL.snapshot()
}

impl SchedStats {
    /// Counter deltas accumulated since `earlier` (saturating, so a
    /// misordered pair degrades to zeros instead of garbage).
    pub fn delta_since(&self, earlier: &SchedStats) -> SchedStats {
        SchedStats {
            dispatch_passes: self.dispatch_passes.saturating_sub(earlier.dispatch_passes),
            dispatch_loops: self.dispatch_loops.saturating_sub(earlier.dispatch_loops),
            order_builds: self.order_builds.saturating_sub(earlier.order_builds),
            bucket_rebuilds: self.bucket_rebuilds.saturating_sub(earlier.bucket_rebuilds),
            lock_acqs: self.lock_acqs.saturating_sub(earlier.lock_acqs),
            batches_formed: self.batches_formed.saturating_sub(earlier.batches_formed),
            jobs_dispatched: self.jobs_dispatched.saturating_sub(earlier.jobs_dispatched),
            dispatch_ns: self.dispatch_ns.saturating_sub(earlier.dispatch_ns),
            graph_wakeups: self.graph_wakeups.saturating_sub(earlier.graph_wakeups),
            graph_completions: self.graph_completions.saturating_sub(earlier.graph_completions),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_delta_is_monotone_and_saturating() {
        let before = snapshot();
        count_dispatch_pass();
        count_dispatch_loop();
        count_order_build();
        count_bucket_rebuild();
        count_lock_acq();
        count_batch(3);
        add_dispatch_ns(1000);
        count_graph_wakeup();
        count_graph_completions(2);
        let after = snapshot();
        let d = after.delta_since(&before);
        // Other test threads may also bump counters; the delta is at
        // least what this thread added.
        assert!(d.dispatch_passes >= 1);
        assert!(d.dispatch_loops >= 1);
        assert!(d.order_builds >= 1);
        assert!(d.bucket_rebuilds >= 1);
        assert!(d.lock_acqs >= 1);
        assert!(d.batches_formed >= 1);
        assert!(d.jobs_dispatched >= 3);
        assert!(d.dispatch_ns >= 1000);
        assert!(d.graph_wakeups >= 1);
        assert!(d.graph_completions >= 2);
        // Saturating: a misordered pair yields zeros, not wraparound.
        assert_eq!(before.delta_since(&after).dispatch_passes, 0);
    }

    #[test]
    fn per_instance_counters_are_isolated() {
        let a = SchedCounters::new();
        let b = SchedCounters::new();
        a.count_dispatch_pass();
        a.count_batch(7);
        b.count_order_build();
        let sa = a.snapshot();
        let sb = b.snapshot();
        assert_eq!(sa.dispatch_passes, 1);
        assert_eq!(sa.jobs_dispatched, 7);
        assert_eq!(sa.order_builds, 0);
        assert_eq!(sb.dispatch_passes, 0);
        assert_eq!(sb.order_builds, 1);
    }
}
