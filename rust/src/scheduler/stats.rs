//! Process-global scheduler hot-path counters (PR9).
//!
//! The `sched-bench` harness isolates orchestration overhead per query
//! (the paper's fig. 12 differentiator) by deltaing these counters
//! around a run: dispatch passes and loop iterations say how often the
//! engine scheduler woke and formed batches, order builds / bucket
//! rebuilds expose the incremental priority structure's work avoidance,
//! lock acquisitions count the remaining mutex traffic on the dispatch
//! path (the tenancy spec table), and `DISPATCH_NS` integrates wall
//! time spent inside `EngineScheduler::dispatch` — the numerator of
//! µs-of-orchestration-per-query.
//!
//! All counters are relaxed atomics: they are monotone event counts
//! with no cross-counter ordering requirement, so the hot path pays one
//! uncontended `fetch_add` per event.  Being process-global they sum
//! over every engine scheduler thread; benches that need isolation
//! snapshot before and delta after (`SchedStats::delta_since`) while
//! holding the process's scheduler population fixed.

use std::sync::atomic::{AtomicU64, Ordering};

/// `EngineScheduler::dispatch` entries (one per wakeup with work).
pub static DISPATCH_PASSES: AtomicU64 = AtomicU64::new(0);
/// Inner dispatch-loop iterations (batch-formation attempts).
pub static DISPATCH_LOOPS: AtomicU64 = AtomicU64::new(0);
/// Full priority-order materializations (cross-bucket key sort + sweep).
pub static ORDER_BUILDS: AtomicU64 = AtomicU64::new(0);
/// Per-query bucket rebuilds (lazy invalidation hits).
pub static BUCKET_REBUILDS: AtomicU64 = AtomicU64::new(0);
/// Mutex acquisitions on the dispatch path (tenancy spec-table clones).
pub static LOCK_ACQS: AtomicU64 = AtomicU64::new(0);
/// Batches handed to an instance.
pub static BATCHES_FORMED: AtomicU64 = AtomicU64::new(0);
/// Jobs dispatched inside those batches.
pub static JOBS_DISPATCHED: AtomicU64 = AtomicU64::new(0);
/// Nanoseconds spent inside `EngineScheduler::dispatch`.
pub static DISPATCH_NS: AtomicU64 = AtomicU64::new(0);
/// Graph-scheduler blocking wakeups (completion `recv` calls).
pub static GRAPH_WAKEUPS: AtomicU64 = AtomicU64::new(0);
/// Completions absorbed per those wakeups (batched draining: this
/// exceeds `GRAPH_WAKEUPS` whenever a wakeup drains more than one).
pub static GRAPH_COMPLETIONS: AtomicU64 = AtomicU64::new(0);

pub fn count_dispatch_pass() {
    DISPATCH_PASSES.fetch_add(1, Ordering::Relaxed);
}

pub fn count_dispatch_loop() {
    DISPATCH_LOOPS.fetch_add(1, Ordering::Relaxed);
}

pub fn count_order_build() {
    ORDER_BUILDS.fetch_add(1, Ordering::Relaxed);
}

pub fn count_bucket_rebuild() {
    BUCKET_REBUILDS.fetch_add(1, Ordering::Relaxed);
}

pub fn count_lock_acq() {
    LOCK_ACQS.fetch_add(1, Ordering::Relaxed);
}

pub fn count_batch(jobs: usize) {
    BATCHES_FORMED.fetch_add(1, Ordering::Relaxed);
    JOBS_DISPATCHED.fetch_add(jobs as u64, Ordering::Relaxed);
}

pub fn add_dispatch_ns(ns: u64) {
    DISPATCH_NS.fetch_add(ns, Ordering::Relaxed);
}

pub fn count_graph_wakeup() {
    GRAPH_WAKEUPS.fetch_add(1, Ordering::Relaxed);
}

pub fn count_graph_completions(n: u64) {
    GRAPH_COMPLETIONS.fetch_add(n, Ordering::Relaxed);
}

/// Point-in-time snapshot of every counter; delta two snapshots to
/// attribute work to a bounded run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedStats {
    pub dispatch_passes: u64,
    pub dispatch_loops: u64,
    pub order_builds: u64,
    pub bucket_rebuilds: u64,
    pub lock_acqs: u64,
    pub batches_formed: u64,
    pub jobs_dispatched: u64,
    pub dispatch_ns: u64,
    pub graph_wakeups: u64,
    pub graph_completions: u64,
}

pub fn snapshot() -> SchedStats {
    SchedStats {
        dispatch_passes: DISPATCH_PASSES.load(Ordering::Relaxed),
        dispatch_loops: DISPATCH_LOOPS.load(Ordering::Relaxed),
        order_builds: ORDER_BUILDS.load(Ordering::Relaxed),
        bucket_rebuilds: BUCKET_REBUILDS.load(Ordering::Relaxed),
        lock_acqs: LOCK_ACQS.load(Ordering::Relaxed),
        batches_formed: BATCHES_FORMED.load(Ordering::Relaxed),
        jobs_dispatched: JOBS_DISPATCHED.load(Ordering::Relaxed),
        dispatch_ns: DISPATCH_NS.load(Ordering::Relaxed),
        graph_wakeups: GRAPH_WAKEUPS.load(Ordering::Relaxed),
        graph_completions: GRAPH_COMPLETIONS.load(Ordering::Relaxed),
    }
}

impl SchedStats {
    /// Counter deltas accumulated since `earlier` (saturating, so a
    /// misordered pair degrades to zeros instead of garbage).
    pub fn delta_since(&self, earlier: &SchedStats) -> SchedStats {
        SchedStats {
            dispatch_passes: self.dispatch_passes.saturating_sub(earlier.dispatch_passes),
            dispatch_loops: self.dispatch_loops.saturating_sub(earlier.dispatch_loops),
            order_builds: self.order_builds.saturating_sub(earlier.order_builds),
            bucket_rebuilds: self.bucket_rebuilds.saturating_sub(earlier.bucket_rebuilds),
            lock_acqs: self.lock_acqs.saturating_sub(earlier.lock_acqs),
            batches_formed: self.batches_formed.saturating_sub(earlier.batches_formed),
            jobs_dispatched: self.jobs_dispatched.saturating_sub(earlier.jobs_dispatched),
            dispatch_ns: self.dispatch_ns.saturating_sub(earlier.dispatch_ns),
            graph_wakeups: self.graph_wakeups.saturating_sub(earlier.graph_wakeups),
            graph_completions: self.graph_completions.saturating_sub(earlier.graph_completions),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_delta_is_monotone_and_saturating() {
        let before = snapshot();
        count_dispatch_pass();
        count_dispatch_loop();
        count_order_build();
        count_bucket_rebuild();
        count_lock_acq();
        count_batch(3);
        add_dispatch_ns(1000);
        count_graph_wakeup();
        count_graph_completions(2);
        let after = snapshot();
        let d = after.delta_since(&before);
        // Other test threads may also bump counters; the delta is at
        // least what this thread added.
        assert!(d.dispatch_passes >= 1);
        assert!(d.dispatch_loops >= 1);
        assert!(d.order_builds >= 1);
        assert!(d.bucket_rebuilds >= 1);
        assert!(d.lock_acqs >= 1);
        assert!(d.batches_formed >= 1);
        assert!(d.jobs_dispatched >= 3);
        assert!(d.dispatch_ns >= 1000);
        assert!(d.graph_wakeups >= 1);
        assert!(d.graph_completions >= 2);
        // Saturating: a misordered pair yields zeros, not wraparound.
        assert_eq!(before.delta_since(&after).dispatch_passes, 0);
    }
}
