//! Upper-tier graph scheduler (§5.1): one runner per query.
//!
//! Tracks in-degrees of the query's e-graph, dispatches primitive nodes
//! whose dependencies are met to the appropriate engine scheduler,
//! evaluates host-side control-flow primitives inline, and handles
//! streaming partial-decode completions arriving out of graph order.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Sender};
use std::time::Instant;

use crate::engines::prefix::{prefix_fingerprint, MIN_PREFIX_LEN};
use crate::engines::{
    Completion, EngineJob, JobOutput, NodeId, PrefixFp, QueryId, SegmentSpec, TenantId,
    UNTENANTED,
};
use crate::error::{Result, TeolaError};
use crate::graph::egraph::EGraph;
use crate::graph::primitive::{AggregateMode, DataRef, PayloadSpec, PrimKind};
use crate::graph::value::Value;
use crate::scheduler::batching::{QueueItem, SuccessorPlan, SuccessorTemplate};
use crate::scheduler::object_store::ObjectStore;
use crate::scheduler::wcp::{self, WcpTracker};

/// Per-query latency accounting (feeds Figs. 1, 12 and EXPERIMENTS.md).
#[derive(Debug, Clone, Default)]
pub struct QueryMetrics {
    /// End-to-end wall time (filled by the caller).
    pub e2e_us: u64,
    /// Graph construction + optimization time (filled by the caller).
    pub opt_us: u64,
    /// Sum of engine-scheduler queueing time across completions.
    pub queue_us: u64,
    /// Sum of engine execution time across completions.
    pub exec_us: u64,
    /// Host-side control-flow evaluation time.
    pub host_us: u64,
    pub n_engine_ops: usize,
    pub n_host_ops: usize,
    /// Graph-scheduler dispatch round-trips: every job that entered an
    /// engine queue via the runner's own dispatch loop.  Direct
    /// engine-to-engine successor handoffs do NOT count — the gap between
    /// pipeline on/off is exactly the orchestration overhead Fig. 12
    /// measures.
    pub dispatch_hops: u64,
    /// exec_us per (component, class) where class is "prefill", "decode"
    /// or "other" — the Fig. 1 module breakdown.
    pub per_component_us: HashMap<(usize, &'static str), u64>,
}

/// Routing table: engine name -> its scheduler's queue.
pub type EngineRouter = HashMap<String, Sender<QueueItem>>;

/// Executes one query's e-graph to completion.
pub struct QueryRunner {
    pub query: QueryId,
    pub egraph: EGraph,
    pub routers: EngineRouter,
    /// SEP token id (prompt-part delimiter in rerank pairs).
    pub sep: i32,
    /// Clamp for prompt length (leave decode headroom in the KV cache).
    pub max_prompt: usize,
    /// Cross-engine pipelining: attach successor plans to dispatched
    /// items (direct engine-to-engine handoff) and speculate template
    /// prefills.  Off = today's queue re-entry behavior, bit-for-bit.
    pub pipeline: bool,
    /// Owning tenant (multi-tenant QoS): stamped onto every queue item
    /// and successor plan this runner emits, so fair queueing, KV quotas
    /// and admission control attribute all of the query's work — including
    /// engine-side handoffs — to the right tenant.
    pub tenant: TenantId,
}

enum NodeState {
    Pending,
    Dispatched,
    Done,
}

/// In-flight speculative template prefill (pipeline mode): the constant
/// instruction prefix of a not-yet-ready prefill node, sent ahead under a
/// sentinel node id (>= egraph length, so it can never be mistaken for a
/// real node's completion).
struct SpecPrefill {
    /// The real prefill node this speculation runs ahead of.
    for_node: NodeId,
    /// Template seq (this query's namespace).
    seq: u32,
    /// Tokens prefilled speculatively (= the instruction length).
    len: usize,
    done: bool,
    /// The speculative prefill's completion output (seed-token surface).
    output: Vec<i32>,
    /// Real node became ready while the speculation was still in flight;
    /// dispatch its suffix as soon as the speculation completes.
    waiting: bool,
    /// Guard resolved false: the seq was cancelled engine-side; ignore
    /// any late completion.
    cancelled: bool,
}

impl QueryRunner {
    /// Build a runner.  Pipelining starts off so direct `QueryRunner`
    /// users keep the classic dispatch loop; `Platform` opts in via
    /// [`QueryRunner::with_pipeline`].
    pub fn new(query: QueryId, egraph: EGraph, routers: EngineRouter, sep: i32) -> QueryRunner {
        QueryRunner {
            query,
            egraph,
            routers,
            sep,
            max_prompt: 224,
            pipeline: false,
            tenant: UNTENANTED,
        }
    }

    /// Enable/disable cross-engine pipelining for this query.
    pub fn with_pipeline(mut self, on: bool) -> QueryRunner {
        self.pipeline = on;
        self
    }

    /// Stamp the owning tenant (multi-tenant QoS).  Direct `QueryRunner`
    /// users stay untenanted; `Platform::spawn_query_as` opts in.
    pub fn with_tenant(mut self, tenant: TenantId) -> QueryRunner {
        self.tenant = tenant;
        self
    }

    /// Run the e-graph; returns the output value and metrics.
    pub fn run(self) -> Result<(Value, QueryMetrics)> {
        let (tx, rx) = channel::<Completion>();
        let n = self.egraph.len();
        let mut indeg = self.egraph.in_degrees();
        let mut state: Vec<NodeState> = (0..n).map(|_| NodeState::Pending).collect();
        let mut store = ObjectStore::new();
        let mut metrics = QueryMetrics::default();
        let mut seq_len: HashMap<u32, usize> = HashMap::new();
        let mut pending_rerank: HashMap<NodeId, (Vec<Vec<i32>>, usize)> = HashMap::new();
        let mut done = 0usize;
        // Remaining critical-path estimate (§8): stamped onto every
        // dispatched queue item, tightened as nodes complete.
        let mut wcp = WcpTracker::new(&self.egraph);

        // Local completion worklist (host ops complete synchronously).
        let mut ready: Vec<NodeId> = self.egraph.sources();
        let mut local_done: Vec<(NodeId, Value)> = Vec::new();
        // Batched completion draining (PR9): one blocking `recv` per
        // wakeup absorbs *every* completion already waiting on the
        // channel, instead of a lock round-trip per completion.
        let mut pending: VecDeque<Completion> = VecDeque::new();
        // Successor nodes handed off engine-side: trigger node -> the
        // downstream nodes the engines will materialize themselves.  When
        // the trigger's completion arrives, those nodes are marked
        // Dispatched so the classic dispatch loop skips them.
        let mut handed_off: HashMap<NodeId, Vec<NodeId>> = HashMap::new();
        // Speculative template prefills, keyed by sentinel node id.
        let mut specs: HashMap<usize, SpecPrefill> = HashMap::new();
        let mut spec_of: HashMap<NodeId, usize> = HashMap::new();

        if self.pipeline {
            self.launch_speculative_prefills(
                &indeg,
                &mut seq_len,
                &tx,
                &mut metrics,
                &mut specs,
                &mut spec_of,
                wcp.remaining_us(),
            );
        }

        while done < n {
            // Dispatch every ready node.
            while let Some(v) = ready.pop() {
                if matches!(state[v], NodeState::Pending) {
                    self.dispatch(
                        v,
                        &mut store,
                        &mut seq_len,
                        &mut pending_rerank,
                        &tx,
                        &mut metrics,
                        &mut state,
                        &mut local_done,
                        wcp.remaining_us(),
                        &mut handed_off,
                        &mut specs,
                        &spec_of,
                    )?;
                }
            }
            // Apply synchronous completions.
            if let Some((v, val)) = local_done.pop() {
                wcp.complete(v);
                self.complete(v, val, &mut store, &mut indeg, &mut ready, &mut state, &mut done)?;
                continue;
            }
            if done >= n {
                break;
            }
            // Wait for an engine completion: consume the batched backlog
            // first, and when it is empty block once then drain every
            // completion already queued behind the first — later loop
            // iterations pop from the local `pending` buffer without
            // touching the channel again.
            let c = match pending.pop_front() {
                Some(c) => c,
                None => {
                    let first = rx
                        .recv()
                        .map_err(|_| TeolaError::Scheduler("completion channel closed".into()))?;
                    crate::scheduler::stats::count_graph_wakeup();
                    let mut drained = 1u64;
                    while let Ok(more) = rx.try_recv() {
                        pending.push_back(more);
                        drained += 1;
                    }
                    crate::scheduler::stats::count_graph_completions(drained);
                    first
                }
            };
            metrics.queue_us += c.timing.queued_us;
            metrics.exec_us += c.timing.exec_us;
            let node = c.node;
            // A failure completion means the engine can never serve this
            // node (e.g. every instance died): surface the error instead
            // of waiting forever for a real completion.  Still release
            // this query's KV sequences and vector-DB namespace on the
            // surviving engines before bailing.
            if let JobOutput::Failed(msg) = &c.output {
                self.cleanup();
                return Err(TeolaError::Engine(format!("node {node}: {msg}")));
            }
            // Sentinel ids live above the e-graph: speculative prefill
            // completions are absorbed here, before any node indexing.
            if node >= n {
                let Some(sp) = specs.get_mut(&node) else { continue };
                sp.done = true;
                if let JobOutput::Tokens(t) = &c.output {
                    sp.output = t.clone();
                }
                metrics.n_engine_ops += 1;
                if sp.waiting && !sp.cancelled {
                    // The real node was ready before the speculation
                    // finished: dispatch its deferred suffix now.
                    let (v, slen, sout) = (sp.for_node, sp.len, sp.output.clone());
                    if let PayloadSpec::Prefill { seq, parts } =
                        &self.egraph.graph.nodes[v].payload
                    {
                        self.dispatch_prefill_suffix(
                            v,
                            *seq,
                            parts,
                            slen,
                            &sout,
                            &store,
                            &mut seq_len,
                            &tx,
                            &mut metrics,
                            &mut local_done,
                            wcp.remaining_us(),
                            &mut handed_off,
                        )?;
                    }
                }
                continue;
            }
            // Successors this completion's engine materialized itself:
            // mark them dispatched so the ready loop never re-sends them.
            if let Some(succs) = handed_off.remove(&node) {
                for s in succs {
                    if matches!(state[s], NodeState::Pending) {
                        state[s] = NodeState::Dispatched;
                    }
                }
            }
            if store.has(node) {
                continue; // duplicate stream delivery (benign)
            }
            let comp = self.egraph.graph.nodes[node].component;
            let class = match self.egraph.graph.nodes[node].kind {
                PrimKind::Prefilling | PrimKind::PartialPrefilling | PrimKind::FullPrefilling => "prefill",
                PrimKind::Decoding | PrimKind::PartialDecoding => "decode",
                _ => "other",
            };
            *metrics.per_component_us.entry((comp, class)).or_default() += c.timing.exec_us;
            // Measured-latency feedback into the WCP cost surface: the
            // per-(engine, op-class) EWMA correction narrows the gap
            // between static build-time estimates and what this machine
            // actually delivers (ROADMAP's PR4 gap).
            wcp::observe_latency(&self.egraph.graph.nodes[node], c.timing.exec_us);

            let mut value = Value::from_output(c.output);
            // Rerank post-selection: scores -> top-k candidate rows.
            if let Some((cands, top_k)) = pending_rerank.remove(&node) {
                if let Value::Scores(scores) = &value {
                    value = Value::TokenBatch(select_top_k(cands, scores, top_k));
                }
            }
            metrics.n_engine_ops += 1;
            wcp.complete(node);
            self.complete(node, value, &mut store, &mut indeg, &mut ready, &mut state, &mut done)?;
        }

        // End-of-query cleanup: release KV + vector namespaces.
        self.cleanup();
        let out = store.require(self.egraph.graph.output)?.clone();
        Ok((out, metrics))
    }

    fn cleanup(&self) {
        for (name, sender) in &self.routers {
            if name.starts_with("llm") || name == "vdb" {
                let (tx, rx) = channel();
                drop(rx);
                let _ = sender.send(QueueItem {
                    query: self.query,
                    node: usize::MAX,
                    depth: 0,
                    bundle: (self.query, u64::MAX),
                    arrival: Instant::now(),
                    rows: 0,
                    tokens: 0,
                    wcp_discounted: false,
                    prefix: None,
                    // Top priority under WCP ordering: cleanup releases KV
                    // residency, so it must never starve behind compute
                    // work (the old `wcp_us: 0` stamp sorted it *last* in
                    // descending-WCP buckets).  The engine scheduler
                    // fast-paths bookkeeping jobs anyway, but a correct
                    // stamp keeps any queued fallback path safe too.
                    // (`wcp_priority_us` uses saturating arithmetic, so
                    // MAX cannot overflow the aging term.)
                    wcp_us: u64::MAX,
                    tenant: self.tenant,
                    job: EngineJob::FreeQuery { query: self.query },
                    reply: tx,
                    successors: Vec::new(),
                });
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn complete(
        &self,
        v: NodeId,
        val: Value,
        store: &mut ObjectStore,
        indeg: &mut [usize],
        ready: &mut Vec<NodeId>,
        state: &mut [NodeState],
        done: &mut usize,
    ) -> Result<()> {
        store.put(v, val)?;
        state[v] = NodeState::Done;
        *done += 1;
        for &c in &self.egraph.children[v] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                ready.push(c);
            }
        }
        Ok(())
    }

    /// Resolve a data ref to token rows (Skipped upstream -> empty).
    fn rows_of(&self, store: &ObjectStore, r: &DataRef) -> Result<Vec<Vec<i32>>> {
        Ok(match r {
            DataRef::Const(rows) => rows.clone(),
            DataRef::Node(n) => store.require(*n)?.rows(),
            DataRef::NodeSlice(n, a, b) => {
                let rows = store.require(*n)?.rows();
                rows.get(*a..(*b).min(rows.len())).unwrap_or(&[]).to_vec()
            }
        })
    }

    fn embeddings_of(&self, store: &ObjectStore, r: &DataRef) -> Result<Vec<Vec<f32>>> {
        match r {
            DataRef::Node(n) => match store.require(*n)? {
                Value::Embeddings(e) => Ok(e.clone()),
                Value::Skipped => Ok(Vec::new()),
                other => Err(TeolaError::Scheduler(format!(
                    "expected embeddings from node {n}, got {other:?}"
                ))),
            },
            DataRef::NodeSlice(n, a, b) => match store.require(*n)? {
                Value::Embeddings(e) => {
                    Ok(e.get(*a..(*b).min(e.len())).unwrap_or(&[]).to_vec())
                }
                other => Err(TeolaError::Scheduler(format!(
                    "expected embeddings from node {n}, got {other:?}"
                ))),
            },
            DataRef::Const(_) => Err(TeolaError::Scheduler(
                "const embeddings are not supported".into(),
            )),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        v: NodeId,
        store: &mut ObjectStore,
        seq_len: &mut HashMap<u32, usize>,
        pending_rerank: &mut HashMap<NodeId, (Vec<Vec<i32>>, usize)>,
        tx: &Sender<Completion>,
        metrics: &mut QueryMetrics,
        state: &mut [NodeState],
        local_done: &mut Vec<(NodeId, Value)>,
        wcp_us: u64,
        handed_off: &mut HashMap<NodeId, Vec<NodeId>>,
        specs: &mut HashMap<usize, SpecPrefill>,
        spec_of: &HashMap<NodeId, usize>,
    ) -> Result<()> {
        let node = &self.egraph.graph.nodes[v];
        state[v] = NodeState::Dispatched;

        // Guard check.
        if let Some((g, want)) = node.guard {
            let pass = matches!(store.get(g), Some(Value::Bool(b)) if *b == want);
            if !pass {
                // Invalidate any speculative template prefill that ran
                // ahead of this node: cancel the seq engine-side so its
                // KV reservation and residency are released.
                if let Some(s) = spec_of.get(&v) {
                    if let Some(sp) = specs.get_mut(s) {
                        if !sp.cancelled {
                            sp.cancelled = true;
                            self.cancel_spec_seq(v, sp.seq);
                        }
                    }
                }
                local_done.push((v, Value::Skipped));
                return Ok(());
            }
        }

        let host_start = Instant::now();
        match &node.payload {
            PayloadSpec::Condition { input, prob_true } => {
                let rows = self.rows_of(store, input)?;
                let mut h: u64 = self.query ^ 0x9E3779B97F4A7C15;
                for t in rows.iter().flatten() {
                    h = h.wrapping_mul(31).wrapping_add(*t as u64);
                }
                let outcome = (h % 10_000) as f64 / 10_000.0 < *prob_true;
                metrics.host_us += host_start.elapsed().as_micros() as u64;
                metrics.n_host_ops += 1;
                local_done.push((v, Value::Bool(outcome)));
            }
            PayloadSpec::Aggregate { parts, mode } => {
                let val = self.eval_aggregate(store, parts, *mode)?;
                metrics.host_us += host_start.elapsed().as_micros() as u64;
                metrics.n_host_ops += 1;
                local_done.push((v, val));
            }
            PayloadSpec::PartialDecode { decode, .. } => {
                // External: completed by the decode's streaming segments.
                // If the decode itself was skipped, skip the marker too.
                if matches!(store.get(*decode), Some(Value::Skipped)) {
                    local_done.push((v, Value::Skipped));
                } else if store.has(v) {
                    // already streamed before the edge fired — nothing to do
                }
                // Otherwise wait for the stream message.
            }
            PayloadSpec::Embed { sources } => {
                let mut chunks = Vec::new();
                for s in sources {
                    chunks.extend(self.rows_of(store, s)?);
                }
                self.send_job(v, EngineJob::Embed { chunks }, tx, wcp_us, metrics, Vec::new())?;
            }
            PayloadSpec::Ingest { chunks, embeddings } => {
                let mut rows = Vec::new();
                for c in chunks {
                    rows.extend(self.rows_of(store, c)?);
                }
                let embs = self.embeddings_of(store, embeddings)?;
                self.send_job(
                    v,
                    EngineJob::Ingest { namespace: self.query, chunks: rows, embeddings: embs },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
            PayloadSpec::VectorSearch { embeddings, top_k } => {
                let embs = self.embeddings_of(store, embeddings)?;
                self.send_job(
                    v,
                    EngineJob::VectorSearch {
                        namespace: self.query,
                        embeddings: embs,
                        top_k: *top_k,
                    },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
            PayloadSpec::Rerank { query, candidates, top_k } => {
                let qrows = self.rows_of(store, query)?;
                let qtok: Vec<i32> = qrows.into_iter().flatten().collect();
                let mut cands = Vec::new();
                for c in candidates {
                    cands.extend(self.rows_of(store, c)?);
                }
                let pairs: Vec<Vec<i32>> = cands
                    .iter()
                    .map(|c| {
                        let mut p = qtok.clone();
                        p.push(self.sep);
                        p.extend(c);
                        p
                    })
                    .collect();
                pending_rerank.insert(v, (cands, *top_k));
                self.send_job(v, EngineJob::Rerank { pairs }, tx, wcp_us, metrics, Vec::new())?;
            }
            PayloadSpec::Prefill { seq, parts } => {
                // A speculative template prefill may already hold this
                // seq's prefix engine-side: serialize behind it and send
                // only the suffix (out-of-order prefills would corrupt
                // the sequence length).
                if let Some(s) = spec_of.get(&v) {
                    if let Some(sp) = specs.get_mut(s) {
                        if !sp.cancelled {
                            if !sp.done {
                                sp.waiting = true;
                                return Ok(());
                            }
                            let (slen, sout) = (sp.len, sp.output.clone());
                            return self.dispatch_prefill_suffix(
                                v, *seq, parts, slen, &sout, store, seq_len, tx, metrics,
                                local_done, wcp_us, handed_off,
                            );
                        }
                    }
                }
                let mut tokens = Vec::new();
                for p in parts {
                    for row in self.rows_of(store, p)? {
                        tokens.extend(row);
                    }
                }
                let offset = *seq_len.get(seq).unwrap_or(&0);
                let budget = self.max_prompt.saturating_sub(offset).max(1);
                tokens.truncate(budget);
                if tokens.is_empty() {
                    tokens.push(self.sep);
                }
                // Cross-query prefix fingerprint: a from-scratch prefill
                // whose first prompt part is a Const instruction template
                // (shared by every query of the app) advertises it to the
                // engine scheduler.  Only set when the full instruction
                // survived truncation and a non-empty suffix follows.
                let prefix: Option<PrefixFp> = if offset == 0 {
                    match parts.first() {
                        Some(DataRef::Const(rows)) if rows.len() == 1 => {
                            let instr = &rows[0];
                            (instr.len() >= MIN_PREFIX_LEN && tokens.len() > instr.len())
                                .then(|| prefix_fingerprint(instr))
                        }
                        _ => None,
                    }
                } else {
                    None
                };
                seq_len.insert(*seq, offset + tokens.len());
                let plans = self.prefill_successor_plans(v, *seq, wcp_us, handed_off);
                self.send_job(
                    v,
                    EngineJob::Prefill { seq: (self.query, *seq), tokens, offset, prefix },
                    tx,
                    wcp_us,
                    metrics,
                    plans,
                )?;
            }
            PayloadSpec::Decode { seq, first_from, segments } => {
                let first = match store.require(*first_from)? {
                    Value::Tokens(t) => *t.first().unwrap_or(&self.sep),
                    _ => self.sep,
                };
                let segs: Vec<SegmentSpec> = segments
                    .iter()
                    .map(|(n, l)| SegmentSpec { node: *n, len: *l })
                    .collect();
                let plans = self.decode_successor_plans(v, &segs, wcp_us, handed_off);
                self.send_job(
                    v,
                    EngineJob::Decode {
                        seq: (self.query, *seq),
                        first_token: first,
                        segments: segs,
                    },
                    tx,
                    wcp_us,
                    metrics,
                    plans,
                )?;
            }
            PayloadSpec::WebSearch { queries, top_k } => {
                let mut rows = Vec::new();
                for q in queries {
                    rows.extend(self.rows_of(store, q)?);
                }
                self.send_job(
                    v,
                    EngineJob::WebSearch { queries: rows, top_k: *top_k },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
            PayloadSpec::ClonePrefix { src_seq, dst_seq, len, .. } => {
                seq_len.insert(*dst_seq, *len);
                self.send_job(
                    v,
                    EngineJob::ClonePrefix {
                        src: (self.query, *src_seq),
                        dst: (self.query, *dst_seq),
                        len: *len,
                    },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
            PayloadSpec::Tool { name, cost_us } => {
                self.send_job(
                    v,
                    EngineJob::ToolCall { name: name.clone(), cost_us: *cost_us },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
        }
        Ok(())
    }

    fn eval_aggregate(
        &self,
        store: &ObjectStore,
        parts: &[DataRef],
        mode: AggregateMode,
    ) -> Result<Value> {
        match mode {
            AggregateMode::Barrier => Ok(Value::Unit),
            AggregateMode::ConcatRows => {
                // If every node part carries embeddings, concatenate those;
                // otherwise concatenate token rows.
                let all_embeddings = parts.iter().all(|p| {
                    matches!(p, DataRef::Node(n)
                        if matches!(store.get(*n), Some(Value::Embeddings(_))))
                });
                if all_embeddings && !parts.is_empty() {
                    let mut all = Vec::new();
                    for p in parts {
                        if let DataRef::Node(n) = p {
                            if let Value::Embeddings(e) = store.require(*n)? {
                                all.extend(e.clone());
                            }
                        }
                    }
                    return Ok(Value::Embeddings(all));
                }
                let mut rows = Vec::new();
                for p in parts {
                    rows.extend(self.rows_of(store, p)?);
                }
                Ok(Value::TokenBatch(rows))
            }
            AggregateMode::JoinTokens => {
                let mut toks = Vec::new();
                for p in parts {
                    for r in self.rows_of(store, p)? {
                        toks.extend(r);
                        toks.push(self.sep);
                    }
                }
                Ok(Value::Tokens(toks))
            }
            AggregateMode::TopK(k) => {
                // parts[0] = scores node, rest = candidate rows.
                let scores = match parts.first() {
                    Some(DataRef::Node(n)) => match store.require(*n)? {
                        Value::Scores(s) => s.clone(),
                        _ => Vec::new(),
                    },
                    _ => Vec::new(),
                };
                let mut rows = Vec::new();
                for p in &parts[1..] {
                    rows.extend(self.rows_of(store, p)?);
                }
                Ok(Value::TokenBatch(select_top_k(rows, &scores, k)))
            }
            AggregateMode::ZipPrepend => {
                // parts[..k] = Tokens (contexts), parts[k] = base rows.
                let (last, ctxs) = parts.split_last().ok_or_else(|| {
                    TeolaError::Scheduler("zip-prepend needs parts".into())
                })?;
                let base = self.rows_of(store, last)?;
                let mut out = Vec::with_capacity(base.len());
                for (i, b) in base.iter().enumerate() {
                    let mut row = ctxs
                        .get(i)
                        .map(|c| self.rows_of(store, c).unwrap_or_default())
                        .unwrap_or_default()
                        .into_iter()
                        .flatten()
                        .collect::<Vec<i32>>();
                    row.extend(b);
                    out.push(row);
                }
                Ok(Value::TokenBatch(out))
            }
        }
    }

    /// Successor plans for a prefill: a decode fed solely by this node
    /// (its seed token is this prefill's completion output) is chained
    /// directly on the engine side, skipping one dispatch round-trip.
    fn prefill_successor_plans(
        &self,
        v: NodeId,
        seq: u32,
        wcp_us: u64,
        handed_off: &mut HashMap<NodeId, Vec<NodeId>>,
    ) -> Vec<SuccessorPlan> {
        if !self.pipeline {
            return Vec::new();
        }
        let mut plans = Vec::new();
        for &d in &self.egraph.children[v] {
            let dn = &self.egraph.graph.nodes[d];
            if dn.guard.is_some() || self.egraph.parents[d] != [v] {
                continue;
            }
            let PayloadSpec::Decode { seq: dseq, first_from, segments } = &dn.payload else {
                continue;
            };
            if *first_from != v || *dseq != seq {
                continue;
            }
            let Some(sender) = self.routers.get(&dn.engine) else { continue };
            let segs: Vec<SegmentSpec> =
                segments.iter().map(|(n, l)| SegmentSpec { node: *n, len: *l }).collect();
            plans.push(SuccessorPlan {
                on_node: v,
                node: d,
                depth: self.egraph.depths[d],
                engine: sender.clone(),
                template: SuccessorTemplate::Decode { seq: (self.query, seq), segments: segs },
                wcp_us,
                tenant: self.tenant,
                fired: std::cell::Cell::new(false),
            });
            handed_off.entry(v).or_default().push(d);
        }
        plans
    }

    /// Successor plans for a decode: each streamed segment marker whose
    /// sole consumer is an embedding of exactly that marker's output is
    /// chained engine-side, so partial results feed the embedder as each
    /// segment completes — without a graph-scheduler round-trip.
    fn decode_successor_plans(
        &self,
        v: NodeId,
        segs: &[SegmentSpec],
        wcp_us: u64,
        handed_off: &mut HashMap<NodeId, Vec<NodeId>>,
    ) -> Vec<SuccessorPlan> {
        if !self.pipeline {
            return Vec::new();
        }
        let mut plans = Vec::new();
        for s in segs {
            let m = s.node;
            if m == v || m >= self.egraph.len() {
                continue; // self-segment (unsplit decode)
            }
            for &e in &self.egraph.children[m] {
                let en = &self.egraph.graph.nodes[e];
                if en.guard.is_some() || self.egraph.parents[e] != [m] {
                    continue;
                }
                let PayloadSpec::Embed { sources } = &en.payload else { continue };
                if *sources != [DataRef::Node(m)] {
                    continue;
                }
                let Some(sender) = self.routers.get(&en.engine) else { continue };
                plans.push(SuccessorPlan {
                    on_node: m,
                    node: e,
                    depth: self.egraph.depths[e],
                    engine: sender.clone(),
                    template: SuccessorTemplate::Embed,
                    wcp_us,
                    tenant: self.tenant,
                    fired: std::cell::Cell::new(false),
                });
                handed_off.entry(m).or_default().push(e);
            }
        }
        plans
    }

    /// Dispatch the non-template suffix of a prefill whose constant
    /// instruction prefix was already prefilled speculatively.  The final
    /// sequence length — and therefore the completion token — matches the
    /// unspeculated path exactly.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_prefill_suffix(
        &self,
        v: NodeId,
        seq: u32,
        parts: &[DataRef],
        spec_len: usize,
        spec_out: &[i32],
        store: &ObjectStore,
        seq_len: &mut HashMap<u32, usize>,
        tx: &Sender<Completion>,
        metrics: &mut QueryMetrics,
        local_done: &mut Vec<(NodeId, Value)>,
        wcp_us: u64,
        handed_off: &mut HashMap<NodeId, Vec<NodeId>>,
    ) -> Result<()> {
        let mut tokens = Vec::new();
        for p in parts {
            for row in self.rows_of(store, p)? {
                tokens.extend(row);
            }
        }
        tokens.truncate(self.max_prompt);
        if tokens.len() <= spec_len {
            // The template covered the whole prompt: the speculative
            // completion IS this node's completion (same seq length).
            local_done.push((v, Value::Tokens(spec_out.to_vec())));
            return Ok(());
        }
        let suffix = tokens.split_off(spec_len);
        seq_len.insert(seq, spec_len + suffix.len());
        let plans = self.prefill_successor_plans(v, seq, wcp_us, handed_off);
        self.send_job(
            v,
            EngineJob::Prefill {
                seq: (self.query, seq),
                tokens: suffix,
                offset: spec_len,
                prefix: None,
            },
            tx,
            wcp_us,
            metrics,
            plans,
        )
    }

    /// Launch speculative template prefills: a monolithic prefill that is
    /// not ready yet (guarded or waiting on upstream data) but whose first
    /// prompt part is a constant instruction template can prefill that
    /// template ahead of time under a sentinel node id.  Exactly one
    /// prefill must own the seq (splittable prefills are already split by
    /// Pass 3 and never qualify).
    #[allow(clippy::too_many_arguments)]
    fn launch_speculative_prefills(
        &self,
        indeg: &[usize],
        seq_len: &mut HashMap<u32, usize>,
        tx: &Sender<Completion>,
        metrics: &mut QueryMetrics,
        specs: &mut HashMap<usize, SpecPrefill>,
        spec_of: &mut HashMap<NodeId, usize>,
        wcp_us: u64,
    ) {
        let n = self.egraph.len();
        // Count writers per seq: speculation is only safe when this node
        // is the seq's sole prefill and nothing clones into it.
        let mut writers: HashMap<u32, usize> = HashMap::new();
        for nd in &self.egraph.graph.nodes {
            match &nd.payload {
                PayloadSpec::Prefill { seq, .. } => *writers.entry(*seq).or_default() += 1,
                PayloadSpec::ClonePrefix { dst_seq, .. } => {
                    *writers.entry(*dst_seq).or_default() += 2
                }
                _ => {}
            }
        }
        for v in 0..n {
            let nd = &self.egraph.graph.nodes[v];
            if nd.kind != PrimKind::Prefilling {
                continue;
            }
            if nd.guard.is_none() && indeg[v] == 0 {
                continue; // ready right now: nothing to win
            }
            let PayloadSpec::Prefill { seq, parts } = &nd.payload else { continue };
            if writers.get(seq).copied().unwrap_or(0) != 1 {
                continue;
            }
            let Some(DataRef::Const(rows)) = parts.first() else { continue };
            if rows.len() != 1 {
                continue;
            }
            let instr = rows[0].clone();
            if instr.len() < MIN_PREFIX_LEN || instr.len() >= self.max_prompt {
                continue;
            }
            let Some(sender) = self.routers.get(&nd.engine) else { continue };
            let sentinel = n + specs.len();
            let job = EngineJob::Prefill {
                seq: (self.query, *seq),
                tokens: instr.clone(),
                offset: 0,
                prefix: None,
            };
            metrics.dispatch_hops += 1;
            let ok = sender
                .send(QueueItem {
                    query: self.query,
                    node: sentinel,
                    depth: self.egraph.depths[v],
                    bundle: (self.query, sentinel as u64),
                    arrival: Instant::now(),
                    rows: job.rows(),
                    tokens: job.kv_tokens(),
                    wcp_discounted: false,
                    prefix: None,
                    wcp_us,
                    tenant: self.tenant,
                    job,
                    reply: tx.clone(),
                    successors: Vec::new(),
                })
                .is_ok();
            if ok {
                seq_len.insert(*seq, instr.len());
                specs.insert(
                    sentinel,
                    SpecPrefill {
                        for_node: v,
                        seq: *seq,
                        len: instr.len(),
                        done: false,
                        output: Vec::new(),
                        waiting: false,
                        cancelled: false,
                    },
                );
                spec_of.insert(v, sentinel);
            }
        }
    }

    /// Cancel a speculated seq engine-side: purge any queued prefill,
    /// drop the sequence state and release residency.  Bookkeeping-only
    /// (the engine never emits a completion toward the speculating node),
    /// so an invalidated speculation can never fail the query.
    fn cancel_spec_seq(&self, v: NodeId, seq: u32) {
        let engine = &self.egraph.graph.nodes[v].engine;
        if let Some(sender) = self.routers.get(engine) {
            let (dead_tx, dead_rx) = channel();
            drop(dead_rx);
            let _ = sender.send(QueueItem {
                query: self.query,
                node: usize::MAX,
                depth: 0,
                bundle: (self.query, u64::MAX),
                arrival: Instant::now(),
                rows: 0,
                tokens: 0,
                wcp_discounted: false,
                prefix: None,
                wcp_us: u64::MAX,
                tenant: self.tenant,
                job: EngineJob::CancelSeq { seq: (self.query, seq) },
                reply: dead_tx,
                successors: Vec::new(),
            });
        }
    }

    fn send_job(
        &self,
        v: NodeId,
        job: EngineJob,
        tx: &Sender<Completion>,
        wcp_us: u64,
        metrics: &mut QueryMetrics,
        successors: Vec<SuccessorPlan>,
    ) -> Result<()> {
        let node = &self.egraph.graph.nodes[v];
        let sender = self.routers.get(&node.engine).ok_or_else(|| {
            TeolaError::Scheduler(format!("no engine registered for '{}'", node.engine))
        })?;
        let rows = job.rows();
        let prefix = job.prefix();
        // KV token estimate from the same token surface the WCP cost
        // estimates weigh: prompt tokens for prefills, planned new
        // tokens for decodes.  The engine scheduler reserves by it under
        // token-denominated accounting.
        let tokens = job.kv_tokens();
        // Every send through this path is one graph-scheduler round-trip;
        // engine-side successor handoffs bypass it by construction.
        metrics.dispatch_hops += 1;
        sender
            .send(QueueItem {
                query: self.query,
                node: v,
                depth: self.egraph.depths[v],
                bundle: (self.query, v as u64),
                arrival: Instant::now(),
                rows,
                tokens,
                wcp_discounted: false,
                prefix,
                wcp_us,
                tenant: self.tenant,
                job,
                reply: tx.clone(),
                successors,
            })
            .map_err(|_| TeolaError::Scheduler(format!("engine '{}' is down", node.engine)))
    }
}

/// Keep the k best-scoring rows (stable on ties by original order).
pub fn select_top_k(rows: Vec<Vec<i32>>, scores: &[f32], k: usize) -> Vec<Vec<i32>> {
    let mut idx: Vec<usize> = (0..rows.len()).collect();
    idx.sort_by(|&a, &b| {
        let sa = scores.get(a).copied().unwrap_or(f32::MIN);
        let sb = scores.get(b).copied().unwrap_or(f32::MIN);
        sb.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.into_iter().map(|i| rows[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_selection() {
        let rows = vec![vec![1], vec![2], vec![3]];
        let got = select_top_k(rows, &[0.1, 0.9, 0.5], 2);
        assert_eq!(got, vec![vec![2], vec![3]]);
    }

    #[test]
    fn top_k_handles_missing_scores() {
        let rows = vec![vec![1], vec![2]];
        let got = select_top_k(rows, &[0.5], 2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], vec![1]);
    }
}
