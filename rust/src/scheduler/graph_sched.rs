//! Upper-tier graph scheduler (§5.1): one runner per query.
//!
//! Tracks in-degrees of the query's e-graph, dispatches primitive nodes
//! whose dependencies are met to the appropriate engine scheduler,
//! evaluates host-side control-flow primitives inline, and handles
//! streaming partial-decode completions arriving out of graph order.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Sender};
use std::time::Instant;

use crate::engines::prefix::{prefix_fingerprint, MIN_PREFIX_LEN};
use crate::engines::{
    Completion, EngineJob, JobOutput, NodeId, PrefixFp, QueryId, SegmentSpec, TenantId,
    UNTENANTED,
};
use crate::error::{Result, TeolaError};
use crate::graph::egraph::EGraph;
use crate::graph::primitive::{AggregateMode, DataRef, PayloadSpec, PrimKind, Primitive};
use crate::graph::value::Value;
use crate::scheduler::batching::{QueueItem, SuccessorPlan, SuccessorTemplate};
use crate::scheduler::object_store::ObjectStore;
use crate::scheduler::wcp::{self, WcpTracker};

/// First sentinel node id for speculative template prefills: far above
/// any real node id, so completions carrying one are absorbed before
/// node indexing.  Runtime graph growth (PR10) appends *real* nodes at
/// `egraph.len()`, so sentinels can no longer start there — a grown
/// node would collide with an in-flight sentinel's completion.
const SPEC_SENTINEL_BASE: usize = 1 << 32;

/// Per-query latency accounting (feeds Figs. 1, 12 and EXPERIMENTS.md).
#[derive(Debug, Clone, Default)]
pub struct QueryMetrics {
    /// End-to-end wall time (filled by the caller).
    pub e2e_us: u64,
    /// Graph construction + optimization time (filled by the caller).
    pub opt_us: u64,
    /// Sum of engine-scheduler queueing time across completions.
    pub queue_us: u64,
    /// Sum of engine execution time across completions.
    pub exec_us: u64,
    /// Host-side control-flow evaluation time.
    pub host_us: u64,
    pub n_engine_ops: usize,
    pub n_host_ops: usize,
    /// Graph-scheduler dispatch round-trips: every job that entered an
    /// engine queue via the runner's own dispatch loop.  Direct
    /// engine-to-engine successor handoffs do NOT count — the gap between
    /// pipeline on/off is exactly the orchestration overhead Fig. 12
    /// measures.
    pub dispatch_hops: u64,
    /// Speculatively dispatched branch nodes whose guard resolved against
    /// them (wasted work, PR10).  Tracked separately from `dispatch_hops`
    /// so the speculation win/waste ratio is directly observable.
    pub speculative_cancelled: u64,
    /// exec_us per (component, class) where class is "prefill", "decode"
    /// or "other" — the Fig. 1 module breakdown.
    pub per_component_us: HashMap<(usize, &'static str), u64>,
}

/// Routing table: engine name -> its scheduler's queue.
pub type EngineRouter = HashMap<String, Sender<QueueItem>>;

/// Executes one query's e-graph to completion.
pub struct QueryRunner {
    pub query: QueryId,
    pub egraph: EGraph,
    pub routers: EngineRouter,
    /// SEP token id (prompt-part delimiter in rerank pairs).
    pub sep: i32,
    /// Clamp for prompt length (leave decode headroom in the KV cache).
    pub max_prompt: usize,
    /// Cross-engine pipelining: attach successor plans to dispatched
    /// items (direct engine-to-engine handoff) and speculate template
    /// prefills.  Off = today's queue re-entry behavior, bit-for-bit.
    pub pipeline: bool,
    /// Owning tenant (multi-tenant QoS): stamped onto every queue item
    /// and successor plan this runner emits, so fair queueing, KV quotas
    /// and admission control attribute all of the query's work — including
    /// engine-side handoffs — to the right tenant.
    pub tenant: TenantId,
    /// Speculative branch dispatch (PR10): when a guard is unresolved,
    /// dispatch ready nodes of the likely branch ahead of the condition,
    /// stamped with a fully discounted WCP rank so they only consume
    /// spare capacity.  Off = classic guard-blocking behavior, bit-for-bit.
    pub speculate: bool,
    /// Minimum branch probability for speculative dispatch.
    pub spec_threshold: f64,
    /// Hot-path counter sink; `None` = process-global counters.
    pub counters: Option<std::sync::Arc<crate::scheduler::stats::SchedCounters>>,
}

enum NodeState {
    Pending,
    Dispatched,
    Done,
}

/// In-flight speculative template prefill (pipeline mode): the constant
/// instruction prefix of a not-yet-ready prefill node, sent ahead under a
/// sentinel node id (>= egraph length, so it can never be mistaken for a
/// real node's completion).
struct SpecPrefill {
    /// The real prefill node this speculation runs ahead of.
    for_node: NodeId,
    /// Template seq (this query's namespace).
    seq: u32,
    /// Tokens prefilled speculatively (= the instruction length).
    len: usize,
    done: bool,
    /// The speculative prefill's completion output (seed-token surface).
    output: Vec<i32>,
    /// Real node became ready while the speculation was still in flight;
    /// dispatch its suffix as soon as the speculation completes.
    waiting: bool,
    /// Guard resolved false: the seq was cancelled engine-side; ignore
    /// any late completion.
    cancelled: bool,
}

/// One speculatively dispatched branch node (PR10): sent to its engine
/// while the guard condition was still unresolved.  On guard resolution
/// it is either confirmed in place (zero re-dispatch) or cancelled
/// (queued work purged, in-flight seqs aborted, fair-share refunded).
struct SpecBranch {
    /// The guarding condition node and the outcome that confirms us.
    cond: NodeId,
    want: bool,
    /// Completion that arrived while the guard was still unresolved:
    /// buffered here — releasing it early would unblock descendants the
    /// unspeculated schedule would not have run yet.
    buffered: Option<Completion>,
    /// seq_len undo record for seq-writing payloads (prefill/decode):
    /// `(seq, prior_len)` captured at dispatch time so cancellation
    /// restores the runner's sequence-length view exactly.
    seq_undo: Option<(u32, Option<usize>)>,
}

impl QueryRunner {
    /// Build a runner.  Pipelining starts off so direct `QueryRunner`
    /// users keep the classic dispatch loop; `Platform` opts in via
    /// [`QueryRunner::with_pipeline`].
    pub fn new(query: QueryId, egraph: EGraph, routers: EngineRouter, sep: i32) -> QueryRunner {
        QueryRunner {
            query,
            egraph,
            routers,
            sep,
            max_prompt: 224,
            pipeline: false,
            tenant: UNTENANTED,
            speculate: false,
            spec_threshold: 0.5,
            counters: None,
        }
    }

    /// Enable/disable cross-engine pipelining for this query.
    pub fn with_pipeline(mut self, on: bool) -> QueryRunner {
        self.pipeline = on;
        self
    }

    /// Enable/disable speculative branch dispatch (PR10).  `threshold` is
    /// the minimum branch probability a guarded node needs before the
    /// runner speculates on it.
    pub fn with_speculation(mut self, on: bool, threshold: f64) -> QueryRunner {
        self.speculate = on;
        self.spec_threshold = threshold;
        self
    }

    /// Route hot-path counters to a per-platform sink instead of the
    /// process-global one (lets concurrent benches not cross-talk).
    pub fn with_counters(
        mut self,
        c: std::sync::Arc<crate::scheduler::stats::SchedCounters>,
    ) -> QueryRunner {
        self.counters = Some(c);
        self
    }

    /// The counter sink in effect for this runner.
    fn ctrs(&self) -> &crate::scheduler::stats::SchedCounters {
        self.counters.as_deref().unwrap_or_else(crate::scheduler::stats::global)
    }

    /// Stamp the owning tenant (multi-tenant QoS).  Direct `QueryRunner`
    /// users stay untenanted; `Platform::spawn_query_as` opts in.
    pub fn with_tenant(mut self, tenant: TenantId) -> QueryRunner {
        self.tenant = tenant;
        self
    }

    /// Run the e-graph; returns the output value and metrics.
    pub fn run(mut self) -> Result<(Value, QueryMetrics)> {
        let (tx, rx) = channel::<Completion>();
        let mut n = self.egraph.len();
        let mut indeg = self.egraph.in_degrees();
        let mut state: Vec<NodeState> = (0..n).map(|_| NodeState::Pending).collect();
        let mut store = ObjectStore::new();
        let mut metrics = QueryMetrics::default();
        let mut seq_len: HashMap<u32, usize> = HashMap::new();
        let mut pending_rerank: HashMap<NodeId, (Vec<Vec<i32>>, usize)> = HashMap::new();
        let mut done = 0usize;
        // Remaining critical-path estimate (§8): stamped onto every
        // dispatched queue item, tightened as nodes complete.  Under
        // speculation the tracker weighs guarded subpaths by their branch
        // probability (expected remaining cost) and prunes refuted
        // branches on guard resolution; off keeps the classic full-cost
        // numerics bit-for-bit.
        let mut wcp = if self.speculate {
            WcpTracker::new_weighted(&self.egraph)
        } else {
            WcpTracker::new(&self.egraph)
        };

        // Local completion worklist (host ops complete synchronously).
        let mut ready: Vec<NodeId> = self.egraph.sources();
        let mut local_done: Vec<(NodeId, Value)> = Vec::new();
        // Batched completion draining (PR9): one blocking `recv` per
        // wakeup absorbs *every* completion already waiting on the
        // channel, instead of a lock round-trip per completion.
        let mut pending: VecDeque<Completion> = VecDeque::new();
        // Successor nodes handed off engine-side: trigger node -> the
        // downstream nodes the engines will materialize themselves.  When
        // the trigger's completion arrives, those nodes are marked
        // Dispatched so the classic dispatch loop skips them.
        let mut handed_off: HashMap<NodeId, Vec<NodeId>> = HashMap::new();
        // Speculative template prefills, keyed by sentinel node id.
        let mut specs: HashMap<usize, SpecPrefill> = HashMap::new();
        let mut spec_of: HashMap<NodeId, usize> = HashMap::new();
        // Speculatively dispatched branch nodes (PR10), keyed by node id;
        // an entry exists exactly while its guard is unresolved.
        let mut spec_branch: HashMap<NodeId, SpecBranch> = HashMap::new();
        // Expansion nodes whose input arrived: the graph grows for them
        // outside the dispatch borrow (dispatch holds `&self`).
        let mut pending_expand: Vec<NodeId> = Vec::new();
        // Runtime-grown join node -> the expansion node it completes.
        let mut expansion_join: HashMap<NodeId, NodeId> = HashMap::new();

        if self.pipeline {
            self.launch_speculative_prefills(
                &indeg,
                &mut seq_len,
                &tx,
                &mut metrics,
                &mut specs,
                &mut spec_of,
                wcp.remaining_us(),
            );
        }

        while done < n {
            // Dispatch every ready node.
            while let Some(v) = ready.pop() {
                if matches!(state[v], NodeState::Pending) {
                    self.dispatch(
                        v,
                        &mut store,
                        &mut seq_len,
                        &mut pending_rerank,
                        &tx,
                        &mut metrics,
                        &mut state,
                        &mut local_done,
                        wcp.remaining_us(),
                        &mut handed_off,
                        &mut specs,
                        &spec_of,
                        &spec_branch,
                        &mut pending_expand,
                    )?;
                }
            }
            // Runtime graph growth: expansions whose input arrived spawn
            // their tool subgraphs now, then re-enter the dispatch loop
            // for the freshly readied nodes.
            if !pending_expand.is_empty() {
                while let Some(x) = pending_expand.pop() {
                    self.expand_node(
                        x,
                        &mut n,
                        &store,
                        &mut indeg,
                        &mut state,
                        &mut ready,
                        &mut wcp,
                        &mut metrics,
                        &mut expansion_join,
                    )?;
                }
                continue;
            }
            // Apply synchronous completions.
            if let Some((v, val)) = local_done.pop() {
                wcp.complete(v);
                // Guard resolution: prune/confirm speculated branch work
                // and re-weight the query's remaining critical path.
                if self.speculate {
                    if let Value::Bool(outcome) = &val {
                        self.resolve_speculation(
                            v,
                            *outcome,
                            &mut wcp,
                            &mut spec_branch,
                            &mut pending,
                            &mut local_done,
                            &mut metrics,
                            &mut seq_len,
                            &mut specs,
                            &spec_of,
                        );
                    }
                }
                self.complete(v, val, &mut store, &mut indeg, &mut ready, &mut state, &mut done)?;
                // A runtime-grown join completing stands in for its
                // expansion node: complete the expansion too, unblocking
                // the components templated downstream of the fan-out.
                if let Some(x) = expansion_join.remove(&v) {
                    wcp.complete(x);
                    self.complete(
                        x,
                        Value::Unit,
                        &mut store,
                        &mut indeg,
                        &mut ready,
                        &mut state,
                        &mut done,
                    )?;
                }
                continue;
            }
            if done >= n {
                break;
            }
            // About to block on engine completions: spare capacity.  Fill
            // it with likely-branch work whose guard is still unresolved
            // (stamped fully discounted, so engines only run it when no
            // committed work competes).
            if self.speculate {
                for (v, cond, want) in self.branch_speculation_candidates(&state, &store) {
                    let seq_undo = match &self.egraph.graph.nodes[v].payload {
                        PayloadSpec::Prefill { seq, .. } | PayloadSpec::Decode { seq, .. } => {
                            Some((*seq, seq_len.get(seq).copied()))
                        }
                        _ => None,
                    };
                    spec_branch
                        .insert(v, SpecBranch { cond, want, buffered: None, seq_undo });
                    self.dispatch(
                        v,
                        &mut store,
                        &mut seq_len,
                        &mut pending_rerank,
                        &tx,
                        &mut metrics,
                        &mut state,
                        &mut local_done,
                        0, // fully discounted WCP rank: never displaces committed work
                        &mut handed_off,
                        &mut specs,
                        &spec_of,
                        &spec_branch,
                        &mut pending_expand,
                    )?;
                }
                // A speculative host-op (none today) or expansion could
                // have produced synchronous work; re-enter the loop.
                if !local_done.is_empty() || !pending_expand.is_empty() {
                    continue;
                }
            }
            // Wait for an engine completion: consume the batched backlog
            // first, and when it is empty block once then drain every
            // completion already queued behind the first — later loop
            // iterations pop from the local `pending` buffer without
            // touching the channel again.
            let c = match pending.pop_front() {
                Some(c) => c,
                None => {
                    let first = rx
                        .recv()
                        .map_err(|_| TeolaError::Scheduler("completion channel closed".into()))?;
                    self.ctrs().count_graph_wakeup();
                    let mut drained = 1u64;
                    while let Ok(more) = rx.try_recv() {
                        pending.push_back(more);
                        drained += 1;
                    }
                    self.ctrs().count_graph_completions(drained);
                    first
                }
            };
            let node = c.node;
            // A speculative branch node completing while its guard is
            // still unresolved: buffer the completion (metrics included —
            // they are accounted once, at replay).  Releasing it early
            // would unblock descendants the unspeculated schedule would
            // not have run yet; a failure is deferred the same way so a
            // branch that ends up cancelled never surfaces `Failed`.
            if let Some(sb) = spec_branch.get_mut(&node) {
                sb.buffered = Some(c);
                continue;
            }
            metrics.queue_us += c.timing.queued_us;
            metrics.exec_us += c.timing.exec_us;
            // A failure completion means the engine can never serve this
            // node (e.g. every instance died): surface the error instead
            // of waiting forever for a real completion.  Still release
            // this query's KV sequences and vector-DB namespace on the
            // surviving engines before bailing.
            if let JobOutput::Failed(msg) = &c.output {
                // A node that already completed can only see a late
                // failure from a cancelled speculative dispatch (its seq
                // was aborted mid-flight); the node's value exists, so
                // the failure is moot.  Only reachable with speculation
                // on — the off path keeps strict failure propagation.
                if self.speculate && node < n && store.has(node) {
                    continue;
                }
                self.cleanup();
                return Err(TeolaError::Engine(format!("node {node}: {msg}")));
            }
            // Sentinel ids live far above any real node id (the graph can
            // grow at runtime): speculative prefill completions are
            // absorbed here, before any node indexing.
            if node >= SPEC_SENTINEL_BASE {
                let Some(sp) = specs.get_mut(&node) else { continue };
                sp.done = true;
                if let JobOutput::Tokens(t) = &c.output {
                    sp.output = t.clone();
                }
                metrics.n_engine_ops += 1;
                if sp.waiting && !sp.cancelled {
                    // The real node was ready before the speculation
                    // finished: dispatch its deferred suffix now.
                    let (v, slen, sout) = (sp.for_node, sp.len, sp.output.clone());
                    if let PayloadSpec::Prefill { seq, parts } =
                        &self.egraph.graph.nodes[v].payload
                    {
                        self.dispatch_prefill_suffix(
                            v,
                            *seq,
                            parts,
                            slen,
                            &sout,
                            &store,
                            &mut seq_len,
                            &tx,
                            &mut metrics,
                            &mut local_done,
                            wcp.remaining_us(),
                            &mut handed_off,
                        )?;
                    }
                }
                continue;
            }
            // Successors this completion's engine materialized itself:
            // mark them dispatched so the ready loop never re-sends them.
            if let Some(succs) = handed_off.remove(&node) {
                for s in succs {
                    if matches!(state[s], NodeState::Pending) {
                        state[s] = NodeState::Dispatched;
                    }
                }
            }
            if store.has(node) {
                continue; // duplicate stream delivery (benign)
            }
            let comp = self.egraph.graph.nodes[node].component;
            let class = match self.egraph.graph.nodes[node].kind {
                PrimKind::Prefilling | PrimKind::PartialPrefilling | PrimKind::FullPrefilling => "prefill",
                PrimKind::Decoding | PrimKind::PartialDecoding => "decode",
                _ => "other",
            };
            *metrics.per_component_us.entry((comp, class)).or_default() += c.timing.exec_us;
            // Measured-latency feedback into the WCP cost surface: the
            // per-(engine, op-class) EWMA correction narrows the gap
            // between static build-time estimates and what this machine
            // actually delivers (ROADMAP's PR4 gap).
            wcp::observe_latency(&self.egraph.graph.nodes[node], c.timing.exec_us);

            let mut value = Value::from_output(c.output);
            // Rerank post-selection: scores -> top-k candidate rows.
            if let Some((cands, top_k)) = pending_rerank.remove(&node) {
                if let Value::Scores(scores) = &value {
                    value = Value::TokenBatch(select_top_k(cands, scores, top_k));
                }
            }
            metrics.n_engine_ops += 1;
            wcp.complete(node);
            self.complete(node, value, &mut store, &mut indeg, &mut ready, &mut state, &mut done)?;
        }

        // End-of-query cleanup: release KV + vector namespaces.
        self.cleanup();
        let out = store.require(self.egraph.graph.output)?.clone();
        Ok((out, metrics))
    }

    fn cleanup(&self) {
        for (name, sender) in &self.routers {
            if name.starts_with("llm") || name == "vdb" {
                let (tx, rx) = channel();
                drop(rx);
                let _ = sender.send(QueueItem {
                    query: self.query,
                    node: usize::MAX,
                    depth: 0,
                    bundle: (self.query, u64::MAX),
                    arrival: Instant::now(),
                    rows: 0,
                    tokens: 0,
                    wcp_discounted: false,
                    prefix: None,
                    // Top priority under WCP ordering: cleanup releases KV
                    // residency, so it must never starve behind compute
                    // work (the old `wcp_us: 0` stamp sorted it *last* in
                    // descending-WCP buckets).  The engine scheduler
                    // fast-paths bookkeeping jobs anyway, but a correct
                    // stamp keeps any queued fallback path safe too.
                    // (`wcp_priority_us` uses saturating arithmetic, so
                    // MAX cannot overflow the aging term.)
                    wcp_us: u64::MAX,
                    tenant: self.tenant,
                    job: EngineJob::FreeQuery { query: self.query },
                    reply: tx,
                    successors: Vec::new(),
                });
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn complete(
        &self,
        v: NodeId,
        val: Value,
        store: &mut ObjectStore,
        indeg: &mut [usize],
        ready: &mut Vec<NodeId>,
        state: &mut [NodeState],
        done: &mut usize,
    ) -> Result<()> {
        store.put(v, val)?;
        state[v] = NodeState::Done;
        *done += 1;
        for &c in &self.egraph.children[v] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                ready.push(c);
            }
        }
        Ok(())
    }

    /// Resolve a data ref to token rows (Skipped upstream -> empty).
    fn rows_of(&self, store: &ObjectStore, r: &DataRef) -> Result<Vec<Vec<i32>>> {
        Ok(match r {
            DataRef::Const(rows) => rows.clone(),
            DataRef::Node(n) => store.require(*n)?.rows(),
            DataRef::NodeSlice(n, a, b) => {
                let rows = store.require(*n)?.rows();
                rows.get(*a..(*b).min(rows.len())).unwrap_or(&[]).to_vec()
            }
        })
    }

    fn embeddings_of(&self, store: &ObjectStore, r: &DataRef) -> Result<Vec<Vec<f32>>> {
        match r {
            DataRef::Node(n) => match store.require(*n)? {
                Value::Embeddings(e) => Ok(e.clone()),
                Value::Skipped => Ok(Vec::new()),
                other => Err(TeolaError::Scheduler(format!(
                    "expected embeddings from node {n}, got {other:?}"
                ))),
            },
            DataRef::NodeSlice(n, a, b) => match store.require(*n)? {
                Value::Embeddings(e) => {
                    Ok(e.get(*a..(*b).min(e.len())).unwrap_or(&[]).to_vec())
                }
                other => Err(TeolaError::Scheduler(format!(
                    "expected embeddings from node {n}, got {other:?}"
                ))),
            },
            DataRef::Const(_) => Err(TeolaError::Scheduler(
                "const embeddings are not supported".into(),
            )),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        v: NodeId,
        store: &mut ObjectStore,
        seq_len: &mut HashMap<u32, usize>,
        pending_rerank: &mut HashMap<NodeId, (Vec<Vec<i32>>, usize)>,
        tx: &Sender<Completion>,
        metrics: &mut QueryMetrics,
        state: &mut [NodeState],
        local_done: &mut Vec<(NodeId, Value)>,
        wcp_us: u64,
        handed_off: &mut HashMap<NodeId, Vec<NodeId>>,
        specs: &mut HashMap<usize, SpecPrefill>,
        spec_of: &HashMap<NodeId, usize>,
        spec_branch: &HashMap<NodeId, SpecBranch>,
        expansions: &mut Vec<NodeId>,
    ) -> Result<()> {
        let node = &self.egraph.graph.nodes[v];
        state[v] = NodeState::Dispatched;

        // Guard check.  A node dispatched speculatively (PR10) bypasses
        // it by construction: its guard is intentionally unresolved, and
        // resolution later confirms or cancels the in-flight work.
        if let Some((g, want)) = node.guard {
            let pass = matches!(store.get(g), Some(Value::Bool(b)) if *b == want);
            if !pass && !spec_branch.contains_key(&v) {
                // Invalidate any speculative template prefill that ran
                // ahead of this node: cancel the seq engine-side so its
                // KV reservation and residency are released.
                if let Some(s) = spec_of.get(&v) {
                    if let Some(sp) = specs.get_mut(s) {
                        if !sp.cancelled {
                            sp.cancelled = true;
                            self.cancel_spec_seq(v, sp.seq);
                        }
                    }
                }
                local_done.push((v, Value::Skipped));
                return Ok(());
            }
        }

        let host_start = Instant::now();
        match &node.payload {
            PayloadSpec::Condition { input, prob_true } => {
                let rows = self.rows_of(store, input)?;
                let mut h: u64 = self.query ^ 0x9E3779B97F4A7C15;
                for t in rows.iter().flatten() {
                    h = h.wrapping_mul(31).wrapping_add(*t as u64);
                }
                let outcome = (h % 10_000) as f64 / 10_000.0 < *prob_true;
                metrics.host_us += host_start.elapsed().as_micros() as u64;
                metrics.n_host_ops += 1;
                local_done.push((v, Value::Bool(outcome)));
            }
            PayloadSpec::Aggregate { parts, mode } => {
                let val = self.eval_aggregate(store, parts, *mode)?;
                metrics.host_us += host_start.elapsed().as_micros() as u64;
                metrics.n_host_ops += 1;
                local_done.push((v, val));
            }
            PayloadSpec::PartialDecode { decode, .. } => {
                // External: completed by the decode's streaming segments.
                // If the decode itself was skipped, skip the marker too.
                if matches!(store.get(*decode), Some(Value::Skipped)) {
                    local_done.push((v, Value::Skipped));
                } else if store.has(v) {
                    // already streamed before the edge fired — nothing to do
                }
                // Otherwise wait for the stream message.
            }
            PayloadSpec::Embed { sources } => {
                let mut chunks = Vec::new();
                for s in sources {
                    chunks.extend(self.rows_of(store, s)?);
                }
                self.send_job(v, EngineJob::Embed { chunks }, tx, wcp_us, metrics, Vec::new())?;
            }
            PayloadSpec::Ingest { chunks, embeddings } => {
                let mut rows = Vec::new();
                for c in chunks {
                    rows.extend(self.rows_of(store, c)?);
                }
                let embs = self.embeddings_of(store, embeddings)?;
                self.send_job(
                    v,
                    EngineJob::Ingest { namespace: self.query, chunks: rows, embeddings: embs },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
            PayloadSpec::VectorSearch { embeddings, top_k } => {
                let embs = self.embeddings_of(store, embeddings)?;
                self.send_job(
                    v,
                    EngineJob::VectorSearch {
                        namespace: self.query,
                        embeddings: embs,
                        top_k: *top_k,
                    },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
            PayloadSpec::Rerank { query, candidates, top_k } => {
                let qrows = self.rows_of(store, query)?;
                let qtok: Vec<i32> = qrows.into_iter().flatten().collect();
                let mut cands = Vec::new();
                for c in candidates {
                    cands.extend(self.rows_of(store, c)?);
                }
                let pairs: Vec<Vec<i32>> = cands
                    .iter()
                    .map(|c| {
                        let mut p = qtok.clone();
                        p.push(self.sep);
                        p.extend(c);
                        p
                    })
                    .collect();
                pending_rerank.insert(v, (cands, *top_k));
                self.send_job(v, EngineJob::Rerank { pairs }, tx, wcp_us, metrics, Vec::new())?;
            }
            PayloadSpec::Prefill { seq, parts } => {
                // A speculative template prefill may already hold this
                // seq's prefix engine-side: serialize behind it and send
                // only the suffix (out-of-order prefills would corrupt
                // the sequence length).
                if let Some(s) = spec_of.get(&v) {
                    if let Some(sp) = specs.get_mut(s) {
                        if !sp.cancelled {
                            if !sp.done {
                                sp.waiting = true;
                                return Ok(());
                            }
                            let (slen, sout) = (sp.len, sp.output.clone());
                            return self.dispatch_prefill_suffix(
                                v, *seq, parts, slen, &sout, store, seq_len, tx, metrics,
                                local_done, wcp_us, handed_off,
                            );
                        }
                    }
                }
                let mut tokens = Vec::new();
                for p in parts {
                    for row in self.rows_of(store, p)? {
                        tokens.extend(row);
                    }
                }
                let offset = *seq_len.get(seq).unwrap_or(&0);
                let budget = self.max_prompt.saturating_sub(offset).max(1);
                tokens.truncate(budget);
                if tokens.is_empty() {
                    tokens.push(self.sep);
                }
                // Cross-query prefix fingerprint: a from-scratch prefill
                // whose first prompt part is a Const instruction template
                // (shared by every query of the app) advertises it to the
                // engine scheduler.  Only set when the full instruction
                // survived truncation and a non-empty suffix follows.
                let prefix: Option<PrefixFp> = if offset == 0 {
                    match parts.first() {
                        Some(DataRef::Const(rows)) if rows.len() == 1 => {
                            let instr = &rows[0];
                            (instr.len() >= MIN_PREFIX_LEN && tokens.len() > instr.len())
                                .then(|| prefix_fingerprint(instr))
                        }
                        _ => None,
                    }
                } else {
                    None
                };
                seq_len.insert(*seq, offset + tokens.len());
                // A speculative dispatch never hands successors off
                // engine-side: auto-triggered downstream work could not
                // be cancelled when the guard refutes this branch.
                let plans = if spec_branch.contains_key(&v) {
                    Vec::new()
                } else {
                    self.prefill_successor_plans(v, *seq, wcp_us, handed_off)
                };
                self.send_job(
                    v,
                    EngineJob::Prefill { seq: (self.query, *seq), tokens, offset, prefix },
                    tx,
                    wcp_us,
                    metrics,
                    plans,
                )?;
            }
            PayloadSpec::Decode { seq, first_from, segments } => {
                let first = match store.require(*first_from)? {
                    Value::Tokens(t) => *t.first().unwrap_or(&self.sep),
                    _ => self.sep,
                };
                let segs: Vec<SegmentSpec> = segments
                    .iter()
                    .map(|(n, l)| SegmentSpec { node: *n, len: *l })
                    .collect();
                let plans = if spec_branch.contains_key(&v) {
                    Vec::new()
                } else {
                    self.decode_successor_plans(v, &segs, wcp_us, handed_off)
                };
                self.send_job(
                    v,
                    EngineJob::Decode {
                        seq: (self.query, *seq),
                        first_token: first,
                        segments: segs,
                    },
                    tx,
                    wcp_us,
                    metrics,
                    plans,
                )?;
            }
            PayloadSpec::WebSearch { queries, top_k } => {
                let mut rows = Vec::new();
                for q in queries {
                    rows.extend(self.rows_of(store, q)?);
                }
                self.send_job(
                    v,
                    EngineJob::WebSearch { queries: rows, top_k: *top_k },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
            PayloadSpec::ClonePrefix { src_seq, dst_seq, len, .. } => {
                seq_len.insert(*dst_seq, *len);
                self.send_job(
                    v,
                    EngineJob::ClonePrefix {
                        src: (self.query, *src_seq),
                        dst: (self.query, *dst_seq),
                        len: *len,
                    },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
            PayloadSpec::Tool { name, cost_us } => {
                self.send_job(
                    v,
                    EngineJob::ToolCall { name: name.clone(), cost_us: *cost_us },
                    tx,
                    wcp_us,
                    metrics,
                    Vec::new(),
                )?;
            }
            PayloadSpec::Expand { .. } => {
                // Runtime graph growth: the node's fan-out depends on its
                // input value, and growing the e-graph needs `&mut self` —
                // defer to `expand_node`, which the run loop calls as soon
                // as this dispatch borrow ends.
                expansions.push(v);
            }
        }
        Ok(())
    }

    fn eval_aggregate(
        &self,
        store: &ObjectStore,
        parts: &[DataRef],
        mode: AggregateMode,
    ) -> Result<Value> {
        match mode {
            AggregateMode::Barrier => Ok(Value::Unit),
            AggregateMode::ConcatRows => {
                // If every node part carries embeddings, concatenate those;
                // otherwise concatenate token rows.
                let all_embeddings = parts.iter().all(|p| {
                    matches!(p, DataRef::Node(n)
                        if matches!(store.get(*n), Some(Value::Embeddings(_))))
                });
                if all_embeddings && !parts.is_empty() {
                    let mut all = Vec::new();
                    for p in parts {
                        if let DataRef::Node(n) = p {
                            if let Value::Embeddings(e) = store.require(*n)? {
                                all.extend(e.clone());
                            }
                        }
                    }
                    return Ok(Value::Embeddings(all));
                }
                let mut rows = Vec::new();
                for p in parts {
                    rows.extend(self.rows_of(store, p)?);
                }
                Ok(Value::TokenBatch(rows))
            }
            AggregateMode::JoinTokens => {
                let mut toks = Vec::new();
                for p in parts {
                    for r in self.rows_of(store, p)? {
                        toks.extend(r);
                        toks.push(self.sep);
                    }
                }
                Ok(Value::Tokens(toks))
            }
            AggregateMode::TopK(k) => {
                // parts[0] = scores node, rest = candidate rows.
                let scores = match parts.first() {
                    Some(DataRef::Node(n)) => match store.require(*n)? {
                        Value::Scores(s) => s.clone(),
                        _ => Vec::new(),
                    },
                    _ => Vec::new(),
                };
                let mut rows = Vec::new();
                for p in &parts[1..] {
                    rows.extend(self.rows_of(store, p)?);
                }
                Ok(Value::TokenBatch(select_top_k(rows, &scores, k)))
            }
            AggregateMode::ZipPrepend => {
                // parts[..k] = Tokens (contexts), parts[k] = base rows.
                let (last, ctxs) = parts.split_last().ok_or_else(|| {
                    TeolaError::Scheduler("zip-prepend needs parts".into())
                })?;
                let base = self.rows_of(store, last)?;
                let mut out = Vec::with_capacity(base.len());
                for (i, b) in base.iter().enumerate() {
                    let mut row = ctxs
                        .get(i)
                        .map(|c| self.rows_of(store, c).unwrap_or_default())
                        .unwrap_or_default()
                        .into_iter()
                        .flatten()
                        .collect::<Vec<i32>>();
                    row.extend(b);
                    out.push(row);
                }
                Ok(Value::TokenBatch(out))
            }
        }
    }

    /// Successor plans for a prefill: a decode fed solely by this node
    /// (its seed token is this prefill's completion output) is chained
    /// directly on the engine side, skipping one dispatch round-trip.
    fn prefill_successor_plans(
        &self,
        v: NodeId,
        seq: u32,
        wcp_us: u64,
        handed_off: &mut HashMap<NodeId, Vec<NodeId>>,
    ) -> Vec<SuccessorPlan> {
        if !self.pipeline {
            return Vec::new();
        }
        let mut plans = Vec::new();
        for &d in &self.egraph.children[v] {
            let dn = &self.egraph.graph.nodes[d];
            if dn.guard.is_some() || self.egraph.parents[d] != [v] {
                continue;
            }
            let PayloadSpec::Decode { seq: dseq, first_from, segments } = &dn.payload else {
                continue;
            };
            if *first_from != v || *dseq != seq {
                continue;
            }
            let Some(sender) = self.routers.get(&dn.engine) else { continue };
            let segs: Vec<SegmentSpec> =
                segments.iter().map(|(n, l)| SegmentSpec { node: *n, len: *l }).collect();
            plans.push(SuccessorPlan {
                on_node: v,
                node: d,
                depth: self.egraph.depths[d],
                engine: sender.clone(),
                template: SuccessorTemplate::Decode { seq: (self.query, seq), segments: segs },
                wcp_us,
                tenant: self.tenant,
                fired: std::cell::Cell::new(false),
            });
            handed_off.entry(v).or_default().push(d);
        }
        plans
    }

    /// Successor plans for a decode: each streamed segment marker whose
    /// sole consumer is an embedding of exactly that marker's output is
    /// chained engine-side, so partial results feed the embedder as each
    /// segment completes — without a graph-scheduler round-trip.
    fn decode_successor_plans(
        &self,
        v: NodeId,
        segs: &[SegmentSpec],
        wcp_us: u64,
        handed_off: &mut HashMap<NodeId, Vec<NodeId>>,
    ) -> Vec<SuccessorPlan> {
        if !self.pipeline {
            return Vec::new();
        }
        let mut plans = Vec::new();
        for s in segs {
            let m = s.node;
            if m == v || m >= self.egraph.len() {
                continue; // self-segment (unsplit decode)
            }
            for &e in &self.egraph.children[m] {
                let en = &self.egraph.graph.nodes[e];
                if en.guard.is_some() || self.egraph.parents[e] != [m] {
                    continue;
                }
                let PayloadSpec::Embed { sources } = &en.payload else { continue };
                if *sources != [DataRef::Node(m)] {
                    continue;
                }
                let Some(sender) = self.routers.get(&en.engine) else { continue };
                plans.push(SuccessorPlan {
                    on_node: m,
                    node: e,
                    depth: self.egraph.depths[e],
                    engine: sender.clone(),
                    template: SuccessorTemplate::Embed,
                    wcp_us,
                    tenant: self.tenant,
                    fired: std::cell::Cell::new(false),
                });
                handed_off.entry(m).or_default().push(e);
            }
        }
        plans
    }

    /// Dispatch the non-template suffix of a prefill whose constant
    /// instruction prefix was already prefilled speculatively.  The final
    /// sequence length — and therefore the completion token — matches the
    /// unspeculated path exactly.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_prefill_suffix(
        &self,
        v: NodeId,
        seq: u32,
        parts: &[DataRef],
        spec_len: usize,
        spec_out: &[i32],
        store: &ObjectStore,
        seq_len: &mut HashMap<u32, usize>,
        tx: &Sender<Completion>,
        metrics: &mut QueryMetrics,
        local_done: &mut Vec<(NodeId, Value)>,
        wcp_us: u64,
        handed_off: &mut HashMap<NodeId, Vec<NodeId>>,
    ) -> Result<()> {
        let mut tokens = Vec::new();
        for p in parts {
            for row in self.rows_of(store, p)? {
                tokens.extend(row);
            }
        }
        tokens.truncate(self.max_prompt);
        if tokens.len() <= spec_len {
            // The template covered the whole prompt: the speculative
            // completion IS this node's completion (same seq length).
            local_done.push((v, Value::Tokens(spec_out.to_vec())));
            return Ok(());
        }
        let suffix = tokens.split_off(spec_len);
        seq_len.insert(seq, spec_len + suffix.len());
        let plans = self.prefill_successor_plans(v, seq, wcp_us, handed_off);
        self.send_job(
            v,
            EngineJob::Prefill {
                seq: (self.query, seq),
                tokens: suffix,
                offset: spec_len,
                prefix: None,
            },
            tx,
            wcp_us,
            metrics,
            plans,
        )
    }

    /// Launch speculative template prefills: a monolithic prefill that is
    /// not ready yet (guarded or waiting on upstream data) but whose first
    /// prompt part is a constant instruction template can prefill that
    /// template ahead of time under a sentinel node id.  Exactly one
    /// prefill must own the seq (splittable prefills are already split by
    /// Pass 3 and never qualify).
    #[allow(clippy::too_many_arguments)]
    fn launch_speculative_prefills(
        &self,
        indeg: &[usize],
        seq_len: &mut HashMap<u32, usize>,
        tx: &Sender<Completion>,
        metrics: &mut QueryMetrics,
        specs: &mut HashMap<usize, SpecPrefill>,
        spec_of: &mut HashMap<NodeId, usize>,
        wcp_us: u64,
    ) {
        let n = self.egraph.len();
        // Count writers per seq: speculation is only safe when this node
        // is the seq's sole prefill and nothing clones into it.
        let mut writers: HashMap<u32, usize> = HashMap::new();
        for nd in &self.egraph.graph.nodes {
            match &nd.payload {
                PayloadSpec::Prefill { seq, .. } => *writers.entry(*seq).or_default() += 1,
                PayloadSpec::ClonePrefix { dst_seq, .. } => {
                    *writers.entry(*dst_seq).or_default() += 2
                }
                _ => {}
            }
        }
        for v in 0..n {
            let nd = &self.egraph.graph.nodes[v];
            if nd.kind != PrimKind::Prefilling {
                continue;
            }
            if nd.guard.is_none() && indeg[v] == 0 {
                continue; // ready right now: nothing to win
            }
            let PayloadSpec::Prefill { seq, parts } = &nd.payload else { continue };
            if writers.get(seq).copied().unwrap_or(0) != 1 {
                continue;
            }
            let Some(DataRef::Const(rows)) = parts.first() else { continue };
            if rows.len() != 1 {
                continue;
            }
            let instr = rows[0].clone();
            if instr.len() < MIN_PREFIX_LEN || instr.len() >= self.max_prompt {
                continue;
            }
            let Some(sender) = self.routers.get(&nd.engine) else { continue };
            let sentinel = SPEC_SENTINEL_BASE + specs.len();
            let job = EngineJob::Prefill {
                seq: (self.query, *seq),
                tokens: instr.clone(),
                offset: 0,
                prefix: None,
            };
            metrics.dispatch_hops += 1;
            let ok = sender
                .send(QueueItem {
                    query: self.query,
                    node: sentinel,
                    depth: self.egraph.depths[v],
                    bundle: (self.query, sentinel as u64),
                    arrival: Instant::now(),
                    rows: job.rows(),
                    tokens: job.kv_tokens(),
                    wcp_discounted: false,
                    prefix: None,
                    wcp_us,
                    tenant: self.tenant,
                    job,
                    reply: tx.clone(),
                    successors: Vec::new(),
                })
                .is_ok();
            if ok {
                seq_len.insert(*seq, instr.len());
                specs.insert(
                    sentinel,
                    SpecPrefill {
                        for_node: v,
                        seq: *seq,
                        len: instr.len(),
                        done: false,
                        output: Vec::new(),
                        waiting: false,
                        cancelled: false,
                    },
                );
                spec_of.insert(v, sentinel);
            }
        }
    }

    /// Cancel a speculated seq engine-side: purge any queued prefill,
    /// drop the sequence state and release residency.  Bookkeeping-only
    /// (the engine never emits a completion toward the speculating node),
    /// so an invalidated speculation can never fail the query.
    fn cancel_spec_seq(&self, v: NodeId, seq: u32) {
        let engine = &self.egraph.graph.nodes[v].engine;
        if let Some(sender) = self.routers.get(engine) {
            let (dead_tx, dead_rx) = channel();
            drop(dead_rx);
            let _ = sender.send(QueueItem {
                query: self.query,
                node: usize::MAX,
                depth: 0,
                bundle: (self.query, u64::MAX),
                arrival: Instant::now(),
                rows: 0,
                tokens: 0,
                wcp_discounted: false,
                prefix: None,
                wcp_us: u64::MAX,
                tenant: self.tenant,
                job: EngineJob::CancelSeq { seq: (self.query, seq) },
                reply: dead_tx,
                successors: Vec::new(),
            });
        }
    }

    /// Guarded nodes eligible for speculative dispatch right now (PR10):
    /// still Pending, an engine op, guard unresolved, every non-guard
    /// parent already Done (so inputs are materialized), and the guarded
    /// branch's probability at or above the speculation threshold.
    fn branch_speculation_candidates(
        &self,
        state: &[NodeState],
        store: &ObjectStore,
    ) -> Vec<(NodeId, NodeId, bool)> {
        let mut out = Vec::new();
        for v in 0..self.egraph.len() {
            if !matches!(state[v], NodeState::Pending) {
                continue;
            }
            let nd = &self.egraph.graph.nodes[v];
            let Some((g, want)) = nd.guard else { continue };
            if !nd.kind.is_engine_op() || store.has(g) {
                continue;
            }
            if !self.egraph.parents[v]
                .iter()
                .all(|&p| p == g || matches!(state[p], NodeState::Done))
            {
                continue;
            }
            if wcp::guard_pass_prob(&self.egraph, Some((g, want))) < self.spec_threshold {
                continue;
            }
            out.push((v, g, want));
        }
        out
    }

    /// Guard resolution (PR10): condition `cond` just completed with
    /// `outcome`.  Prune the refuted branch from the WCP surface, restamp
    /// queued work with the re-weighted remaining critical path, then
    /// confirm (in place — zero re-dispatch) or cancel (purge + abort +
    /// refund) every speculatively dispatched node this guard covers.
    #[allow(clippy::too_many_arguments)]
    fn resolve_speculation(
        &self,
        cond: NodeId,
        outcome: bool,
        wcp: &mut WcpTracker,
        spec_branch: &mut HashMap<NodeId, SpecBranch>,
        pending: &mut VecDeque<Completion>,
        local_done: &mut Vec<(NodeId, Value)>,
        metrics: &mut QueryMetrics,
        seq_len: &mut HashMap<u32, usize>,
        specs: &mut HashMap<usize, SpecPrefill>,
        spec_of: &HashMap<NodeId, usize>,
    ) {
        let new_rem = wcp.resolve_guard(cond, outcome);
        self.restamp_queues(new_rem);
        let affected: Vec<NodeId> = spec_branch
            .iter()
            .filter(|(_, sb)| sb.cond == cond)
            .map(|(&v, _)| v)
            .collect();
        for v in affected {
            let sb = spec_branch.remove(&v).expect("collected above");
            if outcome == sb.want {
                // Confirmed: a buffered completion replays through the
                // normal path; in-flight work just flows on arrival.
                if let Some(c) = sb.buffered {
                    pending.push_front(c);
                }
                continue;
            }
            // Refuted: purge queued work engine-side (replies dropped,
            // fair-share charge refunded), abort any seq the node wrote,
            // and surface the same `Skipped` the unspeculated path yields.
            metrics.speculative_cancelled += 1;
            self.cancel_branch_node(v);
            let mut cancelled_seq = None;
            if let Some((seq, prior)) = sb.seq_undo {
                match prior {
                    Some(l) => {
                        seq_len.insert(seq, l);
                    }
                    None => {
                        seq_len.remove(&seq);
                    }
                }
                self.cancel_spec_seq(v, seq);
                cancelled_seq = Some(seq);
            }
            // A template prefill speculated ahead of this node (PR7) is
            // normally invalidated by the guard-fail dispatch path; branch
            // speculation bypassed that path, so invalidate it here.
            if let Some(s) = spec_of.get(&v) {
                if let Some(sp) = specs.get_mut(s) {
                    if !sp.cancelled {
                        sp.cancelled = true;
                        if cancelled_seq != Some(sp.seq) {
                            self.cancel_spec_seq(v, sp.seq);
                        }
                    }
                }
            }
            local_done.push((v, Value::Skipped));
        }
    }

    /// Purge a refuted speculative node's queued work from its engine
    /// scheduler: matching queue items are dropped (their replies with
    /// them, so a cancelled speculation never surfaces `Failed`) and the
    /// tenant's fair-queueing charge is refunded if already dispatched.
    fn cancel_branch_node(&self, v: NodeId) {
        let engine = &self.egraph.graph.nodes[v].engine;
        if let Some(sender) = self.routers.get(engine) {
            let (dead_tx, dead_rx) = channel();
            drop(dead_rx);
            let _ = sender.send(QueueItem {
                query: self.query,
                node: usize::MAX,
                depth: 0,
                bundle: (self.query, u64::MAX),
                arrival: Instant::now(),
                rows: 0,
                tokens: 0,
                wcp_discounted: false,
                prefix: None,
                wcp_us: u64::MAX,
                tenant: self.tenant,
                job: EngineJob::CancelNode { query: self.query, node: v },
                reply: dead_tx,
                successors: Vec::new(),
            });
        }
    }

    /// Broadcast a fresh remaining-WCP stamp for this query to every
    /// engine scheduler (guard resolution or graph growth re-weighted the
    /// critical path); queued items are restamped in place.
    fn restamp_queues(&self, wcp_us: u64) {
        for sender in self.routers.values() {
            let (dead_tx, dead_rx) = channel();
            drop(dead_rx);
            let _ = sender.send(QueueItem {
                query: self.query,
                node: usize::MAX,
                depth: 0,
                bundle: (self.query, u64::MAX),
                arrival: Instant::now(),
                rows: 0,
                tokens: 0,
                wcp_discounted: false,
                prefix: None,
                wcp_us: u64::MAX,
                tenant: self.tenant,
                job: EngineJob::RestampWcp { query: self.query, wcp_us },
                reply: dead_tx,
                successors: Vec::new(),
            });
        }
    }

    /// Runtime graph growth (PR10): an Expansion node's input arrived —
    /// decide the fan-out (an LLM-runtime decision, modeled here as a
    /// deterministic function of the input token surface), append one
    /// tool-call node per spawn plus a barrier join collecting the
    /// fan-in, and extend the run-local bookkeeping over the grown graph.
    /// With speculation on, the tool calls are independent (concurrent
    /// fan-out); off chains them sequentially — outputs are identical
    /// either way, only the schedule differs.
    #[allow(clippy::too_many_arguments)]
    fn expand_node(
        &mut self,
        x: NodeId,
        n: &mut usize,
        store: &ObjectStore,
        indeg: &mut Vec<usize>,
        state: &mut Vec<NodeState>,
        ready: &mut Vec<NodeId>,
        wcp: &mut WcpTracker,
        metrics: &mut QueryMetrics,
        expansion_join: &mut HashMap<NodeId, NodeId>,
    ) -> Result<()> {
        let host_start = Instant::now();
        let PayloadSpec::Expand { input, tool, cost_us, max_fan } =
            self.egraph.graph.nodes[x].payload.clone()
        else {
            return Err(TeolaError::Scheduler(format!("node {x} is not an expansion")));
        };
        let engine = self.egraph.graph.nodes[x].engine.clone();
        let component = self.egraph.graph.nodes[x].component;
        let rows = self.rows_of(store, &input)?;
        let mut h: u64 = self.query ^ 0xD1B5_4A32_D192_ED03;
        for t in rows.iter().flatten() {
            h = h.wrapping_mul(31).wrapping_add(*t as u64);
        }
        let fan = 1 + (h % max_fan.max(1) as u64) as usize;
        let base = self.egraph.len();
        let mut prims = Vec::with_capacity(fan + 1);
        for i in 0..fan {
            prims.push(Primitive {
                id: 0,
                kind: PrimKind::ToolCalling,
                engine: engine.clone(),
                component,
                batchable: true,
                splittable: false,
                payload: PayloadSpec::Tool { name: format!("{tool}#{i}"), cost_us },
                hard_deps: if self.speculate || i == 0 {
                    Vec::new()
                } else {
                    vec![base + i - 1]
                },
                guard: None,
            });
        }
        prims.push(Primitive {
            id: 0,
            kind: PrimKind::Aggregate,
            engine: String::new(),
            component,
            batchable: false,
            splittable: false,
            payload: PayloadSpec::Aggregate {
                parts: (0..fan).map(|i| DataRef::Node(base + i)).collect(),
                mode: AggregateMode::Barrier,
            },
            hard_deps: Vec::new(),
            guard: None,
        });
        let ids = self.egraph.append(prims)?;
        for &id in &ids {
            indeg.push(self.egraph.parents[id].len());
            state.push(NodeState::Pending);
        }
        *n = self.egraph.len();
        for &id in &ids {
            if indeg[id] == 0 {
                ready.push(id);
            }
        }
        expansion_join.insert(*ids.last().expect("fan >= 1"), x);
        let new_rem = wcp.grow(&self.egraph);
        if self.speculate {
            self.restamp_queues(new_rem);
        }
        metrics.host_us += host_start.elapsed().as_micros() as u64;
        metrics.n_host_ops += 1;
        Ok(())
    }

    fn send_job(
        &self,
        v: NodeId,
        job: EngineJob,
        tx: &Sender<Completion>,
        wcp_us: u64,
        metrics: &mut QueryMetrics,
        successors: Vec<SuccessorPlan>,
    ) -> Result<()> {
        let node = &self.egraph.graph.nodes[v];
        let sender = self.routers.get(&node.engine).ok_or_else(|| {
            TeolaError::Scheduler(format!("no engine registered for '{}'", node.engine))
        })?;
        let rows = job.rows();
        let prefix = job.prefix();
        // KV token estimate from the same token surface the WCP cost
        // estimates weigh: prompt tokens for prefills, planned new
        // tokens for decodes.  The engine scheduler reserves by it under
        // token-denominated accounting.
        let tokens = job.kv_tokens();
        // Every send through this path is one graph-scheduler round-trip;
        // engine-side successor handoffs bypass it by construction.
        metrics.dispatch_hops += 1;
        sender
            .send(QueueItem {
                query: self.query,
                node: v,
                depth: self.egraph.depths[v],
                bundle: (self.query, v as u64),
                arrival: Instant::now(),
                rows,
                tokens,
                wcp_discounted: false,
                prefix,
                wcp_us,
                tenant: self.tenant,
                job,
                reply: tx.clone(),
                successors,
            })
            .map_err(|_| TeolaError::Scheduler(format!("engine '{}' is down", node.engine)))
    }
}

/// Keep the k best-scoring rows (stable on ties by original order).
pub fn select_top_k(rows: Vec<Vec<i32>>, scores: &[f32], k: usize) -> Vec<Vec<i32>> {
    let mut idx: Vec<usize> = (0..rows.len()).collect();
    idx.sort_by(|&a, &b| {
        let sa = scores.get(a).copied().unwrap_or(f32::MIN);
        let sb = scores.get(b).copied().unwrap_or(f32::MIN);
        sb.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.into_iter().map(|i| rows[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_selection() {
        let rows = vec![vec![1], vec![2], vec![3]];
        let got = select_top_k(rows, &[0.1, 0.9, 0.5], 2);
        assert_eq!(got, vec![vec![2], vec![3]]);
    }

    #[test]
    fn top_k_handles_missing_scores() {
        let rows = vec![vec![1], vec![2]];
        let got = select_top_k(rows, &[0.5], 2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], vec![1]);
    }
}
