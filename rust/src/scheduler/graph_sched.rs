//! Upper-tier graph scheduler (§5.1): one runner per query.
//!
//! Tracks in-degrees of the query's e-graph, dispatches primitive nodes
//! whose dependencies are met to the appropriate engine scheduler,
//! evaluates host-side control-flow primitives inline, and handles
//! streaming partial-decode completions arriving out of graph order.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::time::Instant;

use crate::engines::prefix::{prefix_fingerprint, MIN_PREFIX_LEN};
use crate::engines::{Completion, EngineJob, JobOutput, NodeId, PrefixFp, QueryId, SegmentSpec};
use crate::error::{Result, TeolaError};
use crate::graph::egraph::EGraph;
use crate::graph::primitive::{AggregateMode, DataRef, PayloadSpec, PrimKind};
use crate::graph::value::Value;
use crate::scheduler::batching::QueueItem;
use crate::scheduler::object_store::ObjectStore;
use crate::scheduler::wcp::{self, WcpTracker};

/// Per-query latency accounting (feeds Figs. 1, 12 and EXPERIMENTS.md).
#[derive(Debug, Clone, Default)]
pub struct QueryMetrics {
    /// End-to-end wall time (filled by the caller).
    pub e2e_us: u64,
    /// Graph construction + optimization time (filled by the caller).
    pub opt_us: u64,
    /// Sum of engine-scheduler queueing time across completions.
    pub queue_us: u64,
    /// Sum of engine execution time across completions.
    pub exec_us: u64,
    /// Host-side control-flow evaluation time.
    pub host_us: u64,
    pub n_engine_ops: usize,
    pub n_host_ops: usize,
    /// exec_us per (component, class) where class is "prefill", "decode"
    /// or "other" — the Fig. 1 module breakdown.
    pub per_component_us: HashMap<(usize, &'static str), u64>,
}

/// Routing table: engine name -> its scheduler's queue.
pub type EngineRouter = HashMap<String, Sender<QueueItem>>;

/// Executes one query's e-graph to completion.
pub struct QueryRunner {
    pub query: QueryId,
    pub egraph: EGraph,
    pub routers: EngineRouter,
    /// SEP token id (prompt-part delimiter in rerank pairs).
    pub sep: i32,
    /// Clamp for prompt length (leave decode headroom in the KV cache).
    pub max_prompt: usize,
}

enum NodeState {
    Pending,
    Dispatched,
    Done,
}

impl QueryRunner {
    /// Build a runner.
    pub fn new(query: QueryId, egraph: EGraph, routers: EngineRouter, sep: i32) -> QueryRunner {
        QueryRunner { query, egraph, routers, sep, max_prompt: 224 }
    }

    /// Run the e-graph; returns the output value and metrics.
    pub fn run(self) -> Result<(Value, QueryMetrics)> {
        let (tx, rx) = channel::<Completion>();
        let n = self.egraph.len();
        let mut indeg = self.egraph.in_degrees();
        let mut state: Vec<NodeState> = (0..n).map(|_| NodeState::Pending).collect();
        let mut store = ObjectStore::new();
        let mut metrics = QueryMetrics::default();
        let mut seq_len: HashMap<u32, usize> = HashMap::new();
        let mut pending_rerank: HashMap<NodeId, (Vec<Vec<i32>>, usize)> = HashMap::new();
        let mut done = 0usize;
        // Remaining critical-path estimate (§8): stamped onto every
        // dispatched queue item, tightened as nodes complete.
        let mut wcp = WcpTracker::new(&self.egraph);

        // Local completion worklist (host ops complete synchronously).
        let mut ready: Vec<NodeId> = self.egraph.sources();
        let mut local_done: Vec<(NodeId, Value)> = Vec::new();

        while done < n {
            // Dispatch every ready node.
            while let Some(v) = ready.pop() {
                if matches!(state[v], NodeState::Pending) {
                    self.dispatch(
                        v,
                        &mut store,
                        &mut seq_len,
                        &mut pending_rerank,
                        &tx,
                        &mut metrics,
                        &mut state,
                        &mut local_done,
                        wcp.remaining_us(),
                    )?;
                }
            }
            // Apply synchronous completions.
            if let Some((v, val)) = local_done.pop() {
                wcp.complete(v);
                self.complete(v, val, &mut store, &mut indeg, &mut ready, &mut state, &mut done)?;
                continue;
            }
            if done >= n {
                break;
            }
            // Wait for an engine completion.
            let c = rx
                .recv()
                .map_err(|_| TeolaError::Scheduler("completion channel closed".into()))?;
            metrics.queue_us += c.timing.queued_us;
            metrics.exec_us += c.timing.exec_us;
            let node = c.node;
            // A failure completion means the engine can never serve this
            // node (e.g. every instance died): surface the error instead
            // of waiting forever for a real completion.  Still release
            // this query's KV sequences and vector-DB namespace on the
            // surviving engines before bailing.
            if let JobOutput::Failed(msg) = &c.output {
                self.cleanup();
                return Err(TeolaError::Engine(format!("node {node}: {msg}")));
            }
            if store.has(node) {
                continue; // duplicate stream delivery (benign)
            }
            let comp = self.egraph.graph.nodes[node].component;
            let class = match self.egraph.graph.nodes[node].kind {
                PrimKind::Prefilling | PrimKind::PartialPrefilling | PrimKind::FullPrefilling => "prefill",
                PrimKind::Decoding | PrimKind::PartialDecoding => "decode",
                _ => "other",
            };
            *metrics.per_component_us.entry((comp, class)).or_default() += c.timing.exec_us;
            // Measured-latency feedback into the WCP cost surface: the
            // per-(engine, op-class) EWMA correction narrows the gap
            // between static build-time estimates and what this machine
            // actually delivers (ROADMAP's PR4 gap).
            wcp::observe_latency(&self.egraph.graph.nodes[node], c.timing.exec_us);

            let mut value = Value::from_output(c.output);
            // Rerank post-selection: scores -> top-k candidate rows.
            if let Some((cands, top_k)) = pending_rerank.remove(&node) {
                if let Value::Scores(scores) = &value {
                    value = Value::TokenBatch(select_top_k(cands, scores, top_k));
                }
            }
            metrics.n_engine_ops += 1;
            wcp.complete(node);
            self.complete(node, value, &mut store, &mut indeg, &mut ready, &mut state, &mut done)?;
        }

        // End-of-query cleanup: release KV + vector namespaces.
        self.cleanup();
        let out = store.require(self.egraph.graph.output)?.clone();
        Ok((out, metrics))
    }

    fn cleanup(&self) {
        for (name, sender) in &self.routers {
            if name.starts_with("llm") || name == "vdb" {
                let (tx, rx) = channel();
                drop(rx);
                let _ = sender.send(QueueItem {
                    query: self.query,
                    node: usize::MAX,
                    depth: 0,
                    bundle: (self.query, u64::MAX),
                    arrival: Instant::now(),
                    rows: 0,
                    tokens: 0,
                    wcp_discounted: false,
                    prefix: None,
                    // Top priority under WCP ordering: cleanup releases KV
                    // residency, so it must never starve behind compute
                    // work (the old `wcp_us: 0` stamp sorted it *last* in
                    // descending-WCP buckets).  The engine scheduler
                    // fast-paths bookkeeping jobs anyway, but a correct
                    // stamp keeps any queued fallback path safe too.
                    // (`wcp_priority_us` uses saturating arithmetic, so
                    // MAX cannot overflow the aging term.)
                    wcp_us: u64::MAX,
                    job: EngineJob::FreeQuery { query: self.query },
                    reply: tx,
                });
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn complete(
        &self,
        v: NodeId,
        val: Value,
        store: &mut ObjectStore,
        indeg: &mut [usize],
        ready: &mut Vec<NodeId>,
        state: &mut [NodeState],
        done: &mut usize,
    ) -> Result<()> {
        store.put(v, val)?;
        state[v] = NodeState::Done;
        *done += 1;
        for &c in &self.egraph.children[v] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                ready.push(c);
            }
        }
        Ok(())
    }

    /// Resolve a data ref to token rows (Skipped upstream -> empty).
    fn rows_of(&self, store: &ObjectStore, r: &DataRef) -> Result<Vec<Vec<i32>>> {
        Ok(match r {
            DataRef::Const(rows) => rows.clone(),
            DataRef::Node(n) => store.require(*n)?.rows(),
            DataRef::NodeSlice(n, a, b) => {
                let rows = store.require(*n)?.rows();
                rows.get(*a..(*b).min(rows.len())).unwrap_or(&[]).to_vec()
            }
        })
    }

    fn embeddings_of(&self, store: &ObjectStore, r: &DataRef) -> Result<Vec<Vec<f32>>> {
        match r {
            DataRef::Node(n) => match store.require(*n)? {
                Value::Embeddings(e) => Ok(e.clone()),
                Value::Skipped => Ok(Vec::new()),
                other => Err(TeolaError::Scheduler(format!(
                    "expected embeddings from node {n}, got {other:?}"
                ))),
            },
            DataRef::NodeSlice(n, a, b) => match store.require(*n)? {
                Value::Embeddings(e) => {
                    Ok(e.get(*a..(*b).min(e.len())).unwrap_or(&[]).to_vec())
                }
                other => Err(TeolaError::Scheduler(format!(
                    "expected embeddings from node {n}, got {other:?}"
                ))),
            },
            DataRef::Const(_) => Err(TeolaError::Scheduler(
                "const embeddings are not supported".into(),
            )),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        v: NodeId,
        store: &mut ObjectStore,
        seq_len: &mut HashMap<u32, usize>,
        pending_rerank: &mut HashMap<NodeId, (Vec<Vec<i32>>, usize)>,
        tx: &Sender<Completion>,
        metrics: &mut QueryMetrics,
        state: &mut [NodeState],
        local_done: &mut Vec<(NodeId, Value)>,
        wcp_us: u64,
    ) -> Result<()> {
        let node = &self.egraph.graph.nodes[v];
        state[v] = NodeState::Dispatched;

        // Guard check.
        if let Some((g, want)) = node.guard {
            let pass = matches!(store.get(g), Some(Value::Bool(b)) if *b == want);
            if !pass {
                local_done.push((v, Value::Skipped));
                return Ok(());
            }
        }

        let host_start = Instant::now();
        match &node.payload {
            PayloadSpec::Condition { input, prob_true } => {
                let rows = self.rows_of(store, input)?;
                let mut h: u64 = self.query ^ 0x9E3779B97F4A7C15;
                for t in rows.iter().flatten() {
                    h = h.wrapping_mul(31).wrapping_add(*t as u64);
                }
                let outcome = (h % 10_000) as f64 / 10_000.0 < *prob_true;
                metrics.host_us += host_start.elapsed().as_micros() as u64;
                metrics.n_host_ops += 1;
                local_done.push((v, Value::Bool(outcome)));
            }
            PayloadSpec::Aggregate { parts, mode } => {
                let val = self.eval_aggregate(store, parts, *mode)?;
                metrics.host_us += host_start.elapsed().as_micros() as u64;
                metrics.n_host_ops += 1;
                local_done.push((v, val));
            }
            PayloadSpec::PartialDecode { decode, .. } => {
                // External: completed by the decode's streaming segments.
                // If the decode itself was skipped, skip the marker too.
                if matches!(store.get(*decode), Some(Value::Skipped)) {
                    local_done.push((v, Value::Skipped));
                } else if store.has(v) {
                    // already streamed before the edge fired — nothing to do
                }
                // Otherwise wait for the stream message.
            }
            PayloadSpec::Embed { sources } => {
                let mut chunks = Vec::new();
                for s in sources {
                    chunks.extend(self.rows_of(store, s)?);
                }
                self.send_job(v, EngineJob::Embed { chunks }, tx, wcp_us)?;
            }
            PayloadSpec::Ingest { chunks, embeddings } => {
                let mut rows = Vec::new();
                for c in chunks {
                    rows.extend(self.rows_of(store, c)?);
                }
                let embs = self.embeddings_of(store, embeddings)?;
                self.send_job(
                    v,
                    EngineJob::Ingest { namespace: self.query, chunks: rows, embeddings: embs },
                    tx,
                    wcp_us,
                )?;
            }
            PayloadSpec::VectorSearch { embeddings, top_k } => {
                let embs = self.embeddings_of(store, embeddings)?;
                self.send_job(
                    v,
                    EngineJob::VectorSearch {
                        namespace: self.query,
                        embeddings: embs,
                        top_k: *top_k,
                    },
                    tx,
                    wcp_us,
                )?;
            }
            PayloadSpec::Rerank { query, candidates, top_k } => {
                let qrows = self.rows_of(store, query)?;
                let qtok: Vec<i32> = qrows.into_iter().flatten().collect();
                let mut cands = Vec::new();
                for c in candidates {
                    cands.extend(self.rows_of(store, c)?);
                }
                let pairs: Vec<Vec<i32>> = cands
                    .iter()
                    .map(|c| {
                        let mut p = qtok.clone();
                        p.push(self.sep);
                        p.extend(c);
                        p
                    })
                    .collect();
                pending_rerank.insert(v, (cands, *top_k));
                self.send_job(v, EngineJob::Rerank { pairs }, tx, wcp_us)?;
            }
            PayloadSpec::Prefill { seq, parts } => {
                let mut tokens = Vec::new();
                for p in parts {
                    for row in self.rows_of(store, p)? {
                        tokens.extend(row);
                    }
                }
                let offset = *seq_len.get(seq).unwrap_or(&0);
                let budget = self.max_prompt.saturating_sub(offset).max(1);
                tokens.truncate(budget);
                if tokens.is_empty() {
                    tokens.push(self.sep);
                }
                // Cross-query prefix fingerprint: a from-scratch prefill
                // whose first prompt part is a Const instruction template
                // (shared by every query of the app) advertises it to the
                // engine scheduler.  Only set when the full instruction
                // survived truncation and a non-empty suffix follows.
                let prefix: Option<PrefixFp> = if offset == 0 {
                    match parts.first() {
                        Some(DataRef::Const(rows)) if rows.len() == 1 => {
                            let instr = &rows[0];
                            (instr.len() >= MIN_PREFIX_LEN && tokens.len() > instr.len())
                                .then(|| prefix_fingerprint(instr))
                        }
                        _ => None,
                    }
                } else {
                    None
                };
                seq_len.insert(*seq, offset + tokens.len());
                self.send_job(
                    v,
                    EngineJob::Prefill { seq: (self.query, *seq), tokens, offset, prefix },
                    tx,
                    wcp_us,
                )?;
            }
            PayloadSpec::Decode { seq, first_from, segments } => {
                let first = match store.require(*first_from)? {
                    Value::Tokens(t) => *t.first().unwrap_or(&self.sep),
                    _ => self.sep,
                };
                let segs: Vec<SegmentSpec> = segments
                    .iter()
                    .map(|(n, l)| SegmentSpec { node: *n, len: *l })
                    .collect();
                self.send_job(
                    v,
                    EngineJob::Decode {
                        seq: (self.query, *seq),
                        first_token: first,
                        segments: segs,
                    },
                    tx,
                    wcp_us,
                )?;
            }
            PayloadSpec::WebSearch { queries, top_k } => {
                let mut rows = Vec::new();
                for q in queries {
                    rows.extend(self.rows_of(store, q)?);
                }
                self.send_job(v, EngineJob::WebSearch { queries: rows, top_k: *top_k }, tx, wcp_us)?;
            }
            PayloadSpec::ClonePrefix { src_seq, dst_seq, len, .. } => {
                seq_len.insert(*dst_seq, *len);
                self.send_job(
                    v,
                    EngineJob::ClonePrefix {
                        src: (self.query, *src_seq),
                        dst: (self.query, *dst_seq),
                        len: *len,
                    },
                    tx,
                    wcp_us,
                )?;
            }
            PayloadSpec::Tool { name, cost_us } => {
                self.send_job(
                    v,
                    EngineJob::ToolCall { name: name.clone(), cost_us: *cost_us },
                    tx,
                    wcp_us,
                )?;
            }
        }
        Ok(())
    }

    fn eval_aggregate(
        &self,
        store: &ObjectStore,
        parts: &[DataRef],
        mode: AggregateMode,
    ) -> Result<Value> {
        match mode {
            AggregateMode::Barrier => Ok(Value::Unit),
            AggregateMode::ConcatRows => {
                // If every node part carries embeddings, concatenate those;
                // otherwise concatenate token rows.
                let all_embeddings = parts.iter().all(|p| {
                    matches!(p, DataRef::Node(n)
                        if matches!(store.get(*n), Some(Value::Embeddings(_))))
                });
                if all_embeddings && !parts.is_empty() {
                    let mut all = Vec::new();
                    for p in parts {
                        if let DataRef::Node(n) = p {
                            if let Value::Embeddings(e) = store.require(*n)? {
                                all.extend(e.clone());
                            }
                        }
                    }
                    return Ok(Value::Embeddings(all));
                }
                let mut rows = Vec::new();
                for p in parts {
                    rows.extend(self.rows_of(store, p)?);
                }
                Ok(Value::TokenBatch(rows))
            }
            AggregateMode::JoinTokens => {
                let mut toks = Vec::new();
                for p in parts {
                    for r in self.rows_of(store, p)? {
                        toks.extend(r);
                        toks.push(self.sep);
                    }
                }
                Ok(Value::Tokens(toks))
            }
            AggregateMode::TopK(k) => {
                // parts[0] = scores node, rest = candidate rows.
                let scores = match parts.first() {
                    Some(DataRef::Node(n)) => match store.require(*n)? {
                        Value::Scores(s) => s.clone(),
                        _ => Vec::new(),
                    },
                    _ => Vec::new(),
                };
                let mut rows = Vec::new();
                for p in &parts[1..] {
                    rows.extend(self.rows_of(store, p)?);
                }
                Ok(Value::TokenBatch(select_top_k(rows, &scores, k)))
            }
            AggregateMode::ZipPrepend => {
                // parts[..k] = Tokens (contexts), parts[k] = base rows.
                let (last, ctxs) = parts.split_last().ok_or_else(|| {
                    TeolaError::Scheduler("zip-prepend needs parts".into())
                })?;
                let base = self.rows_of(store, last)?;
                let mut out = Vec::with_capacity(base.len());
                for (i, b) in base.iter().enumerate() {
                    let mut row = ctxs
                        .get(i)
                        .map(|c| self.rows_of(store, c).unwrap_or_default())
                        .unwrap_or_default()
                        .into_iter()
                        .flatten()
                        .collect::<Vec<i32>>();
                    row.extend(b);
                    out.push(row);
                }
                Ok(Value::TokenBatch(out))
            }
        }
    }

    fn send_job(
        &self,
        v: NodeId,
        job: EngineJob,
        tx: &Sender<Completion>,
        wcp_us: u64,
    ) -> Result<()> {
        let node = &self.egraph.graph.nodes[v];
        let sender = self.routers.get(&node.engine).ok_or_else(|| {
            TeolaError::Scheduler(format!("no engine registered for '{}'", node.engine))
        })?;
        let rows = job.rows();
        let prefix = job.prefix();
        // KV token estimate from the same token surface the WCP cost
        // estimates weigh: prompt tokens for prefills, planned new
        // tokens for decodes.  The engine scheduler reserves by it under
        // token-denominated accounting.
        let tokens = job.kv_tokens();
        sender
            .send(QueueItem {
                query: self.query,
                node: v,
                depth: self.egraph.depths[v],
                bundle: (self.query, v as u64),
                arrival: Instant::now(),
                rows,
                tokens,
                wcp_discounted: false,
                prefix,
                wcp_us,
                job,
                reply: tx.clone(),
            })
            .map_err(|_| TeolaError::Scheduler(format!("engine '{}' is down", node.engine)))
    }
}

/// Keep the k best-scoring rows (stable on ties by original order).
pub fn select_top_k(rows: Vec<Vec<i32>>, scores: &[f32], k: usize) -> Vec<Vec<i32>> {
    let mut idx: Vec<usize> = (0..rows.len()).collect();
    idx.sort_by(|&a, &b| {
        let sa = scores.get(a).copied().unwrap_or(f32::MIN);
        let sb = scores.get(b).copied().unwrap_or(f32::MIN);
        sb.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.into_iter().map(|i| rows[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_selection() {
        let rows = vec![vec![1], vec![2], vec![3]];
        let got = select_top_k(rows, &[0.1, 0.9, 0.5], 2);
        assert_eq!(got, vec![vec![2], vec![3]]);
    }

    #[test]
    fn top_k_handles_missing_scores() {
        let rows = vec![vec![1], vec![2]];
        let got = select_top_k(rows, &[0.5], 2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], vec![1]);
    }
}
