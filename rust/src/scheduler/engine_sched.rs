//! Lower-tier engine scheduler: owns the engine's instances, queues
//! primitive requests from all queries, forms batches per policy and load
//! balances across free instances (§5.2, §6).

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::engines::instance::Instance;
use crate::engines::{Batch, InstanceFree};
use crate::scheduler::batching::{form_batch, BatchPolicy, QueueItem};

/// One engine's scheduler state (runs on its own thread).
pub struct EngineScheduler {
    pub name: String,
    pub instances: Vec<Instance>,
    pub free_rx: Receiver<InstanceFree>,
    pub job_rx: Receiver<QueueItem>,
    /// Shared, runtime-switchable policy (benches flip it per scheme).
    pub policy: Arc<AtomicU8>,
    /// Pre-tuned max batch rows (the TO tuning / Algorithm 2 slot budget);
    /// shared so harnesses can retune per experiment.
    pub max_slots: Arc<AtomicUsize>,
    /// Load counter per instance (in-flight rows) for least-loaded routing.
    loads: Vec<usize>,
    in_flight_rows: Vec<usize>,
    queue: Vec<QueueItem>,
    /// Dynamic-batching window: when the queue holds fewer rows than the
    /// slot budget, wait this long (from the oldest arrival) for more
    /// requests before dispatching — the Triton/vLLM-style accumulation
    /// delay the paper's engines rely on.
    batch_window: Duration,
}

impl EngineScheduler {
    /// Build a scheduler; `run()` consumes it on a dedicated thread.
    pub fn new(
        name: String,
        instances: Vec<Instance>,
        free_rx: Receiver<InstanceFree>,
        job_rx: Receiver<QueueItem>,
        policy: Arc<AtomicU8>,
        max_slots: Arc<AtomicUsize>,
    ) -> EngineScheduler {
        let n = instances.len();
        EngineScheduler {
            name,
            instances,
            free_rx,
            job_rx,
            policy,
            max_slots,
            loads: vec![0; n],
            in_flight_rows: vec![0; n],
            queue: Vec::new(),
            batch_window: Duration::from_millis(3),
        }
    }

    /// Scheduling loop: drain arrivals, mark freed instances, dispatch.
    pub fn run(mut self) {
        loop {
            // Block briefly for new work; exit when the platform drops.
            match self.job_rx.recv_timeout(Duration::from_micros(500)) {
                Ok(item) => self.queue.push(item),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    if self.queue.is_empty() {
                        break;
                    }
                }
            }
            // Drain everything already waiting.
            while let Ok(item) = self.job_rx.try_recv() {
                self.queue.push(item);
            }
            // Mark freed instances.
            while let Ok(f) = self.free_rx.try_recv() {
                self.instances[f.instance].busy = false;
                self.loads[f.instance] =
                    self.loads[f.instance].saturating_sub(self.in_flight_rows[f.instance]);
                self.in_flight_rows[f.instance] = 0;
            }
            // Dispatch while a free instance and queued work exist.
            loop {
                let Some(inst) = self.pick_instance() else { break };
                if self.queue.is_empty() {
                    break;
                }
                let policy = BatchPolicy::from_u8(self.policy.load(Ordering::Relaxed));
                let slots = self.max_slots.load(Ordering::Relaxed).max(1);
                // Dynamic-batching delay: give co-arriving requests a
                // moment to accumulate unless the slot budget is already
                // covered (or the policy bundles by construction).
                if policy != BatchPolicy::PerInvocation {
                    let rows: usize = self.queue.iter().map(|i| i.rows.max(1)).sum();
                    let oldest = self.queue.iter().map(|i| i.arrival).min();
                    if rows < slots {
                        if let Some(t) = oldest {
                            if t.elapsed() < self.batch_window {
                                break;
                            }
                        }
                    }
                }
                let items = form_batch(&mut self.queue, policy, slots);
                if items.is_empty() {
                    break;
                }
                let rows: usize = items.iter().map(|i| i.rows.max(1)).sum();
                let jobs = items
                    .into_iter()
                    .map(|i| {
                        (
                            crate::engines::RequestCtx {
                                query: i.query,
                                node: i.node,
                                depth: i.depth,
                                arrival: i.arrival,
                                reply: i.reply,
                            },
                            i.job,
                        )
                    })
                    .collect();
                self.loads[inst] += rows;
                self.in_flight_rows[inst] = rows;
                self.instances[inst].busy = true;
                if self.instances[inst].sender.send(Batch { jobs }).is_err() {
                    eprintln!("[{}] instance {inst} died", self.name);
                    self.instances[inst].busy = true; // never pick again
                }
            }
        }
    }

    /// Least-loaded free instance (KV-slot/request-count load balancing).
    fn pick_instance(&self) -> Option<usize> {
        self.instances
            .iter()
            .enumerate()
            .filter(|(_, i)| !i.busy)
            .min_by_key(|(idx, _)| self.loads[*idx])
            .map(|(idx, _)| idx)
    }
}
