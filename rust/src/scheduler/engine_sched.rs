//! Lower-tier engine scheduler: owns the engine's instances, queues
//! primitive requests from all queries, forms batches per policy and load
//! balances across instances (§5.2, §6).
//!
//! Dispatch runs in one of two modes, split by the engine's
//! [`ExecMode`]:
//!
//! * **Full-batch** (encoder-style and model-free engines, and every
//!   engine under the `BlindTO`/`PerInvocation` baselines): an instance
//!   receives work only when fully drained (`loads == 0`), and each
//!   dispatched batch runs to completion — the legacy protocol.
//! * **Continuous** (stepped LLM engines under `TopoAware`, when
//!   enabled): new work is admitted into *partially occupied* instances
//!   mid-flight, bounded by their spare slot budget, in Algorithm 2
//!   priority order.  A late-arriving short decode joins an in-flight
//!   long decode's iteration loop instead of waiting behind its tail —
//!   iteration-level continuous batching.
//!
//! Routing is **prefix-aware** on stepped engines: prefill jobs carry a
//! fingerprint of their shared leading instruction tokens, the scheduler
//! mirrors each instance's resident-prefix LRU registry, and
//! `pick_instance` prefers a live instance already holding the head job's
//! prefix (affinity traded against load imbalance, falling back to
//! least-loaded) — so concurrent queries of one app land where their
//! instruction KV already lives instead of re-prefilling it per instance.
//!
//! Load accounting is event-driven: instances report per-step
//! [`InstanceEvent`]s and the per-instance `loads` counter decreases by
//! the retired rows, so occupancy is exact at iteration granularity.
//!
//! Liveness: when the *last* live instance dies, queued (and any
//! later-arriving) items are failed immediately with a
//! [`JobOutput::Failed`] completion so query runners surface a
//! `TeolaError` instead of blocking on a completion that can never come.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::engines::instance::Instance;
use crate::engines::prefix::{PrefixFp, PrefixRegistry};
use crate::engines::profile::DeviceModel;
use crate::engines::{Batch, Completion, EngineJob, ExecMode, ExecTiming, InstanceEvent, JobOutput, RequestCtx};
use crate::scheduler::batching::{
    form_batch, form_continuous_admission, head_index, BatchPolicy, QueueItem,
};

/// One engine's scheduler state (runs on its own thread).
pub struct EngineScheduler {
    pub name: String,
    pub instances: Vec<Instance>,
    pub event_rx: Receiver<InstanceEvent>,
    pub job_rx: Receiver<QueueItem>,
    /// Shared, runtime-switchable policy (benches flip it per scheme).
    pub policy: Arc<AtomicU8>,
    /// Pre-tuned max batch rows (the TO tuning / Algorithm 2 slot budget);
    /// shared so harnesses can retune per experiment.
    pub max_slots: Arc<AtomicUsize>,
    /// Shared, runtime-switchable continuous-batching toggle (only
    /// meaningful for `ExecMode::Stepped` engines under `TopoAware`).
    pub continuous: Arc<AtomicBool>,
    /// Dynamic-batching window in microseconds: when a formed batch holds
    /// fewer rows than the slot budget, wait this long (from the batch's
    /// own oldest arrival) for more requests before waking an *idle*
    /// instance — the Triton/vLLM-style accumulation delay the paper's
    /// engines rely on.  Shared/atomic so benches and the CLI can sweep
    /// it at runtime.
    pub batch_window_us: Arc<AtomicU64>,
    /// Per-instance resident-prefix budget (0 disables prefix routing);
    /// shares the handle with the executors' registries.
    pub prefix_slots: Arc<AtomicUsize>,
    /// Shared, runtime-switchable weighted-critical-path toggle: under
    /// `TopoAware`, order query buckets by descending remaining
    /// critical-path device time (+ aging) instead of arrival.
    pub wcp: Arc<AtomicBool>,
    /// Whether this engine's executors run the stepped protocol.
    mode: ExecMode,
    /// Cost model of this engine (prefix-hit discounts on `wcp_us`).
    device: DeviceModel,
    /// In-flight rows per instance (admitted minus retired) for
    /// least-loaded routing and spare-slot admission.
    loads: Vec<usize>,
    /// Instances whose channel died; never routed to again.
    dead: Vec<bool>,
    /// Routing mirror of each instance's resident-prefix LRU registry:
    /// updated on dispatch with the same (fingerprint order, budget) the
    /// executor applies, so affinity predictions track actual residency.
    prefix_homes: Vec<PrefixRegistry<()>>,
    queue: Vec<QueueItem>,
}

impl EngineScheduler {
    /// Build a scheduler; `run()` consumes it on a dedicated thread.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        instances: Vec<Instance>,
        event_rx: Receiver<InstanceEvent>,
        job_rx: Receiver<QueueItem>,
        policy: Arc<AtomicU8>,
        max_slots: Arc<AtomicUsize>,
        continuous: Arc<AtomicBool>,
        batch_window_us: Arc<AtomicU64>,
        prefix_slots: Arc<AtomicUsize>,
        wcp: Arc<AtomicBool>,
        mode: ExecMode,
    ) -> EngineScheduler {
        let n = instances.len();
        let prefix_homes =
            (0..n).map(|_| PrefixRegistry::new(prefix_slots.clone())).collect();
        let device = DeviceModel::for_engine(&name);
        EngineScheduler {
            name,
            instances,
            event_rx,
            job_rx,
            policy,
            max_slots,
            continuous,
            batch_window_us,
            prefix_slots,
            wcp,
            mode,
            device,
            loads: vec![0; n],
            dead: vec![false; n],
            prefix_homes,
            queue: Vec::new(),
        }
    }

    /// Scheduling loop: drain arrivals, fold in instance events, dispatch.
    pub fn run(mut self) {
        loop {
            // Block briefly for new work; exit when the platform drops.
            match self.job_rx.recv_timeout(Duration::from_micros(500)) {
                Ok(item) => self.enqueue(item),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    let alive = self.dead.iter().any(|d| !d);
                    if !alive {
                        // Nothing can ever serve the leftovers: fail them
                        // so waiting query runners error out.
                        self.fail_queue();
                        break;
                    }
                    if self.queue.is_empty() {
                        break;
                    }
                    // The job channel is gone but queued work remains:
                    // drain it at event pace instead of busy-spinning
                    // (recv on a disconnected channel returns instantly).
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            // Drain everything already waiting.
            while let Ok(item) = self.job_rx.try_recv() {
                self.enqueue(item);
            }
            // Fold in per-step occupancy reports.
            while let Ok(ev) = self.event_rx.try_recv() {
                self.loads[ev.instance] = self.loads[ev.instance].saturating_sub(ev.retired);
            }
            self.dispatch();
        }
    }

    /// Queue an arriving item, applying the prefix-hit cost feedback: a
    /// prefill whose fingerprinted prefix is already resident on a live
    /// instance will only prefill its suffix, so that much device time
    /// leaves the owning query's remaining-critical-path stamp before
    /// bucket ordering reads it.  (Applied once, at enqueue; residency
    /// observed later doesn't retro-discount — the stamp is a scheduling
    /// weight, not an accounting ledger.)
    fn enqueue(&mut self, mut item: QueueItem) {
        if let Some(fp) = item.prefix {
            let routing = self.prefix_slots.load(Ordering::Relaxed) > 0;
            if routing
                && (0..self.instances.len())
                    .any(|i| !self.dead[i] && self.prefix_homes[i].contains(fp))
            {
                let discount = (self.device.prefill_us_per_token * fp.len as f64) as u64;
                item.wcp_us = item.wcp_us.saturating_sub(discount);
            }
        }
        self.queue.push(item);
    }

    /// Fail every queued item with an engine-dead completion: the engine
    /// has no live instance left, so queries waiting on these replies
    /// would otherwise hang forever.
    fn fail_queue(&mut self) {
        for it in self.queue.drain(..) {
            let _ = it.reply.send(Completion {
                query: it.query,
                node: it.node,
                output: JobOutput::Failed(format!(
                    "engine '{}' is dead (all instances lost)",
                    self.name
                )),
                timing: ExecTiming::default(),
            });
        }
    }

    /// Dispatch while an eligible instance and queued work exist.
    fn dispatch(&mut self) {
        let policy = BatchPolicy::from_u8(self.policy.load(Ordering::Relaxed));
        let slots = self.max_slots.load(Ordering::Relaxed).max(1);
        // Iteration-level admission applies to stepped engines under the
        // topology-aware policy; the TO/PO baselines keep the legacy
        // full-batch dispatch path untouched.
        let continuous = self.mode == ExecMode::Stepped
            && policy == BatchPolicy::TopoAware
            && self.continuous.load(Ordering::Relaxed);
        // Prefix-affinity routing follows the same gating (it is a
        // Teola-side optimization, not part of the baselines) but is
        // independent of the continuous toggle.
        let prefix_routing = self.mode == ExecMode::Stepped
            && policy == BatchPolicy::TopoAware
            && self.prefix_slots.load(Ordering::Relaxed) > 0;
        // Weighted-critical-path bucket ordering: Teola-side (TopoAware)
        // only; the TO/PO baselines keep their arrival semantics.
        let wcp = policy == BatchPolicy::TopoAware && self.wcp.load(Ordering::Relaxed);
        let window =
            Duration::from_micros(self.batch_window_us.load(Ordering::Relaxed));
        // A mid-run `prefix_slots` retune must reach the routing mirrors
        // immediately: trim them to the current budget so affinity never
        // routes toward a prefix the executors have already evicted.
        for home in &mut self.prefix_homes {
            home.resync();
        }
        loop {
            if self.queue.is_empty() {
                break;
            }
            if self.dead.iter().all(|d| *d) {
                // Last instance died with work queued: fail fast rather
                // than holding the queries hostage.
                self.fail_queue();
                break;
            }
            let want_prefix = if prefix_routing {
                head_index(&self.queue, policy, wcp).and_then(|i| self.queue[i].prefix)
            } else {
                None
            };
            let Some(inst) = self.pick_instance(continuous, slots, want_prefix) else {
                break;
            };
            let mid_flight = self.loads[inst] > 0;
            let items = if mid_flight {
                form_continuous_admission(
                    &mut self.queue,
                    slots.saturating_sub(self.loads[inst]),
                    wcp,
                )
            } else {
                form_batch(&mut self.queue, policy, slots, wcp)
            };
            if items.is_empty() {
                break;
            }
            let rows: usize = items.iter().map(|i| i.rows.max(1)).sum();
            // Dynamic-batching delay, gated on the *formed candidate set*:
            // give co-arriving requests a moment to accumulate before
            // waking an idle instance, unless the batch already covers the
            // slot budget (or the policy bundles by construction).  The
            // window is measured from the batch's own oldest arrival — a
            // stale item elsewhere in the queue (different class/bundle)
            // no longer defeats accumulation for fresh co-arrivals.
            // Joining an in-flight instance needs no delay — the resident
            // batch *is* the accumulation.
            if policy != BatchPolicy::PerInvocation
                && !mid_flight
                && rows < slots
                && !batch_window_expired(&items, window)
            {
                self.queue.extend(items);
                break;
            }
            // Keep the routing mirror in sync: after this dispatch the
            // instance holds (or is about to compute and register) every
            // fingerprinted prefix in the batch.
            if prefix_routing {
                for it in &items {
                    if let Some(fp) = it.prefix {
                        self.prefix_homes[inst].insert(fp, ());
                    }
                }
            }
            let jobs: Vec<(RequestCtx, EngineJob)> = items
                .into_iter()
                .map(|i| {
                    (
                        RequestCtx {
                            query: i.query,
                            node: i.node,
                            depth: i.depth,
                            arrival: i.arrival,
                            wcp_us: i.wcp_us,
                            reply: i.reply,
                        },
                        i.job,
                    )
                })
                .collect();
            if let Err(unsent) = self.instances[inst].sender.send(Batch { jobs }) {
                // Instance thread died: recover the unsent batch from the
                // send error and requeue it so its queries don't hang,
                // stop routing to the instance, and leave `loads`
                // untouched (nothing was admitted) so least-loaded
                // routing isn't skewed forever.  If that was the last
                // live instance, the next loop iteration fails the queue.
                eprintln!(
                    "[{}] instance {inst} died; requeueing {} job(s)",
                    self.name,
                    unsent.0.jobs.len()
                );
                self.dead[inst] = true;
                for (ctx, job) in unsent.0.jobs {
                    let rows = job.rows();
                    let prefix = job.prefix();
                    // Plain push, not `enqueue`: the critical-path stamp
                    // survived the round trip through `RequestCtx` and
                    // already carries any prefix discount.
                    self.queue.push(QueueItem {
                        query: ctx.query,
                        node: ctx.node,
                        depth: ctx.depth,
                        // Same per-node key the graph scheduler uses for
                        // invocation bundles.
                        bundle: (ctx.query, ctx.node as u64),
                        arrival: ctx.arrival,
                        rows,
                        prefix,
                        wcp_us: ctx.wcp_us,
                        job,
                        reply: ctx.reply,
                    });
                }
                continue;
            }
            self.loads[inst] += rows;
        }
    }

    /// Eligible-instance choice.  Full-batch mode requires a fully drained
    /// instance (legacy `busy` semantics); continuous mode admits into any
    /// live instance with spare slot budget.  When the head job carries a
    /// prefix fingerprint, an eligible instance already holding that
    /// prefix is preferred — unless taking it would skew load by more
    /// than half the slot budget over the least-loaded choice, in which
    /// case load balance wins (affinity traded against imbalance).
    fn pick_instance(
        &self,
        continuous: bool,
        slots: usize,
        want_prefix: Option<PrefixFp>,
    ) -> Option<usize> {
        let eligible = |i: &usize| -> bool {
            let i = *i;
            let fits = if continuous { self.loads[i] < slots } else { self.loads[i] == 0 };
            !self.dead[i] && fits
        };
        let least = (0..self.instances.len())
            .filter(eligible)
            .min_by_key(|&i| self.loads[i])?;
        if let Some(fp) = want_prefix {
            let holder = (0..self.instances.len())
                .filter(eligible)
                .filter(|&i| self.prefix_homes[i].contains(fp))
                .min_by_key(|&i| self.loads[i]);
            if let Some(h) = holder {
                let margin = (slots / 2).max(1);
                if self.loads[h] <= self.loads[least] + margin {
                    return Some(h);
                }
            }
        }
        Some(least)
    }
}

/// True when the batch's own accumulation window has elapsed: the oldest
/// arrival *within the formed candidate set* is older than `window`.
/// Pure so the window-per-batch policy is unit-testable.
fn batch_window_expired(items: &[QueueItem], window: Duration) -> bool {
    items
        .iter()
        .map(|i| i.arrival)
        .min()
        .map_or(true, |t| t.elapsed() >= window)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn item_at(query: u64, node: usize, arrival: Instant, job: EngineJob) -> QueueItem {
        let (tx, rx) = channel();
        std::mem::forget(rx);
        QueueItem {
            query,
            node,
            depth: 0,
            bundle: (query, node as u64),
            arrival,
            rows: 1,
            prefix: None,
            wcp_us: 0,
            job,
            reply: tx,
        }
    }

    fn decode_job(q: u64) -> EngineJob {
        EngineJob::Decode { seq: (q, 0), first_token: 5, segments: vec![] }
    }

    fn prefill_job(q: u64) -> EngineJob {
        EngineJob::Prefill { seq: (q, 0), tokens: vec![7; 4], offset: 0, prefix: None }
    }

    #[test]
    fn window_measured_on_formed_batch_not_whole_queue() {
        let now = Instant::now();
        let window = Duration::from_millis(50);
        let stale = now - Duration::from_millis(200);

        // Fresh co-arrivals alone: window still open -> accumulate.
        let fresh = vec![
            item_at(1, 1, now, prefill_job(1)),
            item_at(2, 2, now, prefill_job(2)),
        ];
        assert!(!batch_window_expired(&fresh, window));

        // A batch containing the stale item dispatches immediately.
        let with_stale = vec![item_at(3, 3, stale, decode_job(3))];
        assert!(batch_window_expired(&with_stale, window));
    }

    #[test]
    fn stale_item_no_longer_defeats_window_for_fresh_coarrivals() {
        // Regression shape: one stale decode sits in the queue while fresh
        // prefills co-arrive.  The old whole-queue `min(arrival)` gate saw
        // the stale arrival, declared the window elapsed, and dispatched
        // the fresh prefills without accumulation.  With the
        // per-candidate-set gate, the class-restricted batch containing
        // the stale decode goes out at once, while the fresh prefills'
        // own batch keeps its accumulation window.
        let now = Instant::now();
        let window = Duration::from_millis(50);
        let mut queue = vec![
            item_at(1, 1, now - Duration::from_millis(200), decode_job(1)),
            item_at(2, 2, now, prefill_job(2)),
            item_at(3, 3, now, prefill_job(3)),
        ];
        // First formed batch: the stale decode (earliest query bucket,
        // class-restricted) — its own window has expired, dispatch now.
        let first = form_batch(&mut queue, BatchPolicy::TopoAware, 8, false);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].node, 1);
        assert!(batch_window_expired(&first, window));
        // Second formed batch: the fresh prefills — their window is still
        // open, so dispatch waits for more co-arrivals.
        let second = form_batch(&mut queue, BatchPolicy::TopoAware, 8, false);
        assert_eq!(second.len(), 2);
        assert!(!batch_window_expired(&second, window));
    }

    #[test]
    fn empty_batch_counts_as_expired() {
        assert!(batch_window_expired(&[], Duration::from_millis(10)));
    }
}
