//! Lower-tier engine scheduler: owns the engine's instances, queues
//! primitive requests from all queries, forms batches per policy and load
//! balances across instances (§5.2, §6).
//!
//! Dispatch runs in one of two modes, split by the engine's
//! [`ExecMode`]:
//!
//! * **Full-batch** (encoder-style and model-free engines, and every
//!   engine under the `BlindTO`/`PerInvocation` baselines): an instance
//!   receives work only when fully drained (`loads == 0`), and each
//!   dispatched batch runs to completion — the legacy protocol.
//! * **Continuous** (stepped LLM engines under `TopoAware`, when
//!   enabled): new work is admitted into *partially occupied* instances
//!   mid-flight, bounded by their spare slot budget, in Algorithm 2
//!   priority order.  A late-arriving short decode joins an in-flight
//!   long decode's iteration loop instead of waiting behind its tail —
//!   iteration-level continuous batching.
//!
//! Routing is **prefix-aware** on stepped engines: prefill jobs carry a
//! fingerprint of their shared leading instruction tokens, the scheduler
//! mirrors each instance's resident-prefix LRU registry, and
//! `pick_instance` prefers a live instance already holding the head job's
//! prefix (affinity traded against load imbalance, falling back to
//! least-loaded) — so concurrent queries of one app land where their
//! instruction KV already lives instead of re-prefilling it per instance.
//!
//! Load accounting is event-driven and **dual-denominated**: instances
//! report per-step [`InstanceEvent`]s carrying both retired rows and
//! retired KV tokens, and the scheduler maintains a row counter *and* a
//! per-instance token ledger ([`KvBudget`]) in lockstep, so occupancy is
//! exact at iteration granularity in whichever denomination the current
//! mode consults.  With a non-zero `kv_tokens` budget (stepped engines
//! under `TopoAware` only), admission, least-loaded routing, the
//! prefix-affinity skew threshold and spare-capacity continuous
//! admission are all **token-denominated** — a 2048-token prefill costs
//! 256x an 8-token one instead of the same row slot, so dense batches of
//! short requests no longer wait behind row-slot exhaustion.  A budget
//! of 0 keeps the legacy row mode (and the TO/PO baselines always run
//! it).
//!
//! Liveness: when the *last* live instance dies, queued (and any
//! later-arriving) items are failed immediately with a
//! [`JobOutput::Failed`] completion so query runners surface a
//! `TeolaError` instead of blocking on a completion that can never come.
//! A dying instance's reserved rows/tokens are released before its batch
//! is requeued, so the revived queue never double-counts capacity.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engines::instance::Instance;
use crate::engines::kv_budget::{self, KvBudget};
use crate::engines::prefix::{PrefixFp, PrefixRegistry};
use crate::engines::profile::DeviceModel;
use crate::engines::{
    Batch, Completion, EngineJob, ExecMode, ExecTiming, InstanceEvent, JobOutput, QueryId,
    RequestCtx,
};
use crate::scheduler::batching::{BatchPolicy, QueueItem, SchedQueue, SlotUnit};
use crate::scheduler::stats::SchedCounters;
use crate::scheduler::tenancy::{
    boost_class, FairQueue, QosClass, SharedTenancy, TenantId, TenantRanks, TenantSpec,
};

/// One engine's scheduler state (runs on its own thread).
pub struct EngineScheduler {
    pub name: String,
    pub instances: Vec<Instance>,
    pub event_rx: Receiver<InstanceEvent>,
    pub job_rx: Receiver<QueueItem>,
    /// Shared, runtime-switchable policy (benches flip it per scheme).
    pub policy: Arc<AtomicU8>,
    /// Pre-tuned max batch rows (the TO tuning / Algorithm 2 slot budget);
    /// shared so harnesses can retune per experiment.
    pub max_slots: Arc<AtomicUsize>,
    /// Shared, runtime-switchable continuous-batching toggle (only
    /// meaningful for `ExecMode::Stepped` engines under `TopoAware`).
    pub continuous: Arc<AtomicBool>,
    /// Dynamic-batching window in microseconds: when a formed batch holds
    /// fewer rows than the slot budget, wait this long (from the batch's
    /// own oldest arrival) for more requests before waking an *idle*
    /// instance — the Triton/vLLM-style accumulation delay the paper's
    /// engines rely on.  Shared/atomic so benches and the CLI can sweep
    /// it at runtime.
    pub batch_window_us: Arc<AtomicU64>,
    /// Per-instance resident-prefix budget (0 disables prefix routing);
    /// shares the handle with the executors' registries.
    pub prefix_slots: Arc<AtomicUsize>,
    /// Shared, runtime-switchable weighted-critical-path toggle: under
    /// `TopoAware`, order query buckets by descending remaining
    /// critical-path device time (+ aging) instead of arrival.
    pub wcp: Arc<AtomicBool>,
    /// Shared per-instance KV token capacity: > 0 switches admission,
    /// routing and packing to token denomination on stepped engines
    /// under `TopoAware`; 0 keeps the legacy row-slot mode.
    pub kv_tokens: Arc<AtomicUsize>,
    /// Shared residency watermark (percent of capacity; 0 = persistent
    /// residency off).  When on, decode dispatch charges one token (the
    /// executor grows the reservation per iteration) and instance
    /// occupancy includes the residency mirror.
    pub kv_watermark: Arc<AtomicUsize>,
    /// Whether this engine's executors run the stepped protocol.
    mode: ExecMode,
    /// Cost model of this engine (prefix-hit discounts on `wcp_us`).
    device: DeviceModel,
    /// In-flight rows per instance (admitted minus retired) for
    /// least-loaded routing and spare-slot admission.
    loads: Vec<usize>,
    /// In-flight KV token reservations per instance, maintained in
    /// lockstep with `loads` (reserve at dispatch, release by the exact
    /// reserved amount when the instance reports retirement) so the
    /// denomination can be switched at runtime without drift.
    kv: Vec<KvBudget>,
    /// Per-instance mirror of *resident* KV tokens (persistent-residency
    /// mode): accumulated from `InstanceEvent::resident_added` and
    /// drained by `resident_freed`.  Kept separate from the reservation
    /// ledger `kv` — reservations are scheduler-charged and echoed back
    /// verbatim, while residency amounts are executor-actual (swap-ins,
    /// per-iteration decode growth) that the scheduler cannot predict.
    resident_mirror: Vec<usize>,
    /// Instances whose channel died; never routed to again.
    dead: Vec<bool>,
    /// Routing mirror of each instance's resident-prefix LRU registry:
    /// updated on dispatch with the same (fingerprint order, budget) the
    /// executor applies, so affinity predictions track actual residency.
    prefix_homes: Vec<PrefixRegistry<()>>,
    /// Shared tenancy handle (multi-tenant QoS): per-tenant weights, SLO
    /// classes and the runtime-switchable enable flag.  Only consulted
    /// under `TopoAware` with tenancy enabled — otherwise the dispatch
    /// path is bit-for-bit the tenant-blind behavior.
    tenancy: Arc<SharedTenancy>,
    /// Start-time fair-queueing ledger over served cost-weighted work,
    /// one per engine scheduler: charged at dispatch in the active slot
    /// denomination, read as each tenant's virtual start for bucket
    /// ordering between tenants.
    fair: FairQueue,
    /// Shared, runtime-switchable incremental-priority toggle (PR9):
    /// `true` (the default) lets [`SchedQueue`] reuse its cached bucket
    /// levels across dispatch passes; `false` forces the exact
    /// rebuild-and-sort fallback on every ordering call.  The two modes
    /// are output-identical by construction — the flag trades work, not
    /// behavior.
    pub incremental: Arc<AtomicBool>,
    /// Tenancy-config generation backing `specs_cache`: when the shared
    /// handle's epoch moves, the cached spec table is dropped *and* the
    /// fair-queueing ledger is reset, so a runtime retune never carries
    /// stale virtual-time tags into the new registry.
    specs_epoch: u64,
    /// Epoch-cached clone of the tenancy spec table: refreshed only when
    /// the epoch changes, so the dispatch hot path stops taking the
    /// spec-table mutex once per pass.
    specs_cache: Option<HashMap<TenantId, TenantSpec>>,
    queue: SchedQueue,
    /// Hot-path counter sink shared with the owning platform (or a bench
    /// harness): per-scheduler since PR10, so two harnesses in one
    /// process never cross-talk through their counter deltas.
    counters: Arc<SchedCounters>,
    /// Fair-queueing charges still outstanding per dispatched node,
    /// keyed `(query, node)`: populated at successful batch send when
    /// tenancy is on, consumed by a `CancelNode` refund (work the device
    /// never finished must not cost SFQ share) and swept per-query when
    /// the query's `FreeQuery` broadcast passes through.  Empty whenever
    /// tenancy is off.
    charged: HashMap<(QueryId, usize), (TenantId, usize)>,
}

impl EngineScheduler {
    /// Build a scheduler; `run()` consumes it on a dedicated thread.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        instances: Vec<Instance>,
        event_rx: Receiver<InstanceEvent>,
        job_rx: Receiver<QueueItem>,
        policy: Arc<AtomicU8>,
        max_slots: Arc<AtomicUsize>,
        continuous: Arc<AtomicBool>,
        batch_window_us: Arc<AtomicU64>,
        prefix_slots: Arc<AtomicUsize>,
        wcp: Arc<AtomicBool>,
        kv_tokens: Arc<AtomicUsize>,
        kv_watermark: Arc<AtomicUsize>,
        mode: ExecMode,
        tenancy: Arc<SharedTenancy>,
        incremental: Arc<AtomicBool>,
        counters: Arc<SchedCounters>,
    ) -> EngineScheduler {
        let n = instances.len();
        let prefix_homes =
            (0..n).map(|_| PrefixRegistry::new(prefix_slots.clone())).collect();
        let device = DeviceModel::for_engine(&name);
        // The cache generation starts in sync with the handle: only a
        // retune *after* construction triggers the fair-ledger reset.
        let specs_epoch = tenancy.epoch();
        let mut queue = SchedQueue::new();
        queue.set_counters(counters.clone());
        EngineScheduler {
            name,
            instances,
            event_rx,
            job_rx,
            policy,
            max_slots,
            continuous,
            batch_window_us,
            prefix_slots,
            wcp,
            kv_tokens,
            kv_watermark,
            mode,
            device,
            loads: vec![0; n],
            kv: (0..n).map(|_| KvBudget::new(0)).collect(),
            resident_mirror: vec![0; n],
            dead: vec![false; n],
            prefix_homes,
            tenancy,
            fair: FairQueue::new(),
            incremental,
            specs_epoch,
            specs_cache: None,
            queue,
            counters,
            charged: HashMap::new(),
        }
    }

    /// Scheduling loop: drain arrivals, fold in instance events, dispatch.
    pub fn run(mut self) {
        loop {
            // Block briefly for new work; exit when the platform drops.
            match self.job_rx.recv_timeout(Duration::from_micros(500)) {
                Ok(item) => self.enqueue(item),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    let alive = self.dead.iter().any(|d| !d);
                    if !alive {
                        // Nothing can ever serve the leftovers: fail them
                        // so waiting query runners error out.
                        self.fail_queue();
                        break;
                    }
                    if self.queue.is_empty() {
                        break;
                    }
                    // The job channel is gone but queued work remains:
                    // drain it at event pace instead of busy-spinning
                    // (recv on a disconnected channel returns instantly).
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            // Drain everything already waiting.
            while let Ok(item) = self.job_rx.try_recv() {
                self.enqueue(item);
            }
            // Fold in per-step occupancy reports: rows and KV tokens
            // release in lockstep (the token amount is the echo of what
            // dispatch reserved, so the ledger drains exactly to zero).
            while let Ok(ev) = self.event_rx.try_recv() {
                self.loads[ev.instance] = self.loads[ev.instance].saturating_sub(ev.retired);
                self.kv[ev.instance].release(ev.retired_tokens);
                // Residency mirror (persistent-residency mode): track the
                // executor-actual resident amounts so token-mode routing
                // and admission see true per-instance occupancy.
                self.resident_mirror[ev.instance] = self.resident_mirror[ev.instance]
                    .saturating_add(ev.resident_added)
                    .saturating_sub(ev.resident_freed);
            }
            self.dispatch();
        }
    }

    /// Queue an arriving item.  The prefix-hit cost feedback on its
    /// `wcp_us` stamp is applied by [`rediscount_resident_prefixes`] at
    /// the top of every dispatch pass, so residency gained *after* an
    /// item was enqueued still discounts it before bucket ordering reads
    /// the stamp (closing the PR4 enqueue-only gap).
    fn enqueue(&mut self, item: QueueItem) {
        // Scheduler-directed control jobs are intercepted here — they
        // mutate queue state and never reach an instance.
        match item.job {
            EngineJob::CancelNode { query, node } => {
                self.cancel_node(query, node);
                return;
            }
            EngineJob::RestampWcp { query, wcp_us } => {
                self.restamp_query(query, wcp_us);
                return;
            }
            _ => {}
        }
        if item.job.is_bookkeeping() {
            self.dispatch_bookkeeping(item);
            return;
        }
        self.queue.push(item);
    }

    /// Purge one node's queued work (a refuted speculative dispatch).
    /// Queued items are removed with their replies *dropped* — a
    /// cancelled speculation must never surface `Failed` to its runner —
    /// and a node that already dispatched gets its tenant's
    /// fair-queueing charge refunded: the device never finished the
    /// work, so it must not cost SFQ share.  (The in-flight compute
    /// itself is aborted by the separate `CancelSeq` bookkeeping path on
    /// stepped engines; on instant engines it simply runs out and the
    /// runner drops the late completion.)
    fn cancel_node(&mut self, query: QueryId, node: usize) {
        let ids: Vec<usize> = self
            .queue
            .iter_ids()
            .filter(|(_, it)| it.query == query && it.node == node)
            .map(|(id, _)| id)
            .collect();
        for id in ids {
            drop(self.queue.remove(id));
        }
        if let Some((t, cost)) = self.charged.remove(&(query, node)) {
            let w = self
                .specs_cache
                .as_ref()
                .and_then(|s| s.get(&t).map(|spec| spec.weight))
                .unwrap_or_else(|| self.tenancy.spec_of(t).weight);
            self.fair.refund(t, cost, w);
        }
    }

    /// Restamp every queued item of `query` with a fresh remaining
    /// critical-path estimate (guard resolution re-weighted the query's
    /// WCP; confirmation also *promotes* formerly speculative items,
    /// whose discounted stamp kept them from displacing committed work).
    fn restamp_query(&mut self, query: QueryId, wcp_us: u64) {
        self.queue.restamp_wcp(|it| {
            if it.query == query && it.wcp_us != wcp_us {
                it.wcp_us = wcp_us;
                true
            } else {
                false
            }
        });
    }

    /// Fast-path host-side bookkeeping jobs straight to instances,
    /// bypassing the queue, batch packing and budget admission entirely:
    /// the op that *releases* memory (`FreeQuery`) must never be blocked
    /// on lack of memory, and `ClonePrefix` is a host-side cache copy
    /// with no model rows.  `FreeQuery` and `CancelSeq` broadcast to
    /// every live instance — residency ledgers and pending queues are
    /// per-executor, so each instance must drain its own; `ClonePrefix`
    /// goes to one least-loaded live instance.  Each target is charged
    /// one row (stepped executors retire instant ops as a single row)
    /// and zero KV tokens.
    fn dispatch_bookkeeping(&mut self, item: QueueItem) {
        if let EngineJob::FreeQuery { query } = item.job {
            // The query is over: no refund can still arrive, so sweep
            // its outstanding fair-charge entries (bounds the map).
            self.charged.retain(|(q, _), _| *q != query);
        }
        let broadcast = matches!(
            item.job,
            EngineJob::FreeQuery { .. } | EngineJob::CancelSeq { .. }
        );
        let live = |me: &EngineScheduler| -> Vec<usize> {
            (0..me.instances.len()).filter(|&i| !me.dead[i]).collect()
        };
        let mut sent = false;
        loop {
            let targets: Vec<usize> = if broadcast {
                live(self)
            } else {
                // Single least-loaded live target; on a send failure the
                // loop retries with the next-best live instance.
                live(self)
                    .into_iter()
                    .min_by_key(|&i| self.loads[i])
                    .map(|i| vec![i])
                    .unwrap_or_default()
            };
            if targets.is_empty() {
                break;
            }
            for inst in targets {
                let ctx = RequestCtx {
                    query: item.query,
                    node: item.node,
                    depth: item.depth,
                    arrival: item.arrival,
                    wcp_us: item.wcp_us,
                    kv_tokens: 0,
                    wcp_discounted: item.wcp_discounted,
                    tenant: item.tenant,
                    reply: item.reply.clone(),
                    successors: Vec::new(),
                };
                let batch = Batch { jobs: vec![(ctx, item.job.clone())] };
                if self.instances[inst].sender.send(batch).is_err() {
                    self.dead[inst] = true;
                    self.loads[inst] = 0;
                    self.kv[inst].reset();
                    self.resident_mirror[inst] = 0;
                    continue;
                }
                self.loads[inst] += 1;
                sent = true;
            }
            if sent || broadcast {
                break;
            }
        }
        if !sent {
            // No live instance could take it: fail the reply so the
            // owning query errors out instead of hanging.  (FreeQuery
            // replies are fire-and-forget — the send is simply dropped.)
            let _ = item.reply.send(Completion {
                query: item.query,
                node: item.node,
                output: JobOutput::Failed(format!(
                    "engine '{}' is dead (all instances lost)",
                    self.name
                )),
                timing: ExecTiming::default(),
            });
        }
    }

    /// Fail every queued item with an engine-dead completion: the engine
    /// has no live instance left, so queries waiting on these replies
    /// would otherwise hang forever.
    fn fail_queue(&mut self) {
        for it in self.queue.drain_all() {
            let _ = it.reply.send(Completion {
                query: it.query,
                node: it.node,
                output: JobOutput::Failed(format!(
                    "engine '{}' is dead (all instances lost)",
                    self.name
                )),
                timing: ExecTiming::default(),
            });
        }
    }

    /// Dispatch while an eligible instance and queued work exist.
    fn dispatch(&mut self) {
        // A tenancy retune must reach the ledger even while idle-waking:
        // check the epoch before the empty-queue early-out so the reset
        // is not deferred behind an arbitrarily long idle stretch.
        let epoch = self.tenancy.epoch();
        if epoch != self.specs_epoch {
            self.specs_epoch = epoch;
            self.specs_cache = None;
            // PR8 residual fix: a new tenant registry starts with a
            // fresh fair-queueing ledger — stale virtual-time tags from
            // the previous registry would mis-rank its tenants.
            self.fair.reset();
        }
        if self.queue.is_empty() {
            return;
        }
        let t_dispatch = Instant::now();
        self.counters.count_dispatch_pass();
        let policy = BatchPolicy::from_u8(self.policy.load(Ordering::Relaxed));
        let slots = self.max_slots.load(Ordering::Relaxed).max(1);
        // Iteration-level admission applies to stepped engines under the
        // topology-aware policy; the TO/PO baselines keep the legacy
        // full-batch dispatch path untouched.
        let continuous = self.mode == ExecMode::Stepped
            && policy == BatchPolicy::TopoAware
            && self.continuous.load(Ordering::Relaxed);
        // Prefix-affinity routing follows the same gating (it is a
        // Teola-side optimization, not part of the baselines) but is
        // independent of the continuous toggle.
        let prefix_routing = self.mode == ExecMode::Stepped
            && policy == BatchPolicy::TopoAware
            && self.prefix_slots.load(Ordering::Relaxed) > 0;
        // Weighted-critical-path bucket ordering: Teola-side (TopoAware)
        // only; the TO/PO baselines keep their arrival semantics.
        let wcp = policy == BatchPolicy::TopoAware && self.wcp.load(Ordering::Relaxed);
        // Token-denominated KV accounting (PR5): same Teola-side gating,
        // enabled by a non-zero per-instance token budget.  0 keeps the
        // legacy row-slot path (and the TO/PO baselines never leave it).
        let kv_budget = self.kv_tokens.load(Ordering::Relaxed);
        let token_mode = self.mode == ExecMode::Stepped
            && policy == BatchPolicy::TopoAware
            && kv_budget > 0;
        // Persistent-residency mode (PR6): decode dispatch charges a
        // single token — the executor reserves the real swap-in cost and
        // grows the reservation one token per iteration — so admission
        // depth is no longer gated on worst-case `max_new` up front.
        let residency = token_mode && self.kv_watermark.load(Ordering::Relaxed) > 0;
        let unit = if token_mode { SlotUnit::Tokens } else { SlotUnit::Rows };
        let budget = if token_mode { kv_budget } else { slots };
        // Multi-tenant QoS: Teola-side (TopoAware) gating like the other
        // scheduler features; with the knob off every call below takes
        // the `None`-ranked path, bit-for-bit the tenant-blind behavior.
        let tenancy_on = policy == BatchPolicy::TopoAware && self.tenancy.enabled();
        // Epoch-cached spec table: the mutex is taken only when the
        // shared config actually changed (or on the first tenancy-on
        // pass), not once per dispatch — enqueue from the graph side
        // never contends with batch formation here.
        let specs = if tenancy_on {
            if self.specs_cache.is_none() {
                self.counters.count_lock_acq();
                self.specs_cache = Some(self.tenancy.specs());
            }
            self.specs_cache.clone()
        } else {
            None
        };
        // Runtime-switchable incremental ordering (PR9); `false` is the
        // exact rebuild-and-sort fallback.
        let incremental = self.incremental.load(Ordering::Relaxed);
        // Admission control: when an Interactive tenant's measured queue
        // delay has breached its deadline, shed queued Batch-class work
        // — newest-first, bounded by the breached item's estimated cost
        // — (failed loudly, never silently dropped) so Interactive
        // goodput is protected instead of letting p99 explode.
        if let Some(specs) = &specs {
            self.shed_batch_on_slo_breach(specs, unit);
        }
        let window =
            Duration::from_micros(self.batch_window_us.load(Ordering::Relaxed));
        // A mid-run `prefix_slots` retune must reach the routing mirrors
        // immediately: trim them to the current budget so affinity never
        // routes toward a prefix the executors have already evicted.
        for home in &mut self.prefix_homes {
            home.resync();
        }
        // Prefix-hit cost feedback on the WCP stamps, re-checked every
        // pass: a prefix that became resident while an item was already
        // queued still discounts it before bucket ordering reads the
        // stamp (PR4's discount applied at enqueue only).
        if prefix_routing {
            let homes = &self.prefix_homes;
            let dead = &self.dead;
            let n = self.instances.len();
            let ppt = self.device.prefill_us_per_token;
            self.queue.restamp_wcp(|it| {
                rediscount_item(it, |fp| (0..n).any(|i| !dead[i] && homes[i].contains(fp)), ppt)
            });
        }
        loop {
            if self.queue.is_empty() {
                break;
            }
            if self.dead.iter().all(|d| *d) {
                // Last instance died with work queued: fail fast rather
                // than holding the queries hostage.
                self.fail_queue();
                break;
            }
            self.counters.count_dispatch_loop();
            // Tenant ranks are recomputed every iteration: each dispatched
            // batch advances the charged tenant's virtual start, so the
            // next batch may belong to a different tenant (that is the
            // fair-queueing interleave).
            let ranks: Option<TenantRanks> =
                specs.as_ref().map(|s| self.tenant_ranks(s));
            // Priority head (incremental: an O(queries) scan over cached
            // bucket keys): its cost gates the oversized-drain path and
            // its prefix fingerprint steers instance choice.
            let (head_cost, want_prefix) =
                match self.queue.head(policy, wcp, ranks.as_ref(), incremental) {
                    Some(h) => {
                        (unit.cost(h), if prefix_routing { h.prefix } else { None })
                    }
                    None => (0, None),
                };
            let Some(inst) =
                self.pick_instance(continuous, token_mode, budget, want_prefix)
            else {
                break;
            };
            let in_flight = self.load_of(inst, token_mode);
            let mid_flight = in_flight > 0;
            // Oversized-drain gate: when the priority head exceeds the
            // whole budget it can only dispatch alone to a drained
            // instance — stop mid-flight admission (which would pack
            // shorter items around it forever) and let the instance
            // drain.  `pick_instance` prefers drained instances, so the
            // gate only fires when every eligible instance is mid-flight.
            if mid_flight && head_cost > budget {
                break;
            }
            let items = if mid_flight {
                self.queue.form_continuous(
                    budget.saturating_sub(in_flight),
                    wcp,
                    unit,
                    ranks.as_ref(),
                    incremental,
                )
            } else {
                self.queue.form_batch(policy, budget, wcp, unit, ranks.as_ref(), incremental)
            };
            if items.is_empty() {
                break;
            }
            let cost: usize = items.iter().map(|i| unit.cost(i)).sum();
            // "Batch already full" for the accumulation window below: the
            // budget is covered, or — in token mode, where the token
            // budget dwarfs any short-request batch — the historical max
            // batch rows are packed (waiting would not grow the batch's
            // device efficiency, only its latency).
            let batch_full = cost >= budget
                || (token_mode && items.iter().map(|i| i.rows.max(1)).sum::<usize>() >= slots);
            // Dynamic-batching delay, gated on the *formed candidate set*:
            // give co-arriving requests a moment to accumulate before
            // waking an idle instance, unless the batch already covers the
            // budget (or the policy bundles by construction).  The window
            // is measured from the batch's own oldest arrival — a stale
            // item elsewhere in the queue (different class/bundle) no
            // longer defeats accumulation for fresh co-arrivals.  Joining
            // an in-flight instance needs no delay — the resident batch
            // *is* the accumulation.
            if policy != BatchPolicy::PerInvocation
                && !mid_flight
                && !batch_full
                && !batch_window_expired(&items, window)
            {
                for it in items {
                    self.queue.push(it);
                }
                break;
            }
            let mut rows = 0usize;
            let mut reserved = 0usize;
            // Fair-queueing charges for this batch, applied only after a
            // successful send (a dead-instance requeue served nothing).
            let mut fair_charges: Vec<(QueryId, usize, TenantId, usize)> = Vec::new();
            let jobs: Vec<(RequestCtx, EngineJob)> = items
                .into_iter()
                .map(|i| {
                    // Prefix-hit reservations are charged suffix-only: the
                    // holding instance serves the shared instruction from
                    // its resident KV, so a routing hit gets cheaper
                    // admission.  The residency probe runs *before* this
                    // item's own fingerprint is mirrored, so the first
                    // (cold) prefill of a prefix pays in full and every
                    // co-dispatched duplicate pays its suffix — matching
                    // the executors' pending-queue dedupe.
                    let hit = prefix_routing
                        && i.prefix.map_or(false, |fp| self.prefix_homes[inst].contains(fp));
                    if prefix_routing {
                        // Keep the routing mirror in sync: after this
                        // dispatch the instance holds (or is about to
                        // compute and register) the prefix.
                        if let Some(fp) = i.prefix {
                            self.prefix_homes[inst].insert(fp, ());
                        }
                    }
                    let charge = if residency && matches!(i.job, EngineJob::Decode { .. }) {
                        // Residency mode: one-token optimistic decode
                        // charge (the executor owns the real growth and
                        // reports it through the residency mirror).
                        1
                    } else if hit {
                        kv_budget::suffix_charge(i.tokens, i.prefix.unwrap().len)
                    } else {
                        i.tokens.max(1)
                    };
                    rows += i.rows.max(1);
                    reserved += charge;
                    if tenancy_on {
                        // Served work in the active denomination: the SFQ
                        // ledger advances this tenant's virtual start so
                        // under contention other tenants' buckets take the
                        // next batches (weighted interleave).
                        fair_charges.push((i.query, i.node, i.tenant, unit.cost(&i)));
                    }
                    (
                        RequestCtx {
                            query: i.query,
                            node: i.node,
                            depth: i.depth,
                            arrival: i.arrival,
                            wcp_us: i.wcp_us,
                            kv_tokens: charge,
                            wcp_discounted: i.wcp_discounted,
                            tenant: i.tenant,
                            reply: i.reply,
                            successors: i.successors,
                        },
                        i.job,
                    )
                })
                .collect();
            let n_jobs = jobs.len();
            if let Err(unsent) = self.instances[inst].sender.send(Batch { jobs }) {
                // Instance thread died: recover the unsent batch from the
                // send error and requeue it so its queries don't hang,
                // and stop routing to the instance.  Nothing from *this*
                // batch was charged yet, and whatever the dead instance
                // still held in flight can never retire — release its
                // rows and token reservations before the requeue so the
                // revived queue isn't admitted against phantom capacity.
                // If that was the last live instance, the next loop
                // iteration fails the queue.
                eprintln!(
                    "[{}] instance {inst} died; requeueing {} job(s)",
                    self.name,
                    unsent.0.jobs.len()
                );
                self.dead[inst] = true;
                self.loads[inst] = 0;
                self.kv[inst].reset();
                self.resident_mirror[inst] = 0;
                for (ctx, job) in unsent.0.jobs {
                    let rows = job.rows();
                    let prefix = job.prefix();
                    // Recompute the token estimate from the job itself
                    // (the unsent payload is untrimmed): requeueing the
                    // *charge* (suffix-only on a hit) would discount the
                    // prefix a second time at re-dispatch, or
                    // under-reserve on a holder miss.
                    let tokens = job.kv_tokens();
                    // Plain push, not `enqueue`: the critical-path stamp
                    // survived the round trip through `RequestCtx` and
                    // already carries any prefix discount.
                    self.queue.push(QueueItem {
                        query: ctx.query,
                        node: ctx.node,
                        depth: ctx.depth,
                        // Same per-node key the graph scheduler uses for
                        // invocation bundles.
                        bundle: (ctx.query, ctx.node as u64),
                        arrival: ctx.arrival,
                        rows,
                        tokens,
                        wcp_discounted: ctx.wcp_discounted,
                        prefix,
                        wcp_us: ctx.wcp_us,
                        tenant: ctx.tenant,
                        job,
                        reply: ctx.reply,
                        successors: ctx.successors,
                    });
                }
                continue;
            }
            self.loads[inst] += rows;
            self.kv[inst].reserve(reserved);
            self.counters.count_batch(n_jobs);
            if let Some(specs) = &specs {
                for (q, node, t, cost) in fair_charges {
                    let w = specs.get(&t).map_or(1, |s| s.weight);
                    self.fair.charge(t, cost, w);
                    // Remember the charge so a later `CancelNode` can
                    // refund work the device never finished.
                    self.charged.insert((q, node), (t, cost));
                }
            }
        }
        self.counters
            .add_dispatch_ns(t_dispatch.elapsed().as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Per-tenant rank map for one dispatch iteration: for every tenant
    /// with queued work, `(deadline boost, SFQ virtual start, tenant)` —
    /// ascending, so a boosted tenant beats any unboosted one and ties go
    /// to the tenant furthest behind on served work.  Boost is driven by
    /// the tenant's *longest-waiting* queued item against its deadline.
    fn tenant_ranks(&self, specs: &HashMap<TenantId, TenantSpec>) -> TenantRanks {
        let now = Instant::now();
        let mut waited: HashMap<TenantId, u64> = HashMap::new();
        for it in self.queue.iter() {
            let w = now.saturating_duration_since(it.arrival).as_micros() as u64;
            let e = waited.entry(it.tenant).or_insert(0);
            *e = (*e).max(w);
        }
        waited
            .into_iter()
            .map(|(t, w)| {
                let spec =
                    specs.get(&t).cloned().unwrap_or_else(|| TenantSpec::default_for(t));
                (t, (boost_class(&spec, w), self.fair.vstart(t), t))
            })
            .collect()
    }

    /// Admission control (multi-tenant QoS): when any queued Interactive
    /// item has already waited past its tenant's deadline — the measured
    /// signal that queue delay exceeds the SLO budget — queued
    /// Batch-class items are shed with a loud `Failed` completion,
    /// freeing budget for the Interactive backlog.  The shed is
    /// **bounded and newest-first** (PR8 shed the entire Batch backlog):
    /// victims are taken in descending arrival order until the freed
    /// cost (in the active slot denomination) covers the largest
    /// breached Interactive item's estimated cost, so older,
    /// nearly-dispatched Batch work survives a single breach.  Tenants
    /// without a spec (including `UNTENANTED`) default to Interactive
    /// with no deadline: never shed, never a breach trigger.
    fn shed_batch_on_slo_breach(&mut self, specs: &HashMap<TenantId, TenantSpec>, unit: SlotUnit) {
        let now = Instant::now();
        let class_of = |t: TenantId| specs.get(&t).map_or(QosClass::Interactive, |s| s.class);
        // Estimated cost to free: the largest breached Interactive item
        // (its admission is what the shed must make room for).
        let need = self
            .queue
            .iter()
            .filter(|it| {
                let Some(spec) = specs.get(&it.tenant) else { return false };
                spec.class == QosClass::Interactive
                    && spec.deadline_ms.map_or(false, |d| {
                        now.saturating_duration_since(it.arrival).as_millis() as u64 > d
                    })
            })
            .map(|it| unit.cost(it))
            .max();
        let Some(need) = need else { return };
        // Newest-first victim order: the most recently enqueued Batch
        // work has the least sunk queueing investment.
        let mut victims: Vec<(usize, Instant, usize)> = self
            .queue
            .iter_ids()
            .filter(|(_, it)| class_of(it.tenant) == QosClass::Batch)
            .map(|(id, it)| (id, it.arrival, unit.cost(it)))
            .collect();
        victims.sort_by(|a, b| b.1.cmp(&a.1).then(b.0.cmp(&a.0)));
        let mut freed = 0usize;
        for (id, _, cost) in victims {
            if freed >= need {
                break;
            }
            let it = self.queue.remove(id);
            freed += cost;
            let _ = it.reply.send(Completion {
                query: it.query,
                node: it.node,
                output: JobOutput::Failed(format!(
                    "shed by admission control on '{}': Interactive SLO breached, \
                     Batch work bounced to protect goodput",
                    self.name
                )),
                timing: ExecTiming::default(),
            });
        }
    }

    /// In-flight load of an instance in the active denomination: KV
    /// token reservations (plus the executor-reported residency mirror —
    /// zero outside persistent-residency mode) under token accounting,
    /// rows otherwise.
    fn load_of(&self, i: usize, token_mode: bool) -> usize {
        if token_mode {
            self.kv[i].reserved().saturating_add(self.resident_mirror[i])
        } else {
            self.loads[i]
        }
    }

    /// Eligible-instance choice.  Full-batch mode requires a fully drained
    /// instance (legacy `busy` semantics); continuous mode admits into any
    /// live instance with spare budget — row slots in the legacy mode, KV
    /// tokens under token accounting (so a short request joins as long as
    /// its KV fits, regardless of how many rows are resident).  When the
    /// head job carries a prefix fingerprint, an eligible instance
    /// already holding that prefix is preferred — unless taking it would
    /// skew load by more than half the budget over the least-loaded
    /// choice, in which case load balance wins (affinity traded against
    /// imbalance, compared in the active denomination).
    fn pick_instance(
        &self,
        continuous: bool,
        token_mode: bool,
        budget: usize,
        want_prefix: Option<PrefixFp>,
    ) -> Option<usize> {
        let eligible = |i: &usize| -> bool {
            let i = *i;
            let load = self.load_of(i, token_mode);
            let fits = if continuous { load < budget } else { load == 0 };
            !self.dead[i] && fits
        };
        let least = (0..self.instances.len())
            .filter(eligible)
            .min_by_key(|&i| self.load_of(i, token_mode))?;
        if let Some(fp) = want_prefix {
            let holder = (0..self.instances.len())
                .filter(eligible)
                .filter(|&i| self.prefix_homes[i].contains(fp))
                .min_by_key(|&i| self.load_of(i, token_mode));
            if let Some(h) = holder {
                let margin = (budget / 2).max(1);
                if self.load_of(h, token_mode)
                    <= self.load_of(least, token_mode) + margin
                {
                    return Some(h);
                }
            }
        }
        Some(least)
    }
}

/// Apply the prefix-residency WCP discount to every queued item whose
/// fingerprinted prefix `resident` reports as held on a live instance —
/// at most once per item (the `wcp_discounted` flag).  Called at the top
/// of every dispatch pass, so a prefix that becomes resident *after* an
/// item was enqueued (another query's prefill computed it, or a requeue
/// landed behind fresh registrations) still discounts the item's stamp
/// before bucket ordering reads it — closing the PR4 gap where the
/// discount was applied at enqueue only.  Returns how many items were
/// discounted this pass; pure over its inputs so the hook is
/// unit-testable (`tests/wcp_scheduling.rs`).
pub fn rediscount_resident_prefixes(
    queue: &mut [QueueItem],
    resident: impl Fn(PrefixFp) -> bool,
    prefill_us_per_token: f64,
) -> usize {
    let mut discounted = 0;
    for it in queue.iter_mut() {
        if rediscount_item(it, &resident, prefill_us_per_token) {
            discounted += 1;
        }
    }
    discounted
}

/// One item's share of [`rediscount_resident_prefixes`]: apply the
/// prefix-residency discount if due; returns whether the stamp changed
/// (the [`SchedQueue`] restamp path uses this to refresh only the
/// touched buckets' ordering aggregates).
fn rediscount_item(
    it: &mut QueueItem,
    resident: impl Fn(PrefixFp) -> bool,
    prefill_us_per_token: f64,
) -> bool {
    if it.wcp_discounted {
        return false;
    }
    let Some(fp) = it.prefix else { return false };
    if !resident(fp) {
        return false;
    }
    let discount = (prefill_us_per_token * fp.len as f64) as u64;
    it.wcp_us = it.wcp_us.saturating_sub(discount);
    it.wcp_discounted = true;
    true
}

/// True when the batch's own accumulation window has elapsed: the oldest
/// arrival *within the formed candidate set* is older than `window`.
/// Pure so the window-per-batch policy is unit-testable.
fn batch_window_expired(items: &[QueueItem], window: Duration) -> bool {
    items
        .iter()
        .map(|i| i.arrival)
        .min()
        .map_or(true, |t| t.elapsed() >= window)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::batching::form_batch;
    use std::sync::mpsc::channel;

    fn item_at(query: u64, node: usize, arrival: Instant, job: EngineJob) -> QueueItem {
        let (tx, rx) = channel();
        std::mem::forget(rx);
        let tokens = job.kv_tokens();
        QueueItem {
            query,
            node,
            depth: 0,
            bundle: (query, node as u64),
            arrival,
            rows: 1,
            tokens,
            wcp_discounted: false,
            prefix: None,
            wcp_us: 0,
            tenant: crate::engines::UNTENANTED,
            job,
            reply: tx,
            successors: Vec::new(),
        }
    }

    fn decode_job(q: u64) -> EngineJob {
        EngineJob::Decode { seq: (q, 0), first_token: 5, segments: vec![] }
    }

    fn prefill_job(q: u64) -> EngineJob {
        EngineJob::Prefill { seq: (q, 0), tokens: vec![7; 4], offset: 0, prefix: None }
    }

    #[test]
    fn window_measured_on_formed_batch_not_whole_queue() {
        let now = Instant::now();
        let window = Duration::from_millis(50);
        let stale = now - Duration::from_millis(200);

        // Fresh co-arrivals alone: window still open -> accumulate.
        let fresh = vec![
            item_at(1, 1, now, prefill_job(1)),
            item_at(2, 2, now, prefill_job(2)),
        ];
        assert!(!batch_window_expired(&fresh, window));

        // A batch containing the stale item dispatches immediately.
        let with_stale = vec![item_at(3, 3, stale, decode_job(3))];
        assert!(batch_window_expired(&with_stale, window));
    }

    #[test]
    fn stale_item_no_longer_defeats_window_for_fresh_coarrivals() {
        // Regression shape: one stale decode sits in the queue while fresh
        // prefills co-arrive.  The old whole-queue `min(arrival)` gate saw
        // the stale arrival, declared the window elapsed, and dispatched
        // the fresh prefills without accumulation.  With the
        // per-candidate-set gate, the class-restricted batch containing
        // the stale decode goes out at once, while the fresh prefills'
        // own batch keeps its accumulation window.
        let now = Instant::now();
        let window = Duration::from_millis(50);
        let mut queue = vec![
            item_at(1, 1, now - Duration::from_millis(200), decode_job(1)),
            item_at(2, 2, now, prefill_job(2)),
            item_at(3, 3, now, prefill_job(3)),
        ];
        // First formed batch: the stale decode (earliest query bucket,
        // class-restricted) — its own window has expired, dispatch now.
        let first = form_batch(&mut queue, BatchPolicy::TopoAware, 8, false, SlotUnit::Rows);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].node, 1);
        assert!(batch_window_expired(&first, window));
        // Second formed batch: the fresh prefills — their window is still
        // open, so dispatch waits for more co-arrivals.
        let second = form_batch(&mut queue, BatchPolicy::TopoAware, 8, false, SlotUnit::Rows);
        assert_eq!(second.len(), 2);
        assert!(!batch_window_expired(&second, window));
    }

    #[test]
    fn empty_batch_counts_as_expired() {
        assert!(batch_window_expired(&[], Duration::from_millis(10)));
    }
}
