//! Lower-tier engine scheduler: owns the engine's instances, queues
//! primitive requests from all queries, forms batches per policy and load
//! balances across instances (§5.2, §6).
//!
//! Dispatch runs in one of two modes, split by the engine's
//! [`ExecMode`]:
//!
//! * **Full-batch** (encoder-style and model-free engines, and every
//!   engine under the `BlindTO`/`PerInvocation` baselines): an instance
//!   receives work only when fully drained (`loads == 0`), and each
//!   dispatched batch runs to completion — the legacy protocol.
//! * **Continuous** (stepped LLM engines under `TopoAware`, when
//!   enabled): new work is admitted into *partially occupied* instances
//!   mid-flight, bounded by their spare slot budget, in Algorithm 2
//!   priority order.  A late-arriving short decode joins an in-flight
//!   long decode's iteration loop instead of waiting behind its tail —
//!   iteration-level continuous batching.
//!
//! Load accounting is event-driven: instances report per-step
//! [`InstanceEvent`]s and the per-instance `loads` counter decreases by
//! the retired rows, so occupancy is exact at iteration granularity.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::engines::instance::Instance;
use crate::engines::{Batch, EngineJob, ExecMode, InstanceEvent, RequestCtx};
use crate::scheduler::batching::{form_batch, form_continuous_admission, BatchPolicy, QueueItem};

/// One engine's scheduler state (runs on its own thread).
pub struct EngineScheduler {
    pub name: String,
    pub instances: Vec<Instance>,
    pub event_rx: Receiver<InstanceEvent>,
    pub job_rx: Receiver<QueueItem>,
    /// Shared, runtime-switchable policy (benches flip it per scheme).
    pub policy: Arc<AtomicU8>,
    /// Pre-tuned max batch rows (the TO tuning / Algorithm 2 slot budget);
    /// shared so harnesses can retune per experiment.
    pub max_slots: Arc<AtomicUsize>,
    /// Shared, runtime-switchable continuous-batching toggle (only
    /// meaningful for `ExecMode::Stepped` engines under `TopoAware`).
    pub continuous: Arc<AtomicBool>,
    /// Dynamic-batching window in microseconds: when the queue holds
    /// fewer rows than the slot budget, wait this long (from the oldest
    /// arrival) for more requests before dispatching to an *idle*
    /// instance — the Triton/vLLM-style accumulation delay the paper's
    /// engines rely on.  Shared/atomic so benches and the CLI can sweep
    /// it at runtime.
    pub batch_window_us: Arc<AtomicU64>,
    /// Whether this engine's executors run the stepped protocol.
    mode: ExecMode,
    /// In-flight rows per instance (admitted minus retired) for
    /// least-loaded routing and spare-slot admission.
    loads: Vec<usize>,
    /// Instances whose channel died; never routed to again.
    dead: Vec<bool>,
    queue: Vec<QueueItem>,
}

impl EngineScheduler {
    /// Build a scheduler; `run()` consumes it on a dedicated thread.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        instances: Vec<Instance>,
        event_rx: Receiver<InstanceEvent>,
        job_rx: Receiver<QueueItem>,
        policy: Arc<AtomicU8>,
        max_slots: Arc<AtomicUsize>,
        continuous: Arc<AtomicBool>,
        batch_window_us: Arc<AtomicU64>,
        mode: ExecMode,
    ) -> EngineScheduler {
        let n = instances.len();
        EngineScheduler {
            name,
            instances,
            event_rx,
            job_rx,
            policy,
            max_slots,
            continuous,
            batch_window_us,
            mode,
            loads: vec![0; n],
            dead: vec![false; n],
            queue: Vec::new(),
        }
    }

    /// Scheduling loop: drain arrivals, fold in instance events, dispatch.
    pub fn run(mut self) {
        loop {
            // Block briefly for new work; exit when the platform drops.
            match self.job_rx.recv_timeout(Duration::from_micros(500)) {
                Ok(item) => self.queue.push(item),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    let alive = self.dead.iter().any(|d| !d);
                    if self.queue.is_empty() || !alive {
                        break;
                    }
                    // The job channel is gone but queued work remains:
                    // drain it at event pace instead of busy-spinning
                    // (recv on a disconnected channel returns instantly).
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            // Drain everything already waiting.
            while let Ok(item) = self.job_rx.try_recv() {
                self.queue.push(item);
            }
            // Fold in per-step occupancy reports.
            while let Ok(ev) = self.event_rx.try_recv() {
                self.loads[ev.instance] = self.loads[ev.instance].saturating_sub(ev.retired);
            }
            self.dispatch();
        }
    }

    /// Dispatch while an eligible instance and queued work exist.
    fn dispatch(&mut self) {
        let policy = BatchPolicy::from_u8(self.policy.load(Ordering::Relaxed));
        let slots = self.max_slots.load(Ordering::Relaxed).max(1);
        // Iteration-level admission applies to stepped engines under the
        // topology-aware policy; the TO/PO baselines keep the legacy
        // full-batch dispatch path untouched.
        let continuous = self.mode == ExecMode::Stepped
            && policy == BatchPolicy::TopoAware
            && self.continuous.load(Ordering::Relaxed);
        let window =
            Duration::from_micros(self.batch_window_us.load(Ordering::Relaxed));
        loop {
            if self.queue.is_empty() {
                break;
            }
            let Some(inst) = self.pick_instance(continuous, slots) else { break };
            let mid_flight = self.loads[inst] > 0;
            // Dynamic-batching delay: give co-arriving requests a moment
            // to accumulate before waking an idle instance, unless the
            // slot budget is already covered (or the policy bundles by
            // construction).  Joining an in-flight instance needs no
            // delay — the resident batch *is* the accumulation.
            if policy != BatchPolicy::PerInvocation && !mid_flight {
                let rows: usize = self.queue.iter().map(|i| i.rows.max(1)).sum();
                if rows < slots {
                    if let Some(t) = self.queue.iter().map(|i| i.arrival).min() {
                        if t.elapsed() < window {
                            break;
                        }
                    }
                }
            }
            let items = if mid_flight {
                form_continuous_admission(
                    &mut self.queue,
                    slots.saturating_sub(self.loads[inst]),
                )
            } else {
                form_batch(&mut self.queue, policy, slots)
            };
            if items.is_empty() {
                break;
            }
            let rows: usize = items.iter().map(|i| i.rows.max(1)).sum();
            let jobs: Vec<(RequestCtx, EngineJob)> = items
                .into_iter()
                .map(|i| {
                    (
                        RequestCtx {
                            query: i.query,
                            node: i.node,
                            depth: i.depth,
                            arrival: i.arrival,
                            reply: i.reply,
                        },
                        i.job,
                    )
                })
                .collect();
            if let Err(unsent) = self.instances[inst].sender.send(Batch { jobs }) {
                // Instance thread died: recover the unsent batch from the
                // send error and requeue it so its queries don't hang,
                // stop routing to the instance, and leave `loads`
                // untouched (nothing was admitted) so least-loaded
                // routing isn't skewed forever.
                eprintln!(
                    "[{}] instance {inst} died; requeueing {} job(s)",
                    self.name,
                    unsent.0.jobs.len()
                );
                self.dead[inst] = true;
                for (ctx, job) in unsent.0.jobs {
                    let rows = job.rows();
                    self.queue.push(QueueItem {
                        query: ctx.query,
                        node: ctx.node,
                        depth: ctx.depth,
                        // Same per-node formula the graph scheduler uses
                        // for invocation bundles.
                        bundle: (ctx.query << 20) | ctx.node as u64,
                        arrival: ctx.arrival,
                        rows,
                        job,
                        reply: ctx.reply,
                    });
                }
                continue;
            }
            self.loads[inst] += rows;
        }
    }

    /// Least-loaded eligible instance.  Full-batch mode requires a fully
    /// drained instance (legacy `busy` semantics); continuous mode admits
    /// into any live instance with spare slot budget.
    fn pick_instance(&self, continuous: bool, slots: usize) -> Option<usize> {
        (0..self.instances.len())
            .filter(|&i| !self.dead[i])
            .filter(|&i| {
                if continuous {
                    self.loads[i] < slots
                } else {
                    self.loads[i] == 0
                }
            })
            .min_by_key(|&i| self.loads[i])
    }
}
