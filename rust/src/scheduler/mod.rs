//! §5 Runtime Scheduling: the two-tier mechanism.
//!
//! Upper tier (graph scheduler): one runner per query tracks its e-graph's
//! in-degrees and dispatches primitive *nodes* (not loose requests) to the
//! engine schedulers.  Lower tier: one scheduler per engine batches
//! primitives from all queries — topology-aware by default (Algorithm 2),
//! with blind-TO and per-invocation (PO) policies for the baselines.

pub mod batching;
pub mod engine_sched;
pub mod graph_sched;
pub mod object_store;
pub mod platform;
pub mod stats;
pub mod tenancy;
pub mod wcp;

pub use batching::{
    form_batch, form_continuous_admission, head_index, head_needs_drained_instance,
    materialize_successor, wcp_priority_us, BatchPolicy, BundleId, QueueItem, SchedQueue,
    SlotUnit, SuccessorPlan, SuccessorTemplate, WCP_AGING_WEIGHT,
};
pub use engine_sched::{rediscount_resident_prefixes, EngineScheduler};
pub use graph_sched::{QueryMetrics, QueryRunner};
pub use object_store::ObjectStore;
pub use platform::{EngineSpec, Platform, PlatformConfig};
pub use tenancy::{
    boost_class, FairQueue, QosClass, SharedTenancy, TenancyConfig, TenantId, TenantRank,
    TenantRanks, TenantSpec, UNTENANTED,
};
pub use wcp::{
    latency_correction, node_cost_us, observe_latency, reset_latency_feedback,
    static_node_cost_us, WcpTracker,
};
