//! Per-query object store (§5.1): intermediate outputs keyed by node.
//!
//! Acts as the input repository for pending primitives and enforces
//! exactly-once delivery — a double write to the same node indicates a
//! scheduling bug and is rejected (the fault-tolerance hook of the paper).

use std::collections::HashMap;

use crate::engines::NodeId;
use crate::error::{Result, TeolaError};
use crate::graph::value::Value;

/// Intermediate-output store for one query.
#[derive(Debug, Default)]
pub struct ObjectStore {
    values: HashMap<NodeId, Value>,
}

impl ObjectStore {
    /// Empty store.
    pub fn new() -> ObjectStore {
        ObjectStore::default()
    }

    /// Record a node's output; errors on duplicate delivery.
    pub fn put(&mut self, node: NodeId, value: Value) -> Result<()> {
        if self.values.contains_key(&node) {
            return Err(TeolaError::Scheduler(format!(
                "duplicate output for node {node}"
            )));
        }
        self.values.insert(node, value);
        Ok(())
    }

    /// Fetch a node's output.
    pub fn get(&self, node: NodeId) -> Option<&Value> {
        self.values.get(&node)
    }

    /// Fetch or error (for required inputs).
    pub fn require(&self, node: NodeId) -> Result<&Value> {
        self.get(node)
            .ok_or_else(|| TeolaError::Scheduler(format!("missing value for node {node}")))
    }

    /// True once the node has completed.
    pub fn has(&self, node: NodeId) -> bool {
        self.values.contains_key(&node)
    }

    /// Number of stored values.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_once() {
        let mut s = ObjectStore::new();
        s.put(1, Value::Unit).unwrap();
        assert!(s.put(1, Value::Unit).is_err());
        assert!(s.has(1));
        assert!(!s.has(2));
    }

    #[test]
    fn require_missing_errors() {
        let s = ObjectStore::new();
        assert!(s.require(9).is_err());
    }
}
