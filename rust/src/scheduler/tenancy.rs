//! Multi-tenant QoS (PR8): tenant specs, SLO classes, start-time fair
//! queueing, and the shared runtime handle the platform threads through
//! every engine scheduler and stepped executor.
//!
//! Millions of users sharing one engine pool means WCP ordering alone is
//! not enough: a single aggressive tenant floods every queue and the
//! scheduler, blind to tenant identity, serves its work FIFO-within-WCP.
//! This module supplies the missing inputs:
//!
//! * [`TenantSpec`] — per-tenant weight, [`QosClass`]
//!   (`Interactive`/`Batch`), optional latency deadline, and an optional
//!   soft KV-residency quota (percent of instance KV capacity);
//! * [`FairQueue`] — a start-time-fair-queueing (SFQ) ledger over served
//!   cost-weighted work: each dispatch charges `cost / weight` of virtual
//!   time to the tenant, and batch formation orders tenants by their
//!   virtual *start* tag, so long-run served work converges to the weight
//!   ratio while an idle tenant re-enters at the current virtual time
//!   (no stored-up credit, no starvation);
//! * [`boost_class`] — the deadline-aware boost: an `Interactive` tenant
//!   whose queued work has burned more than half its deadline jumps ahead
//!   of every unboosted tenant regardless of SFQ tags;
//! * [`SharedTenancy`] — the runtime handle (`Arc`-shared by the
//!   platform, every engine scheduler, and both stepped executors) whose
//!   enabled flag and spec table are retunable mid-run, mirroring the
//!   other PR knobs.
//!
//! Everything is inert unless the platform enables tenancy
//! (`PlatformConfig::tenancy` / `TEOLA_TENANCY` / `run --tenants`): with
//! the gate off the schedulers never consult this module and the dispatch
//! order is bit-for-bit the pre-PR8 one.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

pub use crate::engines::{TenantId, UNTENANTED};

/// Service-level class of a tenant's traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QosClass {
    /// Latency-sensitive traffic: eligible for the deadline boost and
    /// protected by admission control — never shed.
    Interactive,
    /// Throughput traffic: no deadline boost, and the class admission
    /// control sheds first when `Interactive` SLOs are blowing.
    Batch,
}

impl QosClass {
    /// Stable lowercase name (spec strings, JSON).
    pub fn name(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Batch => "batch",
        }
    }
}

/// One tenant's QoS contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSpec {
    pub id: TenantId,
    /// Fair-queueing weight (served-work share; >= 1).
    pub weight: u32,
    pub class: QosClass,
    /// End-to-end latency SLO in milliseconds (`Interactive` tenants):
    /// drives the deadline boost, admission control, and the goodput
    /// (SLO-attainment) metric.  `None` = best-effort.
    pub deadline_ms: Option<u64>,
    /// Soft cap on this tenant's resident KV, as a percent of each
    /// instance's KV token capacity: an over-quota tenant becomes the
    /// preferred eviction victim at watermark preemption (the quota never
    /// blocks admission — it only orders evictions).
    pub kv_quota_pct: Option<u8>,
}

impl TenantSpec {
    /// The contract of a tenant nobody configured (and of
    /// [`UNTENANTED`] traffic): weight 1, `Interactive` with no
    /// deadline — never boosted, never shed.
    pub fn default_for(id: TenantId) -> TenantSpec {
        TenantSpec {
            id,
            weight: 1,
            class: QosClass::Interactive,
            deadline_ms: None,
            kv_quota_pct: None,
        }
    }
}

/// Platform-level tenancy configuration (the `PlatformConfig::tenancy`
/// knob).  Disabled + empty by default: the off-path is bit-for-bit the
/// tenant-blind scheduler.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenancyConfig {
    pub enabled: bool,
    pub tenants: Vec<TenantSpec>,
}

impl TenancyConfig {
    /// Parse the knob's spec string (`TEOLA_TENANCY` / `run --tenants`).
    ///
    /// Grammar: `""`, `"off"` or `"0"` disable tenancy; `"on"` enables it
    /// with every tenant on defaults; otherwise a `;`-separated list of
    /// `<id>:key=value,...` entries with keys `w` (weight, >= 1), `class`
    /// (`interactive`|`batch`), `deadline_ms`, and `kv_pct` (0-100).
    /// Example: `1:w=4,class=interactive,deadline_ms=250;2:w=1,class=batch`.
    pub fn parse(spec: &str) -> Result<TenancyConfig, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec.eq_ignore_ascii_case("off") || spec == "0" {
            return Ok(TenancyConfig::default());
        }
        if spec.eq_ignore_ascii_case("on") {
            return Ok(TenancyConfig { enabled: true, tenants: Vec::new() });
        }
        let mut tenants = Vec::new();
        for entry in spec.split(';').filter(|e| !e.trim().is_empty()) {
            let entry = entry.trim();
            let (id_s, rest) = match entry.split_once(':') {
                Some((i, r)) => (i.trim(), r),
                None => (entry, ""),
            };
            let id: TenantId = id_s
                .parse()
                .map_err(|_| format!("bad tenant id {id_s:?} in {entry:?}"))?;
            let mut t = TenantSpec::default_for(id);
            for kv in rest.split(',').filter(|s| !s.trim().is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("expected key=value, got {kv:?}"))?;
                let (k, v) = (k.trim(), v.trim());
                match k {
                    "w" | "weight" => {
                        let w: u32 =
                            v.parse().map_err(|_| format!("bad weight {v:?}"))?;
                        t.weight = w.max(1);
                    }
                    "class" => {
                        t.class = match v.to_ascii_lowercase().as_str() {
                            "interactive" => QosClass::Interactive,
                            "batch" => QosClass::Batch,
                            other => return Err(format!("unknown class {other:?}")),
                        };
                    }
                    "deadline_ms" => {
                        t.deadline_ms =
                            Some(v.parse().map_err(|_| format!("bad deadline {v:?}"))?);
                    }
                    "kv_pct" => {
                        let pct: u8 =
                            v.parse().map_err(|_| format!("bad kv_pct {v:?}"))?;
                        if pct > 100 {
                            return Err(format!("kv_pct {pct} > 100"));
                        }
                        t.kv_quota_pct = Some(pct);
                    }
                    other => return Err(format!("unknown tenant key {other:?}")),
                }
            }
            if tenants.iter().any(|e: &TenantSpec| e.id == id) {
                return Err(format!("duplicate tenant id {id}"));
            }
            tenants.push(t);
        }
        Ok(TenancyConfig { enabled: true, tenants })
    }

    /// Render back to the spec-string grammar `parse` accepts (knob
    /// round-trips and snapshot dumps).
    pub fn to_spec(&self) -> String {
        if !self.enabled {
            return "off".into();
        }
        if self.tenants.is_empty() {
            return "on".into();
        }
        let mut parts = Vec::new();
        for t in &self.tenants {
            let mut s = format!("{}:w={},class={}", t.id, t.weight, t.class.name());
            if let Some(d) = t.deadline_ms {
                s.push_str(&format!(",deadline_ms={d}"));
            }
            if let Some(p) = t.kv_quota_pct {
                s.push_str(&format!(",kv_pct={p}"));
            }
            parts.push(s);
        }
        parts.join(";")
    }
}

/// Fixed-point scale of the SFQ virtual clock: one unit of served work at
/// weight 1 advances a tenant's finish tag by this many virtual ticks, so
/// integer division by the weight keeps sub-unit resolution.
pub const SFQ_SCALE: u64 = 1024;

/// Start-time fair queueing over served cost-weighted work.
///
/// One ledger per engine scheduler.  `vstart(t)` is where tenant `t`'s
/// next work would begin on the virtual clock: the maximum of the global
/// virtual time and the tenant's own finish tag.  Ordering backlogged
/// tenants by ascending `vstart` and charging each dispatch
/// `cost * SFQ_SCALE / weight` yields the classic SFQ guarantees —
/// long-run served work proportional to weights, bounded unfairness per
/// busy period, and no starvation (an idle tenant resumes at the current
/// virtual time instead of replaying its idle credit).
#[derive(Debug, Clone, Default)]
pub struct FairQueue {
    vtime: u64,
    vfinish: HashMap<TenantId, u64>,
}

impl FairQueue {
    /// Empty ledger at virtual time zero.
    pub fn new() -> FairQueue {
        FairQueue::default()
    }

    /// Virtual start tag of tenant `t`'s next dispatch.
    pub fn vstart(&self, t: TenantId) -> u64 {
        self.vfinish.get(&t).copied().unwrap_or(0).max(self.vtime)
    }

    /// Account `cost` units of served work (rows or KV tokens — the
    /// engine's batching denomination) to tenant `t` at `weight`.
    pub fn charge(&mut self, t: TenantId, cost: usize, weight: u32) {
        let start = self.vstart(t);
        let w = u64::from(weight.max(1));
        let finish =
            start.saturating_add((cost.max(1) as u64).saturating_mul(SFQ_SCALE) / w);
        self.vfinish.insert(t, finish);
        self.vtime = start;
    }

    /// Give back a prior [`FairQueue::charge`] for work the device never
    /// finished (a cancelled speculative dispatch, a shed job): the
    /// tenant's finish tag retreats by the same `cost * SFQ_SCALE /
    /// weight` the charge advanced it, so rolled-back work costs no SFQ
    /// share.  `vstart` clamps to the live virtual time, so an
    /// over-refund cannot mint credit ahead of other tenants.
    pub fn refund(&mut self, t: TenantId, cost: usize, weight: u32) {
        let w = u64::from(weight.max(1));
        let delta = (cost.max(1) as u64).saturating_mul(SFQ_SCALE) / w;
        if let Some(f) = self.vfinish.get_mut(&t) {
            *f = f.saturating_sub(delta);
        }
    }

    /// Forget everything (comparison-harness hygiene between halves).
    pub fn reset(&mut self) {
        self.vtime = 0;
        self.vfinish.clear();
    }
}

/// Deadline-aware boost class of a queued item whose tenant is `spec`
/// and whose oldest queued work has waited `waited_us`: `0` (dispatch
/// ahead of every unboosted tenant) once an `Interactive` tenant has
/// burned more than half its deadline in queue, else `1`.  `Batch` and
/// deadline-free tenants are never boosted.
pub fn boost_class(spec: &TenantSpec, waited_us: u64) -> u64 {
    match (spec.class, spec.deadline_ms) {
        (QosClass::Interactive, Some(deadline_ms)) => {
            if waited_us.saturating_mul(2) >= deadline_ms.saturating_mul(1000) {
                0
            } else {
                1
            }
        }
        _ => 1,
    }
}

/// Per-tenant ordering key for one batch-formation pass, ascending:
/// boost class first (deadline-pressed `Interactive` tenants beat
/// everything), then the SFQ virtual start tag (weighted fair share),
/// then the tenant id as a deterministic tie-break.
pub type TenantRank = (u64, u64, TenantId);

/// Ranks for every tenant present in a queue, prepared by the engine
/// scheduler once per formation pass and consulted by
/// `batching::topo_order` to sort query buckets *between* tenants while
/// WCP/arrival ordering is preserved *within* each tenant.
pub type TenantRanks = HashMap<TenantId, TenantRank>;

/// The shared runtime handle: enabled flag plus the spec table, both
/// retunable mid-run.  One `Arc<SharedTenancy>` is held by the platform,
/// every engine scheduler, and both stepped executors, so a retune
/// applies to ordering, shedding, and KV-quota eviction at once.
#[derive(Debug, Default)]
pub struct SharedTenancy {
    enabled: AtomicBool,
    specs: Mutex<HashMap<TenantId, TenantSpec>>,
    /// Bumped on every [`SharedTenancy::configure`] — engine schedulers
    /// compare it against their cached copy to (a) refresh the spec
    /// table without taking the mutex on every dispatch pass and (b)
    /// reset their fair-queueing ledgers on a runtime retune, so a
    /// long-lived pool never carries stale virtual-time tags into a new
    /// tenant registry.
    epoch: AtomicU64,
}

impl SharedTenancy {
    /// Handle initialized from a platform config.
    pub fn new(cfg: &TenancyConfig) -> SharedTenancy {
        let t = SharedTenancy::default();
        t.configure(cfg);
        t
    }

    /// Replace the whole configuration (runtime retune / restore).
    pub fn configure(&self, cfg: &TenancyConfig) {
        let mut specs = self.specs.lock().unwrap();
        specs.clear();
        for t in &cfg.tenants {
            specs.insert(t.id, t.clone());
        }
        drop(specs);
        self.enabled.store(cfg.enabled, Ordering::Relaxed);
        self.epoch.fetch_add(1, Ordering::Relaxed);
    }

    /// Configuration generation: changes iff [`SharedTenancy::configure`]
    /// ran.  Starts at 1 for a configured handle (and 0 for a bare
    /// `default()`), so schedulers initializing their cache generation
    /// to 0 observe the first configuration as a change.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Whether tenancy is currently requested (the effective state in a
    /// scheduler also requires the `TopoAware` policy, like every other
    /// PR knob).
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Snapshot the full configuration (tenants sorted by id, so
    /// comparison harnesses can snapshot/restore deterministically).
    pub fn snapshot(&self) -> TenancyConfig {
        let specs = self.specs.lock().unwrap();
        let mut tenants: Vec<TenantSpec> = specs.values().cloned().collect();
        tenants.sort_by_key(|t| t.id);
        TenancyConfig { enabled: self.enabled(), tenants }
    }

    /// The contract of tenant `t`: its configured spec, or the default
    /// (weight 1, `Interactive`, no deadline) when nobody configured it.
    pub fn spec_of(&self, t: TenantId) -> TenantSpec {
        self.specs
            .lock()
            .unwrap()
            .get(&t)
            .cloned()
            .unwrap_or_else(|| TenantSpec::default_for(t))
    }

    /// Clone of the spec table (one lock per formation pass, not one per
    /// item).
    pub fn specs(&self) -> HashMap<TenantId, TenantSpec> {
        self.specs.lock().unwrap().clone()
    }

    /// Tenant `t`'s soft resident-KV quota in tokens against an instance
    /// of `capacity`, if one is configured.
    pub fn kv_quota_tokens(&self, t: TenantId, capacity: usize) -> Option<usize> {
        let pct = self.specs.lock().unwrap().get(&t)?.kv_quota_pct?;
        Some(capacity.saturating_mul(pct as usize) / 100)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        for s in ["", "off", "0", "OFF"] {
            let c = TenancyConfig::parse(s).unwrap();
            assert!(!c.enabled, "{s:?} must disable tenancy");
            assert!(c.tenants.is_empty());
        }
        let c = TenancyConfig::parse("on").unwrap();
        assert!(c.enabled && c.tenants.is_empty());

        let spec = "1:w=4,class=interactive,deadline_ms=250;2:w=1,class=batch,kv_pct=30";
        let c = TenancyConfig::parse(spec).unwrap();
        assert!(c.enabled);
        assert_eq!(c.tenants.len(), 2);
        assert_eq!(c.tenants[0].id, 1);
        assert_eq!(c.tenants[0].weight, 4);
        assert_eq!(c.tenants[0].class, QosClass::Interactive);
        assert_eq!(c.tenants[0].deadline_ms, Some(250));
        assert_eq!(c.tenants[1].class, QosClass::Batch);
        assert_eq!(c.tenants[1].kv_quota_pct, Some(30));
        // to_spec -> parse is the identity.
        assert_eq!(TenancyConfig::parse(&c.to_spec()).unwrap(), c);
        assert_eq!(TenancyConfig::parse(&TenancyConfig::default().to_spec()).unwrap(),
            TenancyConfig::default());

        assert!(TenancyConfig::parse("x:w=1").is_err(), "non-numeric id");
        assert!(TenancyConfig::parse("1:w=zero").is_err(), "bad weight");
        assert!(TenancyConfig::parse("1:class=gold").is_err(), "unknown class");
        assert!(TenancyConfig::parse("1:kv_pct=130").is_err(), "pct > 100");
        assert!(TenancyConfig::parse("1:w=1;1:w=2").is_err(), "duplicate id");
        assert!(TenancyConfig::parse("1:w").is_err(), "missing value");
    }

    #[test]
    fn weight_zero_clamps_to_one() {
        let c = TenancyConfig::parse("1:w=0").unwrap();
        assert_eq!(c.tenants[0].weight, 1);
    }

    #[test]
    fn sfq_shares_track_weights() {
        // Two always-backlogged tenants at weights 3:1 — picking the
        // lower vstart each round must serve them 3:1.
        let mut fq = FairQueue::new();
        let mut served = [0usize; 2];
        for _ in 0..400 {
            let pick = if fq.vstart(1) <= fq.vstart(2) { 0 } else { 1 };
            let (t, w) = [(1, 3u32), (2, 1u32)][pick];
            fq.charge(t, 1, w);
            served[pick] += 1;
        }
        let ratio = served[0] as f64 / served[1] as f64;
        assert!(
            (ratio - 3.0).abs() < 0.1,
            "3:1 weights must serve ~3:1, got {served:?}"
        );
    }

    #[test]
    fn refund_restores_share_without_minting_credit() {
        let mut fq = FairQueue::new();
        let before = fq.vstart(1);
        fq.charge(1, 10, 2);
        let charged = fq.vstart(1);
        assert!(charged > before);
        // Refunding the same (cost, weight) undoes the charge exactly.
        fq.refund(1, 10, 2);
        assert_eq!(fq.vstart(1), before);
        // Over-refunding saturates the finish tag at zero, and vstart
        // still clamps to the live virtual time — a huge refund cannot
        // mint credit that replays ahead of the current busy period.
        fq.charge(2, 100, 1);
        fq.charge(2, 100, 1);
        let vtime_floor = fq.vstart(3); // fresh tenant = current vtime
        fq.refund(1, 1_000_000, 1);
        assert_eq!(
            fq.vstart(1),
            vtime_floor,
            "over-refund clamps to live virtual time, not zero"
        );
        // Refunding a tenant with no ledger entry is a no-op.
        let w99 = fq.vstart(99);
        fq.refund(99, 10, 1);
        assert_eq!(fq.vstart(99), w99);
    }

    #[test]
    fn sfq_idle_tenant_resumes_without_stored_credit() {
        let mut fq = FairQueue::new();
        // Tenant 1 runs alone for a long while.
        for _ in 0..100 {
            fq.charge(1, 10, 1);
        }
        // Tenant 2 wakes up: its vstart is the *current* virtual time,
        // not zero — it does not get to replay its idle period and lock
        // out tenant 1.
        let v2 = fq.vstart(2);
        assert!(v2 > 0, "idle tenant must resume at the live virtual time");
        // It still goes first (its finish tag is behind tenant 1's), but
        // only by the backlog bound, not by its whole idle period: after
        // a couple of its own charges it is back behind tenant 1.
        fq.charge(2, 10, 1);
        fq.charge(2, 10, 1);
        assert!(
            fq.vstart(2) >= fq.vstart(1),
            "no stored-up credit: {} vs {}",
            fq.vstart(2),
            fq.vstart(1)
        );
    }

    #[test]
    fn deadline_boost_ordering_is_pinned() {
        let mut interactive = TenantSpec::default_for(1);
        interactive.deadline_ms = Some(100);
        let mut batch = TenantSpec::default_for(2);
        batch.class = QosClass::Batch;
        batch.deadline_ms = Some(100); // deadline on Batch never boosts
        let free = TenantSpec::default_for(3); // Interactive, no deadline

        // Under half the deadline: nobody is boosted.
        assert_eq!(boost_class(&interactive, 49_000), 1);
        // At/over half the deadline: only the Interactive+deadline
        // tenant is boosted — the boost class sorts strictly first.
        assert_eq!(boost_class(&interactive, 50_000), 0);
        assert_eq!(boost_class(&interactive, 10_000_000), 0);
        assert_eq!(boost_class(&batch, 10_000_000), 1);
        assert_eq!(boost_class(&free, 10_000_000), 1);
        // Rank tuples order boosted-first, then SFQ start, then id.
        let boosted: TenantRank = (0, 999_999, 1);
        let fair_low: TenantRank = (1, 10, 2);
        let fair_high: TenantRank = (1, 20, 3);
        let mut ranks = [fair_high, boosted, fair_low];
        ranks.sort();
        assert_eq!(ranks, [boosted, fair_low, fair_high]);
    }

    #[test]
    fn shared_handle_round_trips_and_defaults() {
        let cfg = TenancyConfig::parse("7:w=2,class=batch,deadline_ms=9,kv_pct=40").unwrap();
        let h = SharedTenancy::new(&cfg);
        assert!(h.enabled());
        assert_eq!(h.snapshot(), cfg);
        assert_eq!(h.spec_of(7).weight, 2);
        assert_eq!(h.spec_of(42), TenantSpec::default_for(42), "unknown -> defaults");
        assert_eq!(h.kv_quota_tokens(7, 1000), Some(400));
        assert_eq!(h.kv_quota_tokens(42, 1000), None);
        h.configure(&TenancyConfig::default());
        assert!(!h.enabled());
        assert_eq!(h.snapshot(), TenancyConfig::default());
    }
}
