//! Synthetic dataset stand-ins for the paper's workloads.
//!
//! The paper synthesizes request streams from web_questions, HotpotQA,
//! FinQABench and TruthfulQA.  End-to-end latency depends on the *shape*
//! of those datasets — question lengths, document/chunk counts, chunk
//! sizes, answer lengths — not their semantics, so each stand-in matches
//! the published length distributions (token-count statistics from the
//! dataset cards, scaled to our 256-position KV budget).

use crate::graph::template::QueryConfig;
use crate::util::rng::Rng;

/// Which dataset to draw queries from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// web_questions: short single-hop questions, no documents.
    WebQuestions,
    /// HotpotQA: longer multi-hop questions, no documents.
    HotpotQa,
    /// FinQABench: financial filings — larger, denser chunk sets.
    FinQaBench,
    /// TruthfulQA: short questions over compact web snippets.
    TruthfulQa,
}

impl DatasetKind {
    /// Display name (matches Fig. 8 subcaptions).
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::WebQuestions => "web_questions",
            DatasetKind::HotpotQa => "hotpotqa",
            DatasetKind::FinQaBench => "finqabench",
            DatasetKind::TruthfulQa => "truthfulqa",
        }
    }
}

/// A deterministic query sampler for one dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub kind: DatasetKind,
    rng: Rng,
}

impl Dataset {
    /// Seeded sampler.
    pub fn new(kind: DatasetKind, seed: u64) -> Dataset {
        Dataset { kind, rng: Rng::new(seed ^ 0xD5EA5E) }
    }

    fn tokens(rng: &mut Rng, n: usize) -> Vec<i32> {
        (0..n).map(|_| 4 + rng.zipf(0, 2000) as i32).collect()
    }

    /// Sample the next query.
    pub fn sample(&mut self) -> QueryConfig {
        let rng = &mut self.rng;
        let (q_lo, q_hi, n_chunks, c_lo, c_hi, ans) = match self.kind {
            // (question len range, chunk count, chunk len range, answer)
            DatasetKind::WebQuestions => (8, 16, 0, 0, 1, 24),
            DatasetKind::HotpotQa => (16, 32, 0, 0, 1, 28),
            // Doc QA uploads split into ~48/32 chunks (Fig. 4a: "48
            // requests for 48 document chunks").
            DatasetKind::FinQaBench => (12, 24, 48, 40, 56, 28),
            DatasetKind::TruthfulQa => (8, 20, 32, 32, 48, 24),
        };
        let qlen = rng.range_usize(q_lo, q_hi);
        let question = Self::tokens(rng, qlen);
        let doc_chunks = (0..n_chunks)
            .map(|_| {
                let l = rng.range_usize(c_lo.max(8), c_hi.max(9));
                Self::tokens(rng, l)
            })
            .collect();
        let seed = rng.next_u64();
        QueryConfig {
            question,
            doc_chunks,
            top_k: 3,
            expansion: 3,
            answer_tokens: ans,
            seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sampling() {
        let mut a = Dataset::new(DatasetKind::TruthfulQa, 5);
        let mut b = Dataset::new(DatasetKind::TruthfulQa, 5);
        let qa = a.sample();
        let qb = b.sample();
        assert_eq!(qa.question, qb.question);
        assert_eq!(qa.doc_chunks, qb.doc_chunks);
    }

    #[test]
    fn shapes_match_dataset_kind() {
        let mut d = Dataset::new(DatasetKind::FinQaBench, 1);
        let q = d.sample();
        assert_eq!(q.doc_chunks.len(), 48);
        assert!(q.doc_chunks[0].len() >= 40);
        let mut w = Dataset::new(DatasetKind::WebQuestions, 1);
        assert!(w.sample().doc_chunks.is_empty());
    }
}
