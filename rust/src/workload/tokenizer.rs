//! Tiny word-hash tokenizer so the examples accept real strings.
//!
//! Not a BPE — a deterministic word -> id hash into the model vocabulary,
//! reserving the special ids.  Enough for demos: the models are synthetic,
//! so only token *counts* and repetition structure matter.

/// Word-level hash tokenizer over the shared vocabulary.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: usize,
}

impl Tokenizer {
    /// Tokenizer for a vocabulary size (first 4 ids are special).
    pub fn new(vocab: usize) -> Tokenizer {
        assert!(vocab > 8);
        Tokenizer { vocab }
    }

    fn hash_word(&self, w: &str) -> i32 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in w.to_lowercase().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        4 + (h % (self.vocab as u64 - 4)) as i32
    }

    /// Encode a string (whitespace/punctuation split).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split(|c: char| c.is_whitespace() || ",.;:!?\"()[]{}".contains(c))
            .filter(|w| !w.is_empty())
            .map(|w| self.hash_word(w))
            .collect()
    }

    /// Decode token ids into a printable pseudo-text (hex word forms) —
    /// demo output only.
    pub fn decode(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|t| match *t {
                0 => "<pad>".to_string(),
                1 => "<bos>".to_string(),
                2 => "<eos>".to_string(),
                3 => "|".to_string(),
                t => format!("w{t:x}"),
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_deterministic_and_in_vocab() {
        let t = Tokenizer::new(2048);
        let a = t.encode("What is the capital of France?");
        let b = t.encode("what is the capital of france");
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| (4..2048).contains(&x)));
        assert_eq!(a.len(), 6);
    }

    #[test]
    fn decode_round_trips_special() {
        let t = Tokenizer::new(2048);
        assert!(t.decode(&[1, 5, 3, 2]).contains('|'));
    }
}
