//! Workload generation: synthetic datasets, Poisson traces, tokenizer.

pub mod dataset;
pub mod poisson;
pub mod tokenizer;

pub use dataset::{Dataset, DatasetKind};
pub use poisson::PoissonTrace;
pub use tokenizer::Tokenizer;
