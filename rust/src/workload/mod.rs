//! Workload generation: synthetic datasets, Poisson traces, tokenizer.

pub mod dataset;
pub mod poisson;
pub mod tokenizer;

pub use dataset::{Dataset, DatasetKind};
pub use poisson::{MultiTenantTrace, PoissonTrace, TenantLoad};
pub use tokenizer::Tokenizer;
