//! Open-loop Poisson arrival traces (the paper's request synthesis),
//! single-tenant and merged multi-tenant mixes.

use std::time::Duration;

use crate::engines::TenantId;
use crate::util::rng::Rng;

/// A deterministic arrival schedule.
#[derive(Debug, Clone)]
pub struct PoissonTrace {
    /// Arrival offsets from trace start.
    pub arrivals: Vec<Duration>,
}

impl PoissonTrace {
    /// `n` arrivals at `rate` requests/second.
    pub fn generate(rate: f64, n: usize, seed: u64) -> PoissonTrace {
        let mut rng = Rng::new(seed ^ 0x90155);
        let mut t = 0f64;
        let mut arrivals = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exp_gap_secs(rate);
            arrivals.push(Duration::from_secs_f64(t));
        }
        PoissonTrace { arrivals }
    }

    /// Trace duration (last arrival offset).
    pub fn span(&self) -> Duration {
        self.arrivals.last().copied().unwrap_or_default()
    }
}

/// One tenant's slice of a multi-tenant Poisson mix.
#[derive(Debug, Clone)]
pub struct TenantLoad {
    pub tenant: TenantId,
    /// Arrivals per second of this tenant's independent process.
    pub rate: f64,
    /// Number of queries this tenant issues.
    pub n: usize,
}

/// A merged multi-tenant arrival schedule: every tenant runs its own
/// independent seeded Poisson process (seed salted by the tenant id, so
/// re-ordering the `loads` slice can never change any tenant's own
/// arrivals), and the union is sorted by arrival offset.
#[derive(Debug, Clone)]
pub struct MultiTenantTrace {
    /// `(arrival offset, tenant)` per query, ascending by offset with
    /// the tenant id as a deterministic tie-break.
    pub arrivals: Vec<(Duration, TenantId)>,
}

impl MultiTenantTrace {
    /// Merge one independent Poisson process per tenant load.
    pub fn generate(loads: &[TenantLoad], seed: u64) -> MultiTenantTrace {
        let mut arrivals = Vec::new();
        for l in loads {
            let salt = u64::from(l.tenant).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let tr = PoissonTrace::generate(l.rate, l.n, seed ^ salt);
            arrivals.extend(tr.arrivals.into_iter().map(|d| (d, l.tenant)));
        }
        arrivals.sort();
        MultiTenantTrace { arrivals }
    }

    /// Trace duration (last arrival offset across all tenants).
    pub fn span(&self) -> Duration {
        self.arrivals.last().map(|(d, _)| *d).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_roughly_respected() {
        let tr = PoissonTrace::generate(10.0, 2000, 3);
        let span = tr.span().as_secs_f64();
        let rate = 2000.0 / span;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn arrivals_monotonic() {
        let tr = PoissonTrace::generate(5.0, 100, 4);
        for w in tr.arrivals.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn deterministic() {
        let a = PoissonTrace::generate(2.0, 50, 9);
        let b = PoissonTrace::generate(2.0, 50, 9);
        assert_eq!(a.arrivals, b.arrivals);
    }

    #[test]
    fn multi_tenant_merge_is_sorted_and_complete() {
        let loads = [
            TenantLoad { tenant: 1, rate: 4.0, n: 10 },
            TenantLoad { tenant: 2, rate: 40.0, n: 100 },
        ];
        let tr = MultiTenantTrace::generate(&loads, 7);
        assert_eq!(tr.arrivals.len(), 110);
        for w in tr.arrivals.windows(2) {
            assert!(w[0] <= w[1], "merged arrivals must be sorted");
        }
        let n1 = tr.arrivals.iter().filter(|(_, t)| *t == 1).count();
        let n2 = tr.arrivals.iter().filter(|(_, t)| *t == 2).count();
        assert_eq!((n1, n2), (10, 100));
        // Deterministic, and each tenant's own subsequence is exactly its
        // independent single-tenant trace (merging changes nothing).
        let again = MultiTenantTrace::generate(&loads, 7);
        assert_eq!(tr.arrivals, again.arrivals);
        let salt1 = 1u64.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let solo1 = PoissonTrace::generate(4.0, 10, 7 ^ salt1);
        let merged1: Vec<Duration> =
            tr.arrivals.iter().filter(|(_, t)| *t == 1).map(|(d, _)| *d).collect();
        assert_eq!(merged1, solo1.arrivals);
        // Re-ordering the load slice cannot move any tenant's arrivals.
        let swapped = MultiTenantTrace::generate(&[loads[1].clone(), loads[0].clone()], 7);
        assert_eq!(tr.arrivals, swapped.arrivals);
    }
}
