//! Open-loop Poisson arrival traces (the paper's request synthesis).

use std::time::Duration;

use crate::util::rng::Rng;

/// A deterministic arrival schedule.
#[derive(Debug, Clone)]
pub struct PoissonTrace {
    /// Arrival offsets from trace start.
    pub arrivals: Vec<Duration>,
}

impl PoissonTrace {
    /// `n` arrivals at `rate` requests/second.
    pub fn generate(rate: f64, n: usize, seed: u64) -> PoissonTrace {
        let mut rng = Rng::new(seed ^ 0x90155);
        let mut t = 0f64;
        let mut arrivals = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exp_gap_secs(rate);
            arrivals.push(Duration::from_secs_f64(t));
        }
        PoissonTrace { arrivals }
    }

    /// Trace duration (last arrival offset).
    pub fn span(&self) -> Duration {
        self.arrivals.last().copied().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_roughly_respected() {
        let tr = PoissonTrace::generate(10.0, 2000, 3);
        let span = tr.span().as_secs_f64();
        let rate = 2000.0 / span;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn arrivals_monotonic() {
        let tr = PoissonTrace::generate(5.0, 100, 4);
        for w in tr.arrivals.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn deterministic() {
        let a = PoissonTrace::generate(2.0, 50, 9);
        let b = PoissonTrace::generate(2.0, 50, 9);
        assert_eq!(a.arrivals, b.arrivals);
    }
}
