//! Engine latency profiles (paper §3.1: developers register engines "along
//! with their latency profiles for various input sizes").
//!
//! Profiles drive two decisions:
//! * Pass 2 (stage decomposition): the *maximum efficient batch size*
//!   beyond which throughput stops improving;
//! * the TO baseline's pre-tuned max batch/token sizes.
//!
//! Defaults below were measured on this image's PJRT-CPU engines (see
//! EXPERIMENTS.md §Perf for the calibration run); `calibrate()` re-measures
//! them for the current machine.

use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Simulated device-occupancy model.
///
/// The paper's engines run on dedicated GPUs: the coordinator dispatches
/// and the device computes asynchronously.  This testbed has a single CPU
/// core, so real parallel compute cannot overlap; instead every engine
/// call executes the real XLA artifact (for numerics) and then *sleeps*
/// until the profiled device time has elapsed.  Sleeping threads overlap
/// freely, so instances behave as independent accelerators and the
/// paper's parallelism/batching/queueing effects are preserved.
///
/// Times are scaled ~10x down from the paper's GPU numbers (llama-2-7B
/// prefill ~= 1 ms/token there -> 200 us/token here for `llm-small`) so a
/// full benchmark sweep stays tractable.  `TEOLA_DEVICE_SCALE` rescales
/// globally; `TEOLA_DEVICE_OFF=1` disables the model (raw CPU timing).
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    /// Prefill cost per prompt token per row, microseconds.
    pub prefill_us_per_token: f64,
    /// Fixed prefill kernel-launch/setup cost per call.
    pub prefill_base_us: f64,
    /// Decode cost per step at batch 1.
    pub decode_step_us: f64,
    /// Marginal decode cost per extra batched row (memory-bound: cheap).
    pub decode_row_frac: f64,
    /// Embed/rerank per-call base + per-row cost.
    pub encoder_base_us: f64,
    pub encoder_row_us: f64,
}

impl DeviceModel {
    /// Model for an engine/variant name.
    pub fn for_engine(name: &str) -> DeviceModel {
        // Values sit ABOVE the real single-core XLA times of this image so
        // the residual sleep (not raw CPU contention) sets the pace and
        // GPU batching economics hold (decode rows nearly free, prefill
        // compute-bound).  Calibration: EXPERIMENTS.md §Calibration.
        let llm = |scale: f64| DeviceModel {
            prefill_us_per_token: 200.0 * scale,
            prefill_base_us: 3_000.0 * scale,
            decode_step_us: 3_000.0 * scale,
            decode_row_frac: 0.15,
            encoder_base_us: 0.0,
            encoder_row_us: 0.0,
        };
        let m = match name {
            "llm-lite" => llm(0.5),
            "llm-small" => llm(1.0),
            "llm-medium" => llm(1.7),
            "llm-large" => llm(2.6),
            "embedder" => DeviceModel {
                prefill_us_per_token: 0.0,
                prefill_base_us: 0.0,
                decode_step_us: 0.0,
                decode_row_frac: 0.0,
                encoder_base_us: 8_000.0,
                encoder_row_us: 1_500.0,
            },
            "reranker" => DeviceModel {
                prefill_us_per_token: 0.0,
                prefill_base_us: 0.0,
                decode_step_us: 0.0,
                decode_row_frac: 0.0,
                encoder_base_us: 10_000.0,
                encoder_row_us: 3_000.0,
            },
            _ => llm(1.0),
        };
        m.scaled(global_scale())
    }

    fn scaled(mut self, s: f64) -> DeviceModel {
        self.prefill_us_per_token *= s;
        self.prefill_base_us *= s;
        self.decode_step_us *= s;
        self.encoder_base_us *= s;
        self.encoder_row_us *= s;
        self
    }

    /// Device time of one prefill call over `rows` rows x `tokens` tokens.
    pub fn prefill_us(&self, rows: usize, tokens: usize) -> u64 {
        (self.prefill_base_us + self.prefill_us_per_token * (rows * tokens) as f64) as u64
    }

    /// Device time of one decode step at `batch` rows.
    pub fn decode_step_us(&self, batch: usize) -> u64 {
        (self.decode_step_us * (1.0 + self.decode_row_frac * (batch.saturating_sub(1)) as f64))
            as u64
    }

    /// Device time of one encoder call over `rows` rows.
    pub fn encoder_us(&self, rows: usize) -> u64 {
        (self.encoder_base_us + self.encoder_row_us * rows as f64) as u64
    }
}

fn global_scale() -> f64 {
    std::env::var("TEOLA_DEVICE_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

/// True when the device-occupancy model is disabled.
pub fn device_model_off() -> bool {
    std::env::var("TEOLA_DEVICE_OFF").map_or(false, |v| v != "0" && !v.is_empty())
}

/// Sleep until `sim_us` of device time has elapsed since `start` (no-op if
/// the real execution already took longer, or if the model is disabled).
pub fn charge_device(start: Instant, sim_us: u64) {
    if device_model_off() {
        return;
    }
    let elapsed = start.elapsed();
    let target = Duration::from_micros(sim_us);
    if let Some(residual) = target.checked_sub(elapsed) {
        std::thread::sleep(residual);
    }
}

/// Latency table for one engine op: batch size -> microseconds.
#[derive(Debug, Clone, Default)]
pub struct OpProfile {
    pub points: Vec<(usize, u64)>,
}

impl OpProfile {
    /// Construct from (batch, us) points (must be ascending in batch).
    pub fn new(points: Vec<(usize, u64)>) -> OpProfile {
        OpProfile { points }
    }

    /// Interpolated latency estimate for a batch size.
    pub fn latency_us(&self, batch: usize) -> u64 {
        if self.points.is_empty() {
            return 0;
        }
        if batch <= self.points[0].0 {
            return self.points[0].1;
        }
        for w in self.points.windows(2) {
            let (b0, t0) = w[0];
            let (b1, t1) = w[1];
            if batch <= b1 {
                let f = (batch - b0) as f64 / (b1 - b0).max(1) as f64;
                return (t0 as f64 + f * (t1 as f64 - t0 as f64)) as u64;
            }
        }
        // extrapolate linearly per row beyond the last point
        let (bl, tl) = *self.points.last().unwrap();
        let per_row = tl as f64 / bl.max(1) as f64;
        (tl as f64 + per_row * (batch - bl) as f64) as u64
    }

    /// Throughput (rows/sec) at a batch size.
    pub fn throughput(&self, batch: usize) -> f64 {
        let us = self.latency_us(batch).max(1);
        batch as f64 * 1e6 / us as f64
    }

    /// The max *efficient* batch: the largest measured batch whose
    /// throughput gain over the previous point is still >= `min_gain`
    /// (paper: "the size beyond which throughput does not increase").
    pub fn max_efficient_batch(&self, min_gain: f64) -> usize {
        if self.points.is_empty() {
            return 1;
        }
        let mut best = self.points[0].0;
        let mut prev_tp = self.throughput(self.points[0].0);
        for &(b, _) in &self.points[1..] {
            let tp = self.throughput(b);
            if tp > prev_tp * (1.0 + min_gain) {
                best = b;
                prev_tp = tp;
            } else {
                break;
            }
        }
        best
    }
}

/// Profile registry: (engine name, op) -> profile.
#[derive(Debug, Clone, Default)]
pub struct ProfileRegistry {
    map: HashMap<(String, String), OpProfile>,
}

impl ProfileRegistry {
    /// Registry pre-populated with this image's measured defaults.
    pub fn with_defaults() -> ProfileRegistry {
        let mut r = ProfileRegistry::default();
        // Measured on PJRT-CPU (see EXPERIMENTS.md §Calibration).
        r.register("embedder", "embed",
            OpProfile::new(vec![(1, 9_500), (4, 14_000), (8, 20_000), (16, 32_000)]));
        r.register("reranker", "score",
            OpProfile::new(vec![(1, 13_000), (4, 22_000), (8, 34_000), (16, 58_000)]));
        for v in ["llm-lite", "llm-small", "llm-medium", "llm-large"] {
            let scale = match v {
                "llm-lite" => 1.0,
                "llm-small" => 2.0,
                "llm-medium" => 3.0,
                _ => 4.0,
            };
            r.register(v, "prefill",
                OpProfile::new(vec![
                    (1, (15_000.0 * scale) as u64),
                    (2, (22_000.0 * scale) as u64),
                    (4, (38_000.0 * scale) as u64),
                ]));
            r.register(v, "decode",
                OpProfile::new(vec![
                    (1, (4_000.0 * scale) as u64),
                    (2, (5_000.0 * scale) as u64),
                    (4, (7_000.0 * scale) as u64),
                    (8, (11_000.0 * scale) as u64),
                ]));
        }
        r
    }

    /// Register/overwrite a profile.
    pub fn register(&mut self, engine: &str, op: &str, p: OpProfile) {
        self.map.insert((engine.to_string(), op.to_string()), p);
    }

    /// Look up a profile.
    pub fn get(&self, engine: &str, op: &str) -> Option<&OpProfile> {
        self.map.get(&(engine.to_string(), op.to_string()))
    }

    /// Max efficient batch with a 10% throughput-gain threshold, falling
    /// back to `fallback` for unknown engines.
    pub fn max_efficient_batch(&self, engine: &str, op: &str, fallback: usize) -> usize {
        self.get(engine, op)
            .map(|p| p.max_efficient_batch(0.10))
            .unwrap_or(fallback)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolation_and_extrapolation() {
        let p = OpProfile::new(vec![(1, 100), (4, 220), (8, 400)]);
        assert_eq!(p.latency_us(1), 100);
        assert_eq!(p.latency_us(2), 140);
        assert_eq!(p.latency_us(8), 400);
        assert!(p.latency_us(16) > 400);
    }

    #[test]
    fn max_efficient_batch_detects_knee() {
        // Throughput rises to batch 8 and then flattens hard.
        let p = OpProfile::new(vec![(1, 100), (4, 150), (8, 220), (16, 440)]);
        assert_eq!(p.max_efficient_batch(0.10), 8);
    }

    #[test]
    fn defaults_present() {
        let r = ProfileRegistry::with_defaults();
        assert!(r.get("embedder", "embed").is_some());
        assert!(r.max_efficient_batch("embedder", "embed", 4) >= 4);
        assert!(r.get("llm-large", "prefill").is_some());
    }
}
