//! Reranking engine (bge-reranker analog): cross-encoder relevance scores
//! over pre-packed `query ++ SEP ++ candidate` sequences.

use std::rc::Rc;
use std::sync::mpsc::Sender;

use crate::engines::instance::{spawn_instance, BatchExecutor, Instance};
use crate::engines::profile::{charge_device, DeviceModel};
use crate::engines::{Batch, Completion, EngineJob, ExecTiming, InstanceEvent, JobOutput};
use crate::error::{Result, TeolaError};
use crate::runtime::{HostTensor, Manifest, XlaContext};

/// Per-instance reranker executor.
pub struct RerankExecutor {
    ctx: XlaContext,
    model: String,
    seq: usize,
    batches: Vec<usize>,
    device: DeviceModel,
}

impl RerankExecutor {
    /// Build on the instance thread; `warm` pre-compiles all buckets.
    pub fn new(manifest: Rc<Manifest>, model: &str, warm: bool) -> Result<RerankExecutor> {
        let info = manifest
            .models
            .get(model)
            .ok_or_else(|| TeolaError::Engine(format!("unknown reranker {model}")))?;
        let seq = info.max_seq;
        let batches = manifest.encoder_batches(model);
        if batches.is_empty() {
            return Err(TeolaError::Engine(format!("no buckets for {model}")));
        }
        let mut ctx = XlaContext::new(manifest)?;
        if warm {
            let names: Vec<String> =
                batches.iter().map(|b| format!("{model}__score__b{b}")).collect();
            ctx.warm(&names)?;
            ctx.model_weights(model)?;
        }
        Ok(RerankExecutor {
            ctx,
            model: model.to_string(),
            seq,
            batches,
            device: DeviceModel::for_engine(model),
        })
    }

    fn score_rows(&mut self, rows: &[Vec<i32>]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(rows.len());
        let maxb = *self.batches.last().unwrap();
        let mut i = 0;
        while i < rows.len() {
            let take = (rows.len() - i).min(maxb);
            let bb = crate::engines::llm::pick_bucket(&self.batches, take);
            let mut tokens = vec![0i32; bb * self.seq];
            let mut mask = vec![0f32; bb * self.seq];
            for (b, row) in rows[i..i + take].iter().enumerate() {
                let len = row.len().min(self.seq);
                tokens[b * self.seq..b * self.seq + len].copy_from_slice(&row[..len]);
                mask[b * self.seq..b * self.seq + len]
                    .iter_mut()
                    .for_each(|x| *x = 1.0);
            }
            let artifact = format!("{}__score__b{}", self.model, bb);
            let started = std::time::Instant::now();
            let res = self.ctx.run(
                &artifact,
                Some(&self.model.clone()),
                &[
                    HostTensor::i32(vec![bb, self.seq], tokens),
                    HostTensor::f32(vec![bb, self.seq], mask),
                ],
            )?;
            charge_device(started, self.device.encoder_us(take));
            let flat = res[0].to_vec::<f32>()?;
            out.extend_from_slice(&flat[..take]);
            i += take;
        }
        Ok(out)
    }
}

impl BatchExecutor for RerankExecutor {
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()> {
        let mut rows: Vec<Vec<i32>> = Vec::new();
        let mut extents = Vec::new();
        for (ctx, job) in &batch.jobs {
            match job {
                EngineJob::Rerank { pairs } => {
                    extents.push((ctx.clone(), rows.len(), pairs.len()));
                    rows.extend(pairs.iter().cloned());
                }
                other => {
                    return Err(TeolaError::Engine(format!(
                        "reranker engine got {other:?}"
                    )))
                }
            }
        }
        let scores = self.score_rows(&rows)?;
        for (ctx, start, count) in extents {
            emit(Completion {
                query: ctx.query,
                node: ctx.node,
                output: JobOutput::Scores(scores[start..start + count].to_vec()),
                timing: ExecTiming::default(),
            });
        }
        Ok(())
    }
}

/// Spawn `n_instances` reranker instance threads (XLA or simulated).
pub fn spawn_reranker_engine(
    manifest: Rc<Manifest>,
    model: &str,
    n_instances: usize,
    warm: bool,
    backend: crate::engines::sim::ExecBackend,
    free_tx: Sender<InstanceEvent>,
    ready_tx: Sender<()>,
) -> Vec<Instance> {
    use crate::engines::sim::{ExecBackend, SimRerankExecutor};

    match backend {
        ExecBackend::Xla => {
            let dir = manifest.dir.clone();
            (0..n_instances)
                .map(|i| {
                    let dir_c = dir.clone();
                    let model_c = model.to_string();
                    spawn_instance(
                        i,
                        format!("rerank-{i}"),
                        move || {
                            let m = Rc::new(Manifest::load(dir_c)?);
                            RerankExecutor::new(m, &model_c, warm)
                        },
                        free_tx.clone(),
                        ready_tx.clone(),
                    )
                })
                .collect()
        }
        ExecBackend::Sim => (0..n_instances)
            .map(|i| {
                let model_c = model.to_string();
                spawn_instance(
                    i,
                    format!("rerank-{i}"),
                    move || {
                        Ok::<_, crate::error::TeolaError>(SimRerankExecutor::new(&model_c, 16))
                    },
                    free_tx.clone(),
                    ready_tx.clone(),
                )
            })
            .collect(),
    }
}
