//! Cross-query KV prefix sharing (§8 scaling discussion; cf. Parrot /
//! SGLang-style prompt-structure exposure).
//!
//! The paper's apps prepend a shared instruction template (~60 tokens) to
//! every LLM call, so under serving load every query re-prefills the same
//! leading tokens.  The graph scheduler fingerprints the leading `Const`
//! prompt part when it lowers a from-scratch prefill; the fingerprint
//! travels with the job ([`crate::engines::EngineJob::Prefill`]) and its
//! queue item, the engine scheduler routes the job to an instance already
//! holding the prefix (affinity traded against load imbalance), and the
//! stepped LLM executors consume the hit — the sim executor charges only
//! the un-cached suffix's prefill time, the XLA executor clones the
//! resident prefix KV rows instead of recomputing them.
//!
//! Residency is bounded: every instance keeps at most
//! `PlatformConfig::prefix_slots` prefixes in an LRU registry
//! ([`PrefixRegistry`]); the engine scheduler mirrors the registries for
//! routing.  A budget of 0 disables the feature entirely (no routing, no
//! caching) — the on/off comparison `tests/prefix_routing.rs` benches.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Prefixes shorter than this are not worth fingerprinting (the clone /
/// bookkeeping overhead rivals the saved prefill).
pub const MIN_PREFIX_LEN: usize = 4;

/// Fingerprint of a shared leading prompt prefix: content hash + token
/// length.  Two prefills with equal fingerprints share their first `len`
/// prompt tokens (FNV-1a collisions are ignorable at this scale).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrefixFp {
    pub hash: u64,
    pub len: usize,
}

/// Fingerprint a token prefix (FNV-1a over the tokens).
pub fn prefix_fingerprint(tokens: &[i32]) -> PrefixFp {
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in tokens {
        h ^= t as u32 as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    PrefixFp { hash: h, len: tokens.len() }
}

/// LRU set of resident prefixes with a shared, runtime-switchable
/// capacity.  Used twice per engine: each instance's executor keeps the
/// authoritative registry (payload `T` = the prefix KV, or `()` on the
/// sim path where KV is virtual), and the engine scheduler keeps a
/// `PrefixRegistry<()>` mirror per instance for affinity routing.  Both
/// share one capacity handle so retuning `prefix_slots` applies
/// everywhere at once; capacity 0 disables lookups and drops all
/// entries at the next insert.
#[derive(Debug)]
pub struct PrefixRegistry<T> {
    cap: Arc<AtomicUsize>,
    /// LRU order: least recently used first.
    entries: Vec<(PrefixFp, T)>,
}

impl<T> PrefixRegistry<T> {
    /// New registry bound to a shared capacity handle.
    pub fn new(cap: Arc<AtomicUsize>) -> PrefixRegistry<T> {
        PrefixRegistry { cap, entries: Vec::new() }
    }

    /// Current capacity (0 = feature disabled).
    pub fn cap(&self) -> usize {
        self.cap.load(Ordering::Relaxed)
    }

    /// Resident prefixes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Non-touching residency probe (routing peek).
    pub fn contains(&self, fp: PrefixFp) -> bool {
        self.cap() > 0 && self.entries.iter().any(|(f, _)| *f == fp)
    }

    /// Touching lookup: on residency, the prefix moves to most-recently
    /// used and its payload is returned.
    pub fn hit(&mut self, fp: PrefixFp) -> Option<&T> {
        if self.cap() == 0 {
            return None;
        }
        let i = self.entries.iter().position(|(f, _)| *f == fp)?;
        let e = self.entries.remove(i);
        self.entries.push(e);
        Some(&self.entries.last().unwrap().1)
    }

    /// Insert (or refresh) a prefix as most-recently used, evicting from
    /// the LRU end down to the current capacity.
    pub fn insert(&mut self, fp: PrefixFp, payload: T) {
        let cap = self.cap();
        if cap == 0 {
            self.entries.clear();
            return;
        }
        if let Some(i) = self.entries.iter().position(|(f, _)| *f == fp) {
            self.entries.remove(i);
        }
        self.entries.push((fp, payload));
        while self.entries.len() > cap {
            self.entries.remove(0);
        }
    }

    /// Re-apply the current capacity without an insert, evicting from the
    /// LRU end.  Eviction used to happen only on the next insert, so a
    /// mid-run `prefix_slots` shrink left the engine scheduler's routing
    /// mirror holding prefixes the executors had already dropped — and
    /// affinity kept routing prefills at phantom residency until entries
    /// churned.  The scheduler (each dispatch) and the executors (each
    /// admission) call this to resync with the shared budget immediately.
    pub fn resync(&mut self) {
        let cap = self.cap();
        if self.entries.len() > cap {
            self.entries.drain(..self.entries.len() - cap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(seed: i32) -> PrefixFp {
        prefix_fingerprint(&[seed, seed + 1, seed + 2, seed + 3])
    }

    #[test]
    fn fingerprint_is_content_addressed() {
        assert_eq!(prefix_fingerprint(&[1, 2, 3]), prefix_fingerprint(&[1, 2, 3]));
        assert_ne!(prefix_fingerprint(&[1, 2, 3]).hash, prefix_fingerprint(&[1, 2, 4]).hash);
        assert_eq!(prefix_fingerprint(&[1, 2, 3]).len, 3);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let cap = Arc::new(AtomicUsize::new(2));
        let mut r: PrefixRegistry<u32> = PrefixRegistry::new(cap);
        r.insert(fp(1), 10);
        r.insert(fp(2), 20);
        // Touch fp(1) so fp(2) becomes the LRU entry.
        assert_eq!(r.hit(fp(1)), Some(&10));
        r.insert(fp(3), 30);
        assert!(r.contains(fp(1)));
        assert!(!r.contains(fp(2)), "LRU entry must be evicted");
        assert!(r.contains(fp(3)));
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn zero_capacity_disables_everything() {
        let cap = Arc::new(AtomicUsize::new(0));
        let mut r: PrefixRegistry<()> = PrefixRegistry::new(cap.clone());
        r.insert(fp(1), ());
        assert!(r.is_empty());
        assert!(!r.contains(fp(1)));
        assert_eq!(r.hit(fp(1)), None);
        // Capacity shrink to zero drops residents on the next insert.
        cap.store(2, Ordering::Relaxed);
        r.insert(fp(1), ());
        assert_eq!(r.len(), 1);
        cap.store(0, Ordering::Relaxed);
        r.insert(fp(2), ());
        assert!(r.is_empty());
    }

    #[test]
    fn resync_applies_a_mid_run_capacity_shrink() {
        let cap = Arc::new(AtomicUsize::new(4));
        let mut r: PrefixRegistry<u32> = PrefixRegistry::new(cap.clone());
        for i in 0..4 {
            r.insert(fp(i), i as u32);
        }
        // Shrink 4 -> 1: only the most recently used prefix may survive.
        cap.store(1, Ordering::Relaxed);
        r.resync();
        assert_eq!(r.len(), 1);
        assert!(r.contains(fp(3)), "MRU entry survives the shrink");
        for i in 0..3 {
            assert!(!r.contains(fp(i)), "fp({i}) must be evicted by resync");
        }
        // Shrink to 0 clears everything; resync under capacity is a no-op.
        cap.store(0, Ordering::Relaxed);
        r.resync();
        assert!(r.is_empty());
        cap.store(8, Ordering::Relaxed);
        r.insert(fp(9), 9);
        r.resync();
        assert_eq!(r.len(), 1);
    }
}
