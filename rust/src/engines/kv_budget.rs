//! Token-denominated KV memory accounting (PR5 tentpole).
//!
//! Real LLM engines admit work by KV memory, not by batch rows: a
//! 2048-token prefill and an 8-token prefill have wildly different memory
//! footprints, so row-slot budgets either overcommit on long prompts or
//! waste capacity on short ones (cf. Parrot's application-aware serving
//! and vLLM's token-block accounting).  [`KvBudget`] is the reservation
//! ledger both sides of the admission protocol share:
//!
//! * the **engine scheduler** keeps one per instance, reserving a job's
//!   token estimate at dispatch and releasing the *same charge* when the
//!   instance reports the job retired (the charge rides
//!   [`crate::engines::RequestCtx::kv_tokens`] so reserve/release pair
//!   exactly — the ledger drains to zero, never negative);
//! * the **stepped LLM executors** keep their own, rejecting over-budget
//!   admissions back to the instance backlog until retirements free
//!   space (vLLM-style admission control).
//!
//! The reservation of a job is its KV growth: prompt tokens for a
//! prefill (suffix-only when the shared instruction prefix is already
//! resident — routing hits get cheaper admission), planned new tokens
//! for a decode.  Over a sequence's life this sums to the classic
//! `prompt_tokens + max_new_tokens` reserve-at-admit.
//!
//! All arithmetic is saturating: a release can never push the ledger
//! negative, and [`KvBudget::release`] reports how much was actually
//! released so invariant tests (`tests/prop_invariants.rs`) can detect
//! any reserve/release mispairing.
//!
//! PR6 adds the second, **resident** ledger: KV a sequence keeps between
//! jobs.  Under persistent residency a prefill's charge moves from
//! "reserved" to "resident against its `SeqId`" at retirement
//! ([`KvBudget::commit_resident`]) instead of being released, and only
//! `FreeQuery` ([`KvBudget::free_query`]) or watermark preemption
//! ([`KvBudget::evict_victim`] + [`KvBudget::free_seq`]) returns it.
//! Capacity checks are against `reserved + resident`
//! ([`KvBudget::occupied`]); with an empty resident ledger (the PR5
//! reserve-at-admit mode) every method behaves exactly as before.

use std::collections::HashMap;

use crate::engines::{QueryId, SeqId, TenantId, UNTENANTED};

/// Per-instance KV token budget: capacity plus the reservation ledger
/// (in-flight jobs) and the resident ledger (per-sequence KV kept
/// between jobs; token count, latest WCP priority stamp, last-use tick,
/// owning tenant).
///
/// A capacity of 0 means "unlimited" (the legacy row-slot mode is in
/// force and the token ledger is maintained only for observability).
#[derive(Debug, Clone, Default)]
pub struct KvBudget {
    capacity: usize,
    reserved: usize,
    resident: HashMap<SeqId, (usize, u64, u64, TenantId)>,
    resident_total: usize,
    /// Eviction clock: advanced once per executor step, stamped onto a
    /// sequence's resident entry whenever it is committed or touched, so
    /// [`KvBudget::evict_victim`] can prefer the *stalest* sequence.
    clock: u64,
    /// Accounting drift: tokens a [`KvBudget::release`] call asked for
    /// beyond what was reserved (a reserve/release mispairing upstream).
    /// The old behavior silently saturated; now every clamp is recorded
    /// so `residency_stats` can surface it and tests can assert it is 0.
    drift: usize,
}

impl KvBudget {
    /// New ledger with the given token capacity (0 = unlimited).
    pub fn new(capacity: usize) -> KvBudget {
        KvBudget {
            capacity,
            reserved: 0,
            resident: HashMap::new(),
            resident_total: 0,
            clock: 0,
            drift: 0,
        }
    }

    /// Advance the eviction clock one tick (once per executor step).
    /// Everything committed or touched within a step shares the tick, so
    /// victim choice inside one step stays order-independent.
    pub fn advance_clock(&mut self) {
        self.clock = self.clock.saturating_add(1);
    }

    /// Refresh `seq`'s last-use tick to now (a resident-hit decode
    /// admission re-used its KV).  No-op when `seq` is not resident.
    pub fn touch_resident(&mut self, seq: SeqId) {
        if let Some(e) = self.resident.get_mut(&seq) {
            e.2 = self.clock;
        }
    }

    /// Current token capacity (0 = unlimited).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Retune the capacity (runtime knob); existing reservations are
    /// kept — the ledger simply stops admitting until enough retires.
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity;
    }

    /// Tokens currently reserved (admitted minus retired).
    pub fn reserved(&self) -> usize {
        self.reserved
    }

    /// Tokens held resident across jobs (per-sequence KV committed at
    /// retirement, not yet freed).
    pub fn resident_total(&self) -> usize {
        self.resident_total
    }

    /// Resident sequences currently in the ledger.
    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    /// Whether `seq` currently holds resident KV in this ledger.
    pub fn is_resident(&self, seq: SeqId) -> bool {
        self.resident.contains_key(&seq)
    }

    /// Total tokens charged against the capacity: in-flight reservations
    /// plus committed residency.
    pub fn occupied(&self) -> usize {
        self.reserved.saturating_add(self.resident_total)
    }

    /// Spare tokens under the capacity (`usize::MAX` when unlimited).
    pub fn spare(&self) -> usize {
        if self.capacity == 0 {
            usize::MAX
        } else {
            self.capacity.saturating_sub(self.occupied())
        }
    }

    /// Whether a reservation of `tokens` fits under the capacity.
    /// Always true when the capacity is 0 (unlimited).
    pub fn fits(&self, tokens: usize) -> bool {
        self.capacity == 0 || self.occupied().saturating_add(tokens) <= self.capacity
    }

    /// Reserve `tokens` (admission).  Saturating: the ledger cannot
    /// overflow, and deliberate over-budget admissions (a single job
    /// larger than the whole capacity must still run — the executors
    /// chunk it internally) are recorded faithfully.
    pub fn reserve(&mut self, tokens: usize) {
        self.reserved = self.reserved.saturating_add(tokens);
    }

    /// Release up to `tokens` (retirement); returns the amount actually
    /// released.  Saturating: the ledger never goes negative — a return
    /// value smaller than `tokens` means a reserve/release mispairing
    /// upstream, recorded in [`KvBudget::accounting_drift`] (and asserted
    /// against in the invariant tests).
    pub fn release(&mut self, tokens: usize) -> usize {
        let freed = tokens.min(self.reserved);
        self.reserved -= freed;
        self.drift = self.drift.saturating_add(tokens - freed);
        freed
    }

    /// Cumulative over-release tokens (reserve/release mispairings) since
    /// construction or the last [`KvBudget::take_drift`]/reset.  0 means
    /// every release paired exactly with a reservation.
    pub fn accounting_drift(&self) -> usize {
        self.drift
    }

    /// Read-and-clear the drift counter (harvested into the executors'
    /// residency stats once per step).
    pub fn take_drift(&mut self) -> usize {
        std::mem::take(&mut self.drift)
    }

    /// Move `tokens` of `seq`'s in-flight reservation into the resident
    /// ledger (job retirement under persistent residency).  `prio` is the
    /// retiring job's WCP stamp — the eviction policy's priority signal;
    /// the latest stamp wins.  The reservation side is released
    /// saturating, the resident side is credited the full charge, so the
    /// resident ledger always reflects what the store actually holds.
    pub fn commit_resident(&mut self, seq: SeqId, tokens: usize, prio: u64) {
        self.commit_resident_as(seq, tokens, prio, UNTENANTED);
    }

    /// [`KvBudget::commit_resident`] attributing the residency to a
    /// tenant (multi-tenant KV quotas): quota checks and the quota-aware
    /// eviction policy sum residency per tenant through this stamp.
    pub fn commit_resident_as(&mut self, seq: SeqId, tokens: usize, prio: u64, tenant: TenantId) {
        self.release(tokens);
        let clock = self.clock;
        let e = self.resident.entry(seq).or_insert((0, prio, clock, tenant));
        e.0 = e.0.saturating_add(tokens);
        e.1 = prio;
        e.2 = clock;
        e.3 = tenant;
        self.resident_total = self.resident_total.saturating_add(tokens);
    }

    /// Resident tokens summed per tenant (quota enforcement input).
    pub fn resident_by_tenant(&self) -> HashMap<TenantId, usize> {
        let mut out: HashMap<TenantId, usize> = HashMap::new();
        for &(tokens, _, _, tenant) in self.resident.values() {
            *out.entry(tenant).or_default() += tokens;
        }
        out
    }

    /// Free one sequence's residency (watermark eviction / swap-out).
    /// Returns the tokens freed (0 when `seq` was not resident).
    pub fn free_seq(&mut self, seq: SeqId) -> usize {
        match self.resident.remove(&seq) {
            Some((tokens, _, _, _)) => {
                self.resident_total = self.resident_total.saturating_sub(tokens);
                tokens
            }
            None => 0,
        }
    }

    /// Free every resident sequence belonging to `query` (the `FreeQuery`
    /// bookkeeping op).  Returns the total tokens freed.
    pub fn free_query(&mut self, query: QueryId) -> usize {
        let mut freed = 0usize;
        self.resident.retain(|seq, entry| {
            if seq.0 == query {
                freed = freed.saturating_add(entry.0);
                false
            } else {
                true
            }
        });
        self.resident_total = self.resident_total.saturating_sub(freed);
        freed
    }

    /// Preemption victim: the *stalest* resident sequence not in
    /// `active` — smallest last-use tick first (LRU: a sequence nothing
    /// has touched for many steps is the least likely to be re-used),
    /// then the lowest WCP priority stamp among equals, then a
    /// deterministic `SeqId` tie-break so victim choice is reproducible
    /// across runs.  Returns the victim and its resident token count.
    pub fn evict_victim(&self, active: &[SeqId]) -> Option<(SeqId, usize)> {
        self.evict_victim_quota(active, &|_| false)
    }

    /// [`KvBudget::evict_victim`] with per-tenant quota awareness: a
    /// sequence whose owning tenant `over_quota` reports as over its
    /// resident-token soft cap is *always* preferred over any
    /// within-quota sequence; staleness/priority/SeqId order applies
    /// within each group.  `|_| false` degenerates to the tenant-blind
    /// policy exactly.
    pub fn evict_victim_quota(
        &self,
        active: &[SeqId],
        over_quota: &dyn Fn(TenantId) -> bool,
    ) -> Option<(SeqId, usize)> {
        let mut best: Option<(SeqId, usize, (bool, u64, u64))> = None;
        for (&seq, &(tokens, prio, tick, tenant)) in &self.resident {
            if active.contains(&seq) {
                continue;
            }
            // `false < true`, so over-quota tenants sort first.
            let key = (!over_quota(tenant), tick, prio);
            let better = match &best {
                None => true,
                Some((bseq, _, bkey)) => (key, seq) < (*bkey, *bseq),
            };
            if better {
                best = Some((seq, tokens, key));
            }
        }
        best.map(|(seq, tokens, _)| (seq, tokens))
    }

    /// Drop every reservation and all residency (instance death: nothing
    /// resident will ever retire, so the capacity must not stay
    /// phantom-occupied while the batch is requeued elsewhere).  Returns
    /// what was held across both ledgers.
    pub fn reset(&mut self) -> usize {
        let held = self.occupied();
        self.reserved = 0;
        self.resident.clear();
        self.resident_total = 0;
        self.clock = 0;
        self.drift = 0;
        held
    }

    /// Admission decision shared by the stepped executors: the
    /// reservation fits, or the ledger is empty — an idle executor must
    /// accept even an over-capacity job (it chunks internally), or a
    /// backlogged oversized job could never run (liveness).
    pub fn admits(&self, tokens: usize) -> bool {
        self.fits(tokens) || self.reserved == 0
    }
}

/// Token charge of a prefill whose leading `prefix_len` tokens are
/// already resident on the serving instance: the un-cached suffix,
/// never 0.  The one rule shared by the engine scheduler's dispatch
/// charge and both stepped executors' admission reservations — change
/// it here, not per call site.
pub fn suffix_charge(prompt_tokens: usize, prefix_len: usize) -> usize {
    prompt_tokens.saturating_sub(prefix_len).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_pair_exactly() {
        let mut b = KvBudget::new(100);
        assert!(b.fits(100));
        assert!(!b.fits(101));
        b.reserve(60);
        assert_eq!(b.reserved(), 60);
        assert_eq!(b.spare(), 40);
        assert!(b.fits(40));
        assert!(!b.fits(41));
        assert_eq!(b.release(60), 60);
        assert_eq!(b.reserved(), 0);
        assert_eq!(b.spare(), 100);
    }

    #[test]
    fn release_saturates_never_negative() {
        let mut b = KvBudget::new(10);
        b.reserve(4);
        // Over-release is clamped and reported.
        assert_eq!(b.release(9), 4);
        assert_eq!(b.reserved(), 0);
        assert_eq!(b.release(1), 0);
        assert_eq!(b.reserved(), 0);
        // The mispair is no longer invisible: both clamps are recorded.
        assert_eq!(b.accounting_drift(), 5 + 1);
    }

    #[test]
    fn accounting_drift_records_mispairs_and_clears() {
        let mut b = KvBudget::new(10);
        b.reserve(6);
        assert_eq!(b.release(6), 6);
        assert_eq!(b.accounting_drift(), 0, "exact pairing leaves no drift");
        b.reserve(2);
        b.release(5);
        assert_eq!(b.accounting_drift(), 3);
        assert_eq!(b.take_drift(), 3, "take reads and clears");
        assert_eq!(b.accounting_drift(), 0);
        b.release(1);
        assert_eq!(b.accounting_drift(), 1);
        b.reset();
        assert_eq!(b.accounting_drift(), 0, "instance reset forgives drift");
    }

    #[test]
    fn zero_capacity_means_unlimited() {
        let mut b = KvBudget::new(0);
        assert!(b.fits(usize::MAX));
        b.reserve(1_000_000);
        assert_eq!(b.spare(), usize::MAX);
        assert_eq!(b.reserved(), 1_000_000);
    }

    #[test]
    fn oversized_reservation_recorded_and_reset_clears() {
        let mut b = KvBudget::new(8);
        // A job larger than the whole budget still reserves faithfully
        // (it was admitted alone; the executor chunks it).
        b.reserve(32);
        assert_eq!(b.reserved(), 32);
        assert!(!b.fits(1));
        assert_eq!(b.reset(), 32);
        assert_eq!(b.reserved(), 0);
        assert!(b.fits(8));
    }

    #[test]
    fn admits_fits_or_idle() {
        let mut b = KvBudget::new(10);
        assert!(b.admits(100), "idle ledger accepts oversized (liveness)");
        b.reserve(4);
        assert!(b.admits(6));
        assert!(!b.admits(7), "occupied ledger bounces over-budget work");
    }

    #[test]
    fn suffix_charge_is_uncached_remainder() {
        assert_eq!(suffix_charge(24, 16), 8);
        assert_eq!(suffix_charge(16, 16), 1, "never 0 (load accounting)");
        assert_eq!(suffix_charge(8, 16), 1, "saturates, never underflows");
    }

    #[test]
    fn commit_resident_moves_tokens_without_changing_occupancy() {
        let mut b = KvBudget::new(100);
        b.reserve(60);
        assert_eq!(b.occupied(), 60);
        b.commit_resident((1, 0), 60, 500);
        assert_eq!(b.reserved(), 0);
        assert_eq!(b.resident_total(), 60);
        assert_eq!(b.occupied(), 60, "commit moves tokens, never mints them");
        assert!(b.is_resident((1, 0)));
        assert!(b.fits(40));
        assert!(!b.fits(41), "residency counts against the capacity");
    }

    #[test]
    fn free_seq_and_free_query_drain_residency() {
        let mut b = KvBudget::new(100);
        b.reserve(30);
        b.commit_resident((7, 0), 10, 1);
        b.commit_resident((7, 1), 12, 2);
        b.commit_resident((8, 0), 8, 3);
        assert_eq!(b.reserved(), 0);
        assert_eq!(b.resident_total(), 30);
        assert_eq!(b.free_seq((7, 1)), 12);
        assert_eq!(b.free_seq((7, 1)), 0, "double-free is a no-op");
        assert_eq!(b.free_query(7), 10, "free_query drops every seq of the query");
        assert_eq!(b.resident_total(), 8);
        assert_eq!(b.free_query(8), 8);
        assert_eq!(b.occupied(), 0, "ledger drains to zero after FreeQuery");
    }

    #[test]
    fn evict_victim_picks_lowest_priority_inactive() {
        // All three commits land on the same clock tick, so the WCP
        // priority stamp is what decides among them.
        let mut b = KvBudget::new(100);
        b.reserve(24);
        b.commit_resident((1, 0), 8, 50);
        b.commit_resident((2, 0), 8, 10);
        b.commit_resident((3, 0), 8, 90);
        // Lowest stamp overall is (2,0), but it is active — skip it.
        assert_eq!(b.evict_victim(&[(2, 0)]), Some(((1, 0), 8)));
        assert_eq!(b.evict_victim(&[]), Some(((2, 0), 8)));
        let freed = b.free_seq((2, 0));
        assert_eq!(freed, 8);
        assert_eq!(b.occupied(), 16);
        // Everything active: no victim, caller must live with the overshoot.
        assert_eq!(b.evict_victim(&[(1, 0), (3, 0)]), None);
    }

    #[test]
    fn evict_victim_prefers_stalest_tick_over_priority() {
        let mut b = KvBudget::new(100);
        b.reserve(24);
        b.commit_resident((1, 0), 8, 90); // tick 0, urgent
        b.advance_clock();
        b.commit_resident((2, 0), 8, 10); // tick 1, lazy
        // Staleness is the primary key: the urgent-but-stale (1,0) goes
        // before the recently committed (2,0) despite its higher stamp.
        assert_eq!(b.evict_victim(&[]), Some(((1, 0), 8)));
        // A resident-hit touch refreshes the tick and flips the order.
        b.advance_clock();
        b.touch_resident((1, 0));
        assert_eq!(b.evict_victim(&[]), Some(((2, 0), 8)));
        // Equal ticks fall back to the WCP stamp (then SeqId).
        b.touch_resident((2, 0));
        assert_eq!(b.evict_victim(&[]), Some(((2, 0), 8)));
        // Touching a non-resident sequence is a harmless no-op.
        b.touch_resident((9, 9));
        assert_eq!(b.resident_count(), 2);
    }

    #[test]
    fn quota_eviction_prefers_over_quota_tenant() {
        let mut b = KvBudget::new(100);
        b.reserve(24);
        // Tenant 1's sequence is the stalest (tick 0); tenant 2 commits
        // later ticks.
        b.commit_resident_as((1, 0), 8, 10, 1);
        b.advance_clock();
        b.commit_resident_as((2, 0), 8, 10, 2);
        b.advance_clock();
        b.commit_resident_as((2, 1), 8, 10, 2);
        // Tenant-blind: staleness wins — tenant 1's sequence goes first.
        assert_eq!(b.evict_victim(&[]), Some(((1, 0), 8)));
        // Tenant 2 over quota: its stalest sequence is preferred despite
        // tenant 1 being staler overall.
        assert_eq!(b.evict_victim_quota(&[], &|t| t == 2), Some(((2, 0), 8)));
        // Active over-quota sequences are still protected.
        assert_eq!(
            b.evict_victim_quota(&[(2, 0), (2, 1)], &|t| t == 2),
            Some(((1, 0), 8))
        );
        // Per-tenant residency sums feed the quota predicate.
        let by_tenant = b.resident_by_tenant();
        assert_eq!(by_tenant.get(&1), Some(&8));
        assert_eq!(by_tenant.get(&2), Some(&16));
    }

    #[test]
    fn untenanted_commit_defaults_to_tenant_zero() {
        let mut b = KvBudget::new(100);
        b.reserve(8);
        b.commit_resident((5, 0), 8, 1);
        assert_eq!(b.resident_by_tenant().get(&UNTENANTED), Some(&8));
    }

    #[test]
    fn reset_clears_both_ledgers() {
        let mut b = KvBudget::new(50);
        b.reserve(20);
        b.commit_resident((4, 0), 12, 7);
        assert_eq!(b.reset(), 20, "8 still reserved + 12 resident");
        assert_eq!(b.occupied(), 0);
        assert_eq!(b.resident_count(), 0);
        assert!(b.fits(50));
    }

    #[test]
    fn retune_keeps_reservations() {
        let mut b = KvBudget::new(100);
        b.reserve(80);
        b.set_capacity(50);
        assert_eq!(b.reserved(), 80);
        assert!(!b.fits(1));
        assert_eq!(b.spare(), 0);
        b.set_capacity(200);
        assert!(b.fits(120));
    }
}
