//! Token-denominated KV memory accounting (PR5 tentpole).
//!
//! Real LLM engines admit work by KV memory, not by batch rows: a
//! 2048-token prefill and an 8-token prefill have wildly different memory
//! footprints, so row-slot budgets either overcommit on long prompts or
//! waste capacity on short ones (cf. Parrot's application-aware serving
//! and vLLM's token-block accounting).  [`KvBudget`] is the reservation
//! ledger both sides of the admission protocol share:
//!
//! * the **engine scheduler** keeps one per instance, reserving a job's
//!   token estimate at dispatch and releasing the *same charge* when the
//!   instance reports the job retired (the charge rides
//!   [`crate::engines::RequestCtx::kv_tokens`] so reserve/release pair
//!   exactly — the ledger drains to zero, never negative);
//! * the **stepped LLM executors** keep their own, rejecting over-budget
//!   admissions back to the instance backlog until retirements free
//!   space (vLLM-style admission control).
//!
//! The reservation of a job is its KV growth: prompt tokens for a
//! prefill (suffix-only when the shared instruction prefix is already
//! resident — routing hits get cheaper admission), planned new tokens
//! for a decode.  Over a sequence's life this sums to the classic
//! `prompt_tokens + max_new_tokens` reserve-at-admit.
//!
//! All arithmetic is saturating: a release can never push the ledger
//! negative, and [`KvBudget::release`] reports how much was actually
//! released so invariant tests (`tests/prop_invariants.rs`) can detect
//! any reserve/release mispairing.

/// Per-instance KV token budget: capacity plus the reservation ledger.
///
/// A capacity of 0 means "unlimited" (the legacy row-slot mode is in
/// force and the token ledger is maintained only for observability).
#[derive(Debug, Clone, Default)]
pub struct KvBudget {
    capacity: usize,
    reserved: usize,
}

impl KvBudget {
    /// New ledger with the given token capacity (0 = unlimited).
    pub fn new(capacity: usize) -> KvBudget {
        KvBudget { capacity, reserved: 0 }
    }

    /// Current token capacity (0 = unlimited).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Retune the capacity (runtime knob); existing reservations are
    /// kept — the ledger simply stops admitting until enough retires.
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity;
    }

    /// Tokens currently reserved (admitted minus retired).
    pub fn reserved(&self) -> usize {
        self.reserved
    }

    /// Spare tokens under the capacity (`usize::MAX` when unlimited).
    pub fn spare(&self) -> usize {
        if self.capacity == 0 {
            usize::MAX
        } else {
            self.capacity.saturating_sub(self.reserved)
        }
    }

    /// Whether a reservation of `tokens` fits under the capacity.
    /// Always true when the capacity is 0 (unlimited).
    pub fn fits(&self, tokens: usize) -> bool {
        self.capacity == 0 || self.reserved.saturating_add(tokens) <= self.capacity
    }

    /// Reserve `tokens` (admission).  Saturating: the ledger cannot
    /// overflow, and deliberate over-budget admissions (a single job
    /// larger than the whole capacity must still run — the executors
    /// chunk it internally) are recorded faithfully.
    pub fn reserve(&mut self, tokens: usize) {
        self.reserved = self.reserved.saturating_add(tokens);
    }

    /// Release up to `tokens` (retirement); returns the amount actually
    /// released.  Saturating: the ledger never goes negative — a return
    /// value smaller than `tokens` means a reserve/release mispairing
    /// upstream (asserted against in the invariant tests).
    pub fn release(&mut self, tokens: usize) -> usize {
        let freed = tokens.min(self.reserved);
        self.reserved -= freed;
        freed
    }

    /// Drop every reservation (instance death: nothing resident will
    /// ever retire, so the capacity must not stay phantom-occupied while
    /// the batch is requeued elsewhere).  Returns what was held.
    pub fn reset(&mut self) -> usize {
        std::mem::take(&mut self.reserved)
    }

    /// Admission decision shared by the stepped executors: the
    /// reservation fits, or the ledger is empty — an idle executor must
    /// accept even an over-capacity job (it chunks internally), or a
    /// backlogged oversized job could never run (liveness).
    pub fn admits(&self, tokens: usize) -> bool {
        self.fits(tokens) || self.reserved == 0
    }
}

/// Token charge of a prefill whose leading `prefix_len` tokens are
/// already resident on the serving instance: the un-cached suffix,
/// never 0.  The one rule shared by the engine scheduler's dispatch
/// charge and both stepped executors' admission reservations — change
/// it here, not per call site.
pub fn suffix_charge(prompt_tokens: usize, prefix_len: usize) -> usize {
    prompt_tokens.saturating_sub(prefix_len).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_pair_exactly() {
        let mut b = KvBudget::new(100);
        assert!(b.fits(100));
        assert!(!b.fits(101));
        b.reserve(60);
        assert_eq!(b.reserved(), 60);
        assert_eq!(b.spare(), 40);
        assert!(b.fits(40));
        assert!(!b.fits(41));
        assert_eq!(b.release(60), 60);
        assert_eq!(b.reserved(), 0);
        assert_eq!(b.spare(), 100);
    }

    #[test]
    fn release_saturates_never_negative() {
        let mut b = KvBudget::new(10);
        b.reserve(4);
        // Over-release is clamped and reported.
        assert_eq!(b.release(9), 4);
        assert_eq!(b.reserved(), 0);
        assert_eq!(b.release(1), 0);
        assert_eq!(b.reserved(), 0);
    }

    #[test]
    fn zero_capacity_means_unlimited() {
        let mut b = KvBudget::new(0);
        assert!(b.fits(usize::MAX));
        b.reserve(1_000_000);
        assert_eq!(b.spare(), usize::MAX);
        assert_eq!(b.reserved(), 1_000_000);
    }

    #[test]
    fn oversized_reservation_recorded_and_reset_clears() {
        let mut b = KvBudget::new(8);
        // A job larger than the whole budget still reserves faithfully
        // (it was admitted alone; the executor chunks it).
        b.reserve(32);
        assert_eq!(b.reserved(), 32);
        assert!(!b.fits(1));
        assert_eq!(b.reset(), 32);
        assert_eq!(b.reserved(), 0);
        assert!(b.fits(8));
    }

    #[test]
    fn admits_fits_or_idle() {
        let mut b = KvBudget::new(10);
        assert!(b.admits(100), "idle ledger accepts oversized (liveness)");
        b.reserve(4);
        assert!(b.admits(6));
        assert!(!b.admits(7), "occupied ledger bounces over-budget work");
    }

    #[test]
    fn suffix_charge_is_uncached_remainder() {
        assert_eq!(suffix_charge(24, 16), 8);
        assert_eq!(suffix_charge(16, 16), 1, "never 0 (load accounting)");
        assert_eq!(suffix_charge(8, 16), 1, "saturates, never underflows");
    }

    #[test]
    fn retune_keeps_reservations() {
        let mut b = KvBudget::new(100);
        b.reserve(80);
        b.set_capacity(50);
        assert_eq!(b.reserved(), 80);
        assert!(!b.fits(1));
        assert_eq!(b.spare(), 0);
        b.set_capacity(200);
        assert!(b.fits(120));
    }
}
