//! Execution-engine substrates.
//!
//! The paper deploys vLLM (LLM), Triton-style servers (embedding,
//! reranking), postgres+pgvector (vector DB) and Google custom search.  We
//! rebuild each as a Rust engine:
//!
//! * model-based engines execute AOT XLA artifacts on per-instance PJRT
//!   contexts (one OS thread per instance == one GPU in the paper);
//! * model-free engines (vector DB, web search) are CPU-side services with
//!   their own worker threads.
//!
//! All engines share one job/admission protocol so the lower-tier engine
//! schedulers (scheduler/engine_sched.rs) can batch primitives uniformly.
//! Execution is iteration-level (instance.rs::StepExecutor): LLM engines
//! interleave chunked-prefill calls and decode iterations over a resident
//! sequence set and retire rows at EOS (continuous batching), while
//! run-to-completion engines execute each admitted batch atomically
//! through the `RunToCompletion` adapter; instances report per-step
//! occupancy to their scheduler via `InstanceEvent`.

pub mod embedding;
pub mod instance;
pub mod kv_budget;
pub mod llm;
pub mod prefix;
pub mod profile;
pub mod reranker;
pub mod search;
pub mod sim;
pub mod vector_db;

pub use kv_budget::KvBudget;
pub use prefix::{prefix_fingerprint, PrefixFp};
pub use sim::ExecBackend;

use std::sync::mpsc::Sender;
use std::time::Instant;

/// Query identifier (assigned by the frontend).
pub type QueryId = u64;
/// Node identifier within one query's e-graph.
pub type NodeId = usize;
/// LLM sequence identifier: (query, call index within the query).
pub type SeqId = (QueryId, u32);
/// Tenant identifier (multi-tenant QoS, PR8): stamped onto every query
/// at submission and carried through queue -> batch -> instance so fair
/// queueing, KV quotas and admission control can attribute work.
pub type TenantId = u32;
/// The default tenant: single-tenant traffic and bookkeeping jobs.  With
/// tenancy disabled every request carries this and nothing downstream
/// looks at it.
pub const UNTENANTED: TenantId = 0;

/// The engine types of the paper's applications.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// An LLM serving engine for a specific model variant.
    Llm,
    /// Embedding model engine.
    Embedding,
    /// Cross-encoder reranker engine.
    Reranker,
    /// Vector database (ingestion + search).
    VectorDb,
    /// External web-search service.
    WebSearch,
    /// Generic external tool API (agent workflows).
    Tool,
}

/// How many new tokens a decode must produce and how the output splits into
/// semantically separate segments (paper Pass 4: splittable decodes).
#[derive(Debug, Clone)]
pub struct SegmentSpec {
    /// e-graph node credited when this segment completes (enables partial
    /// decoding primitives to fire downstream work early).
    pub node: NodeId,
    /// Number of tokens in this segment (SEP token terminates it).
    pub len: usize,
}

/// One schedulable unit of engine work (the payload of a primitive).
#[derive(Debug, Clone)]
pub enum EngineJob {
    /// Chunked (partial or full) prefill of `tokens` into `seq` at `offset`.
    /// `prefix` fingerprints the leading shared-instruction tokens (set by
    /// the graph scheduler on from-scratch prefills whose first prompt part
    /// is a `Const` instruction template): the engine scheduler routes on
    /// it and a holding instance serves the prefix from its resident KV.
    Prefill {
        seq: SeqId,
        tokens: Vec<i32>,
        offset: usize,
        prefix: Option<PrefixFp>,
    },
    /// Autoregressive decode after the seq's prefill completed.
    /// `segments` partitions the planned output; unsplit decodes use a
    /// single segment pointing at the decode node itself.
    Decode {
        seq: SeqId,
        first_token: i32,
        segments: Vec<SegmentSpec>,
    },
    /// Copy the first `len` cache positions from `src` into `dst`
    /// (prefix-cache reuse — used by the LlamaDistPC baseline).
    ClonePrefix { src: SeqId, dst: SeqId, len: usize },
    /// Release every sequence belonging to a query (end-of-query cleanup).
    FreeQuery { query: QueryId },
    /// Cancel one sequence's in-flight and resident state (speculative
    /// template prefill invalidated by a guard/rerank outcome): drop any
    /// queued prefill rows for `seq`, release their reservations, free
    /// the sequence's store entry and residency.  Never emits a
    /// completion toward the speculating node — cancellation must not
    /// surface as a `Failed` query.
    CancelSeq { seq: SeqId },
    /// Embed a batch of token chunks.
    Embed { chunks: Vec<Vec<i32>> },
    /// Score pre-packed (query ++ SEP ++ candidate) pair sequences.
    Rerank { pairs: Vec<Vec<i32>> },
    /// Store chunk embeddings in the per-query vector-DB namespace.
    Ingest {
        namespace: QueryId,
        chunks: Vec<Vec<i32>>,
        embeddings: Vec<Vec<f32>>,
    },
    /// Top-k cosine search per query embedding in a namespace.
    VectorSearch {
        namespace: QueryId,
        embeddings: Vec<Vec<f32>>,
        top_k: usize,
    },
    /// Web-search over the global corpus (single or batched queries).
    WebSearch { queries: Vec<Vec<i32>>, top_k: usize },
    /// Simulated external tool API call with a fixed latency envelope.
    ToolCall { name: String, cost_us: u64 },
    /// Cancel one query node's queued work (speculative branch refuted by
    /// its guard): the engine *scheduler* intercepts this at enqueue,
    /// purges every matching queued item (dropping their replies — a
    /// cancelled speculation must never surface `Failed`), and refunds
    /// the tenant's fair-queueing charge if the node was already
    /// dispatched.  Never reaches an instance.
    CancelNode { query: QueryId, node: NodeId },
    /// Restamp every queued item of `query` with a fresh remaining
    /// critical-path estimate (guard resolution re-weighted the query's
    /// WCP).  Intercepted at enqueue like `CancelNode`; never reaches an
    /// instance.
    RestampWcp { query: QueryId, wcp_us: u64 },
}

impl EngineJob {
    /// Rows this job occupies for scheduler slot accounting.  Never zero,
    /// so admission (`loads += slot_rows`) and retirement
    /// (`loads -= retired`) stay balanced even for empty payloads.
    pub fn slot_rows(&self) -> usize {
        self.rows().max(1)
    }

    /// Shared-prompt-prefix fingerprint of the job, if it carries one
    /// (prefills only) — the engine scheduler's routing signal.
    pub fn prefix(&self) -> Option<PrefixFp> {
        match self {
            EngineJob::Prefill { prefix, .. } => *prefix,
            _ => None,
        }
    }

    /// KV token estimate of the job — its KV-cache growth on the serving
    /// instance.  Prompt tokens for a prefill, planned new tokens for a
    /// decode, row count for everything else (non-LLM engines stay
    /// row-denominated).  This is the same token surface the WCP cost
    /// estimates weigh; the graph scheduler stamps it onto the queue item
    /// and token-denominated admission (`KvBudget`) reserves by it.
    pub fn kv_tokens(&self) -> usize {
        match self {
            EngineJob::Prefill { tokens, .. } => tokens.len().max(1),
            EngineJob::Decode { segments, .. } => {
                segments.iter().map(|s| s.len).sum::<usize>().max(1)
            }
            _ => self.slot_rows(),
        }
    }

    /// Host-side bookkeeping jobs (`FreeQuery`, `ClonePrefix`) occupy no
    /// model rows and grow no KV: they bypass budget admission and batch
    /// packing entirely (the op that releases memory must never be
    /// blocked on lack of memory) and the engine scheduler fast-paths
    /// them to instances the moment they arrive.
    pub fn is_bookkeeping(&self) -> bool {
        matches!(
            self,
            EngineJob::FreeQuery { .. }
                | EngineJob::ClonePrefix { .. }
                | EngineJob::CancelSeq { .. }
        )
    }

    /// Number of model "rows" this job contributes to a batch (for slot
    /// accounting in Algorithm 2).
    pub fn rows(&self) -> usize {
        match self {
            EngineJob::Prefill { .. } | EngineJob::Decode { .. } => 1,
            EngineJob::Embed { chunks } => chunks.len(),
            EngineJob::Rerank { pairs } => pairs.len(),
            EngineJob::Ingest { chunks, .. } => chunks.len(),
            EngineJob::VectorSearch { embeddings, .. } => embeddings.len(),
            EngineJob::WebSearch { queries, .. } => queries.len(),
            EngineJob::ClonePrefix { .. }
            | EngineJob::FreeQuery { .. }
            | EngineJob::CancelSeq { .. }
            | EngineJob::CancelNode { .. }
            | EngineJob::RestampWcp { .. }
            | EngineJob::ToolCall { .. } => 1,
        }
    }
}

/// Result value of a completed job/segment.
#[derive(Debug, Clone)]
pub enum JobOutput {
    /// Generated tokens (decode segment output).
    Tokens(Vec<i32>),
    /// A list of token sequences (retrieved chunks, search results, ...).
    TokenBatch(Vec<Vec<i32>>),
    /// Embedding vectors.
    Embeddings(Vec<Vec<f32>>),
    /// Relevance scores.
    Scores(Vec<f32>),
    /// Side-effect only.
    Unit,
    /// The engine could not serve the job and never will (e.g. every
    /// instance of the engine is dead): the query must fail instead of
    /// waiting for a completion that cannot come.
    Failed(String),
}

/// Execution timing recorded by the instance for metrics/fig12.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecTiming {
    /// Microseconds spent queued in the engine scheduler.
    pub queued_us: u64,
    /// Microseconds of actual engine execution (batched; shared rows see
    /// the same value).
    pub exec_us: u64,
}

/// Completion notification sent to the query's graph scheduler.
#[derive(Debug, Clone)]
pub struct Completion {
    pub query: QueryId,
    pub node: NodeId,
    pub output: JobOutput,
    pub timing: ExecTiming,
}

/// Request context travelling with a job through queue -> batch -> instance.
#[derive(Debug, Clone)]
pub struct RequestCtx {
    pub query: QueryId,
    pub node: NodeId,
    /// Topological depth of the node in its e-graph (Algorithm 2).
    pub depth: u32,
    /// When the job entered the engine scheduler queue.
    pub arrival: Instant,
    /// Remaining critical-path stamp of the owning query (see
    /// `QueueItem::wcp_us`); carried through dispatch so a
    /// requeue-on-instance-death rebuilds the queue item with its
    /// priority intact.
    pub wcp_us: u64,
    /// KV tokens the engine scheduler reserved for this job at dispatch
    /// (suffix-only on a prefix-routing hit).  The instance reports the
    /// same amount back when the job retires, so the scheduler's
    /// per-instance `KvBudget` reserve/release pairs exactly; a
    /// requeue-on-instance-death restores it as the queue item's charge.
    pub kv_tokens: usize,
    /// Whether the prefix-residency WCP discount has already been applied
    /// to `wcp_us` (applied at most once per item — see
    /// `engine_sched::rediscount_resident_prefixes`).
    pub wcp_discounted: bool,
    /// Owning tenant of the request (multi-tenant QoS): survives
    /// requeue-on-instance-death and rides successor handoff plans so
    /// pipelined work is accounted to the same tenant as its parent.
    pub tenant: TenantId,
    /// Completion channel of the owning query's graph scheduler.
    pub reply: Sender<Completion>,
    /// Direct cross-engine handoff plans riding with the job (pipelining
    /// gate on): when the triggering completion is emitted, the instance
    /// thread materializes the successor straight into the target
    /// engine's admission queue — no graph-scheduler re-entry.  Empty
    /// with the gate off, preserving the queue re-entry path exactly.
    pub successors: Vec<crate::scheduler::batching::SuccessorPlan>,
}

/// A batch the engine scheduler hands to one engine instance.
#[derive(Debug)]
pub struct Batch {
    pub jobs: Vec<(RequestCtx, EngineJob)>,
}

impl Batch {
    /// Total model rows across jobs.
    pub fn rows(&self) -> usize {
        self.jobs.iter().map(|(_, j)| j.rows()).sum()
    }
}

/// How an engine's executors consume admitted work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Iteration-level loop: jobs are admitted between steps and retire
    /// individually the moment they finish (LLM engines — this is what
    /// enables continuous batching in the engine scheduler).
    Stepped,
    /// Every dispatched batch runs to completion before the next one is
    /// accepted (encoder-style and model-free engines).
    FullBatch,
}

/// Per-iteration status report an instance sends its engine scheduler.
///
/// Replaces the old terminal-only `InstanceFree` token: stepped executors
/// emit one event per iteration so the scheduler can observe occupancy
/// and route new decode work to partially occupied instances (continuous
/// batching); run-to-completion executors emit a single terminal event
/// with `resident == 0` per batch, which reproduces the legacy protocol.
#[derive(Debug, Clone, Copy)]
pub struct InstanceEvent {
    pub instance: usize,
    /// Slot-rows still resident on the instance after this step.
    pub resident: usize,
    /// Slot-rows retired (final completion emitted) during this step.
    pub retired: usize,
    /// KV tokens retired during this step: the sum of the retired jobs'
    /// dispatch-time reservations (`RequestCtx::kv_tokens`), so the
    /// scheduler's token ledger releases exactly what it reserved.
    pub retired_tokens: usize,
    /// KV tokens that became resident on the instance during this step
    /// (persistent-residency mode: charges committed per-`SeqId` at job
    /// retirement instead of released).  The scheduler accumulates these
    /// into its per-instance residency mirror.
    pub resident_added: usize,
    /// KV tokens whose residency the instance released during this step
    /// (`FreeQuery` cleanup or watermark eviction).
    pub resident_freed: usize,
}
