//! Generic engine-instance worker: one OS thread per instance, one
//! `BatchExecutor` implementation per engine type.
//!
//! The thread owns all non-`Send` XLA state (client, executables, weight
//! buffers).  Batches arrive over a channel; completions are emitted to
//! each request's reply channel; an `InstanceFree` token returns to the
//! engine scheduler so it can dispatch the next batch.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::engines::{Batch, Completion, ExecTiming, InstanceFree};
use crate::error::Result;

/// Engine-type-specific batched execution logic.  Implementations run on
/// the instance thread and may emit multiple completions per job
/// (streaming partial decodes).
pub trait BatchExecutor {
    /// Execute a batch; call `emit` for every (possibly partial) completion.
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()>;
}

/// Handle to a spawned instance thread.
pub struct Instance {
    pub sender: Sender<Batch>,
    pub handle: JoinHandle<()>,
    /// Whether a batch is currently in flight (scheduler bookkeeping).
    pub busy: bool,
}

/// Spawn an instance worker.  `make_executor` runs *on the new thread* so
/// it can own non-Send XLA state; `free_tx` receives an `InstanceFree`
/// after every batch.
pub fn spawn_instance<F, E>(
    index: usize,
    name: String,
    make_executor: F,
    free_tx: Sender<InstanceFree>,
    ready_tx: Sender<()>,
) -> Instance
where
    F: FnOnce() -> Result<E> + Send + 'static,
    E: BatchExecutor,
{
    let (tx, rx): (Sender<Batch>, Receiver<Batch>) = channel();
    let handle = std::thread::Builder::new()
        .name(name.clone())
        .spawn(move || {
            let mut exec = match make_executor() {
                Ok(e) => {
                    let _ = ready_tx.send(());
                    e
                }
                Err(err) => {
                    eprintln!("[{name}] executor init failed: {err}");
                    let _ = ready_tx.send(());
                    return;
                }
            };
            while let Ok(batch) = rx.recv() {
                let started = Instant::now();
                // (query, node, arrival, reply) per job, for routing.
                let ctxs: Vec<(u64, usize, Instant, Sender<Completion>)> = batch
                    .jobs
                    .iter()
                    .map(|(ctx, _)| (ctx.query, ctx.node, ctx.arrival, ctx.reply.clone()))
                    .collect();
                let mut route = |mut c: Completion| {
                    // Exact (query, node) match first; segment completions
                    // may target sibling nodes of the same query (partial
                    // decodes), so fall back to any job of that query.
                    let entry = ctxs
                        .iter()
                        .find(|(q, n, _, _)| *q == c.query && *n == c.node)
                        .or_else(|| ctxs.iter().find(|(q, _, _, _)| *q == c.query));
                    if let Some((_, _, arrival, reply)) = entry {
                        c.timing.queued_us =
                            started.duration_since(*arrival).as_micros() as u64;
                        if c.timing.exec_us == 0 {
                            c.timing.exec_us = started.elapsed().as_micros() as u64;
                        }
                        let _ = reply.send(c);
                    }
                };
                if let Err(err) = exec.execute(batch, &mut route) {
                    eprintln!("[{name}] batch failed: {err}");
                }
                let _ = free_tx.send(InstanceFree { instance: index });
            }
        })
        .expect("spawn instance thread");
    Instance { sender: tx, handle, busy: false }
}

/// Build an ExecTiming carrying a measured execution time.
pub fn timing_exec(exec_us: u64) -> ExecTiming {
    ExecTiming { queued_us: 0, exec_us }
}
