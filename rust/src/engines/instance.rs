//! Generic engine-instance worker: one OS thread per instance, one
//! executor per engine type.
//!
//! The thread owns all non-`Send` XLA state (client, executables, weight
//! buffers).  Execution follows an *iteration-level* protocol: work is
//! admitted between steps, each `step()` runs one unit of engine work (one
//! chunked-prefill call, one decode iteration, or one full legacy batch),
//! completions are emitted to each request's reply channel, and an
//! `InstanceEvent` reports per-step occupancy back to the engine scheduler
//! so it can admit new jobs into a partially occupied instance
//! (continuous batching).  Run-to-completion engines participate through
//! the [`RunToCompletion`] blanket adapter.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::engines::{
    Batch, Completion, EngineJob, ExecTiming, InstanceEvent, JobOutput, NodeId, QueryId,
    RequestCtx,
};
use crate::error::Result;
use crate::scheduler::batching::{materialize_successor, SuccessorPlan};

/// Engine-type-specific batched execution logic.  Implementations run on
/// the instance thread and may emit multiple completions per job
/// (streaming partial decodes).  Executors of this legacy trait always
/// run a dispatched batch to completion; they are lifted into the stepped
/// protocol by [`RunToCompletion`].
pub trait BatchExecutor {
    /// Execute a batch; call `emit` for every (possibly partial) completion.
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()>;
}

/// Result of one [`StepExecutor::step`].
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// Slot-rows still resident after the step.
    pub resident: usize,
    /// Slot-rows retired during the step.
    pub retired_rows: usize,
    /// (query, node) of jobs whose *final* completion was emitted this
    /// step — the instance frees their request contexts.
    pub retired: Vec<(QueryId, NodeId)>,
    /// KV tokens committed to the executor's resident ledger this step
    /// (persistent-residency mode; 0 otherwise).
    pub resident_added: usize,
    /// KV tokens of residency released this step (`FreeQuery` cleanup or
    /// watermark eviction; 0 outside residency mode).
    pub resident_freed: usize,
}

/// Iteration-level execution protocol (vLLM-style continuous batching).
///
/// The instance thread calls `admit` with newly arrived jobs between
/// steps, then `step` repeatedly until `resident` reaches zero.  LLM
/// executors implement this directly (interleaving chunked prefills and
/// decode iterations over a resident sequence set); everything else goes
/// through [`RunToCompletion`].
pub trait StepExecutor {
    /// Take new jobs into the resident set, returning any it cannot
    /// admit *yet* (over the executor's KV token budget); the instance
    /// thread backlogs those and re-offers them after later steps free
    /// capacity.  Called between steps; must not block on device work
    /// (defer it to `step`).  Liveness contract: an executor with an
    /// empty reservation ledger must accept any job regardless of size
    /// (oversized work is chunked internally), so a backlogged job can
    /// never starve once the instance drains.  Jobs an executor can
    /// *never* serve (mis-routed kinds) are still consumed — queued
    /// internally and retired (without a completion) at the next step,
    /// so scheduler load accounting never leaks.
    fn admit(&mut self, jobs: Vec<(RequestCtx, EngineJob)>) -> Vec<(RequestCtx, EngineJob)>;

    /// Run one unit of work and emit any completions it produced.
    fn step(&mut self, emit: &mut dyn FnMut(Completion)) -> Result<StepOutcome>;

    /// Drop all resident work after an unrecoverable step error: clear
    /// internal state and report everything retired so scheduler load
    /// accounting stays balanced.  Completions for the dropped jobs are
    /// never emitted (legacy failed-batch semantics).
    fn abort(&mut self) -> StepOutcome;

    /// Slot-rows currently admitted and not yet retired.
    fn resident(&self) -> usize;
}

/// Blanket adapter running any [`BatchExecutor`] under the stepped
/// protocol: admitted batches queue, each `step` executes exactly one
/// batch to completion, and all of that batch's jobs retire together.
/// Non-LLM engines (embedding, reranker, vector DB, web search, tools)
/// keep their run-to-completion semantics through this adapter.
pub struct RunToCompletion<E: BatchExecutor> {
    inner: E,
    pending: VecDeque<Batch>,
    resident: usize,
}

impl<E: BatchExecutor> RunToCompletion<E> {
    /// Wrap a batch executor.
    pub fn new(inner: E) -> RunToCompletion<E> {
        RunToCompletion { inner, pending: VecDeque::new(), resident: 0 }
    }
}

impl<E: BatchExecutor> StepExecutor for RunToCompletion<E> {
    fn admit(&mut self, jobs: Vec<(RequestCtx, EngineJob)>) -> Vec<(RequestCtx, EngineJob)> {
        // Run-to-completion engines are row-budgeted by the scheduler
        // alone: everything offered is accepted.
        self.resident += jobs.iter().map(|(_, j)| j.slot_rows()).sum::<usize>();
        self.pending.push_back(Batch { jobs });
        Vec::new()
    }

    fn step(&mut self, emit: &mut dyn FnMut(Completion)) -> Result<StepOutcome> {
        let Some(batch) = self.pending.pop_front() else {
            return Ok(StepOutcome::default());
        };
        let rows: usize = batch.jobs.iter().map(|(_, j)| j.slot_rows()).sum();
        let retired: Vec<(QueryId, NodeId)> =
            batch.jobs.iter().map(|(c, _)| (c.query, c.node)).collect();
        if let Err(err) = self.inner.execute(batch, emit) {
            // The batch is consumed either way; report its rows retired so
            // scheduler load accounting cannot leak — but the waiting
            // query runners must hear about the failure too, or they
            // block forever on completions that can never come.  Emit a
            // `Failed` output per job so the error surfaces upstream as
            // `TeolaError::Engine` (mirroring `fail_queue`).
            let t = std::thread::current();
            eprintln!("[{}] batch failed: {err}", t.name().unwrap_or("instance"));
            for (q, n) in &retired {
                emit(Completion {
                    query: *q,
                    node: *n,
                    output: JobOutput::Failed(err.to_string()),
                    timing: ExecTiming::default(),
                });
            }
        }
        self.resident = self.resident.saturating_sub(rows);
        Ok(StepOutcome { resident: self.resident, retired_rows: rows, retired, ..StepOutcome::default() })
    }

    fn abort(&mut self) -> StepOutcome {
        let mut out = StepOutcome::default();
        for batch in self.pending.drain(..) {
            for (ctx, job) in batch.jobs {
                out.retired_rows += job.slot_rows();
                out.retired.push((ctx.query, ctx.node));
            }
        }
        self.resident = 0;
        out
    }

    fn resident(&self) -> usize {
        self.resident
    }
}

/// Handle to a spawned instance thread.
pub struct Instance {
    pub sender: Sender<Batch>,
    pub handle: JoinHandle<()>,
}

/// Resident-job bookkeeping on the instance thread.
struct JobCtx {
    query: QueryId,
    node: NodeId,
    /// Segment target nodes of a splittable decode (empty for everything
    /// else): the only nodes, besides `node` itself, this job's
    /// completions may legitimately be routed to.
    seg_nodes: Vec<NodeId>,
    /// Slot-rows this job was charged for (mirrors the scheduler's
    /// admission accounting, so error-path sweeps retire exact counts).
    rows: usize,
    /// KV tokens the scheduler reserved at dispatch; echoed back in the
    /// retirement event so the scheduler's token ledger releases exactly
    /// what it reserved.
    kv_tokens: usize,
    arrival: Instant,
    admitted: Instant,
    reply: Sender<Completion>,
    /// Direct-handoff plans for this job's ready successors: materialized
    /// and injected into the target engine's queue the moment the
    /// triggering completion is emitted (cross-engine pipelining).
    successors: Vec<SuccessorPlan>,
}

/// Offer `jobs` to the executor, registering contexts for the accepted
/// ones; jobs the executor bounced (over its KV budget) are returned for
/// the caller's backlog.
fn register_and_admit<E: StepExecutor>(
    exec: &mut E,
    jobs: Vec<(RequestCtx, EngineJob)>,
    ctxs: &mut Vec<JobCtx>,
) -> Vec<(RequestCtx, EngineJob)> {
    let now = Instant::now();
    for (ctx, job) in &jobs {
        let seg_nodes = match job {
            EngineJob::Decode { segments, .. } => {
                segments.iter().map(|s| s.node).collect()
            }
            _ => Vec::new(),
        };
        ctxs.push(JobCtx {
            query: ctx.query,
            node: ctx.node,
            seg_nodes,
            rows: job.slot_rows(),
            kv_tokens: ctx.kv_tokens,
            arrival: ctx.arrival,
            admitted: now,
            reply: ctx.reply.clone(),
            successors: ctx.successors.clone(),
        });
    }
    let bounced = exec.admit(jobs);
    for (ctx, _) in &bounced {
        if let Some(i) =
            ctxs.iter().rposition(|j| j.query == ctx.query && j.node == ctx.node)
        {
            ctxs.remove(i);
        }
    }
    bounced
}

/// Spawn an instance worker running the stepped protocol.
/// `make_executor` runs *on the new thread* so it can own non-Send XLA
/// state; `event_tx` receives an `InstanceEvent` after every step.
pub fn spawn_stepped_instance<F, E>(
    index: usize,
    name: String,
    make_executor: F,
    event_tx: Sender<InstanceEvent>,
    ready_tx: Sender<()>,
) -> Instance
where
    F: FnOnce() -> Result<E> + Send + 'static,
    E: StepExecutor,
{
    let (tx, rx): (Sender<Batch>, Receiver<Batch>) = channel();
    let handle = std::thread::Builder::new()
        .name(name.clone())
        .spawn(move || {
            let mut exec = match make_executor() {
                Ok(e) => {
                    let _ = ready_tx.send(());
                    e
                }
                Err(err) => {
                    eprintln!("[{name}] executor init failed: {err}");
                    let _ = ready_tx.send(());
                    return;
                }
            };
            let mut ctxs: Vec<JobCtx> = Vec::new();
            // Jobs the executor bounced (over its KV token budget):
            // re-offered when admission could have changed — new arrivals
            // or a retirement freed capacity — not on every step (a
            // saturated instance would otherwise re-register and bounce
            // the whole backlog per iteration for nothing).
            let mut backlog: VecDeque<(RequestCtx, EngineJob)> = VecDeque::new();
            let mut retry_backlog = true;
            loop {
                // Idle with no backlog: block for work (and exit when the
                // scheduler drops).  Mid-flight or backlogged: only drain
                // what has already arrived, so the iteration loop keeps
                // stepping and the backlog keeps retrying.
                if exec.resident() == 0 && backlog.is_empty() {
                    match rx.recv() {
                        Ok(batch) => {
                            backlog.extend(batch.jobs);
                            retry_backlog = true;
                        }
                        Err(_) => break,
                    }
                }
                while let Ok(batch) = rx.try_recv() {
                    backlog.extend(batch.jobs);
                    retry_backlog = true;
                }
                if retry_backlog && !backlog.is_empty() {
                    let offer: Vec<(RequestCtx, EngineJob)> = backlog.drain(..).collect();
                    backlog.extend(register_and_admit(&mut exec, offer, &mut ctxs));
                }
                retry_backlog = false;
                let mut aborted = false;
                let mut outcome = {
                    let ctxs_ref: &Vec<JobCtx> = &ctxs;
                    let mut route = |mut c: Completion| {
                        // Exact (query, node) match first; segment
                        // completions of a splittable decode may target
                        // the decode's *declared* segment nodes, so fall
                        // back only to the resident job whose segment
                        // list names this node.  (Falling back to "any
                        // job of the query" mis-delivered completions
                        // when a query had two concurrent resident LLM
                        // nodes.)
                        let now = Instant::now();
                        let entry = ctxs_ref
                            .iter()
                            .find(|j| j.query == c.query && j.node == c.node)
                            .or_else(|| {
                                ctxs_ref.iter().find(|j| {
                                    j.query == c.query && j.seg_nodes.contains(&c.node)
                                })
                            });
                        if let Some(j) = entry {
                            c.timing.queued_us =
                                j.admitted.duration_since(j.arrival).as_micros() as u64;
                            if c.timing.exec_us == 0 {
                                c.timing.exec_us =
                                    now.duration_since(j.admitted).as_micros() as u64;
                            }
                            // Direct successor handoff (cross-engine
                            // pipelining): materialize the downstream
                            // jobs this completion unlocks, forward the
                            // completion FIRST — mpsc preserves enqueue
                            // order, so the query runner always observes
                            // the trigger before any successor
                            // completion — then inject the successors
                            // into their target engines' queues.
                            let mut inject = Vec::new();
                            let mut fail = Vec::new();
                            for plan in &j.successors {
                                if plan.on_node != c.node || plan.fired.get() {
                                    continue;
                                }
                                if matches!(c.output, JobOutput::Failed(_)) {
                                    break; // runner bails on the trigger
                                }
                                plan.fired.set(true);
                                match materialize_successor(plan, c.query, &c.output, &j.reply)
                                {
                                    Some(item) => inject.push((plan, item)),
                                    None => fail.push(plan),
                                }
                            }
                            let query = c.query;
                            let reply = j.reply.clone();
                            let _ = j.reply.send(c);
                            for (plan, item) in inject {
                                if plan.engine.send(item).is_err() {
                                    fail.push(plan);
                                }
                            }
                            for plan in fail {
                                // Fail loud: a successor that cannot be
                                // handed off would otherwise hang its
                                // query forever (the graph scheduler has
                                // already ceded the node).
                                let _ = reply.send(Completion {
                                    query,
                                    node: plan.node,
                                    output: JobOutput::Failed(
                                        "successor handoff failed \
                                         (engine down or unusable output)"
                                            .into(),
                                    ),
                                    timing: ExecTiming::default(),
                                });
                            }
                        }
                    };
                    match exec.step(&mut route) {
                        Ok(o) => o,
                        Err(err) => {
                            eprintln!("[{name}] step failed: {err}");
                            aborted = true;
                            exec.abort()
                        }
                    }
                };
                let mut retired_tokens = 0usize;
                for (q, n) in &outcome.retired {
                    if let Some(i) =
                        ctxs.iter().position(|j| j.query == *q && j.node == *n)
                    {
                        retired_tokens += ctxs[i].kv_tokens;
                        ctxs.remove(i);
                    }
                }
                if aborted {
                    // Sweep contexts the executor lost track of mid-step
                    // (e.g. a prefill group drained out of its queue
                    // before the device call failed): retire their exact
                    // slot-rows and token reservations too, so scheduler
                    // load accounting stays balanced and the instance
                    // remains routable.
                    for j in ctxs.drain(..) {
                        outcome.retired_rows += j.rows;
                        retired_tokens += j.kv_tokens;
                    }
                    outcome.resident = 0;
                }
                if outcome.retired_rows > 0 {
                    // Retirement freed executor capacity: the backlog is
                    // worth re-offering next iteration.
                    retry_backlog = true;
                }
                let _ = event_tx.send(InstanceEvent {
                    instance: index,
                    resident: outcome.resident,
                    retired: outcome.retired_rows,
                    retired_tokens,
                    resident_added: outcome.resident_added,
                    resident_freed: outcome.resident_freed,
                });
            }
        })
        .expect("spawn instance thread");
    Instance { sender: tx, handle }
}

/// Spawn an instance worker for a run-to-completion engine: the executor
/// is lifted into the stepped protocol via [`RunToCompletion`], so every
/// dispatched batch executes atomically and retires as a whole (the
/// legacy engine protocol, one event per batch).
pub fn spawn_instance<F, E>(
    index: usize,
    name: String,
    make_executor: F,
    event_tx: Sender<InstanceEvent>,
    ready_tx: Sender<()>,
) -> Instance
where
    F: FnOnce() -> Result<E> + Send + 'static,
    E: BatchExecutor,
{
    spawn_stepped_instance(
        index,
        name,
        move || -> Result<RunToCompletion<E>> { Ok(RunToCompletion::new(make_executor()?)) },
        event_tx,
        ready_tx,
    )
}

/// Split `n` rows into contiguous chunks of at most `max`, calling
/// `f(start, len)` once per chunk — the one grouping loop shared by every
/// executor that packs variable row counts into bounded device calls.
pub fn for_chunks(
    n: usize,
    max: usize,
    mut f: impl FnMut(usize, usize) -> Result<()>,
) -> Result<()> {
    let max = max.max(1);
    let mut i = 0;
    while i < n {
        let take = (n - i).min(max);
        f(i, take)?;
        i += take;
    }
    Ok(())
}

/// Build an ExecTiming carrying a measured execution time.
pub fn timing_exec(exec_us: u64) -> ExecTiming {
    ExecTiming { queued_us: 0, exec_us }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_chunks_covers_all_rows() {
        let mut seen = Vec::new();
        for_chunks(10, 4, |start, len| {
            seen.push((start, len));
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, vec![(0, 4), (4, 4), (8, 2)]);
        let total: usize = seen.iter().map(|(_, l)| l).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn for_chunks_handles_zero_and_degenerate_max() {
        let mut calls = 0;
        for_chunks(0, 4, |_, _| {
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls, 0);
        // max 0 is clamped to 1 instead of looping forever
        let mut n = 0;
        for_chunks(3, 0, |_, len| {
            n += len;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 3);
    }
}
