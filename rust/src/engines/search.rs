//! Web-search engine simulator (Google custom search analog).
//!
//! The paper calls an external search API (single and batched requests)
//! with network latency we cannot reproduce; this module indexes a
//! synthetic corpus and models the latency envelope: a per-request base
//! RTT plus a small per-result transfer cost, drawn deterministically per
//! request.  Relevance is token-overlap scoring (BM25-lite) — retrieval
//! *content* only needs to be shape-realistic for the serving benchmarks.
//!
//! External tool APIs for the agent workflow reuse the same worker with a
//! fixed `cost_us` (paper Fig. 2b: draft/send email etc.).

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engines::instance::{spawn_instance, BatchExecutor, Instance};
use crate::engines::{Batch, Completion, EngineJob, ExecTiming, InstanceEvent, JobOutput};
use crate::error::{Result, TeolaError};
use crate::util::rng::Rng;

/// One indexed document (its token ids; doubles as the snippet returned).
#[derive(Debug, Clone)]
pub struct Doc {
    pub tokens: Vec<i32>,
}

/// The searchable corpus + inverted index.
#[derive(Debug)]
pub struct Corpus {
    pub docs: Vec<Doc>,
    index: HashMap<i32, Vec<u32>>, // token -> doc ids
}

impl Corpus {
    /// Build a deterministic synthetic corpus of `n_docs` documents with
    /// Zipf-distributed tokens of `len` each.
    pub fn synthetic(n_docs: usize, len: usize, vocab: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        let mut docs = Vec::with_capacity(n_docs);
        for _ in 0..n_docs {
            let tokens: Vec<i32> =
                (0..len).map(|_| 4 + rng.zipf(0, (vocab - 4) as u64) as i32).collect();
            docs.push(Doc { tokens });
        }
        Corpus::from_docs(docs)
    }

    /// Index an explicit document set.
    pub fn from_docs(docs: Vec<Doc>) -> Corpus {
        let mut index: HashMap<i32, Vec<u32>> = HashMap::new();
        for (i, d) in docs.iter().enumerate() {
            let mut seen = std::collections::HashSet::new();
            for &t in &d.tokens {
                if seen.insert(t) {
                    index.entry(t).or_default().push(i as u32);
                }
            }
        }
        Corpus { docs, index }
    }

    /// Token-overlap scored top-k (BM25-lite: idf-weighted hit counting).
    pub fn search(&self, query: &[i32], k: usize) -> Vec<usize> {
        let n = self.docs.len() as f32;
        let mut scores: HashMap<u32, f32> = HashMap::new();
        for &t in query {
            if let Some(postings) = self.index.get(&t) {
                let idf = (n / (postings.len() as f32 + 0.5)).ln().max(0.0);
                for &d in postings {
                    *scores.entry(d).or_default() += idf;
                }
            }
        }
        let mut ranked: Vec<(f32, u32)> = scores.into_iter().map(|(d, s)| (s, d)).collect();
        ranked.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        ranked.into_iter().take(k).map(|(_, d)| d as usize).collect()
    }
}

/// Latency envelope of the simulated external service.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Base round-trip in microseconds.
    pub base_us: u64,
    /// Additional cost per result row.
    pub per_result_us: u64,
    /// +- jitter fraction applied deterministically per request.
    pub jitter: f64,
}

impl Default for NetModel {
    fn default() -> Self {
        // ~35 ms RTT to a search API, 1 ms per extra result row.
        NetModel { base_us: 35_000, per_result_us: 1_000, jitter: 0.2 }
    }
}

/// Web-search batch executor.
pub struct SearchExecutor {
    corpus: Arc<Corpus>,
    net: NetModel,
    rng: Rng,
}

impl BatchExecutor for SearchExecutor {
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()> {
        // Tool calls batched together model concurrent API requests: they
        // share one wall-clock window of the *longest* call (like batched
        // web search shares one RTT) instead of sleeping serially.  This
        // is what runtime tool fan-out (PR10) buys latency from.
        let mut tools: Vec<(crate::engines::RequestCtx, u64)> = Vec::new();
        for (ctx, job) in batch.jobs {
            let started = Instant::now();
            match job {
                EngineJob::WebSearch { queries, top_k } => {
                    // Batched requests share one RTT (the paper's search
                    // engine "supports single and batched requests").
                    let rows: usize = queries.len() * top_k;
                    let jit = 1.0 + self.net.jitter * (self.rng.next_f64() * 2.0 - 1.0);
                    let cost = Duration::from_micros(
                        ((self.net.base_us + self.net.per_result_us * rows as u64) as f64
                            * jit) as u64,
                    );
                    std::thread::sleep(cost);
                    let mut results = Vec::new();
                    for q in &queries {
                        for d in self.corpus.search(q, top_k) {
                            results.push(self.corpus.docs[d].tokens.clone());
                        }
                    }
                    emit(Completion {
                        query: ctx.query,
                        node: ctx.node,
                        output: JobOutput::TokenBatch(results),
                        timing: ExecTiming {
                            queued_us: 0,
                            exec_us: started.elapsed().as_micros() as u64,
                        },
                    });
                }
                EngineJob::ToolCall { cost_us, .. } => {
                    tools.push((ctx, cost_us));
                }
                other => {
                    return Err(TeolaError::Engine(format!("search engine got {other:?}")))
                }
            }
        }
        if !tools.is_empty() {
            let started = Instant::now();
            let window = tools.iter().map(|(_, c)| *c).max().unwrap_or(0);
            std::thread::sleep(Duration::from_micros(window));
            for (ctx, _) in tools {
                emit(Completion {
                    query: ctx.query,
                    node: ctx.node,
                    output: JobOutput::Unit,
                    timing: ExecTiming {
                        queued_us: 0,
                        exec_us: started.elapsed().as_micros() as u64,
                    },
                });
            }
        }
        Ok(())
    }
}

/// Spawn the web-search engine over a shared corpus.
pub fn spawn_search_engine(
    corpus: Arc<Corpus>,
    net: NetModel,
    n_instances: usize,
    free_tx: Sender<InstanceEvent>,
    ready_tx: Sender<()>,
) -> Vec<Instance> {
    (0..n_instances)
        .map(|i| {
            let corpus_c = corpus.clone();
            spawn_instance(
                i,
                format!("search-{i}"),
                move || {
                    Ok::<_, crate::error::TeolaError>(SearchExecutor {
                        corpus: corpus_c,
                        net,
                        rng: Rng::new(4242 + i as u64),
                    })
                },
                free_tx.clone(),
                ready_tx.clone(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_finds_overlapping_doc() {
        let docs = vec![
            Doc { tokens: vec![10, 11, 12] },
            Doc { tokens: vec![20, 21, 22] },
            Doc { tokens: vec![10, 21, 30] },
        ];
        let c = Corpus::from_docs(docs);
        let got = c.search(&[20, 21, 22], 2);
        assert_eq!(got[0], 1);
    }

    #[test]
    fn search_respects_k() {
        let c = Corpus::synthetic(50, 32, 512, 7);
        let q: Vec<i32> = c.docs[3].tokens[..8].to_vec();
        let got = c.search(&q, 4);
        assert!(got.len() <= 4);
        assert!(got.contains(&3), "self-similar doc should rank");
    }

    #[test]
    fn synthetic_corpus_deterministic() {
        let a = Corpus::synthetic(5, 16, 256, 9);
        let b = Corpus::synthetic(5, 16, 256, 9);
        for (x, y) in a.docs.iter().zip(&b.docs) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
