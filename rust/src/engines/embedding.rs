//! Embedding engine (bge-large analog): batched sentence embeddings.
//!
//! Jobs may carry many chunks (document indexing) or a single query; the
//! executor packs all rows of a batch into the smallest covering bucket
//! and splits oversized groups across successive calls.

use std::rc::Rc;
use std::sync::mpsc::Sender;

use crate::engines::instance::{for_chunks, spawn_instance, BatchExecutor, Instance};
use crate::engines::profile::{charge_device, DeviceModel};
use crate::engines::{Batch, Completion, EngineJob, ExecTiming, InstanceEvent, JobOutput};
use crate::error::{Result, TeolaError};
use crate::runtime::{HostTensor, Manifest, XlaContext};

/// Per-instance embedding executor.
pub struct EmbeddingExecutor {
    ctx: XlaContext,
    model: String,
    seq: usize,
    d_model: usize,
    batches: Vec<usize>,
    device: DeviceModel,
}

impl EmbeddingExecutor {
    /// Build on the instance thread; `warm` pre-compiles all buckets.
    pub fn new(manifest: Rc<Manifest>, model: &str, warm: bool) -> Result<EmbeddingExecutor> {
        let info = manifest
            .models
            .get(model)
            .ok_or_else(|| TeolaError::Engine(format!("unknown embedder {model}")))?;
        let seq = info.max_seq;
        let d_model = info.d_model;
        let batches = manifest.encoder_batches(model);
        if batches.is_empty() {
            return Err(TeolaError::Engine(format!("no buckets for {model}")));
        }
        let mut ctx = XlaContext::new(manifest)?;
        if warm {
            let names: Vec<String> =
                batches.iter().map(|b| format!("{model}__embed__b{b}")).collect();
            ctx.warm(&names)?;
            ctx.model_weights(model)?;
        }
        Ok(EmbeddingExecutor {
            ctx,
            model: model.to_string(),
            seq,
            d_model,
            batches,
            device: DeviceModel::for_engine(model),
        })
    }

    /// Embed up to `max_bucket` rows in one XLA call.
    fn embed_rows(&mut self, rows: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(rows.len());
        let maxb = *self.batches.last().unwrap();
        for_chunks(rows.len(), maxb, |i, take| {
            let bb = crate::engines::llm::pick_bucket(&self.batches, take);
            let mut tokens = vec![0i32; bb * self.seq];
            let mut mask = vec![0f32; bb * self.seq];
            for (b, row) in rows[i..i + take].iter().enumerate() {
                let len = row.len().min(self.seq);
                tokens[b * self.seq..b * self.seq + len].copy_from_slice(&row[..len]);
                mask[b * self.seq..b * self.seq + len]
                    .iter_mut()
                    .for_each(|x| *x = 1.0);
            }
            let artifact = format!("{}__embed__b{}", self.model, bb);
            let started = std::time::Instant::now();
            let res = self.ctx.run(
                &artifact,
                Some(&self.model.clone()),
                &[
                    HostTensor::i32(vec![bb, self.seq], tokens),
                    HostTensor::f32(vec![bb, self.seq], mask),
                ],
            )?;
            charge_device(started, self.device.encoder_us(take));
            let flat = res[0].to_vec::<f32>()?;
            for b in 0..take {
                out.push(flat[b * self.d_model..(b + 1) * self.d_model].to_vec());
            }
            Ok(())
        })?;
        Ok(out)
    }
}

impl BatchExecutor for EmbeddingExecutor {
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()> {
        // Flatten all jobs' chunks into one row list, remembering extents.
        let mut rows: Vec<Vec<i32>> = Vec::new();
        let mut extents = Vec::new();
        for (ctx, job) in &batch.jobs {
            match job {
                EngineJob::Embed { chunks } => {
                    extents.push((ctx.clone(), rows.len(), chunks.len()));
                    rows.extend(chunks.iter().cloned());
                }
                other => {
                    return Err(TeolaError::Engine(format!(
                        "embedding engine got {other:?}"
                    )))
                }
            }
        }
        let embs = self.embed_rows(&rows)?;
        for (ctx, start, count) in extents {
            emit(Completion {
                query: ctx.query,
                node: ctx.node,
                output: JobOutput::Embeddings(embs[start..start + count].to_vec()),
                timing: ExecTiming::default(),
            });
        }
        Ok(())
    }
}

/// Spawn `n_instances` embedding instance threads (XLA or simulated).
pub fn spawn_embedding_engine(
    manifest: Rc<Manifest>,
    model: &str,
    n_instances: usize,
    warm: bool,
    backend: crate::engines::sim::ExecBackend,
    free_tx: Sender<InstanceEvent>,
    ready_tx: Sender<()>,
) -> Vec<Instance> {
    use crate::engines::sim::{ExecBackend, SimEmbedExecutor};

    match backend {
        ExecBackend::Xla => {
            let dir = manifest.dir.clone();
            (0..n_instances)
                .map(|i| {
                    let dir_c = dir.clone();
                    let model_c = model.to_string();
                    spawn_instance(
                        i,
                        format!("embed-{i}"),
                        move || {
                            let m = Rc::new(Manifest::load(dir_c)?);
                            EmbeddingExecutor::new(m, &model_c, warm)
                        },
                        free_tx.clone(),
                        ready_tx.clone(),
                    )
                })
                .collect()
        }
        ExecBackend::Sim => {
            let d_model = manifest.models.get(model).map(|m| m.d_model).unwrap_or(64);
            (0..n_instances)
                .map(|i| {
                    let model_c = model.to_string();
                    spawn_instance(
                        i,
                        format!("embed-{i}"),
                        move || {
                            Ok::<_, crate::error::TeolaError>(SimEmbedExecutor::new(
                                &model_c, d_model, 16,
                            ))
                        },
                        free_tx.clone(),
                        ready_tx.clone(),
                    )
                })
                .collect()
        }
    }
}
