//! Simulated engine backend (`SimBackend`).
//!
//! Produces shape-correct synthetic outputs — token streams, embeddings,
//! rerank scores — with latencies charged from the `DeviceModel` profile,
//! so the *entire* orchestration stack (graph passes, two-tier scheduling,
//! batching policies, streaming partial decodes) runs without AOT
//! artifacts, deterministically and in milliseconds.  This is a
//! Parrot-style profile-driven simulation path: the executors mirror the XLA
//! executors' batch semantics exactly — same grouping, same SEP/EOS
//! forcing at segment boundaries, same completion routing — only the
//! numerics are replaced by hashes of the inputs.
//!
//! Every output is a pure function of the job's inputs (sequence id,
//! token content), never of batching order, so concurrent runs are
//! reproducible: the same (query id, e-graph) always yields the same
//! final value regardless of policy or load.

use std::time::Instant;

use crate::engines::instance::BatchExecutor;
use crate::engines::llm::{SeqState, SeqStore};
use crate::engines::profile::{charge_device, DeviceModel};
use crate::engines::{
    Batch, Completion, EngineJob, ExecTiming, JobOutput, RequestCtx, SegmentSpec, SeqId,
};
use crate::error::{Result, TeolaError};
use crate::util::rng::Rng;

/// Which execution substrate the model-based engines (LLM, embedder,
/// reranker) use.  Model-free engines (vector DB, web search, tools) are
/// native Rust either way.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// AOT XLA artifacts on PJRT (requires `artifacts/` and the real
    /// `xla` crate; see runtime/xla_stub.rs).
    #[default]
    Xla,
    /// Profile-driven simulation: synthetic outputs, `DeviceModel` timing.
    Sim,
}

impl ExecBackend {
    /// `TEOLA_BACKEND=sim|xla` environment override (benches, CLI).
    /// Unknown values are ignored with a warning so a typo doesn't
    /// silently fall back to the XLA default.
    pub fn from_env() -> Option<ExecBackend> {
        let raw = std::env::var("TEOLA_BACKEND").ok()?;
        match raw.trim().to_ascii_lowercase().as_str() {
            "sim" => Some(ExecBackend::Sim),
            "xla" => Some(ExecBackend::Xla),
            "" => None,
            other => {
                eprintln!("warning: unknown TEOLA_BACKEND={other:?} (want sim|xla); ignoring");
                None
            }
        }
    }
}

/// 64-bit finalizer (murmur3-style) for deterministic synthetic content.
fn mix(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51AFD7ED558CCD);
    h ^= h >> 33;
    h = h.wrapping_mul(0xC4CEB9FE1A85EC53);
    h ^ (h >> 33)
}

/// FNV-1a over a token sequence.
fn hash_tokens(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in tokens {
        h ^= t as u32 as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic non-special token for (sequence, position) — never
/// collides with pad/bos/eos/sep (ids < 4).
fn synth_token(seq: SeqId, pos: usize) -> i32 {
    let h = mix(seq.0 ^ ((seq.1 as u64) << 40) ^ (pos as u64).wrapping_mul(0x9E3779B97F4A7C15));
    4 + (h % 1996) as i32
}

/// Deterministic unit-norm embedding of a token row.
pub fn synth_embedding(tokens: &[i32], d_model: usize) -> Vec<f32> {
    let mut rng = Rng::new(hash_tokens(tokens));
    let mut v: Vec<f32> = (0..d_model).map(|_| (rng.next_f64() - 0.5) as f32).collect();
    let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    } else if d_model > 0 {
        v[0] = 1.0;
    }
    v
}

/// Deterministic relevance score in [0, 1) for a packed rerank pair.
fn synth_score(pair: &[i32]) -> f32 {
    (mix(hash_tokens(pair)) % 10_000) as f32 / 10_000.0
}

struct SimPrefillRow {
    ctx: RequestCtx,
    seq: SeqId,
    tokens: Vec<i32>,
    offset: usize,
}

struct SimDecodeRow {
    ctx: RequestCtx,
    seq: SeqId,
    segments: Vec<SegmentSpec>,
}

/// Simulated LLM executor: chunked prefill + batched streaming decode over
/// the shared sequence store, with device time from the variant's profile.
pub struct SimLlmExecutor {
    store: SeqStore,
    device: DeviceModel,
    max_seq: usize,
    max_decode_batch: usize,
    sep: i32,
    eos: i32,
}

impl SimLlmExecutor {
    /// Build an executor for an LLM variant (no artifacts required).
    pub fn new(variant: &str, store: SeqStore, sep: i32, eos: i32, max_seq: usize) -> SimLlmExecutor {
        SimLlmExecutor {
            store,
            device: DeviceModel::for_engine(variant),
            max_seq: max_seq.max(16),
            max_decode_batch: 8,
            sep,
            eos,
        }
    }

    fn run_prefill_group(
        &mut self,
        rows: Vec<SimPrefillRow>,
        emit: &mut dyn FnMut(Completion),
    ) -> Result<()> {
        // One simulated device call over all rows; like the XLA path the
        // charge is proportional to the *valid* tokens, so bucket padding
        // costs nothing here and the batching economics match.
        let started = Instant::now();
        let valid: usize = rows.iter().map(|r| r.tokens.len()).sum();
        let mut next = Vec::with_capacity(rows.len());
        {
            let mut store = self.store.lock().unwrap();
            for r in &rows {
                let new_len = (r.offset + r.tokens.len()).min(self.max_seq);
                store.insert(r.seq, SeqState { kv: Vec::new(), len: new_len });
                next.push(synth_token(r.seq, new_len));
            }
        }
        charge_device(started, self.device.prefill_us(1, valid));
        for (i, r) in rows.iter().enumerate() {
            emit(Completion {
                query: r.ctx.query,
                node: r.ctx.node,
                output: JobOutput::Tokens(vec![next[i]]),
                timing: ExecTiming::default(),
            });
        }
        Ok(())
    }

    fn run_decode_group(
        &mut self,
        mut rows: Vec<SimDecodeRow>,
        emit: &mut dyn FnMut(Completion),
    ) -> Result<()> {
        while !rows.is_empty() {
            let take = rows.len().min(self.max_decode_batch);
            let group: Vec<SimDecodeRow> = rows.drain(..take).collect();
            self.exec_decode_batch(group, emit)?;
        }
        Ok(())
    }

    fn exec_decode_batch(
        &mut self,
        rows: Vec<SimDecodeRow>,
        emit: &mut dyn FnMut(Completion),
    ) -> Result<()> {
        let n = rows.len();
        let planned: Vec<usize> =
            rows.iter().map(|r| r.segments.iter().map(|s| s.len).sum()).collect();
        let base_len: Vec<usize> = {
            let store = self.store.lock().unwrap();
            rows.iter().map(|r| store.get(&r.seq).map(|s| s.len).unwrap_or(0)).collect()
        };

        let mut produced = vec![0usize; n];
        let mut seg_idx = vec![0usize; n];
        let mut seg_tokens: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut all_segments: Vec<Vec<Vec<i32>>> = vec![Vec::new(); n];
        let total: usize = planned.iter().sum();
        let mut emitted = 0usize;

        // Autoregressive loop: all rows step together (one batched decode
        // iteration per planned token), segments stream out mid-loop —
        // exactly the contract Pass 4 (decoding pipelining) relies on.
        while emitted < total {
            let step_started = Instant::now();
            charge_device(step_started, self.device.decode_step_us(n));
            for (b, r) in rows.iter().enumerate() {
                if produced[b] >= planned[b] {
                    continue;
                }
                let seg = &r.segments[seg_idx[b]];
                let pos_in_seg = seg_tokens[b].len() + 1;
                let is_seg_end = pos_in_seg >= seg.len;
                let is_last = produced[b] + 1 >= planned[b];
                let tok = if is_last {
                    self.eos
                } else if is_seg_end {
                    self.sep
                } else {
                    synth_token(r.seq, base_len[b] + produced[b])
                };
                seg_tokens[b].push(tok);
                produced[b] += 1;
                emitted += 1;

                if is_seg_end || is_last {
                    let out_tokens = std::mem::take(&mut seg_tokens[b]);
                    all_segments[b].push(out_tokens.clone());
                    if seg.node != r.ctx.node {
                        emit(Completion {
                            query: r.ctx.query,
                            node: seg.node,
                            output: JobOutput::Tokens(out_tokens),
                            timing: ExecTiming::default(),
                        });
                    }
                    if seg_idx[b] + 1 < r.segments.len() {
                        seg_idx[b] += 1;
                    }
                    if is_last {
                        emit(Completion {
                            query: r.ctx.query,
                            node: r.ctx.node,
                            output: JobOutput::TokenBatch(std::mem::take(&mut all_segments[b])),
                            timing: ExecTiming::default(),
                        });
                    }
                }
            }
        }

        {
            let mut store = self.store.lock().unwrap();
            for (b, r) in rows.iter().enumerate() {
                let len = (base_len[b] + produced[b]).min(self.max_seq);
                store.insert(r.seq, SeqState { kv: Vec::new(), len });
            }
        }
        Ok(())
    }
}

impl BatchExecutor for SimLlmExecutor {
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()> {
        let mut prefills: Vec<SimPrefillRow> = Vec::new();
        let mut decodes: Vec<SimDecodeRow> = Vec::new();
        for (ctx, job) in batch.jobs {
            match job {
                EngineJob::Prefill { seq, tokens, offset } => {
                    prefills.push(SimPrefillRow { ctx, seq, tokens, offset })
                }
                EngineJob::Decode { seq, segments, .. } => {
                    decodes.push(SimDecodeRow { ctx, seq, segments })
                }
                EngineJob::ClonePrefix { src, dst, len } => {
                    let mut store = self.store.lock().unwrap();
                    if let Some(s) = store.get(&src) {
                        let len = len.min(s.len);
                        store.insert(dst, SeqState { kv: Vec::new(), len });
                    }
                    drop(store);
                    emit(Completion {
                        query: ctx.query,
                        node: ctx.node,
                        output: JobOutput::Unit,
                        timing: ExecTiming::default(),
                    });
                }
                EngineJob::FreeQuery { query } => {
                    let mut store = self.store.lock().unwrap();
                    store.retain(|k, _| k.0 != query);
                    drop(store);
                    emit(Completion {
                        query: ctx.query,
                        node: ctx.node,
                        output: JobOutput::Unit,
                        timing: ExecTiming::default(),
                    });
                }
                other => {
                    return Err(TeolaError::Engine(format!(
                        "sim LLM engine got non-LLM job {other:?}"
                    )))
                }
            }
        }
        if !prefills.is_empty() {
            self.run_prefill_group(prefills, emit)?;
        }
        if !decodes.is_empty() {
            self.run_decode_group(decodes, emit)?;
        }
        Ok(())
    }
}

/// Simulated embedding executor: deterministic unit-norm vectors, device
/// time charged per bucket-sized call like the XLA path.
pub struct SimEmbedExecutor {
    device: DeviceModel,
    d_model: usize,
    max_batch: usize,
}

impl SimEmbedExecutor {
    /// Build a sim embedder with the given output dimensionality.
    pub fn new(model: &str, d_model: usize, max_batch: usize) -> SimEmbedExecutor {
        SimEmbedExecutor {
            device: DeviceModel::for_engine(model),
            d_model: d_model.max(8),
            max_batch: max_batch.max(1),
        }
    }
}

impl BatchExecutor for SimEmbedExecutor {
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()> {
        let mut rows: Vec<Vec<i32>> = Vec::new();
        let mut extents = Vec::new();
        for (ctx, job) in &batch.jobs {
            match job {
                EngineJob::Embed { chunks } => {
                    extents.push((ctx.clone(), rows.len(), chunks.len()));
                    rows.extend(chunks.iter().cloned());
                }
                other => {
                    return Err(TeolaError::Engine(format!(
                        "sim embedding engine got {other:?}"
                    )))
                }
            }
        }
        let mut embs = Vec::with_capacity(rows.len());
        let mut i = 0;
        while i < rows.len() {
            let take = (rows.len() - i).min(self.max_batch);
            let started = Instant::now();
            for row in &rows[i..i + take] {
                embs.push(synth_embedding(row, self.d_model));
            }
            charge_device(started, self.device.encoder_us(take));
            i += take;
        }
        for (ctx, start, count) in extents {
            emit(Completion {
                query: ctx.query,
                node: ctx.node,
                output: JobOutput::Embeddings(embs[start..start + count].to_vec()),
                timing: ExecTiming::default(),
            });
        }
        Ok(())
    }
}

/// Simulated reranker executor: deterministic scores per packed pair.
pub struct SimRerankExecutor {
    device: DeviceModel,
    max_batch: usize,
}

impl SimRerankExecutor {
    /// Build a sim reranker.
    pub fn new(model: &str, max_batch: usize) -> SimRerankExecutor {
        SimRerankExecutor { device: DeviceModel::for_engine(model), max_batch: max_batch.max(1) }
    }
}

impl BatchExecutor for SimRerankExecutor {
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()> {
        let mut rows: Vec<Vec<i32>> = Vec::new();
        let mut extents = Vec::new();
        for (ctx, job) in &batch.jobs {
            match job {
                EngineJob::Rerank { pairs } => {
                    extents.push((ctx.clone(), rows.len(), pairs.len()));
                    rows.extend(pairs.iter().cloned());
                }
                other => {
                    return Err(TeolaError::Engine(format!(
                        "sim reranker engine got {other:?}"
                    )))
                }
            }
        }
        let mut scores = Vec::with_capacity(rows.len());
        let mut i = 0;
        while i < rows.len() {
            let take = (rows.len() - i).min(self.max_batch);
            let started = Instant::now();
            for row in &rows[i..i + take] {
                scores.push(synth_score(row));
            }
            charge_device(started, self.device.encoder_us(take));
            i += take;
        }
        for (ctx, start, count) in extents {
            emit(Completion {
                query: ctx.query,
                node: ctx.node,
                output: JobOutput::Scores(scores[start..start + count].to_vec()),
                timing: ExecTiming::default(),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;
    use std::sync::mpsc::channel;
    use std::sync::{Arc, Mutex};

    fn ctx(query: u64, node: usize, reply: std::sync::mpsc::Sender<Completion>) -> RequestCtx {
        RequestCtx { query, node, depth: 0, arrival: Instant::now(), reply }
    }

    #[test]
    fn synth_token_is_deterministic_and_non_special() {
        let stream = |seq: SeqId| -> Vec<i32> {
            (0..200).map(|pos| synth_token(seq, pos)).collect()
        };
        assert_eq!(stream((7, 1)), stream((7, 1)));
        assert!(stream((7, 1)).iter().all(|&t| t >= 4));
        // Different sequences yield different streams (single-position
        // collisions are possible; whole-stream collisions are not).
        assert_ne!(stream((7, 1)), stream((8, 1)));
    }

    #[test]
    fn synth_embedding_unit_norm_and_content_addressed() {
        let a = synth_embedding(&[5, 6, 7], 32);
        let b = synth_embedding(&[5, 6, 7], 32);
        let c = synth_embedding(&[5, 6, 8], 32);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4, "norm {norm}");
    }

    #[test]
    fn sim_llm_prefill_then_decode_streams_segments() {
        let store: SeqStore = Arc::new(Mutex::new(HashMap::new()));
        let mut exec =
            SimLlmExecutor::new("llm-lite", store.clone(), 3, 2, 256);
        let (tx, rx) = channel();

        // Prefill 10 tokens into seq (1, 0).
        let batch = Batch {
            jobs: vec![(
                ctx(1, 0, tx.clone()),
                EngineJob::Prefill { seq: (1, 0), tokens: vec![10; 10], offset: 0 },
            )],
        };
        let mut out = Vec::new();
        exec.execute(batch, &mut |c| out.push(c)).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(store.lock().unwrap().get(&(1, 0)).unwrap().len, 10);

        // Decode 6 tokens in 2 segments streamed to marker nodes 8 and 9.
        let batch = Batch {
            jobs: vec![(
                ctx(1, 5, tx),
                EngineJob::Decode {
                    seq: (1, 0),
                    first_token: 42,
                    segments: vec![
                        SegmentSpec { node: 8, len: 3 },
                        SegmentSpec { node: 9, len: 3 },
                    ],
                },
            )],
        };
        let mut out = Vec::new();
        exec.execute(batch, &mut |c| out.push(c)).unwrap();
        drop(rx);
        // Two streamed segments + the final decode completion.
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].node, 8);
        assert_eq!(out[1].node, 9);
        assert_eq!(out[2].node, 5);
        match &out[2].output {
            JobOutput::TokenBatch(segs) => {
                assert_eq!(segs.len(), 2);
                assert_eq!(segs[0].len(), 3);
                // non-final segment ends with SEP, final with EOS
                assert_eq!(*segs[0].last().unwrap(), 3);
                assert_eq!(*segs[1].last().unwrap(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(store.lock().unwrap().get(&(1, 0)).unwrap().len, 16);
    }

    #[test]
    fn sim_embed_and_rerank_preserve_extents() {
        let (tx, rx) = channel();
        let mut emb = SimEmbedExecutor::new("embedder", 16, 4);
        let batch = Batch {
            jobs: vec![
                (ctx(1, 0, tx.clone()), EngineJob::Embed { chunks: vec![vec![1], vec![2]] }),
                (ctx(2, 0, tx.clone()), EngineJob::Embed { chunks: vec![vec![3]] }),
            ],
        };
        let mut out = Vec::new();
        emb.execute(batch, &mut |c| out.push(c)).unwrap();
        assert_eq!(out.len(), 2);
        match (&out[0].output, &out[1].output) {
            (JobOutput::Embeddings(a), JobOutput::Embeddings(b)) => {
                assert_eq!(a.len(), 2);
                assert_eq!(b.len(), 1);
            }
            other => panic!("unexpected {other:?}"),
        }

        let mut rr = SimRerankExecutor::new("reranker", 4);
        let batch = Batch {
            jobs: vec![(
                ctx(3, 0, tx),
                EngineJob::Rerank { pairs: vec![vec![1, 3, 9], vec![1, 3, 10]] },
            )],
        };
        let mut out = Vec::new();
        rr.execute(batch, &mut |c| out.push(c)).unwrap();
        drop(rx);
        match &out[0].output {
            JobOutput::Scores(s) => {
                assert_eq!(s.len(), 2);
                assert!(s.iter().all(|x| (0.0..1.0).contains(x)));
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
