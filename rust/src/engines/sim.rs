//! Simulated engine backend (`SimBackend`).
//!
//! Produces shape-correct synthetic outputs — token streams, embeddings,
//! rerank scores — with latencies charged from the `DeviceModel` profile,
//! so the *entire* orchestration stack (graph passes, two-tier scheduling,
//! batching policies, streaming partial decodes, iteration-level
//! continuous batching) runs without AOT artifacts, deterministically and
//! in milliseconds.  This is a Parrot-style profile-driven simulation
//! path: the executors mirror the XLA executors' batch semantics exactly —
//! same grouping, same SEP/EOS forcing at segment boundaries, same
//! completion routing, same stepped admission protocol — only the
//! numerics are replaced by hashes of the inputs.
//!
//! Every output is a pure function of the job's inputs (sequence id,
//! token content, KV length at admission), never of batching order or of
//! which rows shared an iteration, so concurrent runs are reproducible:
//! the same (query id, e-graph) always yields the same final value
//! regardless of policy, load, or mid-flight admission.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::engines::instance::{for_chunks, BatchExecutor, StepExecutor, StepOutcome};
use crate::engines::kv_budget::{self, KvBudget};
use crate::engines::llm::{SeqState, SeqStore};
use crate::engines::prefix::{PrefixFp, PrefixRegistry};
use crate::engines::profile::{charge_device, DeviceModel};
use crate::engines::{
    Batch, Completion, EngineJob, ExecTiming, JobOutput, RequestCtx, SegmentSpec, SeqId,
};
use crate::error::{Result, TeolaError};
use crate::util::rng::Rng;

/// Which execution substrate the model-based engines (LLM, embedder,
/// reranker) use.  Model-free engines (vector DB, web search, tools) are
/// native Rust either way.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// AOT XLA artifacts on PJRT (requires `artifacts/` and the real
    /// `xla` crate; see runtime/xla_stub.rs).
    #[default]
    Xla,
    /// Profile-driven simulation: synthetic outputs, `DeviceModel` timing.
    Sim,
}

impl ExecBackend {
    /// `TEOLA_BACKEND=sim|xla` environment override (benches, CLI).
    /// Unknown values are ignored with a warning so a typo doesn't
    /// silently fall back to the XLA default.
    pub fn from_env() -> Option<ExecBackend> {
        let raw = std::env::var("TEOLA_BACKEND").ok()?;
        match raw.trim().to_ascii_lowercase().as_str() {
            "sim" => Some(ExecBackend::Sim),
            "xla" => Some(ExecBackend::Xla),
            "" => None,
            other => {
                eprintln!("warning: unknown TEOLA_BACKEND={other:?} (want sim|xla); ignoring");
                None
            }
        }
    }
}

/// Process-wide residency observability counters for the sim LLM path
/// (reset per measurement leg by the bench/test harnesses).  Statics
/// rather than per-executor state so the serving comparisons can observe
/// executors living on instance threads without re-plumbing the spawn
/// signatures.
static SIM_PEAK_RESIDENT_ROWS: AtomicUsize = AtomicUsize::new(0);
static SIM_EVICTIONS: AtomicUsize = AtomicUsize::new(0);
static SIM_ACCOUNTING_DRIFT: AtomicUsize = AtomicUsize::new(0);

/// Reset the sim residency counters (start of a measurement leg).
pub fn reset_residency_stats() {
    SIM_PEAK_RESIDENT_ROWS.store(0, Ordering::Relaxed);
    SIM_EVICTIONS.store(0, Ordering::Relaxed);
    SIM_ACCOUNTING_DRIFT.store(0, Ordering::Relaxed);
}

/// `(peak concurrent prefill+decode rows on any sim LLM executor step,
/// watermark evictions, executor-ledger accounting drift)` since the last
/// [`reset_residency_stats`].  Drift is reserve/release mispairing tokens
/// ([`KvBudget::accounting_drift`]) — always 0 on a healthy run.
pub fn residency_stats() -> (usize, usize, usize) {
    (
        SIM_PEAK_RESIDENT_ROWS.load(Ordering::Relaxed),
        SIM_EVICTIONS.load(Ordering::Relaxed),
        SIM_ACCOUNTING_DRIFT.load(Ordering::Relaxed),
    )
}

/// 64-bit finalizer (murmur3-style) for deterministic synthetic content.
fn mix(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51AFD7ED558CCD);
    h ^= h >> 33;
    h = h.wrapping_mul(0xC4CEB9FE1A85EC53);
    h ^ (h >> 33)
}

/// FNV-1a over a token sequence.
fn hash_tokens(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in tokens {
        h ^= t as u32 as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic non-special token for (sequence, position) — never
/// collides with pad/bos/eos/sep (ids < 4).
fn synth_token(seq: SeqId, pos: usize) -> i32 {
    let h = mix(seq.0 ^ ((seq.1 as u64) << 40) ^ (pos as u64).wrapping_mul(0x9E3779B97F4A7C15));
    4 + (h % 1996) as i32
}

/// Deterministic unit-norm embedding of a token row.
pub fn synth_embedding(tokens: &[i32], d_model: usize) -> Vec<f32> {
    let mut rng = Rng::new(hash_tokens(tokens));
    let mut v: Vec<f32> = (0..d_model).map(|_| (rng.next_f64() - 0.5) as f32).collect();
    let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    } else if d_model > 0 {
        v[0] = 1.0;
    }
    v
}

/// Deterministic relevance score in [0, 1) for a packed rerank pair.
fn synth_score(pair: &[i32]) -> f32 {
    (mix(hash_tokens(pair)) % 10_000) as f32 / 10_000.0
}

struct SimPrefillRow {
    ctx: RequestCtx,
    seq: SeqId,
    tokens: Vec<i32>,
    offset: usize,
    prefix: Option<PrefixFp>,
    /// Executor-side KV reservation (suffix-only on an admit-time prefix
    /// hit); released when the row retires.
    kv_res: usize,
}

/// One resident decode sequence: all per-row loop state lives here so the
/// row can advance one token per `step` and retire independently of the
/// rest of the batch.
struct SimDecodeRow {
    ctx: RequestCtx,
    seq: SeqId,
    segments: Vec<SegmentSpec>,
    /// KV length at admission (token positions are addressed from here so
    /// outputs never depend on which rows shared an iteration).
    base_len: usize,
    planned: usize,
    produced: usize,
    seg_idx: usize,
    seg_tokens: Vec<i32>,
    all_segments: Vec<Vec<i32>>,
    /// Executor-side KV reservation (the planned new tokens); released
    /// when the row retires.
    kv_res: usize,
}

/// Simulated LLM executor running the iteration-level protocol: chunked
/// prefill calls and decode iterations interleave over a resident
/// sequence set, new jobs are admitted between steps, and each row
/// retires the moment it emits EOS — with device time from the variant's
/// profile.
pub struct SimLlmExecutor {
    store: SeqStore,
    device: DeviceModel,
    max_seq: usize,
    max_decode_batch: usize,
    sep: i32,
    eos: i32,
    /// Host-side KV bookkeeping ops (ClonePrefix/FreeQuery): executed at
    /// the start of the next step, free of device time.
    instant: Vec<(RequestCtx, EngineJob)>,
    /// Jobs this engine cannot serve (mis-routed kinds): retired without
    /// a completion at the next step so load accounting stays balanced.
    rejected: Vec<(RequestCtx, usize)>,
    prefills: VecDeque<SimPrefillRow>,
    decodes: Vec<SimDecodeRow>,
    /// Resident instruction prefixes of this instance (the KV itself is
    /// virtual on the sim path; residency is what matters for charging).
    prefixes: PrefixRegistry<()>,
    /// Valid prefill tokens charged so far (resident-prefix hits charge
    /// only the suffix) — the test/metric observable for prefix reuse.
    charged_prefill_tokens: usize,
    /// Shared per-instance KV token capacity handle (0 = unlimited, the
    /// legacy row-slot mode).
    kv_capacity: Arc<AtomicUsize>,
    /// Shared high-watermark handle, percent of capacity (0 = persistent
    /// residency off: PR5 reserve-at-admit/release-at-retire semantics).
    /// When on, prefill charges become resident per `SeqId` at
    /// retirement, decode reservations grow one token per step, and
    /// crossing the watermark evicts the lowest-priority idle resident
    /// sequence (swap-out: the ledger charge is freed, the host-side
    /// store entry survives, and the next decode re-charges it on
    /// admission — swap-in).
    kv_watermark: Arc<AtomicUsize>,
    /// Executor-side reservation + resident ledger: admissions that would
    /// overflow it are bounced back to the instance backlog (vLLM-style
    /// admission control); an empty ledger accepts anything (liveness).
    kv: KvBudget,
    /// Shared tenancy handle (multi-tenant QoS): when set and enabled,
    /// residency commits are attributed to the owning tenant and
    /// watermark preemption prefers over-quota tenants' sequences.
    tenancy: Option<Arc<crate::scheduler::tenancy::SharedTenancy>>,
}

impl SimLlmExecutor {
    /// Build an executor for an LLM variant (no artifacts required).
    /// `prefix_slots` is the shared resident-prefix budget handle (0
    /// disables prefix caching).
    pub fn new(
        variant: &str,
        store: SeqStore,
        sep: i32,
        eos: i32,
        max_seq: usize,
        prefix_slots: Arc<AtomicUsize>,
    ) -> SimLlmExecutor {
        SimLlmExecutor {
            store,
            device: DeviceModel::for_engine(variant),
            max_seq: max_seq.max(16),
            max_decode_batch: 8,
            sep,
            eos,
            instant: Vec::new(),
            rejected: Vec::new(),
            prefills: VecDeque::new(),
            decodes: Vec::new(),
            prefixes: PrefixRegistry::new(prefix_slots),
            charged_prefill_tokens: 0,
            kv_capacity: Arc::new(AtomicUsize::new(0)),
            kv_watermark: Arc::new(AtomicUsize::new(0)),
            kv: KvBudget::new(0),
            tenancy: None,
        }
    }

    /// Bind the executor to a shared per-instance KV token capacity
    /// handle (`PlatformConfig::kv_tokens_per_instance`); 0 keeps the
    /// legacy unlimited behavior.
    pub fn with_kv_budget(mut self, capacity: Arc<AtomicUsize>) -> SimLlmExecutor {
        self.kv_capacity = capacity;
        self
    }

    /// Bind the executor to a shared residency watermark handle (percent
    /// of KV capacity; 0 keeps PR5 reserve-at-admit semantics).
    pub fn with_kv_watermark(mut self, watermark: Arc<AtomicUsize>) -> SimLlmExecutor {
        self.kv_watermark = watermark;
        self
    }

    /// Bind the executor to the shared tenancy handle (multi-tenant QoS:
    /// per-tenant residency attribution and quota-aware eviction).
    pub fn with_tenancy(
        mut self,
        tenancy: Arc<crate::scheduler::tenancy::SharedTenancy>,
    ) -> SimLlmExecutor {
        self.tenancy = Some(tenancy);
        self
    }

    /// Whether persistent per-sequence residency is in force.
    fn residency_on(&self) -> bool {
        self.kv_watermark.load(Ordering::Relaxed) > 0
    }

    /// KV tokens currently charged on this instance across both ledgers
    /// (in-flight reservations + committed residency).
    pub fn kv_occupied(&self) -> usize {
        self.kv.occupied()
    }

    /// KV tokens held resident across jobs (0 outside residency mode).
    pub fn kv_resident_total(&self) -> usize {
        self.kv.resident_total()
    }

    /// Evict idle resident sequences (lowest WCP stamp first) until the
    /// occupancy drops back under the watermark or no evictable sequence
    /// remains.  Swap-out only: the host-side store entry survives, so a
    /// later decode recomputes nothing — it re-charges the sequence's KV
    /// on admission (swap-in) and outputs stay bit-identical.
    fn preempt_to_watermark(&mut self, out: &mut StepOutcome) {
        let pct = self.kv_watermark.load(Ordering::Relaxed);
        let cap = self.kv.capacity();
        if pct == 0 || cap == 0 {
            return;
        }
        let limit = cap.saturating_mul(pct) / 100;
        while self.kv.occupied() > limit {
            let active: Vec<SeqId> = self
                .prefills
                .iter()
                .map(|r| r.seq)
                .chain(self.decodes.iter().map(|r| r.seq))
                .collect();
            // Quota-aware victim choice (multi-tenant QoS): an over-quota
            // tenant's sequences are shed first, so one tenant's KV
            // appetite evicts its own residency before anyone else's.
            // The per-tenant sums are recomputed per eviction — each
            // freed sequence may bring its tenant back under quota.
            let victim = match &self.tenancy {
                Some(tn) if tn.enabled() => {
                    let by_tenant = self.kv.resident_by_tenant();
                    self.kv.evict_victim_quota(&active, &|t| {
                        tn.kv_quota_tokens(t, cap).map_or(false, |q| {
                            by_tenant.get(&t).copied().unwrap_or(0) > q
                        })
                    })
                }
                _ => self.kv.evict_victim(&active),
            };
            let Some((victim, _tokens)) = victim else {
                break;
            };
            let freed = self.kv.free_seq(victim);
            out.resident_freed += freed;
            SIM_EVICTIONS.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Total valid prefill tokens this instance has charged device time
    /// for (prefix hits charge only the un-cached suffix).
    pub fn charged_prefill_tokens(&self) -> usize {
        self.charged_prefill_tokens
    }

    /// KV tokens currently reserved on this instance (executor-side
    /// ledger: suffix-only prefill reservations plus planned decode
    /// growth of every admitted, un-retired row).
    pub fn kv_reserved(&self) -> usize {
        self.kv.reserved()
    }

    /// Execute the queued host-side bookkeeping ops.
    fn run_instant(&mut self, emit: &mut dyn FnMut(Completion), out: &mut StepOutcome) {
        for (ctx, job) in self.instant.drain(..) {
            match job {
                EngineJob::ClonePrefix { src, dst, len } => {
                    let mut store = self.store.lock().unwrap();
                    if let Some(s) = store.get(&src) {
                        let len = len.min(s.len);
                        store.insert(dst, SeqState { kv: Vec::new(), len });
                    }
                }
                EngineJob::FreeQuery { query } => {
                    let mut store = self.store.lock().unwrap();
                    store.retain(|k, _| k.0 != query);
                    drop(store);
                    // Residency is freed only here (or by watermark
                    // eviction): report it so the scheduler's mirror
                    // drains in lockstep.  No-op outside residency mode.
                    out.resident_freed += self.kv.free_query(query);
                }
                EngineJob::CancelSeq { seq } => {
                    // A speculative template prefill whose guard resolved
                    // false: purge any still-queued prefill rows for the
                    // sequence (their reservations go back to the ledger
                    // and the rows retire WITHOUT a completion — the
                    // runner has dropped its interest, and a Failed here
                    // would poison an otherwise healthy query), drop the
                    // host-side KV entry, and free any residency the
                    // sequence already committed.
                    let mut kept = VecDeque::with_capacity(self.prefills.len());
                    for r in self.prefills.drain(..) {
                        if r.seq == seq {
                            self.kv.release(r.kv_res);
                            out.retired_rows += 1;
                            out.retired.push((r.ctx.query, r.ctx.node));
                        } else {
                            kept.push_back(r);
                        }
                    }
                    self.prefills = kept;
                    self.store.lock().unwrap().remove(&seq);
                    out.resident_freed += self.kv.free_seq(seq);
                }
                _ => unreachable!("only bookkeeping jobs are queued as instant"),
            }
            emit(Completion {
                query: ctx.query,
                node: ctx.node,
                output: JobOutput::Unit,
                timing: ExecTiming::default(),
            });
            out.retired_rows += 1;
            out.retired.push((ctx.query, ctx.node));
        }
    }

    /// One batched prefill call over every queued prefill row; like the
    /// XLA path the charge is proportional to the *valid* tokens, so
    /// bucket padding costs nothing here and the batching economics match.
    fn step_prefill(&mut self, emit: &mut dyn FnMut(Completion), out: &mut StepOutcome) {
        let mut rows: Vec<SimPrefillRow> = self.prefills.drain(..).collect();
        // Pending-queue dedupe: prefix registration used to happen only
        // at step time, so two same-prefix prefills admitted in one burst
        // both prefilled cold.  Within this batched call the *first*
        // from-scratch row of each fingerprint computes the prefix; every
        // later co-admitted row is trimmed to its suffix exactly as an
        // admit-time hit would be (same final KV length, so outputs are
        // unchanged — only the charge shrinks).
        if self.prefixes.cap() > 0 {
            let mut warm: Vec<PrefixFp> = Vec::new();
            for r in rows.iter_mut() {
                let Some(fp) = r.prefix else { continue };
                if r.offset != 0 {
                    continue;
                }
                if warm.contains(&fp) && r.tokens.len() > fp.len {
                    r.tokens.drain(..fp.len);
                    r.offset = fp.len;
                } else if r.tokens.len() >= fp.len {
                    warm.push(fp);
                }
            }
        }
        let started = Instant::now();
        let valid: usize = rows.iter().map(|r| r.tokens.len()).sum();
        self.charged_prefill_tokens += valid;
        let mut next = Vec::with_capacity(rows.len());
        {
            let mut store = self.store.lock().unwrap();
            for r in &rows {
                let new_len = (r.offset + r.tokens.len()).min(self.max_seq);
                store.insert(r.seq, SeqState { kv: Vec::new(), len: new_len });
                next.push(synth_token(r.seq, new_len));
            }
        }
        // Register freshly computed instruction prefixes: a from-scratch
        // row that covered its full fingerprinted prefix now holds that
        // KV, so later queries sharing it can prefill the suffix only.
        // (Hit rows were trimmed at admission — their offset is nonzero —
        // so they only refresh LRU recency, which `admit` already did.)
        for r in &rows {
            if let Some(fp) = r.prefix {
                if r.offset == 0 && r.tokens.len() >= fp.len {
                    self.prefixes.insert(fp, ());
                }
            }
        }
        charge_device(started, self.device.prefill_us(1, valid));
        let residency = self.residency_on();
        for (i, r) in rows.iter().enumerate() {
            emit(Completion {
                query: r.ctx.query,
                node: r.ctx.node,
                output: JobOutput::Tokens(vec![next[i]]),
                timing: ExecTiming::default(),
            });
            if residency {
                // The prefilled KV stays on the instance between jobs:
                // move the charge from reserved to resident against the
                // sequence instead of releasing it, attributed to the
                // owning tenant for quota enforcement.
                self.kv.commit_resident_as(r.seq, r.kv_res, r.ctx.wcp_us, r.ctx.tenant);
                out.resident_added += r.kv_res;
            } else {
                self.kv.release(r.kv_res);
            }
            out.retired_rows += 1;
            out.retired.push((r.ctx.query, r.ctx.node));
        }
    }

    /// One decode iteration over all resident rows: every row produces
    /// one token, segments stream out mid-flight, and rows hitting the
    /// end of their plan retire immediately — exactly the contract Pass 4
    /// (decoding pipelining) and continuous batching rely on.
    fn step_decode(&mut self, emit: &mut dyn FnMut(Completion), out: &mut StepOutcome) {
        let started = Instant::now();
        let n = self.decodes.len();
        // Device charge: the iteration runs as sub-batches of the max
        // decode width, each priced by the memory-bound step model.
        let mut cost = 0u64;
        let _ = for_chunks(n, self.max_decode_batch, |_, take| {
            cost += self.device.decode_step_us(take);
            Ok(())
        });
        charge_device(started, cost);

        let sep = self.sep;
        let eos = self.eos;
        let residency = self.residency_on();
        let mut b = 0;
        while b < self.decodes.len() {
            let mut is_last = true;
            if self.decodes[b].planned > 0 {
                let r = &mut self.decodes[b];
                let seg_node = r.segments[r.seg_idx].node;
                let seg_len = r.segments[r.seg_idx].len;
                let pos_in_seg = r.seg_tokens.len() + 1;
                let is_seg_end = pos_in_seg >= seg_len;
                is_last = r.produced + 1 >= r.planned;
                let tok = if is_last {
                    eos
                } else if is_seg_end {
                    sep
                } else {
                    synth_token(r.seq, r.base_len + r.produced)
                };
                r.seg_tokens.push(tok);
                r.produced += 1;
                if residency && !is_last {
                    // Decode reservations grow one token per iteration
                    // instead of max_new at admission: reserve the next
                    // step's token now that this one materialized.
                    r.kv_res += 1;
                    self.kv.reserve(1);
                }
                if is_seg_end || is_last {
                    let out_tokens = std::mem::take(&mut r.seg_tokens);
                    r.all_segments.push(out_tokens.clone());
                    if seg_node != r.ctx.node {
                        emit(Completion {
                            query: r.ctx.query,
                            node: seg_node,
                            output: JobOutput::Tokens(out_tokens),
                            timing: ExecTiming::default(),
                        });
                    }
                    if r.seg_idx + 1 < r.segments.len() {
                        r.seg_idx += 1;
                    }
                }
            }
            if is_last {
                let r = self.decodes.swap_remove(b);
                let len = (r.base_len + r.produced).min(self.max_seq);
                self.store.lock().unwrap().insert(r.seq, SeqState { kv: Vec::new(), len });
                emit(Completion {
                    query: r.ctx.query,
                    node: r.ctx.node,
                    output: JobOutput::TokenBatch(r.all_segments),
                    timing: ExecTiming::default(),
                });
                if residency {
                    // The grown KV stays resident for the query's next
                    // hop; only FreeQuery or eviction returns it.
                    self.kv.commit_resident_as(r.seq, r.kv_res, r.ctx.wcp_us, r.ctx.tenant);
                    out.resident_added += r.kv_res;
                } else {
                    self.kv.release(r.kv_res);
                }
                out.retired_rows += 1;
                out.retired.push((r.ctx.query, r.ctx.node));
                // swap_remove moved a later row into slot b: revisit it.
            } else {
                b += 1;
            }
        }
    }
}

impl StepExecutor for SimLlmExecutor {
    fn admit(&mut self, jobs: Vec<(RequestCtx, EngineJob)>) -> Vec<(RequestCtx, EngineJob)> {
        // Apply any mid-run `prefix_slots` retune before consulting
        // residency, so a shrink evicts immediately instead of at the
        // next insert.
        self.prefixes.resync();
        self.kv.set_capacity(self.kv_capacity.load(Ordering::Relaxed));
        let mut bounced = Vec::new();
        for (ctx, job) in jobs {
            match job {
                EngineJob::Prefill { seq, mut tokens, mut offset, prefix } => {
                    // Resident-prefix hit: the shared instruction KV is
                    // already on this instance — seed the sequence at the
                    // prefix boundary and prefill only the suffix, so the
                    // device charge (and the KV reservation) covers the
                    // un-cached tokens alone.  Output arithmetic is
                    // untouched (the final KV length is offset + tokens
                    // regardless), keeping sim runs deterministic with
                    // routing on or off.  Residency is probed without
                    // touching LRU order first, so a bounced job mutates
                    // nothing.
                    let hit = prefix.map_or(false, |fp| {
                        offset == 0 && tokens.len() > fp.len && self.prefixes.contains(fp)
                    });
                    let kv_res = if hit {
                        kv_budget::suffix_charge(tokens.len(), prefix.unwrap().len)
                    } else {
                        tokens.len().max(1)
                    };
                    if !self.kv.admits(kv_res) {
                        bounced.push((ctx, EngineJob::Prefill { seq, tokens, offset, prefix }));
                        continue;
                    }
                    if hit {
                        let fp = prefix.unwrap();
                        self.prefixes.hit(fp); // refresh LRU recency
                        self.store
                            .lock()
                            .unwrap()
                            .insert(seq, SeqState { kv: Vec::new(), len: fp.len });
                        tokens.drain(..fp.len);
                        offset = fp.len;
                    }
                    self.kv.reserve(kv_res);
                    self.prefills
                        .push_back(SimPrefillRow { ctx, seq, tokens, offset, prefix, kv_res });
                }
                EngineJob::Decode { seq, segments, first_token } => {
                    let planned: usize = segments.iter().map(|s| s.len).sum();
                    let base_len = self
                        .store
                        .lock()
                        .unwrap()
                        .get(&seq)
                        .map(|s| s.len)
                        .unwrap_or(0);
                    let resident_hit = self.residency_on() && self.kv.is_resident(seq);
                    let kv_res = if self.residency_on() {
                        // Per-iteration growth: reserve the first token
                        // only, plus a swap-in charge when the sequence's
                        // KV is not in the resident ledger (cold after an
                        // eviction, or produced before residency mode
                        // switched on).
                        let swap_in = if resident_hit { 0 } else { base_len };
                        swap_in.saturating_add(1)
                    } else {
                        planned.max(1)
                    };
                    if !self.kv.admits(kv_res) {
                        bounced.push((ctx, EngineJob::Decode { seq, segments, first_token }));
                        continue;
                    }
                    if resident_hit {
                        // Refresh the sequence's last-use tick only after
                        // admission is certain — a bounced job must leave
                        // eviction order untouched.
                        self.kv.touch_resident(seq);
                    }
                    self.kv.reserve(kv_res);
                    self.decodes.push(SimDecodeRow {
                        ctx,
                        seq,
                        segments,
                        base_len,
                        planned,
                        produced: 0,
                        seg_idx: 0,
                        seg_tokens: Vec::new(),
                        all_segments: Vec::new(),
                        kv_res,
                    });
                }
                other @ (EngineJob::ClonePrefix { .. }
                | EngineJob::FreeQuery { .. }
                | EngineJob::CancelSeq { .. }) => {
                    // Host-side bookkeeping: no KV growth, always admitted.
                    self.instant.push((ctx, other));
                }
                other => {
                    let t = std::thread::current();
                    eprintln!(
                        "[{}] sim LLM engine dropping non-LLM job {other:?}",
                        t.name().unwrap_or("instance")
                    );
                    self.rejected.push((ctx, other.slot_rows()));
                }
            }
        }
        bounced
    }

    fn step(&mut self, emit: &mut dyn FnMut(Completion)) -> Result<StepOutcome> {
        let mut out = StepOutcome::default();
        self.kv.set_capacity(self.kv_capacity.load(Ordering::Relaxed));
        // One eviction-clock tick per executor step: resident sequences
        // touched this step all share the tick, so recency (not WCP
        // priority) is the primary eviction key across steps.
        self.kv.advance_clock();
        for (ctx, rows) in self.rejected.drain(..) {
            out.retired_rows += rows;
            out.retired.push((ctx.query, ctx.node));
        }
        SIM_PEAK_RESIDENT_ROWS
            .fetch_max(self.prefills.len() + self.decodes.len(), Ordering::Relaxed);
        self.run_instant(emit, &mut out);
        // Watermark preemption before compute: crossing the high
        // watermark evicts idle residency so this step's admissions and
        // per-iteration decode growth have headroom.
        self.preempt_to_watermark(&mut out);
        // One chunked-prefill call *or* one decode iteration per step;
        // prefill first so newly admitted sequences join the decode set
        // quickly (vLLM-style prefill priority).
        if !self.prefills.is_empty() {
            self.step_prefill(emit, &mut out);
        } else if !self.decodes.is_empty() {
            self.step_decode(emit, &mut out);
        }
        // Harvest accounting drift (reserve/release mispairings) into the
        // process-wide counter.  The executor's own ledger must always
        // pair exactly — every release is the echo of a reservation this
        // executor made — so any drift here is a bug, asserted loudly in
        // debug builds and surfaced via `residency_stats` in release.
        let drift = self.kv.take_drift();
        if drift > 0 {
            SIM_ACCOUNTING_DRIFT.fetch_add(drift, Ordering::Relaxed);
            debug_assert_eq!(drift, 0, "KV reserve/release mispairing: {drift} tokens over-released");
        }
        out.resident = self.resident();
        Ok(out)
    }

    fn abort(&mut self) -> StepOutcome {
        let mut out = StepOutcome::default();
        for (ctx, rows) in self.rejected.drain(..) {
            out.retired_rows += rows;
            out.retired.push((ctx.query, ctx.node));
        }
        for (ctx, _) in self.instant.drain(..) {
            out.retired_rows += 1;
            out.retired.push((ctx.query, ctx.node));
        }
        for r in self.prefills.drain(..) {
            out.retired_rows += 1;
            out.retired.push((r.ctx.query, r.ctx.node));
        }
        for r in self.decodes.drain(..) {
            out.retired_rows += 1;
            out.retired.push((r.ctx.query, r.ctx.node));
        }
        // The reset wipes residency with the reservations: report it so
        // the scheduler's residency mirror drains too (the instance stays
        // alive after an abort, so no dead-instance reset covers this).
        out.resident_freed += self.kv.resident_total();
        self.kv.reset();
        out
    }

    fn resident(&self) -> usize {
        self.rejected.len() + self.instant.len() + self.prefills.len() + self.decodes.len()
    }
}

/// Simulated embedding executor: deterministic unit-norm vectors, device
/// time charged per bucket-sized call like the XLA path.
pub struct SimEmbedExecutor {
    device: DeviceModel,
    d_model: usize,
    max_batch: usize,
}

impl SimEmbedExecutor {
    /// Build a sim embedder with the given output dimensionality.
    pub fn new(model: &str, d_model: usize, max_batch: usize) -> SimEmbedExecutor {
        SimEmbedExecutor {
            device: DeviceModel::for_engine(model),
            d_model: d_model.max(8),
            max_batch: max_batch.max(1),
        }
    }
}

impl BatchExecutor for SimEmbedExecutor {
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()> {
        let mut rows: Vec<Vec<i32>> = Vec::new();
        let mut extents = Vec::new();
        for (ctx, job) in &batch.jobs {
            match job {
                EngineJob::Embed { chunks } => {
                    extents.push((ctx.clone(), rows.len(), chunks.len()));
                    rows.extend(chunks.iter().cloned());
                }
                other => {
                    return Err(TeolaError::Engine(format!(
                        "sim embedding engine got {other:?}"
                    )))
                }
            }
        }
        let mut embs = Vec::with_capacity(rows.len());
        for_chunks(rows.len(), self.max_batch, |start, take| {
            let started = Instant::now();
            for row in &rows[start..start + take] {
                embs.push(synth_embedding(row, self.d_model));
            }
            charge_device(started, self.device.encoder_us(take));
            Ok(())
        })?;
        for (ctx, start, count) in extents {
            emit(Completion {
                query: ctx.query,
                node: ctx.node,
                output: JobOutput::Embeddings(embs[start..start + count].to_vec()),
                timing: ExecTiming::default(),
            });
        }
        Ok(())
    }
}

/// Simulated reranker executor: deterministic scores per packed pair.
pub struct SimRerankExecutor {
    device: DeviceModel,
    max_batch: usize,
}

impl SimRerankExecutor {
    /// Build a sim reranker.
    pub fn new(model: &str, max_batch: usize) -> SimRerankExecutor {
        SimRerankExecutor { device: DeviceModel::for_engine(model), max_batch: max_batch.max(1) }
    }
}

impl BatchExecutor for SimRerankExecutor {
    fn execute(&mut self, batch: Batch, emit: &mut dyn FnMut(Completion)) -> Result<()> {
        let mut rows: Vec<Vec<i32>> = Vec::new();
        let mut extents = Vec::new();
        for (ctx, job) in &batch.jobs {
            match job {
                EngineJob::Rerank { pairs } => {
                    extents.push((ctx.clone(), rows.len(), pairs.len()));
                    rows.extend(pairs.iter().cloned());
                }
                other => {
                    return Err(TeolaError::Engine(format!(
                        "sim reranker engine got {other:?}"
                    )))
                }
            }
        }
        let mut scores = Vec::with_capacity(rows.len());
        for_chunks(rows.len(), self.max_batch, |start, take| {
            let started = Instant::now();
            for row in &rows[start..start + take] {
                scores.push(synth_score(row));
            }
            charge_device(started, self.device.encoder_us(take));
            Ok(())
        })?;
        for (ctx, start, count) in extents {
            emit(Completion {
                query: ctx.query,
                node: ctx.node,
                output: JobOutput::Scores(scores[start..start + count].to_vec()),
                timing: ExecTiming::default(),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;
    use std::sync::mpsc::channel;
    use std::sync::{Arc, Mutex};

    fn ctx(query: u64, node: usize, reply: std::sync::mpsc::Sender<Completion>) -> RequestCtx {
        RequestCtx {
            query,
            node,
            depth: 0,
            arrival: Instant::now(),
            wcp_us: 0,
            kv_tokens: 0,
            wcp_discounted: false,
            tenant: crate::engines::UNTENANTED,
            reply,
            successors: Vec::new(),
        }
    }

    fn no_prefix_slots() -> Arc<AtomicUsize> {
        Arc::new(AtomicUsize::new(0))
    }

    /// Drive a stepped executor until it drains, collecting completions.
    fn run_to_idle(exec: &mut SimLlmExecutor, out: &mut Vec<Completion>) {
        while exec.resident() > 0 {
            exec.step(&mut |c| out.push(c)).unwrap();
        }
    }

    #[test]
    fn synth_token_is_deterministic_and_non_special() {
        let stream = |seq: SeqId| -> Vec<i32> {
            (0..200).map(|pos| synth_token(seq, pos)).collect()
        };
        assert_eq!(stream((7, 1)), stream((7, 1)));
        assert!(stream((7, 1)).iter().all(|&t| t >= 4));
        // Different sequences yield different streams (single-position
        // collisions are possible; whole-stream collisions are not).
        assert_ne!(stream((7, 1)), stream((8, 1)));
    }

    #[test]
    fn synth_embedding_unit_norm_and_content_addressed() {
        let a = synth_embedding(&[5, 6, 7], 32);
        let b = synth_embedding(&[5, 6, 7], 32);
        let c = synth_embedding(&[5, 6, 8], 32);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4, "norm {norm}");
    }

    #[test]
    fn sim_llm_prefill_then_decode_streams_segments() {
        let store: SeqStore = Arc::new(Mutex::new(HashMap::new()));
        let mut exec =
            SimLlmExecutor::new("llm-lite", store.clone(), 3, 2, 256, no_prefix_slots());
        let (tx, rx) = channel();

        // Prefill 10 tokens into seq (1, 0).
        exec.admit(vec![(
            ctx(1, 0, tx.clone()),
            EngineJob::Prefill { seq: (1, 0), tokens: vec![10; 10], offset: 0, prefix: None },
        )]);
        let mut out = Vec::new();
        run_to_idle(&mut exec, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(store.lock().unwrap().get(&(1, 0)).unwrap().len, 10);

        // Decode 6 tokens in 2 segments streamed to marker nodes 8 and 9.
        exec.admit(vec![(
            ctx(1, 5, tx),
            EngineJob::Decode {
                seq: (1, 0),
                first_token: 42,
                segments: vec![
                    SegmentSpec { node: 8, len: 3 },
                    SegmentSpec { node: 9, len: 3 },
                ],
            },
        )]);
        let mut out = Vec::new();
        run_to_idle(&mut exec, &mut out);
        drop(rx);
        // Two streamed segments + the final decode completion.
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].node, 8);
        assert_eq!(out[1].node, 9);
        assert_eq!(out[2].node, 5);
        match &out[2].output {
            JobOutput::TokenBatch(segs) => {
                assert_eq!(segs.len(), 2);
                assert_eq!(segs[0].len(), 3);
                // non-final segment ends with SEP, final with EOS
                assert_eq!(*segs[0].last().unwrap(), 3);
                assert_eq!(*segs[1].last().unwrap(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(store.lock().unwrap().get(&(1, 0)).unwrap().len, 16);
    }

    #[test]
    fn sim_llm_step_outcome_reports_retirement() {
        let store: SeqStore = Arc::new(Mutex::new(HashMap::new()));
        let mut exec = SimLlmExecutor::new("llm-lite", store, 3, 2, 256, no_prefix_slots());
        let (tx, _rx) = channel();
        exec.admit(vec![(
            ctx(9, 1, tx.clone()),
            EngineJob::Prefill { seq: (9, 0), tokens: vec![5; 4], offset: 0, prefix: None },
        )]);
        assert_eq!(exec.resident(), 1);
        let o = exec.step(&mut |_| {}).unwrap();
        assert_eq!(o.retired_rows, 1);
        assert_eq!(o.retired, vec![(9, 1)]);
        assert_eq!(o.resident, 0);

        exec.admit(vec![(
            ctx(9, 2, tx),
            EngineJob::Decode {
                seq: (9, 0),
                first_token: 7,
                segments: vec![SegmentSpec { node: 2, len: 3 }],
            },
        )]);
        // 3 planned tokens: two mid-steps, then retirement on the third.
        let o = exec.step(&mut |_| {}).unwrap();
        assert_eq!(o.retired_rows, 0);
        assert_eq!(o.resident, 1);
        let _ = exec.step(&mut |_| {}).unwrap();
        let o = exec.step(&mut |_| {}).unwrap();
        assert_eq!(o.retired_rows, 1);
        assert_eq!(o.resident, 0);
    }

    #[test]
    fn sim_embed_and_rerank_preserve_extents() {
        let (tx, rx) = channel();
        let mut emb = SimEmbedExecutor::new("embedder", 16, 4);
        let batch = Batch {
            jobs: vec![
                (ctx(1, 0, tx.clone()), EngineJob::Embed { chunks: vec![vec![1], vec![2]] }),
                (ctx(2, 0, tx.clone()), EngineJob::Embed { chunks: vec![vec![3]] }),
            ],
        };
        let mut out = Vec::new();
        emb.execute(batch, &mut |c| out.push(c)).unwrap();
        assert_eq!(out.len(), 2);
        match (&out[0].output, &out[1].output) {
            (JobOutput::Embeddings(a), JobOutput::Embeddings(b)) => {
                assert_eq!(a.len(), 2);
                assert_eq!(b.len(), 1);
            }
            other => panic!("unexpected {other:?}"),
        }

        let mut rr = SimRerankExecutor::new("reranker", 4);
        let batch = Batch {
            jobs: vec![(
                ctx(3, 0, tx),
                EngineJob::Rerank { pairs: vec![vec![1, 3, 9], vec![1, 3, 10]] },
            )],
        };
        let mut out = Vec::new();
        rr.execute(batch, &mut |c| out.push(c)).unwrap();
        drop(rx);
        match &out[0].output {
            JobOutput::Scores(s) => {
                assert_eq!(s.len(), 2);
                assert!(s.iter().all(|x| (0.0..1.0).contains(x)));
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
